"""Paper fig. 10/11 analogue: PW advection and NEMO tracer advection via
the PSyclone-like loop frontend.

Reproduces the paper's structural result: PW advection's three stencil
computations fuse into ONE region; tracer advection's dependent chain
leaves multiple regions (the paper: 24 computations → 18 regions).
Throughput is XLA-CPU; the region counts are the shared-stack signal.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import gpts, save_record, table, target_record, time_step
from repro.api import Program, Target, compile as api_compile
from repro.core.dialects import stencil
from repro.core.passes import cse_apply_bodies, dce, fuse_applies
from repro.frontends.psyclone_like import build_stencil_func


# -- PW advection: 3 independent stencils over 3 fields (su, sv, sw) -------


def pw_advection(u, v, w, su, sv, sw):
    su[i, j, k] = 0.5 * (
        u[i, j, k] * (v[i, j, k] + v[i + 1, j, k])
        - u[i - 1, j, k] * (v[i - 1, j, k] + v[i, j, k])
    )
    sv[i, j, k] = 0.5 * (
        v[i, j, k] * (w[i, j, k] + w[i, j + 1, k])
        - v[i, j - 1, k] * (w[i, j - 1, k] + w[i, j, k])
    )
    sw[i, j, k] = 0.5 * (
        w[i, j, k] * (u[i, j, k] + u[i, j, k + 1])
        - w[i, j, k - 1] * (u[i, j, k - 1] + u[i, j, k])
    )


# -- tracer advection: dependent flux/update chain over tracer fields ------


def tracer_advection(t, u, v, zwx, zwy, out):
    zwx[i, j, k] = u[i, j, k] * (t[i + 1, j, k] - t[i, j, k])
    zwy[i, j, k] = v[i, j, k] * (t[i, j + 1, k] - t[i, j, k])
    out[i, j, k] = t[i, j, k] - 0.1 * (
        zwx[i, j, k] - zwx[i - 1, j, k] + zwy[i, j, k] - zwy[i, j - 1, k]
    )


def _count_applies(func) -> int:
    return sum(1 for op in func.body.ops if isinstance(op, stencil.ApplyOp))


def run(fast: bool = False, tune: bool = False) -> dict:
    shape = (64, 64, 32) if fast else (128, 128, 64)
    rng = np.random.default_rng(0)
    record, rows = {}, []

    for name, kern, nfields in (
        ("pw", pw_advection, 6),
        ("traadv", tracer_advection, 6),
    ):
        func = build_stencil_func(kern, shape)
        n_raw = _count_applies(func)
        fuse_applies(func)
        cse_apply_bodies(func)
        dce(func)
        n_fused = _count_applies(func)

        prog = Program(func, boundary="periodic")
        if tune:
            # cost-model-only search (cheap; cached on disk) — the timed
            # call below measures the tuned choice; ranks=1 keeps tuned
            # rows comparable with the manual single-device rows
            target = Target.tuned(prog, ranks=1, measure=False)
        else:
            target = Target()
        step = api_compile(prog, target)
        args = [
            jnp.asarray(rng.standard_normal(shape), jnp.float32)
            for _ in range(len(prog.field_args))
        ]
        sec = time_step(lambda *a: step(*a), args, iters=3, warmup=1)
        # one call of a depth-k tuned artifact advances k time steps
        tp = gpts(shape, sec, target.exchange_every)
        record[name] = {
            "shape": shape,
            "regions_raw": n_raw,
            "regions_fused": n_fused,
            "sec": sec,
            "gpts": tp,
            "target": target_record(target, "tuned" if tune else "manual"),
        }
        rows.append((name, "x".join(map(str, shape)), n_raw, n_fused, f"{tp:.3f}"))

    print(table(
        "fig10: advection benchmarks (PSyclone-like frontend)",
        rows,
        ["bench", "grid", "regions", "fused", "GPts/s"],
    ))
    # the paper's structural claim: PW fuses to 1; tracer keeps >1 due to
    # cross-field dependencies... unless vertical fusion absorbs them —
    # record both rather than asserting the tracer count.
    assert record["pw"]["regions_fused"] == 1, record["pw"]
    save_record("fig10_advection", record)
    return record


if __name__ == "__main__":
    run()
