"""Benchmark driver: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

fig7a/b  heat / acoustic-wave throughput sweeps (Devito-like frontend)
fig8     strong-scaling model (halo bytes + roofline terms vs ranks)
fig10    PW + tracer advection (PSyclone-like frontend, fusion counts)
table1   backend comparison (jnp vs pallas; raw vs optimized pipeline)
serve    mixed-traffic serving load test (repro.serve.stencil engine)
serve_load_bursty  bursty autoscaled bucket (PoolSizer grow/shrink)
soak     fault-injected resilience soak (checkpoint overhead, recovery)
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced sizes (CI)")
    ap.add_argument("--only", default=None, help="comma-list of benches")
    ap.add_argument("--tune", action="store_true",
                    help="autotune Targets (repro.tune) in benches that "
                         "support it; records carry tuned-vs-manual "
                         "provenance")
    ap.add_argument("--fused-epoch", action="store_true",
                    help="add/time the pallas epoch-megakernel variants "
                         "in benches that support them")
    args = ap.parse_args()

    from benchmarks import (
        backend_compare,
        fig7_heat,
        fig7_wave,
        fig8_scaling,
        fig10_advection,
        resilience_soak,
        serve_load,
    )

    benches = {
        "fig7_heat": fig7_heat.run,
        "fig7_wave": fig7_wave.run,
        "fig8_scaling": fig8_scaling.run,
        "fig10_advection": fig10_advection.run,
        "backend_compare": backend_compare.run,
        "serve_load": serve_load.run,
        "serve_load_bursty": serve_load.run_bursty,
        "resilience_soak": resilience_soak.run,
    }
    wanted = args.only.split(",") if args.only else list(benches)
    failures = 0
    for name in wanted:
        print(f"\n=== {name} ===")
        t0 = time.time()
        kwargs = {"fast": args.fast}
        params = inspect.signature(benches[name]).parameters
        if args.tune and "tune" in params:
            kwargs["tune"] = True
        if args.fused_epoch and "fused_epoch" in params:
            kwargs["fused_epoch"] = True
        try:
            benches[name](**kwargs)
            print(f"[{name} done in {time.time()-t0:.1f}s]")
        except Exception as e:  # pragma: no cover
            failures += 1
            import traceback

            traceback.print_exc()
            print(f"[{name} FAILED: {e}]")
    return failures


if __name__ == "__main__":
    sys.exit(main())
