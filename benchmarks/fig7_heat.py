"""Paper fig. 7a analogue: heat-diffusion (Jacobi-like) stencil
throughput, 2D and 3D, space orders 2/4/8.

Devito DSL input → shared stencil stack → XLA-CPU executable; the paper's
ARCHER2 run uses 16384²/1024³ grids — the CPU container scales those down
but keeps the sweep structure (dims × SDO) and reports GPts/s.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    gpts, measure_drift, save_record, table, target_record, time_step,
)
from repro.api import Target, time_loop
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

CASES = [
    # (ndim, shape, timesteps)
    (2, (2048, 2048), 16),
    (3, (192, 192, 192), 8),
]
ORDERS = (2, 4, 8)


def run(fast: bool = False, tune: bool = False,
        fused_epoch: bool = False, drift: bool = False) -> dict:
    """``fused_epoch=True`` times the pallas epoch-megakernel target
    (k=4, one kernel dispatch per epoch) instead of the default jnp
    path; the recorded ``target`` dict carries the axes either way.
    ``drift=True`` additionally runs each case under span tracing and
    records the roofline model-vs-measured error (``repro.obs.drift``)."""
    cases = CASES if not fast else [(2, (256, 256), 4)]
    rows, record = [], {}
    for ndim, shape, steps in cases:
        for so in ORDERS if not fast else (2,):
            g = Grid(shape=shape, extent=tuple(1.0 for _ in shape))
            u = TimeFunction(name="u", grid=g, space_order=so)
            op = Operator(Eq(u.dt, 0.5 * u.laplace), dt=1e-7, boundary="zero")
            if tune:
                # cost-model-only search (cheap; cached on disk) — the
                # timed loop below then measures the tuned choice.
                # ranks=1 keeps tuned rows comparable with the manual
                # single-device rows on multi-device hosts
                target = Target.tuned(op.program, ranks=1, measure=False)
            elif fused_epoch:
                target = Target(
                    backend="pallas", exchange_every=4, fused_epoch=True
                )
            else:
                target = Target()
            step = op.compile_step(target=target)
            u0 = jnp.asarray(
                np.random.default_rng(0).standard_normal(shape), jnp.float32
            )

            import jax

            many = jax.jit(
                lambda u0, step=step, steps=steps: time_loop(step, (u0,), steps)
            )
            sec = time_step(many, (u0,), iters=3, warmup=1)
            # one call of a depth-k tuned artifact advances k time steps
            tp = gpts(shape, sec, steps * target.exchange_every)
            key = f"heat{ndim}d_so{so}"
            record[key] = {
                "shape": shape, "steps": steps, "sec": sec, "gpts": tp,
                "target": target_record(target, "tuned" if tune else "manual"),
            }
            if drift:
                from repro.api import compile as api_compile

                record[key]["drift"] = measure_drift(
                    api_compile(op.program, target),
                    (u0,), 2 * target.exchange_every,
                )
            rows.append((f"{ndim}D", f"so{so}", "x".join(map(str, shape)), f"{tp:.3f}"))
    print(table("fig7a: heat diffusion throughput (GPts/s, XLA-CPU)", rows,
                ["dims", "SDO", "grid", "GPts/s"]))
    save_record("fig7_heat", record)
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--tune", action="store_true")
    ap.add_argument("--fused-epoch", action="store_true",
                    help="time the pallas epoch-megakernel target "
                         "(k=4, one kernel dispatch per epoch)")
    ap.add_argument("--drift", action="store_true",
                    help="record roofline model-vs-measured drift per case")
    a = ap.parse_args()
    run(fast=a.fast, tune=a.tune, fused_epoch=a.fused_epoch, drift=a.drift)
