"""Paper fig. 7b analogue: isotropic acoustic wave equation (2nd-order in
time, u.dt2) throughput, 2D and 3D, space orders 2/4/8.

Higher arithmetic intensity than heat (three time buffers, wider star) —
the paper's case where flop-reduction optimizations matter; here CSE hits
the duplicate Laplacian taps.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import gpts, save_record, table, time_step
from repro.api import Target, time_loop
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

CASES = [
    (2, (2048, 2048), 16),
    (3, (192, 192, 192), 8),
]
ORDERS = (2, 4, 8)


def run(fast: bool = False) -> dict:
    cases = CASES if not fast else [(2, (256, 256), 4)]
    rows, record = [], {}
    for ndim, shape, steps in cases:
        for so in ORDERS if not fast else (2,):
            g = Grid(shape=shape, extent=tuple(1.0 for _ in shape))
            u = TimeFunction(name="u", grid=g, space_order=so, time_order=2)
            op = Operator(Eq(u.dt2, 1.0 * u.laplace), dt=1e-7, boundary="zero")
            step = op.compile_step(target=Target())
            rng = np.random.default_rng(0)
            um1 = jnp.asarray(rng.standard_normal(shape), jnp.float32)
            u0 = jnp.asarray(rng.standard_normal(shape), jnp.float32)

            import jax

            many = jax.jit(
                lambda a, b, step=step, steps=steps: time_loop(step, (a, b), steps)
            )
            sec = time_step(many, (um1, u0), iters=3, warmup=1)
            tp = gpts(shape, sec, steps)
            key = f"wave{ndim}d_so{so}"
            record[key] = {"shape": shape, "steps": steps, "sec": sec, "gpts": tp}
            rows.append((f"{ndim}D", f"so{so}", "x".join(map(str, shape)), f"{tp:.3f}"))
    print(table("fig7b: acoustic wave throughput (GPts/s, XLA-CPU)", rows,
                ["dims", "SDO", "grid", "GPts/s"]))
    save_record("fig7_wave", record)
    return record


if __name__ == "__main__":
    run()
