"""Shared benchmark utilities: timing, throughput, result records.

Throughput unit is GPts/s (grid points updated per second) — the paper's
fig. 7/8/10 metric.  The CPU container measures XLA-CPU absolute numbers;
the *relative* effects (fusion, CSE, decomposition overhead, backend
choice) are the reproducible signal, and the TPU roofline model
(launch/roofline.py) provides the target-hardware projection.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Callable, Optional

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def _git_rev() -> Optional[str]:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(__file__),
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or None
    except Exception:
        return None


def provenance_block() -> dict:
    """Where this record came from: the hardware signature the autotuner
    keys its cache on, the git revision, and the wall-clock moment — so
    two ``results/bench`` JSONs are comparable (or visibly not)."""
    from repro.tune.cache import hardware_signature

    return {
        "hardware": hardware_signature(),
        "git_rev": _git_rev(),
        "created": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "traced": _obs_enabled(),
    }


def _obs_enabled() -> bool:
    from repro import obs

    return obs.enabled()


def time_step(fn: Callable, args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-clock seconds per call (blocked until ready)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def gpts(shape: tuple, seconds: float, timesteps: int = 1) -> float:
    pts = float(np.prod(shape)) * timesteps
    return pts / seconds / 1e9


def save_record(name: str, record: dict) -> None:
    """Write ``results/bench/<name>.json``, stamped with a provenance
    block.  When span tracing is live (``repro.obs``), the collected
    trace is exported next to the record as ``<name>.trace.json``
    (Chrome/Perfetto format) and the record's provenance carries its
    path — a benchmark number always links back to the spans behind it."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    record = dict(record)
    prov = provenance_block()
    if _obs_enabled():
        from repro import obs

        if obs.spans():
            trace_path = os.path.join(RESULTS_DIR, f"{name}.trace.json")
            obs.write_chrome(trace_path)
            prov["trace"] = os.path.relpath(trace_path, RESULTS_DIR)
    record["provenance"] = prov
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(record, f, indent=1)


def measure_drift(compiled, state, n_steps: int, **kwargs) -> dict:
    """Run ``n_steps`` of ``compiled`` under span tracing and compare the
    measured per-step epoch time against the roofline model
    (``compiled.cost().step_time(k)``) — the model-vs-measured error and
    achieved comm/compute overlap every results record should carry.

    Restores the tracer's prior enabled/collected state, so calling this
    inside an otherwise-untraced benchmark leaves timing unperturbed.
    """
    from repro import obs

    was_enabled = obs.enabled()
    prior = list(obs.spans())
    obs.enable()
    obs.clear()
    try:
        compiled.time_loop(tuple(state), n_steps, **kwargs)
        rep = obs.drift_report(
            terms=compiled.cost(),
            exchange_every=compiled.target.exchange_every,
        )
    finally:
        obs.clear()
        if not was_enabled:
            obs.disable()
        for s in prior:
            obs.tracer()._commit(s)
    return rep.as_dict()


def target_record(target, provenance: str = "manual") -> dict:
    """The full ``Target`` as a JSON-able dict for results records —
    every knob plus where the config came from (``"manual"`` for a
    hand-picked target, ``"tuned"`` for an autotuner winner), so a
    benchmark number can always be traced back to the exact
    configuration that produced it."""
    from repro.tune.cache import target_to_dict

    record = target_to_dict(target)
    record["provenance"] = provenance
    return record


def table(title: str, rows: list, headers: list) -> str:
    widths = [
        max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
        for i, h in enumerate(headers)
    ]
    out = [title, "-" * len(title)]
    out.append("  ".join(str(h).ljust(w) for h, w in zip(headers, widths)))
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)
