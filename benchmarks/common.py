"""Shared benchmark utilities: timing, throughput, result records.

Throughput unit is GPts/s (grid points updated per second) — the paper's
fig. 7/8/10 metric.  The CPU container measures XLA-CPU absolute numbers;
the *relative* effects (fusion, CSE, decomposition overhead, backend
choice) are the reproducible signal, and the TPU roofline model
(launch/roofline.py) provides the target-hardware projection.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def time_step(fn: Callable, args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-clock seconds per call (blocked until ready)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def gpts(shape: tuple, seconds: float, timesteps: int = 1) -> float:
    pts = float(np.prod(shape)) * timesteps
    return pts / seconds / 1e9


def save_record(name: str, record: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(record, f, indent=1)


def target_record(target, provenance: str = "manual") -> dict:
    """The full ``Target`` as a JSON-able dict for results records —
    every knob plus where the config came from (``"manual"`` for a
    hand-picked target, ``"tuned"`` for an autotuner winner), so a
    benchmark number can always be traced back to the exact
    configuration that produced it."""
    from repro.tune.cache import target_to_dict

    record = target_to_dict(target)
    record["provenance"] = provenance
    return record


def table(title: str, rows: list, headers: list) -> str:
    widths = [
        max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
        for i, h in enumerate(headers)
    ]
    out = [title, "-" * len(title)]
    out.append("  ".join(str(h).ljust(w) for h, w in zip(headers, widths)))
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)
