"""Serving load test: mixed stencil traffic through one StencilEngine.

The tentpole measurement for ``repro.serve.stencil``: many tenants'
heat / wave / advection jobs — varied shapes, epoch depths and step
counts, Poisson arrivals — stream through ONE engine, and we report what
a serving operator cares about: aggregate sustained GPts/s across all
tenants, request latency percentiles (p50/p99 wall-clock), batched-vs-
solo dispatch mix, slot-pool utilization and compile-cache reuse.

Acceptance (asserted here, not just reported): at least one engine step
batches >= 2 same-fingerprint requests into one vmapped dispatch, and a
spot-check request per traffic profile is bitwise-equal to a solo
``compile(...).time_loop(...)`` run.

A second, *bursty* phase (ISSUE 9) slams one fingerprint with a
same-instant burst against a small autoscaled pool and then drains to a
long tail: the run must record >= 1 PoolSizer grow and >= 1 shrink with
queue-depth/utilization provenance (saved verbatim under ``burst`` in
``serve_load.json``), every post-resize result must stay bitwise-equal,
and the drained bucket must retire.  ``run_bursty`` runs that phase
standalone (``benchmarks.run --only serve_load_bursty``).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_record, table
from repro import api
from repro.api import Target
from repro.frontends.oec_like import ProgramBuilder
from repro.serve.stencil import (
    PoolSizerConfig,
    StencilEngine,
    StencilEngineConfig,
)


def _heat(shape):
    p = ProgramBuilder(f"heat{len(shape)}d", shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: u.at(0, 0)
        + 0.1
        * (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1) - 4.0 * u.at(0, 0)),
    )
    p.store(r, out)
    return p.finish(boundary="periodic")


def _wave(shape):
    # p=2 inputs / q=1 output: exercises carried-state rotation under
    # exchange_every=2 inside the batched slot pool
    p = ProgramBuilder(f"wave{len(shape)}d", shape)
    um = p.input("u_prev")
    u0 = p.input("u_now")
    out = p.output("u_next")
    tm, t0 = p.load(um), p.load(u0)
    r = p.apply(
        [tm, t0],
        lambda b, um, u0: 2.0 * u0.at(0, 0)
        - um.at(0, 0)
        + 0.1
        * (
            u0.at(-1, 0)
            + u0.at(1, 0)
            + u0.at(0, -1)
            + u0.at(0, 1)
            - 4.0 * u0.at(0, 0)
        ),
    )
    p.store(r, out)
    return p.finish(boundary="zero")


def _advection(shape):
    # first-order upwind transport, c=(0.4, 0.3)
    p = ProgramBuilder(f"adv{len(shape)}d", shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: u.at(0, 0)
        - 0.4 * (u.at(0, 0) - u.at(-1, 0))
        - 0.3 * (u.at(0, 0) - u.at(0, -1)),
    )
    p.store(r, out)
    return p.finish(boundary="periodic")


def _profiles(fast: bool):
    """Mixed traffic: (name, program, target, n_inputs, steps choices).
    Shapes differ across profiles, so each is its own fingerprint bucket."""
    s, m = ((48, 48), (64, 64)) if fast else ((96, 96), (128, 128))
    return [
        ("heat_small", _heat(s), Target(), 1, (8, 12, 16)),
        ("heat_large", _heat(m), Target(), 1, (8, 12)),
        ("wave_k2", _wave(s), Target(exchange_every=2), 2, (8, 12, 16)),
        ("advection", _advection(s), Target(), 1, (8, 16)),
    ]


def _burst_phase(fast: bool, rng) -> dict:
    """Bursty arrivals against one autoscaled fingerprint bucket.

    A same-instant burst of short jobs lands on a 2-slot pool (queue depth
    forces >= 1 PoolSizer grow), then the burst drains and one long-tail
    job keeps the bucket alive at low utilization (forces >= 1 shrink).
    Asserts: grow and shrink both recorded with queue-depth / utilization
    provenance, every result bitwise-equal to a solo ``time_loop`` despite
    the drain→rebuild→readmit hops, and the drained bucket retires.
    """
    import time

    shape = (48, 48) if fast else (96, 96)
    prog = _heat(shape)  # one fingerprint: the whole burst shares a bucket
    n_burst = 8 if fast else 12
    steps = [8] * (n_burst - 1) + [48 if fast else 96]  # long-tail last job
    sizer = PoolSizerConfig(
        min_capacity=1,
        max_capacity=16,
        ewma_alpha=1.0,  # react to the instantaneous signal in a short run
        cooldown_steps=1,
    )
    eng = StencilEngine(
        StencilEngineConfig(
            slots_per_group=2, autoscale=sizer, bucket_idle_steps=4
        )
    )
    states = [
        rng.standard_normal(shape).astype(np.float32) for _ in range(n_burst)
    ]
    t0 = time.perf_counter()
    handles = [
        eng.submit(prog, (s,), n, tenant=f"burst{i}")
        for i, (s, n) in enumerate(zip(states, steps))
    ]
    eng.run()
    # keep stepping the empty engine so the drained bucket retires
    for _ in range(eng.config.bucket_idle_steps + 1):
        eng.step()
    wall_s = time.perf_counter() - t0

    snap = eng.metrics.snapshot()
    auto = snap["autoscale"]
    assert auto["grows"] >= 1, (
        "burst never grew the pool — queue-depth autoscaling is broken"
    )
    assert auto["shrinks"] >= 1, (
        "long tail never shrank the pool — utilization autoscaling is broken"
    )
    for event in auto["events"]:
        for field in ("action", "from_capacity", "to_capacity",
                      "queue_depth", "queue_ewma", "utilization_ewma"):
            assert field in event, f"autoscale event missing {field!r}"
    assert snap["buckets_retired"] >= 1, "drained bucket never retired"
    # bitwise across every resize hop (drain → rebuild → readmit)
    solo = api.compile(prog, Target())
    for h, state, n_steps in zip(handles, states, steps):
        want = solo.time_loop((state,), n_steps)
        for w, o in zip(want, h.result()):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(o))
    return {
        "n_requests": n_burst,
        "steps": steps,
        "wall_s": wall_s,
        "grows": auto["grows"],
        "shrinks": auto["shrinks"],
        "events": auto["events"],
        "buckets_retired": snap["buckets_retired"],
        "requests_evacuated": snap["requests_evacuated"],
        "requests_resumed": snap["requests_resumed"],
    }


def run_bursty(fast: bool = False) -> dict:
    """Standalone bursty mode (``--only serve_load_bursty``)."""
    record = _burst_phase(fast, np.random.default_rng(7))
    rows = [
        ("requests", record["n_requests"]),
        ("pool grows", record["grows"]),
        ("pool shrinks", record["shrinks"]),
        ("buckets retired", record["buckets_retired"]),
        ("resize evac/readmit", f"{record['requests_evacuated']}"
                                f"/{record['requests_resumed']}"),
        ("wall (s)", f"{record['wall_s']:.2f}"),
    ]
    print(table("serve_load: bursty autoscaled bucket", rows,
                ["metric", "value"]))
    save_record("serve_load_bursty", record)
    return record


def run(fast: bool = False) -> dict:
    rng = np.random.default_rng(42)
    profiles = _profiles(fast)
    n_requests = 12 if fast else 48
    arrival_rate = 2.0  # mean arrivals per engine step (Poisson process)

    # Poisson arrivals: exponential inter-arrival gaps in engine-step
    # units, cumulated to an arrival schedule
    gaps = rng.exponential(1.0 / arrival_rate, size=n_requests)
    arrive_at = np.cumsum(gaps)

    plan = []
    for i in range(n_requests):
        name, prog, target, n_in, steps_menu = profiles[
            rng.integers(len(profiles))
        ]
        shape = prog.field_args[0].type.bounds.shape
        state = tuple(
            rng.standard_normal(shape).astype(np.float32) for _ in range(n_in)
        )
        plan.append(
            (arrive_at[i], name, prog, target, state, int(rng.choice(steps_menu)))
        )

    eng = StencilEngine(StencilEngineConfig(slots_per_group=4))
    handles = []  # (profile name, handle, state, n_steps)

    import time

    t0 = time.perf_counter()
    next_req = 0
    # drive the engine in virtual time: engine step s admits every
    # request whose Poisson arrival time has passed
    while next_req < len(plan) or eng.pending:
        while (
            next_req < len(plan)
            and plan[next_req][0] <= eng.engine_step_count + 1
        ):
            _, name, prog, target, state, n_steps = plan[next_req]
            h = eng.submit(prog, state, n_steps, target=target, tenant=name)
            handles.append((name, h, state, n_steps))
            next_req += 1
        eng.step()
    wall_s = time.perf_counter() - t0

    # ---- acceptance: batching happened, results are bitwise-correct ----
    peak_live_batched = max(
        (m.live_slots for m in eng.metrics.history if m.batched_dispatches),
        default=0,
    )
    assert eng.metrics.batched_dispatches >= 1, (
        "no engine step coalesced >= 2 same-fingerprint requests into one "
        "vmapped dispatch — the load pattern should force this"
    )
    checked = set()
    for name, h, state, n_steps in handles:
        if name in checked:
            continue
        checked.add(name)
        prog, target = h._req.program, h._req.target
        want = api.compile(prog, target).time_loop(state, n_steps)
        got = h.result()
        for w, o in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(o))

    # ---- report --------------------------------------------------------
    lat = np.array([h.latency_s for _, h, _, _ in handles])
    points = sum(
        float(np.prod(h._req.program.field_args[0].type.bounds.shape)) * n
        for _, h, _, n in handles
    )
    snap = eng.metrics.snapshot()
    record = {
        "n_requests": n_requests,
        "arrival_rate_per_step": arrival_rate,
        "wall_s": wall_s,
        "aggregate_gpts": points / wall_s / 1e9,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "latency_mean_s": float(lat.mean()),
        "peak_live_on_batched_step": peak_live_batched,
        "profiles": {
            name: sum(1 for n, *_ in handles if n == name)
            for name, *_ in profiles
        },
        "engine": snap,
        # bursty phase: autoscale grow/shrink events with queue-depth /
        # utilization provenance land in serve_load.json alongside the
        # steady-state numbers
        "burst": _burst_phase(fast, rng),
    }
    rows = [
        ("requests", n_requests),
        ("engine steps", snap["engine_steps"]),
        ("aggregate GPts/s", f"{record['aggregate_gpts']:.4f}"),
        ("latency p50 (ms)", f"{record['latency_p50_s'] * 1e3:.1f}"),
        ("latency p99 (ms)", f"{record['latency_p99_s'] * 1e3:.1f}"),
        ("batched dispatches", snap["batched_dispatches"]),
        ("solo dispatches", snap["solo_dispatches"]),
        ("peak live (batched step)", peak_live_batched),
        ("mean utilization", f"{snap['mean_utilization']:.2f}"),
        ("compile-cache hits", snap["compile_cache"]["hits"]),
        ("compile-cache misses", snap["compile_cache"]["misses"]),
        ("burst pool grows", record["burst"]["grows"]),
        ("burst pool shrinks", record["burst"]["shrinks"]),
        ("burst buckets retired", record["burst"]["buckets_retired"]),
    ]
    print(table("serve_load: mixed stencil traffic (one engine)", rows,
                ["metric", "value"]))
    save_record("serve_load", record)
    return record


if __name__ == "__main__":
    run()
