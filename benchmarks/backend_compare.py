"""Paper table-1 spirit: one stencil IR, multiple backends.

The paper compiles the same Fortran source to CPU, GPU, and FPGA (initial
vs auto-tuned).  Our backends are (a) pure-jnp lowering and (b) the
Pallas TPU kernel (interpret mode on CPU — numerics validated, perf
measured on the jnp path), plus the optimization pipeline on/off —
reporting both throughput and compiled-HLO op counts as the structural
"tuning" signal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import gpts, save_record, table, target_record, time_step
from repro.api import Target
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction


def _hlo_op_count(fn, *args) -> int:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return sum(
        1 for line in txt.splitlines() if "=" in line and "fusion" not in line
    )


def run(fast: bool = False, overlap: str = "off",
        exchange_every: int = 1, tune: bool = False,
        fused_epoch: bool = False) -> dict:
    """``overlap="on"`` adds a variant compiled through the IR-level
    ``split_overlapped_applies`` path (interior/frame split + combine),
    so the rewrite's overhead/win is measurable against ``jnp_opt`` on
    the same hardware.  ``exchange_every=k`` adds a temporally-tiled
    variant (one exchange epoch, k steps per call): its output after one
    epoch must equal k sequential ``jnp_opt`` steps, and its throughput
    is reported *per step* so the redundant-compute overhead is visible.
    ``tune=True`` adds the autotuner's winner (``Target.tuned``,
    measured search) as a variant, recorded with tuned provenance.
    ``fused_epoch=True`` adds the k=4 pallas pair — per-step dispatch vs
    ONE megakernel per epoch (``Target(fused_epoch=True)``) — validated
    bitwise against each other and allclose against jnp_opt steps."""
    shape = (256, 256) if fast else (1024, 1024)
    g = Grid(shape=shape, extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=g, space_order=8)
    rng = np.random.default_rng(0)
    u0 = jnp.asarray(rng.standard_normal(shape), jnp.float32)

    variants = {
        "jnp_raw": Target(backend="jnp", fuse=False, cse=False),
        "jnp_opt": Target(backend="jnp", fuse=True, cse=True),
        "pallas_interpret": Target(backend="pallas"),
    }
    if overlap == "on":
        variants["jnp_opt_overlap"] = Target(
            backend="jnp", fuse=True, cse=True, overlap=True
        )
    record, rows = {}, []
    ref_out = None
    for name, target in variants.items():
        op = Operator(Eq(u.dt, 0.5 * u.laplace), dt=1e-7, boundary="zero")
        step = op.compile_step(target=target)
        out = np.asarray(step(u0)[0])
        if ref_out is None:
            ref_out = out
        np.testing.assert_allclose(out, ref_out, rtol=1e-5, atol=1e-6)
        sec = time_step(lambda a: step(a), (u0,), iters=3, warmup=1)
        record[name] = {
            "sec": sec,
            "gpts": gpts(shape, sec),
            "target": target_record(target, "manual"),
        }
        rows.append((name, f"{gpts(shape, sec):.3f}", "allclose ✓"))

    if exchange_every > 1:
        k = exchange_every
        op = Operator(Eq(u.dt, 0.5 * u.laplace), dt=1e-7, boundary="zero")
        base_step = op.compile_step(target=variants["jnp_opt"])
        epoch_step = op.compile_step(
            target=Target(backend="jnp", fuse=True, cse=True,
                          exchange_every=k)
        )
        want = u0
        for _ in range(k):
            want = base_step(want)[0]
        got = epoch_step(u0)[0]  # one epoch == k steps
        # so8 under jit: XLA may FMA-contract the fused epoch differently
        # than k separate step programs (~1 ulp, DESIGN.md §2) — compare
        # at ulp tolerance like the distribution tests
        np.testing.assert_allclose(
            np.asarray(want), np.asarray(got), rtol=1e-6, atol=1e-6
        )
        sec = time_step(lambda a: epoch_step(a), (u0,), iters=3, warmup=1) / k
        name = f"jnp_opt_ee{k}"
        record[name] = {
            "sec": sec,
            "gpts": gpts(shape, sec),
            "target": target_record(
                Target(backend="jnp", fuse=True, cse=True, exchange_every=k),
                "manual",
            ),
        }
        rows.append((name, f"{gpts(shape, sec):.3f}",
                     f"allclose == {k}× jnp_opt"))

    if fused_epoch:
        # the epoch-megakernel pair: k=4 pallas epoch, k kernel
        # dispatches vs ONE.  Correctness on the jitted pair — bitwise
        # against each other (DESIGN.md §10), allclose against jnp.
        # Throughput on the *eager* pair: jit inlines both into the same
        # XLA module (launch count vanishes), so eager dispatch is where
        # the k-vs-1 launch overhead is actually measurable on CPU — and
        # it mirrors the real-device situation, where pallas kernels are
        # opaque custom calls XLA cannot fuse across.
        k = 4
        op = Operator(Eq(u.dt, 0.5 * u.laplace), dt=1e-7, boundary="zero")
        base_step = op.compile_step(target=variants["jnp_opt"])
        want = u0
        for _ in range(k):
            want = base_step(want)[0]
        unfused_jit = op.compile_step(target=Target(
            backend="pallas", exchange_every=k, pallas_interpret=True))
        fused_jit = op.compile_step(target=Target(
            backend="pallas", exchange_every=k, fused_epoch=True,
            pallas_interpret=True))
        a, b = unfused_jit(u0)[0], fused_jit(u0)[0]  # one epoch == k steps
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(
            np.asarray(want), np.asarray(b), rtol=1e-6, atol=1e-6
        )
        pair = {
            f"pallas_ee{k}": Target(
                backend="pallas", exchange_every=k, jit=False,
                pallas_interpret=True,
            ),
            f"pallas_fused_ee{k}": Target(
                backend="pallas", exchange_every=k, fused_epoch=True,
                jit=False, pallas_interpret=True,
            ),
        }
        for name, target in pair.items():
            step = op.compile_step(target=target)
            sec = time_step(lambda a: step(a), (u0,), iters=5, warmup=2) / k
            record[name] = {
                "sec": sec,
                "gpts": gpts(shape, sec),
                "target": target_record(target, "manual"),
            }
            launches = "1 kernel" if target.fused_epoch else f"{k} kernels"
            note = f"{launches}/epoch, eager, {sec * 1e3:.1f} ms/step"
            rows.append((name, f"{gpts(shape, sec):.3f}", note))

    if tune:
        # the autotuner's pick for this program on this machine (measured
        # search, persisted in the on-disk tune cache); validated against
        # k sequential jnp_opt steps like the manual epoch variant
        op = Operator(Eq(u.dt, 0.5 * u.laplace), dt=1e-7, boundary="zero")
        tuned_target = Target.tuned(
            op.program, ranks=1, measure=True, steps=4, trials=2,
        )
        k = tuned_target.exchange_every
        tuned_step = op.compile_step(target=tuned_target)
        base_step = op.compile_step(target=variants["jnp_opt"])
        want = u0
        for _ in range(k):
            want = base_step(want)[0]
        got = tuned_step(u0)[0]
        np.testing.assert_allclose(
            np.asarray(want), np.asarray(got), rtol=1e-5, atol=1e-5
        )
        sec = time_step(lambda a: tuned_step(a), (u0,), iters=3, warmup=1) / k
        record["tuned"] = {
            "sec": sec,
            "gpts": gpts(shape, sec),
            "target": target_record(tuned_target, "tuned"),
        }
        rows.append(("tuned", f"{gpts(shape, sec):.3f}",
                     f"autotuned (k={k}, backend={tuned_target.backend})"))

    print(table("backend comparison (so8 heat, one IR → N backends)", rows,
                ["backend", "GPts/s", "vs jnp_raw"]))
    save_record("backend_compare", record)
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--overlap", choices=["on", "off"], default="off")
    ap.add_argument("--exchange-every", type=int, default=1,
                    help="epoch depth k: adds a one-exchange-per-k-steps "
                         "variant (bitwise-checked against k jnp_opt steps)")
    ap.add_argument("--tune", action="store_true",
                    help="add the repro.tune winner as a measured variant")
    ap.add_argument("--fused-epoch", action="store_true",
                    help="add the k=4 pallas per-step vs fused-megakernel "
                         "pair (bitwise-checked against each other)")
    a = ap.parse_args()
    run(fast=a.fast, overlap=a.overlap, exchange_every=a.exchange_every,
        tune=a.tune, fused_epoch=a.fused_epoch)
