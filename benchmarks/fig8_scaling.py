"""Paper fig. 8 analogue: strong scaling of 3D so4 heat/wave kernels.

Two parts:

1. **Measured** (virtual devices, subprocess model not needed here — the
   structural signal): decompose the global stencil for rank counts
   8→1024 and report per-rank halo-exchange bytes vs per-rank compute
   points from the dmp swap declarations — the quantities that drive the
   paper's strong-scaling curves.

2. **Modeled TPU step time** from roofline constants (197 TFLOP/s bf16,
   819 GB/s HBM, 50 GB/s ICI link): compute term (memory-bound stencils:
   bytes-limited) vs collective term (halo bytes / link bw), reported
   with and without comm/compute overlap — the paper's Devito-vs-xDSL
   gap is exactly the no-overlap penalty, and our beyond-paper overlap
   pass closes it.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_record, table
from repro.core.dialects import dmp, stencil
from repro.core.passes import decompose_stencil, eliminate_redundant_swaps
from repro.core.passes.decompose import make_strategy_3d
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

# TPU v5e constants
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
LINK_LATENCY = 2e-6  # per-message launch latency (matches launch/roofline)

GLOBAL = (512, 512, 512)
RANK_GRIDS = {
    8: (2, 2, 2),
    64: (4, 4, 4),
    128: (8, 4, 4),
    256: (8, 8, 4),
    512: (8, 8, 8),
    1024: (16, 8, 8),
}


def _stencil_stats(kind: str, so: int, grid_shape: tuple) -> dict:
    g = Grid(shape=GLOBAL, extent=(1.0,) * 3)
    u = TimeFunction(name="u", grid=g, space_order=so,
                     time_order=2 if kind == "wave" else 1)
    eq = Eq(u.dt2 if kind == "wave" else u.dt, 1.0 * u.laplace)
    op = Operator(eq, dt=1e-7)
    func = op.program.func
    local = decompose_stencil(func, make_strategy_3d(grid_shape))
    eliminate_redundant_swaps(local)
    swaps = [o for o in local.body.ops if isinstance(o, dmp.SwapOp)]
    halo_elems = sum(s.total_exchange_elems() for s in swaps)
    applies = [o for o in local.body.ops if isinstance(o, stencil.ApplyOp)]
    # flops per point: arithmetic ops in the apply bodies
    flop_per_pt = sum(
        sum(1 for bop in a.body.ops if type(bop).__name__ in
            ("AddOp", "SubOp", "MulOp", "DivOp"))
        for a in applies
    )
    local_pts = int(np.prod([G // r for G, r in
                             zip(GLOBAL, grid_shape)]))
    return {
        "halo_bytes": halo_elems * 4,
        "local_points": local_pts,
        "flops_per_point": flop_per_pt,
        "n_swaps": len(swaps),
    }


def _tiling_sweep(record: dict, ranks: list, exchange_every: tuple) -> list:
    """Temporal-tiling model rows: per-step time at epoch depth k =
    redundant-compute-scaled work + amortized per-epoch message latency +
    (depth-k) halo bytes once per k steps ≈ per-step bytes.

    Heat only: the wave kernel is time_order=2 (two input buffers, one
    output) — its state does not rotate closed within one epoch, so
    ``Target(exchange_every=k)`` rejects it (``TargetError``) and a
    modeled number would describe an uncompilable configuration."""
    rows = []
    for kind in ("heat",):
        for R in ranks:
            st = record[f"{kind}_r{R}"]
            local = tuple(G // r for G, r in zip(GLOBAL, RANK_GRIDS[R]))
            w = 2  # so4 taps reach ±2
            t_comp = st["t_comp"]
            t_bytes = st["halo_bytes"] / LINK_BW
            n_msgs = 2 * len(local)  # one send/recv pair per face
            row = [kind, R]
            for k in exchange_every:
                if any(k * w > n for n in local):
                    row.append("-")  # deep halo outgrows the shard
                    continue
                vols = [
                    float(np.prod([n + 2 * j * w for n in local]))
                    for j in range(k)
                ]
                rcf = sum(vols) / (k * float(np.prod(local)))
                t_step = (
                    t_comp * rcf + t_bytes + n_msgs * LINK_LATENCY / k
                )
                gp = st["local_points"] * R / t_step / 1e9
                record[f"{kind}_r{R}"][f"gpts_ee{k}"] = gp
                row.append(f"{gp:.0f}")
            rows.append(tuple(row))
    return rows


def _tune_rows(record: dict, ranks: list) -> list:
    """Autotuner view of the scaling table: feed each rank count's
    modeled stats through the *shared* roofline terms
    (``launch/roofline.RooflineTerms``) and report the epoch depth the
    autotuner would pick (``recommend_exchange_every``) with its modeled
    per-step ranking — the same code path ``repro.tune`` scores live
    candidates with."""
    from repro.launch.roofline import RooflineTerms

    rows = []
    for kind in ("heat",):
        for R in ranks:
            st = record[f"{kind}_r{R}"]
            local = tuple(G // r for G, r in zip(GLOBAL, RANK_GRIDS[R]))
            terms = RooflineTerms(
                flops=st["local_points"] * st["flops_per_point"],
                bytes_accessed=st["local_points"] * 12,
                collectives={"collective-permute": st["halo_bytes"]},
                exchange_every=1,
                messages_per_epoch=2 * len(local),
                step_halo=(2,) * len(local),  # so4 taps reach ±2
                local_shape=local,
            )
            ranked = terms.ranked_exchange_every(max_k=8)
            best_k, best_t = ranked[0]
            record[f"{kind}_r{R}"]["tuned_exchange_every"] = best_k
            record[f"{kind}_r{R}"]["tuned_step_time"] = best_t
            rows.append((
                kind, R, best_k, f"{best_t * 1e6:.0f}",
                " ".join(f"k{k}:{t*1e6:.0f}µs" for k, t in ranked[:3]),
            ))
    return rows


def run(fast: bool = False, overlap: str = "both",
        exchange_every: tuple = (1,), tune: bool = False) -> dict:
    """``overlap`` selects the latency-hiding regime to report: "off" is
    the paper's blocking exchange (t_comp + t_comm), "on" is the
    split-overlapped pipeline (max(t_comp, t_comm) — the IR-level
    ``split_overlapped_applies`` rewrite), "both" prints the two columns
    side by side so the win is explicit in the perf trajectory.
    ``tune=True`` appends the shared roofline model's recommended epoch
    depth per rank count (the quantity ``repro.tune`` searches for)."""
    assert overlap in ("on", "off", "both")
    record, rows = {"overlap": overlap}, []
    ranks = list(RANK_GRIDS) if not fast else [8, 64]
    for kind in ("heat", "wave"):
        for R in ranks:
            st = _stencil_stats(kind, 4, RANK_GRIDS[R])
            # memory-bound stencil: per-point bytes = read star + write ≈
            # (1 read + 1 write + reuse-miss) × 4B; use 3 streams as the
            # classic Jacobi estimate
            t_comp = max(
                st["local_points"] * st["flops_per_point"] / PEAK_FLOPS,
                st["local_points"] * 12 / HBM_BW,
            )
            t_comm = st["halo_bytes"] / LINK_BW
            t_nooverlap = t_comp + t_comm
            t_overlap = max(t_comp, t_comm)
            gpts_no = st["local_points"] * R / t_nooverlap / 1e9
            gpts_ov = st["local_points"] * R / t_overlap / 1e9
            record[f"{kind}_r{R}"] = dict(
                st, t_comp=t_comp, t_comm=t_comm,
                gpts_nooverlap=gpts_no, gpts_overlap=gpts_ov,
            )
            row = [kind, R, f"{st['halo_bytes']/2**20:.2f}",
                   f"{t_comp*1e6:.0f}", f"{t_comm*1e6:.0f}"]
            if overlap in ("off", "both"):
                row.append(f"{gpts_no:.0f}")
            if overlap in ("on", "both"):
                row.append(f"{gpts_ov:.0f}")
            rows.append(tuple(row))
    headers = ["kernel", "ranks", "halo MiB/rank", "t_comp µs", "t_comm µs"]
    if overlap in ("off", "both"):
        headers.append("GPts/s (paper)")
    if overlap in ("on", "both"):
        headers.append("GPts/s (+overlap)")
    print(table(
        f"fig8: strong scaling, 512³ so4 (TPU-v5e roofline model, "
        f"overlap={overlap})",
        rows, headers,
    ))
    if tuple(exchange_every) != (1,):
        tile_rows = _tiling_sweep(record, ranks, tuple(exchange_every))
        print(table(
            "fig8: temporal-tiling sweep (GPts/s per exchange_every, "
            "latency amortized 1/k vs redundant boundary compute)",
            tile_rows,
            ["kernel", "ranks"] + [f"k={k}" for k in exchange_every],
        ))
    if tune:
        print(table(
            "fig8: autotuner recommendation (RooflineTerms per rank count)",
            _tune_rows(record, ranks),
            ["kernel", "ranks", "best k", "t_step µs", "ranking"],
        ))
    # structural assertion recorded for EXPERIMENTS.md: halo bytes per
    # rank shrink as ranks grow (surface/volume)
    hb = [record[f"heat_r{R}"]["halo_bytes"] for R in ranks]
    assert all(a >= b for a, b in zip(hb, hb[1:])), hb
    save_record("fig8_scaling", record)
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--overlap", choices=["on", "off", "both"], default="both")
    ap.add_argument("--exchange-every", default="1",
                    help="comma list of epoch depths to sweep, e.g. 1,2,4,8")
    ap.add_argument("--tune", action="store_true",
                    help="append the roofline model's recommended epoch "
                         "depth per rank count")
    a = ap.parse_args()
    run(fast=a.fast, overlap=a.overlap,
        exchange_every=tuple(int(k) for k in a.exchange_every.split(",")),
        tune=a.tune)
