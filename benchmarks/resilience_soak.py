"""Fault-injected soak: checkpoint overhead and time-to-recover.

The resilience layer's two costs, measured on the fig7 heat kernel:

1. **checkpoint overhead** — per-step wall time of a ``ResilientLoop``
   with no checkpointing (the epoch-driver baseline) vs checkpointing
   every epoch, blocking and async.  Reported as seconds/step and as
   overhead % over the no-checkpoint driver — the number a user trades
   against their preemption rate when picking ``checkpoint_every``.
2. **time-to-recover** — a ``FaultPlan`` kills the run mid-soak; the
   wall time of ``resume()`` (manifest verify + restore + recompile)
   plus the first post-resume epoch is the recovery latency.  The
   resumed run's final state is spot-checked bitwise against the
   uninterrupted reference, so the numbers describe a *correct*
   recovery.

Writes ``results/bench/resilience_soak.json``.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import save_record, table, target_record


def _heat_program(shape):
    from repro.frontends.oec_like import ProgramBuilder

    p = ProgramBuilder("heat_soak", shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1))
        * 0.25,
    )
    p.store(r, out)
    return p.finish(boundary="periodic")


def _run_loop(prog, target, u0, n_steps, **kwargs):
    """One ResilientLoop soak; returns (final state, wall seconds)."""
    import jax

    from repro.resilience import ResilientLoop

    loop = ResilientLoop(prog, target, (u0,), n_steps, **kwargs)
    t0 = time.perf_counter()
    final = loop.run()
    jax.block_until_ready(final)
    return final, time.perf_counter() - t0


def run(fast: bool = False) -> dict:
    from repro.api import Target
    from repro.resilience import FaultPlan, SimulatedFault, resume

    shape = (128, 128) if fast else (256, 256)
    n_steps = 64 if fast else 256
    k = 4
    target = Target(exchange_every=k)
    prog = _heat_program(shape)
    u0 = np.random.default_rng(0).standard_normal(shape).astype(np.float32)

    root = tempfile.mkdtemp(prefix="repro-soak-")
    rows = []
    record: dict = {
        "shape": list(shape),
        "n_steps": n_steps,
        "target": target_record(target),
        "variants": {},
    }
    try:
        # warm the compile cache so the baseline is not paying the trace
        ref, _ = _run_loop(prog, target, u0, n_steps, checkpoint_every=0)
        baseline = None
        variants = [
            ("no-checkpoint", dict(checkpoint_every=0)),
            ("blocking-every-epoch", dict(checkpoint_every=1)),
            ("async-every-epoch", dict(checkpoint_every=1, async_saves=True)),
            ("blocking-every-4-epochs", dict(checkpoint_every=4)),
        ]
        for name, kw in variants:
            d = os.path.join(root, name)
            if kw.get("checkpoint_every"):
                kw = dict(kw, directory=d)
            final, secs = _run_loop(prog, target, u0, n_steps, **kw)
            assert all(
                np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(final, ref)
            ), f"variant {name} is not bitwise vs the baseline"
            per_step = secs / n_steps
            overhead = (
                0.0 if baseline is None else (per_step / baseline - 1.0) * 100
            )
            if baseline is None:
                baseline = per_step
            record["variants"][name] = {
                "seconds_per_step": per_step,
                "overhead_pct": overhead,
            }
            rows.append((name, f"{per_step * 1e6:.1f}µs", f"{overhead:+.1f}%"))

        # --- time-to-recover -------------------------------------------
        kill_epoch = (n_steps // k) // 2
        d = os.path.join(root, "killed")
        try:
            _run_loop(
                prog, target, u0, n_steps, directory=d, checkpoint_every=1,
                fault_plan=FaultPlan(kill_at_epoch=kill_epoch),
            )
            raise RuntimeError("FaultPlan did not fire")
        except SimulatedFault:
            pass
        t0 = time.perf_counter()
        loop = resume(prog, d, target)
        restore_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        loop.advance_epoch()  # first post-resume epoch (compile + run)
        first_epoch_s = time.perf_counter() - t0
        final = loop.run()
        assert all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(final, ref)
        ), "resumed run is not bitwise vs the uninterrupted reference"
        record["recovery"] = {
            "killed_at_step": kill_epoch * k,
            "restore_seconds": restore_s,
            "first_epoch_seconds": first_epoch_s,
            "time_to_recover_seconds": restore_s + first_epoch_s,
            "bitwise_ok": True,
        }
        rows.append(
            (
                "time-to-recover",
                f"{(restore_s + first_epoch_s) * 1e3:.1f}ms",
                f"(restore {restore_s * 1e3:.1f}ms)",
            )
        )
    finally:
        shutil.rmtree(root, ignore_errors=True)

    print(
        table(
            f"resilience soak  {shape[0]}x{shape[1]}, {n_steps} steps, k={k}",
            rows,
            ["variant", "per-step / total", "overhead"],
        )
    )
    save_record("resilience_soak", record)
    return record


if __name__ == "__main__":
    run(fast=True)
