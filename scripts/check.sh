#!/usr/bin/env bash
# Repo check: fast import smoke over every module, then tier-1 tests.
#
#   scripts/check.sh            # smoke + full tier-1 suite
#   scripts/check.sh --smoke    # smoke only (seconds; used by CI's first job)
#
# Works both with an editable install (pip install -e .) and without
# (falls back to PYTHONPATH=src).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import repro" >/dev/null 2>&1; then
  export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
fi

echo "== import smoke: src/repro/** =="
python - <<'EOF'
import importlib
import pathlib
import sys

failed = []
root = pathlib.Path("src")
mods = sorted(
    str(p.relative_to(root).with_suffix("")).replace("/", ".")
    for p in root.glob("repro/**/*.py")
)
for mod in mods:
    name = mod[: -len(".__init__")] if mod.endswith(".__init__") else mod
    try:
        importlib.import_module(name)
    except Exception as e:  # noqa: BLE001 - report every failure
        failed.append((name, f"{type(e).__name__}: {e}"))
for name, err in failed:
    print(f"FAIL  {name}: {err}")
print(f"{len(mods) - len(failed)}/{len(mods)} modules import cleanly")
sys.exit(1 if failed else 0)
EOF

echo "== import smoke: benchmarks/*.py =="
python - <<'EOF'
import importlib.util
import pathlib
import sys

failed = []
files = sorted(pathlib.Path("benchmarks").glob("*.py"))
for path in files:
    spec = importlib.util.spec_from_file_location(f"bench_{path.stem}", path)
    try:
        spec.loader.exec_module(importlib.util.module_from_spec(spec))
    except Exception as e:  # noqa: BLE001
        failed.append((str(path), f"{type(e).__name__}: {e}"))
for name, err in failed:
    print(f"FAIL  {name}: {err}")
print(f"{len(files) - len(failed)}/{len(files)} benchmark modules import cleanly")
sys.exit(1 if failed else 0)
EOF

echo "== compile-cache smoke =="
python - <<'EOF'
# the quickstart program compiled twice: the second compile must be a
# cache hit (same artifact, hit counter bumped) and run zero passes
from repro import api
from repro.core.passes import PassManager
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction


def quickstart_program():
    grid = Grid(shape=(64, 64), extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=grid, space_order=2)
    dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
    return Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="zero").program


target = api.Target()
first = api.compile(quickstart_program(), target)
runs = PassManager.runs_completed
hits = api.cache_stats().hits
second = api.compile(quickstart_program(), target)
assert second is first, "second compile did not return the cached artifact"
assert api.cache_stats().hits == hits + 1, "cache hit counter did not bump"
assert PassManager.runs_completed == runs, (
    "cache hit re-ran the pass pipeline"
)
print(f"cache smoke OK: hit on recompile, {runs} pipeline run(s) total, "
      f"stats={api.cache_stats().as_dict()}")
EOF

echo "== pass-pipeline smoke =="
python -m repro.core.passes \
  "fuse,cse,dce,decompose{grid=2x2},swap-elim,overlap,lower-comm" --quiet
python -m repro.core.passes \
  "decompose{grid=2x2xy,boundary=periodic},swap-elim,diagonal,overlap,lower-comm" \
  --program box --quiet
python -m repro.core.passes \
  "decompose{grid=2x2},swap-elim,temporal-tile{k=2},overlap,lower-comm" --quiet

echo "== temporal-tiling smoke =="
python - <<'EOF'
# the heat kernel at exchange_every 1 vs 4: distinct cache keys, equal
# outputs over one epoch, and no more exchange_start ops per EPOCH than
# the per-STEP baseline emits (1 exchange volley serves 4 steps)
import numpy as np

from repro import api
from repro.core.dialects import comm
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

grid = Grid(shape=(64, 64), extent=(1.0, 1.0))
u = TimeFunction(name="u", grid=grid, space_order=2)
dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
op = Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="zero")

t1, t4 = api.Target(), api.Target(exchange_every=4)
assert t1.fingerprint != t4.fingerprint, "epoch depth must change the cache key"
s1, s4 = api.compile(op.program, t1), api.compile(op.program, t4)
assert s1 is not s4, "distinct targets must yield distinct cached artifacts"


def starts(s):
    return sum(
        1 for o in s.local_ir.body.ops if isinstance(o, comm.ExchangeStartOp)
    )


assert starts(s4) <= starts(s1), (starts(s4), starts(s1))
assert starts(s4) < 4 * starts(s1), "k=4 must not exchange per step"

rng = np.random.default_rng(0)
u0 = rng.standard_normal((64, 64)).astype(np.float32)
import jax.numpy as jnp

a = np.asarray(s1.time_loop((jnp.asarray(u0),), 4)[0])
b = np.asarray(s4.time_loop((jnp.asarray(u0),), 4)[0])
assert np.array_equal(a, b), f"epoch != 4 steps, max diff {np.abs(a-b).max()}"
print(f"temporal smoke OK: starts/epoch k=1: {starts(s1)}, k=4: {starts(s4)}, "
      "4-step outputs bitwise-equal")
EOF

echo "== tune smoke =="
python - <<'EOF'
# cost-model-only autotuning of the heat program must return a valid
# cached Target; the second search must hit the on-disk cache
import os
import tempfile

os.environ["REPRO_TUNE_CACHE"] = tempfile.mkdtemp(prefix="repro-tune-smoke-")

from repro import api
from repro.tune import cache_stats, tune
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

grid = Grid(shape=(64, 64), extent=(1.0, 1.0))
u = TimeFunction(name="u", grid=grid, space_order=2)
dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
prog = Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="zero").program

r1 = tune(prog, measure=False)
assert not r1.from_cache, "first search must be a cache miss"
assert cache_stats().misses == 1 and cache_stats().stores == 1, (
    cache_stats().as_dict()
)
api.compile(prog, r1.target)  # the winner is a valid, compilable Target
unpruned = [c for c in r1.candidates if not c.pruned]
assert unpruned and all(
    r1.winner.modeled_s <= c.modeled_s for c in unpruned
), "winner must have the minimal modeled step time among unpruned candidates"

r2 = tune(prog, measure=False)
assert r2.from_cache, "second search must hit the persistent cache"
assert cache_stats().hits == 1, cache_stats().as_dict()
assert r2.target.fingerprint == r1.target.fingerprint
print(f"tune smoke OK: winner {r1.winner.describe()!r}, "
      f"{len(r1.candidates)} candidates ({len(unpruned)} unpruned), "
      f"stats={cache_stats().as_dict()}")
EOF

echo "== serve smoke =="
python - <<'EOF'
# two concurrent same-fingerprint requests plus one epoch-depth wave
# request through one StencilEngine: the heat pair must coalesce into a
# batched vmapped dispatch, and every result must be bitwise-equal to a
# solo compile(...).time_loop(...) run
import numpy as np

from repro import api
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction
from repro.serve.stencil import StencilEngine, StencilEngineConfig

grid = Grid(shape=(48, 48), extent=(1.0, 1.0))
u = TimeFunction(name="u", grid=grid, space_order=2)
dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
heat = Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="zero").program
w = TimeFunction(name="w", grid=grid, space_order=2, time_order=2)
wave = Operator(Eq(w.dt2, w.laplace), dt=1e-3, boundary="zero").program

rng = np.random.default_rng(0)
t_heat, t_wave = api.Target(), api.Target(exchange_every=2)
eng = StencilEngine(StencilEngineConfig(slots_per_group=2))
jobs = []
for i in range(2):  # same fingerprint → one vmapped dispatch
    s = (rng.standard_normal((48, 48)).astype(np.float32),)
    jobs.append((eng.submit(heat, s, 4, tenant=f"heat{i}"), heat, t_heat, s, 4))
s = tuple(rng.standard_normal((48, 48)).astype(np.float32) for _ in range(2))
jobs.append((eng.submit(wave, s, 4, target=t_wave, tenant="wave"),
             wave, t_wave, s, 4))
eng.run()

snap = eng.metrics.snapshot()
assert snap["batched_dispatches"] >= 1, (
    f"heat pair did not coalesce: {snap}"
)
assert snap["requests_completed"] == 3, snap
for h, prog, target, state, n in jobs:
    want = api.compile(prog, target).time_loop(state, n)
    for a, b in zip(h.result(), want):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            f"serve result differs from solo run for rid={h.rid}"
        )
print(f"serve smoke OK: {snap['batched_dispatches']} batched / "
      f"{snap['solo_dispatches']} solo dispatches over "
      f"{snap['engine_steps']} engine steps, all results bitwise-equal")
EOF

echo "== fused-epoch smoke =="
python - <<'EOF'
# Target(exchange_every=4, fused_epoch=True): the whole epoch must be
# exactly ONE pallas kernel dispatch (trace counter + IR census) and
# bitwise-equal to the unfused pallas path over two epochs
import numpy as np

from repro import api, kernels
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

grid = Grid(shape=(64, 64), extent=(1.0, 1.0))
u = TimeFunction(name="u", grid=grid, space_order=2)
dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
heat = Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="zero").program

unfused = api.compile(heat, api.Target(
    backend="pallas", exchange_every=4, pallas_interpret=True))
fused = api.compile(heat, api.Target(
    backend="pallas", exchange_every=4, fused_epoch=True,
    pallas_interpret=True))
assert fused.kernel_dispatches == {"fused_epoch": 1, "apply": 0, "total": 1}, (
    fused.kernel_dispatches
)
assert unfused.kernel_dispatches["apply"] == 4, unfused.kernel_dispatches

rng = np.random.default_rng(0)
u0 = rng.standard_normal((64, 64)).astype(np.float32)
kernels.reset_dispatch_stats()
a = fused.time_loop((u0,), 8)[0]  # 2 epochs
stats = kernels.dispatch_stats().as_dict()  # live object: snapshot now
assert stats["fused_epoch_calls"] == 1 and stats["apply_calls"] == 0, (
    stats  # jit traces the epoch once: 1 kernel per epoch
)
b = unfused.time_loop((u0,), 8)[0]
a, b = np.asarray(a), np.asarray(b)
assert np.array_equal(a, b), f"fused != unfused, max {np.abs(a-b).max()}"
print(f"fused-epoch smoke OK: one kernel per k=4 epoch "
      f"(trace stats {stats}), 8-step outputs bitwise-equal")
EOF

echo "== resilience smoke =="
python - <<'EOF'
# a FaultPlan-killed checkpointing run (heat, k=4, checkpoint every
# epoch, keep_last=2) must resume from its last committed snapshot and
# finish bitwise-equal to compile(...).time_loop(...) — and the
# retention knob must have pruned older snapshots truthfully
import os
import shutil
import tempfile

import numpy as np

from repro import api
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction
from repro.resilience import FaultPlan, ResilientLoop, SimulatedFault, resume

grid = Grid(shape=(64, 64), extent=(1.0, 1.0))
u = TimeFunction(name="u", grid=grid, space_order=2)
dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
prog = Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="zero").program

tgt = api.Target(exchange_every=4)
rng = np.random.default_rng(0)
u0 = rng.standard_normal((64, 64)).astype(np.float32)
want = api.compile(prog, tgt).time_loop((u0,), 32)
want = want if isinstance(want, tuple) else (want,)

d = tempfile.mkdtemp(prefix="repro-res-smoke-")
loop = ResilientLoop(
    prog, tgt, (u0,), 32, directory=d, checkpoint_every=1, keep_last=2,
    fault_plan=FaultPlan(kill_at_epoch=5),
)
try:
    loop.run()
    raise SystemExit("FaultPlan did not fire")
except SimulatedFault:
    pass
# 5 epochs checkpointed, keep_last=2: steps 16 & 20 remain, 3 pruned
assert loop.checkpointer.available_steps() == [16, 20], (
    loop.checkpointer.available_steps()
)
assert loop.checkpointer.stats.prunes == 3, loop.checkpointer.stats.as_dict()

resumed = resume(prog, d, tgt, keep_last=2)
assert resumed.step_count == 20, resumed.step_count
got = resumed.run()
for a, b in zip(got, want):
    assert np.array_equal(np.asarray(a), np.asarray(b)), (
        "killed+resumed run is not bitwise-equal to time_loop"
    )
shutil.rmtree(d, ignore_errors=True)
print("resilience smoke OK: killed at epoch 5, resumed from step 20, "
      f"bitwise-equal over 32 steps; ckpt stats "
      f"{loop.checkpointer.stats.as_dict()}")
EOF

echo "== elastic-serve smoke =="
python - <<'EOF'
# ISSUE 9: a 2-rank distributed bucket must batch its live slots into
# ONE pooled slot-axis dispatch per engine step (per-bucket counters:
# batched > 0, solo == 0), and a queue burst against a small autoscaled
# bucket must record >= 1 PoolSizer grow and >= 1 shrink — with every
# result bitwise-equal to a solo compile(...).time_loop(...) run
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np
import jax
from jax.sharding import Mesh

from repro import api
from repro.core.passes.decompose import make_strategy_1d
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction
from repro.serve.stencil import (
    PoolSizerConfig,
    StencilEngine,
    StencilEngineConfig,
)

grid = Grid(shape=(48, 48), extent=(1.0, 1.0))
u = TimeFunction(name="u", grid=grid, space_order=2)
dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
heat = Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="zero").program
mesh = Mesh(np.array(jax.devices()[:2]), ("x",))
target = api.Target(mesh=mesh, strategy=make_strategy_1d(2))
rng = np.random.default_rng(0)
solo = api.compile(heat, target)

# -- pooled distributed dispatch: 4 live slots, ONE dispatch per step --
eng = StencilEngine(StencilEngineConfig(slots_per_group=4))
states = [rng.standard_normal((48, 48)).astype(np.float32) for _ in range(4)]
hs = [eng.submit(heat, (s,), 6, target=target) for s in states]
eng.run()
bd = eng.metrics.bucket_dispatches[f"{heat.fingerprint}/{target.fingerprint}"]
assert bd["batched"] >= 1 and bd["solo"] == 0, (
    f"2-rank bucket did not dispatch pooled: {bd}"
)
for h, s in zip(hs, states):
    want = solo.time_loop((s,), 6)
    for a, b in zip(h.result(), want if isinstance(want, tuple) else (want,)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            f"pooled result differs from solo run for rid={h.rid}"
        )

# -- queue burst: autoscaler must grow on depth, shrink on the tail ----
eng2 = StencilEngine(StencilEngineConfig(
    slots_per_group=2,
    autoscale=PoolSizerConfig(min_capacity=1, max_capacity=8,
                              cooldown_steps=1, ewma_alpha=1.0),
))
burst = [rng.standard_normal((48, 48)).astype(np.float32) for _ in range(8)]
steps = [6] * 7 + [36]
hs2 = [eng2.submit(heat, (s,), n, target=target)
       for s, n in zip(burst, steps)]
eng2.run()
auto = eng2.metrics.snapshot()["autoscale"]
assert auto["grows"] >= 1 and auto["shrinks"] >= 1, auto
for h, s, n in zip(hs2, burst, steps):
    want = solo.time_loop((s,), n)
    for a, b in zip(h.result(), want if isinstance(want, tuple) else (want,)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            f"post-resize result differs from solo run for rid={h.rid}"
        )
print(f"elastic-serve smoke OK: bucket counters {bd}, "
      f"autoscale grows={auto['grows']} shrinks={auto['shrinks']}, "
      "all results bitwise-equal")
EOF

echo "== obs smoke =="
python - <<'EOF'
# ISSUE 10: a traced 8-step heat run (k=4, two epochs) must export a
# valid Chrome trace with >= 1 epoch span per epoch, a drift report
# against the roofline model, and a unified obs.snapshot() covering all
# five counter namespaces.  The traced time_loop runs the epoch body
# eagerly (spans per epoch), which may differ from the fused fori_loop
# by one ulp on a single device (FMA fusion) — the distributed traced
# path is checked bitwise in tests/dist_worker.py obs-trace-2rank.
import json
import os

import numpy as np

from repro import api, obs

from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

grid = Grid(shape=(64, 64), extent=(1.0, 1.0))
u = TimeFunction(name="u", grid=grid, space_order=2)
dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
prog = Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="zero").program
tgt = api.Target(exchange_every=4)
step = api.compile(prog, tgt)
rng = np.random.default_rng(0)
u0 = rng.standard_normal((64, 64)).astype(np.float32)

want = step.time_loop((u0,), 8)
want = np.asarray(want[0] if isinstance(want, tuple) else want)
obs.enable()
obs.clear()
got = step.time_loop((u0,), 8)
got = np.asarray(got[0] if isinstance(got, tuple) else got)
rep = obs.drift_report(terms=step.cost(), exchange_every=4)
obs.disable()
assert np.allclose(got, want, rtol=1e-6, atol=1e-6), (
    f"traced time_loop diverged: max abs diff {np.abs(got - want).max()}"
)

epochs = [s for s in obs.spans() if s.name == "epoch"]
assert len(epochs) == 2, f"expected 2 epoch spans, got {len(epochs)}"
assert rep.epochs == 2 and rep.measured_step_s > 0, rep.as_dict()
assert rep.modeled_step_s > 0 and rep.drift_ratio > 0, rep.as_dict()

os.makedirs("results/bench", exist_ok=True)
path = obs.write_chrome("results/bench/obs_smoke_trace.json")
with open(path) as f:
    doc = json.load(f)
xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
assert xs and all(
    {"name", "cat", "ts", "dur", "pid", "tid"} <= set(e) for e in xs
), "invalid Chrome trace events"
assert any(e["name"] == "epoch" for e in xs)

snap = obs.snapshot()
missing = {"compile", "kernel", "serve", "checkpoint", "tune"} - set(snap)
assert not missing, f"snapshot missing namespaces {missing}"
obs.clear()
print(f"obs smoke OK: {len(xs)} trace events -> {path}, "
      f"drift {rep.drift_ratio:.3g}x over {rep.epochs} epochs, "
      f"snapshot namespaces {sorted(snap)}")
EOF

if [[ "${1:-}" == "--smoke" ]]; then
  echo "smoke only: skipping tier-1 tests"
  exit 0
fi

echo "== tier-1 tests =="
python -m pytest -x -q
