#!/usr/bin/env bash
# Repo check: fast import smoke over every module, then tier-1 tests.
#
#   scripts/check.sh            # smoke + full tier-1 suite
#   scripts/check.sh --smoke    # smoke only (seconds; used by CI's first job)
#
# Works both with an editable install (pip install -e .) and without
# (falls back to PYTHONPATH=src).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import repro" >/dev/null 2>&1; then
  export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
fi

echo "== import smoke: src/repro/** =="
python - <<'EOF'
import importlib
import pathlib
import sys

failed = []
root = pathlib.Path("src")
mods = sorted(
    str(p.relative_to(root).with_suffix("")).replace("/", ".")
    for p in root.glob("repro/**/*.py")
)
for mod in mods:
    name = mod[: -len(".__init__")] if mod.endswith(".__init__") else mod
    try:
        importlib.import_module(name)
    except Exception as e:  # noqa: BLE001 - report every failure
        failed.append((name, f"{type(e).__name__}: {e}"))
for name, err in failed:
    print(f"FAIL  {name}: {err}")
print(f"{len(mods) - len(failed)}/{len(mods)} modules import cleanly")
sys.exit(1 if failed else 0)
EOF

echo "== import smoke: benchmarks/*.py =="
python - <<'EOF'
import importlib.util
import pathlib
import sys

failed = []
files = sorted(pathlib.Path("benchmarks").glob("*.py"))
for path in files:
    spec = importlib.util.spec_from_file_location(f"bench_{path.stem}", path)
    try:
        spec.loader.exec_module(importlib.util.module_from_spec(spec))
    except Exception as e:  # noqa: BLE001
        failed.append((str(path), f"{type(e).__name__}: {e}"))
for name, err in failed:
    print(f"FAIL  {name}: {err}")
print(f"{len(files) - len(failed)}/{len(files)} benchmark modules import cleanly")
sys.exit(1 if failed else 0)
EOF

echo "== pass-pipeline smoke =="
python -m repro.core.passes \
  "fuse,cse,dce,decompose{grid=2x2},swap-elim,overlap,lower-comm" --quiet
python -m repro.core.passes \
  "decompose{grid=2x2xy,boundary=periodic},swap-elim,diagonal,overlap,lower-comm" \
  --program box --quiet

if [[ "${1:-}" == "--smoke" ]]; then
  echo "smoke only: skipping tier-1 tests"
  exit 0
fi

echo "== tier-1 tests =="
python -m pytest -x -q
