"""Continuous-batching serving engine.

Slot-pool design (vLLM-style, ring caches instead of paged blocks):

- a fixed pool of ``max_slots`` decode slots, each owning one row of the
  batched KV/state cache (``[cells, max_slots, T, ...]``);
- arriving requests are prefilled one at a time (compiled once per
  prompt-length bucket) and their caches *inserted* into a free slot;
- every engine step runs ONE batched ``decode_step`` over all live slots
  with **per-slot positions** (slots decode at different depths — the
  continuous part);
- finished slots (EOS / max_new_tokens) are freed and immediately
  reusable, so throughput does not stall on the longest request.

All compiled functions are shape-stable: one prefill executable per
length bucket, one decode executable total.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 4
    max_len: int = 256
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    greedy: bool = True
    temperature: float = 1.0
    prefill_buckets: tuple = (32, 64, 128, 256)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    out: list = dataclasses.field(default_factory=list)
    slot: int = -1
    pos: int = 0            # next position to be written
    done: bool = False


def _bucket(n: int, buckets) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"prompt length {n} exceeds largest bucket {buckets[-1]}")


class Engine:
    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig,
                 rng: Optional[np.random.Generator] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.rng = rng or np.random.default_rng(0)
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}   # slot -> request
        self.free = list(range(ecfg.max_slots))
        self.finished: list[Request] = []
        self._next_rid = 0

        # pooled cache: [cells, max_slots, T(or window), ...]
        self.cache = lm.init_cache(cfg, ecfg.max_slots, ecfg.max_len)
        self.positions = jnp.zeros((ecfg.max_slots,), jnp.int32)
        self.last_token = jnp.zeros((ecfg.max_slots,), jnp.int32)
        self.live = np.zeros((ecfg.max_slots,), bool)

        # Compiled executables come from repro.api's process-wide cache,
        # keyed on the model-config fingerprint (+ bucket): a new Engine
        # over the same config reuses the already-traced decode/prefill
        # callables instead of re-jitting them.
        self._cfg_fp = repr(cfg)
        self._decode = api.cached_callable(
            ("serve-decode", self._cfg_fp),
            lambda: jax.jit(
                lambda params, tok, pos, cache: lm.decode_step(
                    params, cfg, tok, pos, cache
                )
            ),
        )

    # -- public API --------------------------------------------------------
    def add_request(self, prompt: list) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid=rid, prompt=list(prompt)))
        return rid

    def step(self) -> None:
        """Admit waiting requests into free slots, then one decode round."""
        while self.queue and self.free:
            self._admit(self.queue.pop(0), self.free.pop(0))
        if not self.active:
            return
        tok = self.last_token
        pos = self.positions
        logits, self.cache = self._decode(self.params, tok, pos, self.cache)
        next_tok = self._sample(logits)
        for slot, req in list(self.active.items()):
            t = int(next_tok[slot])
            req.out.append(t)
            req.pos += 1
            if (
                (self.ecfg.eos_id is not None and t == self.ecfg.eos_id)
                or len(req.out) >= self.ecfg.max_new_tokens
                or req.pos >= self.ecfg.max_len
            ):
                req.done = True
                self.finished.append(req)
                del self.active[slot]
                self.free.append(slot)
                self.live[slot] = False
        self.last_token = jnp.asarray(np.asarray(next_tok))
        self.positions = jnp.where(
            jnp.asarray(self.live), self.positions + 1, self.positions
        )

    def run(self, max_steps: int = 10_000) -> list:
        for _ in range(max_steps):
            if not self.queue and not self.active:
                break
            self.step()
        return self.finished

    @property
    def utilization(self) -> float:
        return len(self.active) / self.ecfg.max_slots

    # -- internals ----------------------------------------------------------
    def _prefill_fn(self, bucket: int) -> Callable:
        cfg = self.cfg

        def build() -> Callable:
            def fn(params, toks):
                return lm.forward_prefill(params, cfg, toks, q_chunk=min(bucket, 512))

            return jax.jit(fn)

        return api.cached_callable(("serve-prefill", self._cfg_fp, bucket), build)

    def _needs_exact_prefill(self) -> bool:
        """Right-padded prefill poisons ring windows and recurrent states;
        only pure global-attention stacks can use length buckets."""
        return any(k != "attn" for k in self.cfg.block_pattern)

    def _admit(self, req: Request, slot: int) -> None:
        n = len(req.prompt)
        bucket = n if self._needs_exact_prefill() else _bucket(
            n, self.ecfg.prefill_buckets
        )
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.prompt
        toks[0, n:] = req.prompt[-1]  # right padding (discarded below)
        logits, cache1 = self._prefill_fn(bucket)(self.params, jnp.asarray(toks))
        # insert only the first n cache entries (padding K/V discarded)
        self.cache = _insert_cache(
            self.cfg, self.cache, cache1, slot, n, bucket, self.ecfg.max_len
        )
        req.slot = slot
        self.active[slot] = req
        self.live[slot] = True
        first = self._first_token(req, n, bucket, logits)
        req.out.append(int(first))
        req.pos = n
        self.positions = self.positions.at[slot].set(n)
        self.last_token = self.last_token.at[slot].set(int(first))

    def _first_token(self, req: Request, n: int, bucket: int, padded_logits) -> int:
        """Logits at the true last prompt position.

        forward_prefill returns last-*bucket*-position logits; for padded
        prompts we rerun the last token through a single decode against
        the already-inserted cache (cheap, one token; idempotent cache
        writes for the other live slots)."""
        if bucket == n:
            return int(self._sample(padded_logits)[0])
        # other slots keep their own pending (token, pos) — their cache
        # writes are idempotent re-writes of values already present
        tok = self.last_token.at[req.slot].set(req.prompt[-1])
        pos = self.positions.at[req.slot].set(n - 1)
        logits, cache = self._decode(self.params, tok, pos, self.cache)
        self.cache = cache
        return int(self._sample(logits)[req.slot])

    def _sample(self, logits) -> np.ndarray:
        logits = np.asarray(logits, np.float32)[..., : self.cfg.vocab_size]
        if self.ecfg.greedy:
            return logits.argmax(-1)
        z = logits / max(self.ecfg.temperature, 1e-5)
        p = np.exp(z - z.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        return np.array([self.rng.choice(len(q), p=q) for q in p])


def _insert_cache(cfg, pool, cache1, slot, n, bucket, max_len):
    """Insert a single-request prefill cache (length ``bucket``, ``n``
    valid) into slot ``slot`` of the pooled cache (length ``max_len``)."""

    def ins(pool_leaf, new_leaf):
        if pool_leaf.ndim >= 3 and new_leaf.shape[0] == pool_leaf.shape[0]:
            # attention K/V: [cells, 1, T_src, ...] -> pool [cells, S, T_dst, ...]
            if new_leaf.ndim == pool_leaf.ndim and new_leaf.shape[2] != pool_leaf.shape[2]:
                T_dst = pool_leaf.shape[2]
                # prefill ring layout: position p at index p % T_src.
                # un-roll to position order, take first n, re-ring for T_dst
                T_src = new_leaf.shape[2]
                src = jnp.roll(new_leaf, -(bucket % T_src), axis=2) if bucket % T_src else new_leaf
                # src now position-ordered for the last min(T_src,bucket)
                take = min(n, T_dst, T_src)
                entries = src[:, :, :take] if n <= T_src else src[:, :, T_src - take:]
                start_pos = 0 if n <= T_dst else n - take
                dst = pool_leaf
                idx = (start_pos + jnp.arange(take)) % T_dst
                dst = dst.at[:, slot, idx].set(entries[:, 0])
                return dst
            return pool_leaf.at[:, slot].set(new_leaf[:, 0])
        return pool_leaf

    return jax.tree.map(ins, pool, cache1)
