"""Multi-tenant stencil-simulation serving engine.

The ROADMAP's "millions of users" direction: many tenants submit
``(Program, initial state, n_steps, Target)`` jobs against ONE running
service, and throughput under concurrent mixed traffic — not single-run
latency — is the figure of merit.  The design generalizes the vLLM-style
slot pool of ``serve/engine.py`` onto the PR 3 compile surface:

- **fingerprint batching** — live requests are grouped by
  ``(program.fingerprint, target.fingerprint)``; each group's engine step
  is ONE vmapped ``CompiledStencil`` call over a fixed slot pool, so the
  executable is shape-stable per bucket and compiled exactly once
  (``repro.api``'s process-wide cache, now LRU-bounded, keys it);
- **continuous admission** — requests finish at different ``n_steps``;
  a finished slot is reclaimed and refilled from the bucket's FIFO queue
  within the same engine step, so short jobs never wait on long ones;
- **epoch-aligned stepping** — a ``Target(exchange_every=k)`` bucket
  advances every live slot by one *epoch* (k time steps) per dispatch;
  ``n_steps`` must be a multiple of k (validated at submit), so deep-halo
  temporal tiling stays bitwise-correct inside the batch;
- **streaming frames** — each request can stream intermediate state back
  at a ``frame_every`` cadence via callback or pull iterator
  (``request.py``), snapshots taken at epoch boundaries;
- **metrics** — per-step utilization (live/pool), batched-vs-solo
  dispatch counts, compile-cache hit deltas, per-fingerprint queue
  depth, and per-fingerprint dispatch latency (p50/p99 wall time per
  epoch dispatch — ``metrics.py``).

Distributed targets (``target.distributed``) batch too: the engine
derives the bucket target's *slot-axis sibling* (``api.pooled_target`` —
a second mesh axis factored out of the device inventory, widest feasible
per ``tune.space.slot_width_candidates``) and dispatches the whole pool
as ONE ``shard_map`` over ``(slot, *spatial)`` per engine step.  Halo
collectives bind the spatial axis names and vmap batches through them,
so the pooled dispatch stays bitwise-equal to per-slot solo dispatches —
the ``dist_worker`` harness asserts it.  When the sibling cannot compile
(exotic backend, inventory too small) the bucket falls back to the solo
loop, now with a single batched row-commit per step instead of a
full-pool rewrite per slot.

Buckets are *elastic*: an optional ``PoolSizer`` (``config.autoscale``)
resizes capacities between steps from queue-depth/utilization EWMAs —
the resize drains the bucket to epoch-aligned checkpoints and readmits
through ``repro.resilience.migrate``, so it is bitwise-invisible to
tenants — and buckets idle past ``config.bucket_idle_steps`` retire,
freeing their pooled device arrays (``metrics.buckets_retired``).

Every request's final state is **bitwise-equal** to a solo
``compile(program, target).time_loop(state, n_steps)`` run — the batched
dispatch vmaps the very same compiled step, and stencil arithmetic is
slot-local, so XLA executes identical per-slot op sequences.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Sequence

import jax

from repro import api
from repro.obs import trace as _obs
from repro.serve.stencil.metrics import EngineMetrics, StepMetrics
from repro.serve.stencil.request import (
    DONE,
    Frame,  # noqa: F401  (re-export for tenants)
    RequestHandle,
    StencilRequest,
    now,
)
from repro.serve.stencil.scheduler import (
    PoolSizer,
    PoolSizerConfig,
    Scheduler,
    SlotPool,
)


@dataclasses.dataclass(frozen=True)
class StencilEngineConfig:
    """Engine knobs.

    ``slots_per_group`` is the *initial* pool size per fingerprint
    bucket — the batch width of the pooled dispatch.  ``history_limit``
    bounds the retained per-step metrics rows.  ``pooled_distributed``
    dispatches distributed buckets as one slot-axis ``shard_map`` call
    (the solo per-slot loop survives as fallback).  ``autoscale`` turns
    on the queue-depth ``PoolSizer`` with the given policy.
    ``bucket_idle_steps`` retires a bucket after that many consecutive
    workless engine steps, freeing its pooled arrays (0 = never).
    """

    slots_per_group: int = 4
    history_limit: int = 10_000
    pooled_distributed: bool = True
    autoscale: Optional[PoolSizerConfig] = None
    bucket_idle_steps: int = 50

    def __post_init__(self) -> None:
        if self.slots_per_group < 1:
            raise ValueError(
                f"slots_per_group must be >= 1, got {self.slots_per_group}"
            )
        if self.bucket_idle_steps < 0:
            raise ValueError(
                f"bucket_idle_steps must be >= 0, got "
                f"{self.bucket_idle_steps}"
            )


class StencilEngine:
    """Admit stencil jobs from many tenants; advance them in
    fingerprint-batched, epoch-aligned engine steps."""

    def __init__(self, config: Optional[StencilEngineConfig] = None) -> None:
        self.config = config or StencilEngineConfig()
        self.scheduler = Scheduler(self.config.slots_per_group)
        self.metrics = EngineMetrics(self.config.history_limit)
        self.sizer = (
            PoolSizer(self.config.autoscale)
            if self.config.autoscale is not None
            else None
        )
        self.finished: list[StencilRequest] = []
        self.engine_step_count = 0
        self._next_rid = 0

    # -- public API ------------------------------------------------------
    def submit(
        self,
        program,
        state: Sequence[Any],
        n_steps: int,
        target=None,
        *,
        frame_every: int = 0,
        on_frame: Optional[Callable] = None,
        tenant: Optional[str] = None,
        start_step: int = 0,
    ) -> RequestHandle:
        """Enqueue one simulation job; returns a handle immediately.

        ``state`` is the input buffers oldest → newest (exactly what
        ``CompiledStencil.time_loop`` takes).  ``n_steps`` counts single
        time steps and must be a positive multiple of the target's
        ``exchange_every`` (one engine dispatch advances a whole epoch).
        ``frame_every`` > 0 streams a state snapshot at each epoch
        boundary crossing a multiple of that cadence.  ``start_step`` > 0
        admits a *mid-run* request (the migration path: ``state`` is the
        checkpointed state at that epoch-aligned step, and the engine
        advances only the remaining ``n_steps - start_step`` steps).
        """
        target = target if target is not None else api.Target()
        compiled = api.compile(program, target)  # cache-keyed by fingerprints
        k = target.exchange_every
        if n_steps < 1:
            raise ValueError(f"n_steps must be >= 1, got {n_steps}")
        if n_steps % k != 0:
            raise ValueError(
                f"n_steps={n_steps} is not a multiple of the target's "
                f"exchange_every={k}; the engine advances whole epochs, so "
                "round the request up or pick a dividing epoch depth"
            )
        if not 0 <= start_step < n_steps or start_step % k != 0:
            raise ValueError(
                f"start_step={start_step} must be an epoch-aligned step "
                f"(multiple of {k}) strictly below n_steps={n_steps}; a "
                "migrated request resumes at the checkpointed step count"
            )
        if frame_every < 0:
            raise ValueError(f"frame_every must be >= 0, got {frame_every}")
        inputs = compiled.input_indices
        if len(state) != len(inputs):
            raise ValueError(
                f"program {program.name!r} takes {len(inputs)} input "
                f"buffer(s) (oldest → newest), got {len(state)}"
            )
        for arr, idx in zip(state, inputs):
            want = tuple(program.field_args[idx].type.bounds.shape)
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"input buffer for field "
                    f"{program.field_names[idx]!r} has shape "
                    f"{tuple(arr.shape)}, expected {want}"
                )
        req = StencilRequest(
            rid=self._next_rid,
            program=program,
            target=target,
            state=tuple(state),
            n_steps=int(n_steps),
            frame_every=int(frame_every),
            on_frame=on_frame,
            tenant=tenant,
            submitted_at=now(),
            steps_done=int(start_step),
        )
        self._next_rid += 1
        group = self.scheduler.group_for(compiled)
        self.scheduler.enqueue(group, req)
        self.metrics.requests_submitted += 1
        return RequestHandle(req)

    def step(self) -> StepMetrics:
        """One engine step: autoscale, admit, dispatch every non-empty
        bucket once (pooled — vmapped or slot-axis ``shard_map``ed — with
        a solo fallback), stream frames, reclaim + refill finished slots,
        retire idle buckets."""
        self.engine_step_count += 1
        with _obs.span("engine.step", cat="serve",
                       step=self.engine_step_count):
            return self._step_inner()

    def _step_inner(self) -> StepMetrics:
        if self.sizer is not None:
            self._autoscale()
        batched = solo = steps_advanced = 0
        live_at_dispatch = 0
        busy = set()
        for group in list(self.scheduler.groups.values()):
            self.scheduler.admit(group)
            live = sorted(group.active.items())
            live_at_dispatch += len(live)
            if not live:
                continue
            busy.add(group.key)
            bucket = f"{group.key[0]}/{group.key[1]}"
            pooled_fn = None
            if group.compiled.target.distributed:
                if self.config.pooled_distributed:
                    pooled_fn = self._pooled_fn(group)
            else:
                pooled_fn = self._pool_fn(group)
            dispatched = False
            if pooled_fn is not None:
                try:
                    with _obs.span("dispatch:pooled", cat="serve",
                                   bucket=bucket, live=len(live)):
                        t0 = time.perf_counter()
                        outs = pooled_fn(*group.state)
                        outs = outs if isinstance(outs, tuple) else (outs,)
                        jax.block_until_ready(outs)
                except Exception:
                    if not group.compiled.target.distributed:
                        raise
                    # the slot-axis sibling traced but cannot execute on
                    # this inventory — remember and fall back to solo
                    group.pooled = (group.capacity, None)
                else:
                    self.metrics.record_dispatch(
                        bucket, time.perf_counter() - t0
                    )
                    group.rotate(outs)
                    dispatched = True
                    if len(live) >= 2:
                        batched += 1
                        self.metrics.record_bucket_dispatch(bucket, True)
                    else:
                        solo += 1
                        self.metrics.record_bucket_dispatch(bucket, False)
            if not dispatched:
                # solo fallback: one shard_map call per live slot, rows
                # buffered and committed in ONE batched write per buffer
                rows = {}
                for slot, _ in live:
                    with _obs.span("dispatch:solo", cat="serve",
                                   bucket=bucket, slot=slot):
                        t0 = time.perf_counter()
                        outs = group.compiled.step()(*group.read_slot(slot))
                        outs = outs if isinstance(outs, tuple) else (outs,)
                        jax.block_until_ready(outs)
                    self.metrics.record_dispatch(
                        bucket, time.perf_counter() - t0
                    )
                    row = group.read_slot(slot)
                    rows[slot] = tuple(row[len(outs):]) + tuple(outs)
                    solo += 1
                    self.metrics.record_bucket_dispatch(bucket, False)
                group.commit_rows(rows)
            k = group.exchange_every
            for slot, req in live:
                req.steps_done += k
                steps_advanced += k
                self._stream_frames(group, req)
                if req.steps_done >= req.n_steps:
                    self._finish(group, req)
            # continuous admission: refill slots freed this very step so
            # the next dispatch runs at full width
            self.scheduler.admit(group)
        if self.config.bucket_idle_steps:
            retired = self.scheduler.retire_idle(
                self.config.bucket_idle_steps, busy
            )
            self.metrics.buckets_retired += len(retired)
        metrics = StepMetrics(
            engine_step=self.engine_step_count,
            live_slots=live_at_dispatch,
            pool_slots=self.scheduler.total_slots,
            queued=self.scheduler.total_queued,
            batched_dispatches=batched,
            solo_dispatches=solo,
            steps_advanced=steps_advanced,
            queue_depth=self.scheduler.queue_depths(),
        )
        self.metrics.record_step(metrics)
        return metrics

    def run(self, max_engine_steps: int = 100_000) -> list:
        """Drive the engine until every submitted request finished (or the
        step budget runs out); returns the requests that finished during
        THIS call — ``self.finished`` keeps the engine-lifetime history,
        but a second ``run()`` must not re-report the first one's work."""
        first = len(self.finished)
        for _ in range(max_engine_steps):
            if not self.pending:
                break
            self.step()
        return self.finished[first:]

    @property
    def pending(self) -> int:
        """Requests admitted or queued but not yet finished."""
        return self.scheduler.total_live + self.scheduler.total_queued

    # -- migration (repro.resilience.migrate) ----------------------------
    def evacuate(self, program_fingerprint: str, directory: str) -> list:
        """Drain every request of ``program_fingerprint`` to epoch-aligned
        checkpoints under ``directory`` and release their slots — the
        serve layer's request-migration primitive: a second engine picks
        them up mid-run with ``admit_evacuated``, and each request's
        final state stays bitwise-equal to an unmigrated run."""
        from repro.resilience.migrate import evacuate as _evacuate

        with _obs.span("engine.evacuate", cat="serve",
                       program=program_fingerprint):
            evacuated = _evacuate(self, program_fingerprint, directory)
        if evacuated:
            _obs.instant("evacuated", cat="serve", count=len(evacuated))
        return evacuated

    def admit_evacuated(self, directory: str, programs, target=None) -> list:
        """Admit the requests another engine evacuated into ``directory``;
        ``programs`` maps checkpoint fingerprints back to live ``Program``
        objects, and ``target`` optionally re-targets every admitted
        request (e.g. onto this engine's mesh).  Returns new handles."""
        from repro.resilience.migrate import admit as _admit

        with _obs.span("engine.admit_evacuated", cat="serve"):
            admitted = _admit(self, directory, programs, target=target)
        if admitted:
            _obs.instant("admitted", cat="serve", count=len(admitted))
        return admitted

    @property
    def utilization(self) -> float:
        return self.scheduler.total_live / max(1, self.scheduler.total_slots)

    # -- elasticity ------------------------------------------------------
    def resize_bucket(
        self, group: SlotPool, new_capacity: int,
        directory: Optional[str] = None,
    ) -> None:
        """Rebuild ``group``'s pool at ``new_capacity`` through the
        migration path: drain every active request to an epoch-aligned
        checkpoint, reallocate the pool arrays at the new width, readmit
        the same request objects at the queue front.  Bitwise-invisible
        to tenants by PR 8's migration contract — the checkpointed state
        is exact, admission rewrites it into a (new) slot, and frame
        cadence continues from the preserved ``steps_done``."""
        import shutil
        import tempfile

        from repro.resilience.migrate import drain_group, readmit_group

        tmp = directory or tempfile.mkdtemp(prefix="repro-pool-resize-")
        try:
            drained = drain_group(self, group, tmp)
            group.rebuild(int(new_capacity))
            readmit_group(self, group, tmp, drained)
        finally:
            if directory is None:
                shutil.rmtree(tmp, ignore_errors=True)

    def _autoscale(self) -> None:
        for group in list(self.scheduler.groups.values()):
            decision = self.sizer.observe(group)
            if decision is None:
                continue
            new_capacity, provenance = decision
            bucket = f"{group.key[0]}/{group.key[1]}"
            with _obs.span("pool.resize", cat="serve", bucket=bucket,
                           action=provenance.get("action"),
                           to_capacity=int(new_capacity)):
                self.resize_bucket(group, new_capacity)
            provenance["engine_step"] = self.engine_step_count
            self.metrics.record_autoscale(provenance)

    # -- internals -------------------------------------------------------
    def _pool_fn(self, group: SlotPool) -> Callable:
        """The bucket's shape-stable pool executable: ONE jitted vmap of
        the compiled step over the slot axis, cached process-wide on the
        same fingerprints the compile cache uses — a second engine (or a
        restarted one) over the same traffic re-traces nothing."""
        compiled = group.compiled
        key = (
            "serve-stencil",
            compiled.program.fingerprint,
            compiled.target.fingerprint,
            group.capacity,
        )
        return api.cached_callable(
            key, lambda: jax.jit(jax.vmap(compiled.step()))
        )

    def _pooled_fn(self, group: SlotPool) -> Optional[Callable]:
        """The distributed bucket's ONE-dispatch executable: the compiled
        step of the target's slot-axis sibling (``api.pooled_target``),
        taking the whole ``[capacity, *shape]`` pool per buffer.  The
        slot width is the widest feasible for this inventory
        (``tune.space.slot_width_candidates``; width 1 still pools — the
        inner vmap batches within each spatial shard).  Memoized on the
        group per pool width; ``None`` when the sibling cannot compile,
        which routes the bucket to the solo fallback loop."""
        if group.pooled is not None and group.pooled[0] == group.capacity:
            compiled = group.pooled[1]
            return None if compiled is None else compiled.step()
        from repro.tune.space import slot_width_candidates

        target = group.compiled.target
        compiled = None
        try:
            width = slot_width_candidates(
                len(jax.devices()), target.spatial_ranks, group.capacity
            )[0]
            pooled = api.pooled_target(target, slots=width)
            compiled = api.compile(group.compiled.program, pooled)
        except Exception:
            compiled = None
        group.pooled = (group.capacity, compiled)
        return None if compiled is None else compiled.step()

    def _stream_frames(self, group: SlotPool, req: StencilRequest) -> None:
        if req.frame_every <= 0:
            return
        emitted = False
        while req.next_frame_at and req.steps_done >= req.next_frame_at:
            req.next_frame_at += req.frame_every
            emitted = True
        if emitted and req.steps_done < req.n_steps:
            # one snapshot per engine step at most — the state only
            # changes at epoch boundaries, so coalescing crossed marks
            # into the boundary snapshot is the honest cadence
            req.emit_frame(group.read_slot(req.slot))
            self.metrics.frames_emitted += 1

    def _finish(self, group: SlotPool, req: StencilRequest) -> None:
        req.result = group.read_slot(req.slot)
        req.status = DONE
        req.finished_at = now()
        if req.frame_every and req.n_steps % req.frame_every == 0:
            # final-state frame when the cadence lands exactly on n_steps
            req.emit_frame(req.result)
            self.metrics.frames_emitted += 1
        self.finished.append(req)
        self.metrics.requests_completed += 1
        self.scheduler.reclaim(group, req.slot)
