"""Multi-tenant stencil-simulation serving (fingerprint-batched slot pools).

See ``engine.py`` for the execution model and DESIGN.md §9 for the
design rationale.
"""
from repro.serve.stencil.engine import (  # noqa: F401
    StencilEngine,
    StencilEngineConfig,
)
from repro.serve.stencil.metrics import EngineMetrics, StepMetrics  # noqa: F401
from repro.serve.stencil.request import (  # noqa: F401
    DONE,
    QUEUED,
    RUNNING,
    Frame,
    RequestHandle,
    StencilRequest,
)
from repro.serve.stencil.scheduler import (  # noqa: F401
    PoolSizer,
    PoolSizerConfig,
    Scheduler,
    SlotPool,
)
