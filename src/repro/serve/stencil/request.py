"""Requests, frames and handles for the stencil-serving engine.

A *request* is one tenant's simulation job: ``(Program, initial state,
n_steps, Target)``.  The engine advances it inside a fingerprint-batched
slot pool (``scheduler.py``); the tenant watches progress through a
``RequestHandle`` — intermediate *frames* stream back at a configurable
``frame_every`` cadence (per-request callback and/or a pull iterator),
and ``result()`` is the final state, bitwise-equal to a solo
``compile(program, target).time_loop(state, n_steps)`` run.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Iterator, Optional

import numpy as np

# request lifecycle: queued → running → done, with an exit ramp:
# a request drained to a checkpoint by StencilEngine.evacuate (it no
# longer occupies this engine; a second engine admits it mid-run)
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
EVACUATED = "evacuated"


@dataclasses.dataclass(frozen=True)
class Frame:
    """One streamed snapshot of a request's state.

    ``step`` is the number of *time steps* completed when the frame was
    taken (always an epoch boundary of the request's target, so with
    ``Target(exchange_every=k)`` frames land on multiples of k);
    ``arrays`` is the full state tuple, oldest → newest, as host arrays.
    """

    rid: int
    step: int
    arrays: tuple


@dataclasses.dataclass
class StencilRequest:
    """One admitted simulation job plus its runtime bookkeeping."""

    rid: int
    program: Any               # repro.api.Program
    target: Any                # repro.api.Target
    state: tuple               # input arrays, oldest → newest
    n_steps: int
    frame_every: int = 0       # 0 = no intermediate frames
    on_frame: Optional[Callable[[Frame], None]] = None
    tenant: Optional[str] = None

    # runtime state (owned by the scheduler/engine)
    steps_done: int = 0
    slot: int = -1
    status: str = QUEUED
    result: Optional[tuple] = None
    submitted_at: float = 0.0
    started_at: float = 0.0
    finished_at: float = 0.0
    next_frame_at: int = 0
    frames_emitted: int = 0
    _frames: deque = dataclasses.field(default_factory=deque)

    @property
    def done(self) -> bool:
        return self.status == DONE

    @property
    def latency_s(self) -> float:
        """Submit-to-finish wall-clock seconds (0.0 until done)."""
        if not self.done:
            return 0.0
        return self.finished_at - self.submitted_at

    def emit_frame(self, arrays: tuple) -> None:
        frame = Frame(
            rid=self.rid,
            step=self.steps_done,
            arrays=tuple(np.asarray(a) for a in arrays),
        )
        self.frames_emitted += 1
        if self.on_frame is not None:
            self.on_frame(frame)
        else:
            # buffered for the pull iterator only when nobody consumes
            # frames eagerly — an unread callback stream must not grow
            self._frames.append(frame)


class RequestHandle:
    """The tenant's view of a submitted request."""

    def __init__(self, request: StencilRequest) -> None:
        self._req = request

    @property
    def rid(self) -> int:
        return self._req.rid

    @property
    def status(self) -> str:
        return self._req.status

    @property
    def done(self) -> bool:
        return self._req.done

    @property
    def steps_done(self) -> int:
        return self._req.steps_done

    @property
    def latency_s(self) -> float:
        return self._req.latency_s

    def frames(self) -> Iterator[Frame]:
        """Drain buffered frames (frames delivered to an ``on_frame``
        callback are not re-buffered here)."""
        while self._req._frames:
            yield self._req._frames.popleft()

    def result(self) -> tuple:
        """Final state (oldest → newest) after ``n_steps``; raises if the
        request has not finished — drive the engine (``step()``/``run()``)
        first."""
        if not self._req.done:
            raise RuntimeError(
                f"request {self.rid} is {self._req.status} "
                f"({self._req.steps_done}/{self._req.n_steps} steps); "
                "run the engine to completion before reading the result"
            )
        return self._req.result

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"RequestHandle(rid={self.rid}, status={self.status!r}, "
            f"steps={self._req.steps_done}/{self._req.n_steps})"
        )


def now() -> float:
    return time.perf_counter()
