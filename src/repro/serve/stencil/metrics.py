"""Utilization and dispatch accounting for the stencil-serving engine.

Per engine step the engine records a ``StepMetrics`` row (live slots over
pool size, batched vs solo dispatch counts, per-fingerprint queue depth);
``EngineMetrics`` aggregates them and folds in the process-wide compile
cache counters (``repro.api.cache_stats``) as deltas since the engine was
constructed, so a serving process can see exactly how many compiles its
traffic caused vs reused.

Dispatch *latency* is tracked per fingerprint bucket too: every timed
dispatch records wall seconds under its "program_fp/target_fp" key (the
same keys ``queue_depth`` uses), and ``step_latency()`` summarizes each
bucket as p50/p99/mean — so a ``fused_epoch=True`` target's one-kernel
epoch is directly comparable against its unfused sibling in the same
``serve_load.json`` snapshot.
"""
from __future__ import annotations

import dataclasses
import weakref
from collections import deque
from typing import Optional

from repro import api

# Live EngineMetrics instances, for the process-wide ``serve.*`` view in
# ``repro.obs.snapshot()``.  A weak set: a retired engine's metrics are
# garbage like the engine itself — aggregation only ever sums the living.
_LIVE: "weakref.WeakSet" = weakref.WeakSet()


def global_counters() -> dict:
    """Summed counters over every live engine in this process — the
    ``serve`` namespace of ``repro.obs.snapshot()``.  Per-instance
    ``EngineMetrics`` objects stay the source of truth; this is a read."""
    fields = (
        "requests_submitted", "requests_completed", "requests_evacuated",
        "requests_resumed", "frames_emitted", "steps_advanced",
        "batched_dispatches", "solo_dispatches", "kernel_dispatches",
        "buckets_retired", "pool_grows", "pool_shrinks",
    )
    out = {f: 0 for f in fields}
    engines = 0
    for m in list(_LIVE):
        engines += 1
        for f in fields:
            out[f] += getattr(m, f)
    out["engines"] = engines
    return out


@dataclasses.dataclass(frozen=True)
class StepMetrics:
    """One engine step's snapshot."""

    engine_step: int
    live_slots: int
    pool_slots: int
    queued: int
    batched_dispatches: int   # dispatches batching >= 2 live requests
    solo_dispatches: int      # dispatches advancing exactly 1 request
    steps_advanced: int       # time steps advanced, summed over requests
    queue_depth: dict         # "program_fp/target_fp" -> waiting requests

    @property
    def utilization(self) -> float:
        """Live slots over pool slots for this step (0.0 on an idle
        engine with no groups yet)."""
        return self.live_slots / self.pool_slots if self.pool_slots else 0.0


class EngineMetrics:
    """Aggregated engine counters plus a bounded step history."""

    def __init__(self, history_limit: int = 10_000) -> None:
        self.history: deque = deque(maxlen=int(history_limit))
        self.batched_dispatches = 0
        self.solo_dispatches = 0
        self.requests_submitted = 0
        self.requests_completed = 0
        self.requests_evacuated = 0   # drained to checkpoints (migration out)
        self.requests_resumed = 0     # admitted from checkpoints (migration in)
        self.frames_emitted = 0
        self.steps_advanced = 0
        self.kernel_dispatches = 0    # total timed dispatches (kernel launches)
        self.buckets_retired = 0      # idle buckets whose pools were freed
        self.pool_grows = 0
        self.pool_shrinks = 0
        self.autoscale_events: list = []   # PoolSizer provenance dicts
        # "program_fp/target_fp" -> {"batched": n, "solo": n} — the
        # per-bucket proof that a distributed bucket dispatched pooled
        self.bucket_dispatches: dict = {}
        # "program_fp/target_fp" -> bounded deque of dispatch wall seconds
        self.step_seconds: dict = {}
        self._latency_limit = int(history_limit)
        stats = api.cache_stats()
        self._cache_baseline = stats.as_dict()
        _LIVE.add(self)

    # -- recording (engine-internal) ------------------------------------
    def record_step(self, step: StepMetrics) -> None:
        self.history.append(step)
        self.batched_dispatches += step.batched_dispatches
        self.solo_dispatches += step.solo_dispatches
        self.steps_advanced += step.steps_advanced

    def record_dispatch(self, key: str, seconds: float) -> None:
        """One timed dispatch (batched or solo) for the fingerprint
        bucket ``key`` ("program_fp/target_fp"); the per-bucket window is
        bounded like the step history."""
        times = self.step_seconds.get(key)
        if times is None:
            times = self.step_seconds[key] = deque(maxlen=self._latency_limit)
        times.append(float(seconds))
        self.kernel_dispatches += 1

    def record_bucket_dispatch(self, key: str, batched: bool) -> None:
        """Per-bucket batched/solo tally — a ≥2-live distributed bucket
        on the pooled path must show ``batched > 0, solo == 0``."""
        d = self.bucket_dispatches.setdefault(key, {"batched": 0, "solo": 0})
        d["batched" if batched else "solo"] += 1

    def record_autoscale(self, event: dict) -> None:
        """One PoolSizer resize decision, with its queue/utilization
        provenance (the event dict ``PoolSizer.observe`` returned)."""
        self.autoscale_events.append(dict(event))
        if len(self.autoscale_events) > self._latency_limit:
            del self.autoscale_events[0]
        if event.get("action") == "grow":
            self.pool_grows += 1
        else:
            self.pool_shrinks += 1

    # -- reporting -------------------------------------------------------
    @property
    def engine_steps(self) -> int:
        return len(self.history)

    def mean_utilization(self) -> float:
        """Mean live/pool over the recorded (non-idle-pool) history."""
        rows = [m for m in self.history if m.pool_slots]
        if not rows:
            return 0.0
        return sum(m.utilization for m in rows) / len(rows)

    def step_latency(self) -> dict:
        """Per-fingerprint dispatch latency: key ->
        {"count", "mean_s", "p50_s", "p99_s", "max_s"} over the recorded
        window.  One dispatch advances a whole epoch (``exchange_every``
        time steps) for every live slot in the bucket.  Degenerate
        windows are well-defined: an empty window reports all-zero
        latencies with ``count: 0`` (instead of vanishing from the
        snapshot), and a single sample is its own p50/p99/max."""
        out = {}
        for key, times in self.step_seconds.items():
            ordered = sorted(times)
            if not ordered:
                out[key] = {"count": 0, "mean_s": 0.0, "p50_s": 0.0,
                            "p99_s": 0.0, "max_s": 0.0}
                continue
            out[key] = {
                "count": len(ordered),
                "mean_s": sum(ordered) / len(ordered),
                "p50_s": _quantile(ordered, 0.50),
                "p99_s": _quantile(ordered, 0.99),
                "max_s": ordered[-1],
            }
        return out

    def compile_cache(self) -> dict:
        """Process-wide compile-cache counters as deltas since this
        engine was constructed (hits = artifact/executable reuse across
        this engine's traffic)."""
        stats = api.cache_stats().as_dict()
        return {
            k: stats[k] - self._cache_baseline.get(k, 0) for k in stats
        }

    def snapshot(self, last: Optional[StepMetrics] = None) -> dict:
        last = last or (self.history[-1] if self.history else None)
        return {
            "engine_steps": self.engine_steps,
            "requests_submitted": self.requests_submitted,
            "requests_completed": self.requests_completed,
            "requests_evacuated": self.requests_evacuated,
            "requests_resumed": self.requests_resumed,
            "frames_emitted": self.frames_emitted,
            "steps_advanced": self.steps_advanced,
            "batched_dispatches": self.batched_dispatches,
            "solo_dispatches": self.solo_dispatches,
            "kernel_dispatches": self.kernel_dispatches,
            "buckets_retired": self.buckets_retired,
            "bucket_dispatches": {
                k: dict(v) for k, v in self.bucket_dispatches.items()
            },
            "autoscale": {
                "grows": self.pool_grows,
                "shrinks": self.pool_shrinks,
                "events": [dict(e) for e in self.autoscale_events],
            },
            "mean_utilization": self.mean_utilization(),
            "compile_cache": self.compile_cache(),
            "queue_depth": dict(last.queue_depth) if last else {},
            "step_latency": self.step_latency(),
        }


def _quantile(ordered: list, q: float) -> float:
    """Linear-interpolated quantile of a pre-sorted non-empty list."""
    if len(ordered) == 1:
        return ordered[0]
    pos = q * (len(ordered) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(ordered) - 1)
    frac = pos - lo
    return ordered[lo] * (1.0 - frac) + ordered[hi] * frac
