"""Fingerprint-bucketed slot pools and admission for the stencil engine.

The vLLM-style slot-pool ideas from ``serve/engine.py`` (fixed pool,
shape-stable executables, continuous admission) applied to stencil jobs:

- live requests are grouped by **compile fingerprint**
  ``(program.fingerprint, target.fingerprint)`` — the same key the
  process-wide ``repro.api`` compile cache uses, so every member of a
  group shares one ``CompiledStencil`` and (non-distributed) one vmapped
  pool executable;
- each group owns a fixed pool of ``capacity`` slots; the pooled state is
  one array of shape ``[capacity, *field_shape]`` per input buffer, so
  the batched dispatch is shape-stable regardless of how many slots are
  live (dead slots compute garbage that is never read);
- admission writes a request's initial state into its slot's rows;
  reclaim frees the slot the moment the request's ``n_steps`` are done,
  so a long request never stalls the short ones behind it.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import jax.numpy as jnp

from repro.serve.stencil.request import QUEUED, RUNNING, StencilRequest, now


@dataclasses.dataclass
class SlotPool:
    """One fingerprint bucket: compiled artifact + fixed slot pool."""

    key: tuple                  # (program fp, target fp)
    compiled: Any               # repro.api.CompiledStencil
    capacity: int
    state: tuple = ()           # per input buffer: [capacity, *shape]
    free: list = dataclasses.field(default_factory=list)
    active: dict = dataclasses.field(default_factory=dict)  # slot -> request
    queue: deque = dataclasses.field(default_factory=deque)

    def __post_init__(self) -> None:
        self.free = list(range(self.capacity))
        if not self.state:
            prog = self.compiled.program
            self.state = tuple(
                jnp.zeros(
                    (self.capacity,)
                    + tuple(prog.field_args[i].type.bounds.shape),
                    jnp.float32,
                )
                for i in self.compiled.input_indices
            )

    @property
    def live(self) -> int:
        return len(self.active)

    @property
    def exchange_every(self) -> int:
        return self.compiled.target.exchange_every

    # -- slot state ------------------------------------------------------
    def write_slot(self, slot: int, arrays) -> None:
        self.state = tuple(
            ps.at[slot].set(jnp.asarray(a, ps.dtype))
            for ps, a in zip(self.state, arrays)
        )

    def read_slot(self, slot: int) -> tuple:
        return tuple(ps[slot] for ps in self.state)

    def rotate(self, outs: tuple) -> None:
        """Pool-wide time-buffer rotation after one batched epoch —
        identical shape to ``api.time_loop``: state' = state[len(outs):]
        + outs, each leaf carrying the slot axis in front."""
        self.state = tuple(self.state[len(outs):]) + tuple(outs)

    def rotate_slot(self, slot: int, outs: tuple) -> None:
        """Per-slot rotation for solo (distributed-target) dispatches."""
        row = self.read_slot(slot)
        new_row = tuple(row[len(outs):]) + tuple(outs)
        self.write_slot(slot, new_row)


class Scheduler:
    """Admission + reclaim over all fingerprint buckets (FIFO per bucket)."""

    def __init__(self, slots_per_group: int) -> None:
        self.slots_per_group = int(slots_per_group)
        self.groups: dict[tuple, SlotPool] = {}

    def group_for(self, compiled, capacity: Optional[int] = None) -> SlotPool:
        key = (compiled.program.fingerprint, compiled.target.fingerprint)
        group = self.groups.get(key)
        if group is None:
            group = SlotPool(
                key=key,
                compiled=compiled,
                capacity=int(capacity or self.slots_per_group),
            )
            self.groups[key] = group
        return group

    def enqueue(self, group: SlotPool, request: StencilRequest) -> None:
        request.status = QUEUED
        group.queue.append(request)

    def admit(self, group: SlotPool) -> list:
        """Move queued requests into free slots (FIFO); returns the newly
        admitted requests.  Called at the top of every engine step and
        again right after reclaim, so a freed slot is refilled within the
        same engine step — continuous admission."""
        admitted = []
        while group.queue and group.free:
            req = group.queue.popleft()
            slot = group.free.pop(0)
            req.slot = slot
            req.status = RUNNING
            req.started_at = now()
            # next cadence mark strictly after the steps already done —
            # a migrated request (steps_done > 0 at admission) continues
            # its frame schedule instead of restarting it
            req.next_frame_at = (
                req.frame_every * (req.steps_done // req.frame_every + 1)
                if req.frame_every
                else 0
            )
            group.write_slot(slot, req.state)
            group.active[slot] = req
            admitted.append(req)
        return admitted

    def reclaim(self, group: SlotPool, slot: int) -> None:
        """Free a finished request's slot for immediate reuse."""
        del group.active[slot]
        group.free.append(slot)

    # -- introspection ---------------------------------------------------
    def queue_depths(self) -> dict:
        return {
            f"{k[0]}/{k[1]}": len(g.queue) for k, g in self.groups.items()
        }

    @property
    def total_live(self) -> int:
        return sum(g.live for g in self.groups.values())

    @property
    def total_slots(self) -> int:
        return sum(g.capacity for g in self.groups.values())

    @property
    def total_queued(self) -> int:
        return sum(len(g.queue) for g in self.groups.values())
