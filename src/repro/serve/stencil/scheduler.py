"""Fingerprint-bucketed slot pools and admission for the stencil engine.

The vLLM-style slot-pool ideas from ``serve/engine.py`` (fixed pool,
shape-stable executables, continuous admission) applied to stencil jobs:

- live requests are grouped by **compile fingerprint**
  ``(program.fingerprint, target.fingerprint)`` — the same key the
  process-wide ``repro.api`` compile cache uses, so every member of a
  group shares one ``CompiledStencil`` and (non-distributed) one vmapped
  pool executable;
- each group owns a fixed pool of ``capacity`` slots; the pooled state is
  one array of shape ``[capacity, *field_shape]`` per input buffer, so
  the batched dispatch is shape-stable regardless of how many slots are
  live (dead slots compute garbage that is never read);
- admission writes a request's initial state into its slot's rows;
  reclaim frees the slot the moment the request's ``n_steps`` are done,
  so a long request never stalls the short ones behind it;
- buckets are *elastic*: a ``PoolSizer`` policy resizes ``capacity``
  between engine steps from queue-depth / utilization EWMAs (the engine
  drains + readmits through the migration checkpointing path, so resizes
  stay bitwise-invisible), and a bucket that stays idle past a threshold
  is retired — its pooled ``[capacity, *shape]`` arrays freed — so a
  serving process's memory tracks its *live* traffic, not every
  fingerprint it has ever seen.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import jax.numpy as jnp

from repro.serve.stencil.request import QUEUED, RUNNING, StencilRequest, now


@dataclasses.dataclass
class SlotPool:
    """One fingerprint bucket: compiled artifact + fixed slot pool."""

    key: tuple                  # (program fp, target fp)
    compiled: Any               # repro.api.CompiledStencil
    capacity: int
    state: tuple = ()           # per input buffer: [capacity, *shape]
    free: list = dataclasses.field(default_factory=list)
    active: dict = dataclasses.field(default_factory=dict)  # slot -> request
    queue: deque = dataclasses.field(default_factory=deque)
    idle_steps: int = 0         # consecutive engine steps with no work
    # (capacity, CompiledStencil|None): the slot-axis pooled sibling for a
    # distributed target, memoized per pool width (None = not factorable)
    pooled: Optional[tuple] = None

    def __post_init__(self) -> None:
        self.free = list(range(self.capacity))
        if not self.state:
            prog = self.compiled.program
            self.state = tuple(
                jnp.zeros(
                    (self.capacity,)
                    + tuple(prog.field_args[i].type.bounds.shape),
                    jnp.float32,
                )
                for i in self.compiled.input_indices
            )

    @property
    def live(self) -> int:
        return len(self.active)

    @property
    def exchange_every(self) -> int:
        return self.compiled.target.exchange_every

    # -- slot state ------------------------------------------------------
    def write_slot(self, slot: int, arrays) -> None:
        self.state = tuple(
            ps.at[slot].set(jnp.asarray(a, ps.dtype))
            for ps, a in zip(self.state, arrays)
        )

    def read_slot(self, slot: int) -> tuple:
        return tuple(ps[slot] for ps in self.state)

    def rotate(self, outs: tuple) -> None:
        """Pool-wide time-buffer rotation after one batched epoch —
        identical shape to ``api.time_loop``: state' = state[len(outs):]
        + outs, each leaf carrying the slot axis in front."""
        self.state = tuple(self.state[len(outs):]) + tuple(outs)

    def rotate_slot(self, slot: int, outs: tuple) -> None:
        """Per-slot rotation for solo (distributed-target) dispatches."""
        row = self.read_slot(slot)
        new_row = tuple(row[len(outs):]) + tuple(outs)
        self.write_slot(slot, new_row)

    def commit_rows(self, rows: dict) -> None:
        """Batched commit of per-slot rows: ONE ``.at[idx].set`` per
        input buffer instead of a full-pool rewrite per slot — the solo
        dispatch loop buffers each slot's rotated row here and commits
        once, turning O(capacity²) memory traffic per engine step back
        into O(capacity)."""
        if not rows:
            return
        slots = sorted(rows)
        idx = jnp.asarray(slots)
        self.state = tuple(
            ps.at[idx].set(
                jnp.stack([jnp.asarray(rows[s][b], ps.dtype) for s in slots])
            )
            for b, ps in enumerate(self.state)
        )

    # -- elasticity ------------------------------------------------------
    def rebuild(self, new_capacity: int) -> None:
        """Reallocate the pool at ``new_capacity`` (resize path).  Only
        legal on a drained pool — the engine checkpoints every active
        request out first, rebuilds, then readmits through the queue."""
        if self.active:
            raise RuntimeError(
                f"rebuild of bucket {self.key[0][:12]}… with "
                f"{len(self.active)} active slots; drain it first"
            )
        self.capacity = int(new_capacity)
        self.state = ()
        self.pooled = None  # pool width changed; re-factor the slot axis
        self.__post_init__()

    def release(self) -> None:
        """Drop the pooled device arrays (retirement path)."""
        self.state = ()
        self.free = []
        self.pooled = None


class Scheduler:
    """Admission + reclaim over all fingerprint buckets (FIFO per bucket)."""

    def __init__(self, slots_per_group: int) -> None:
        self.slots_per_group = int(slots_per_group)
        self.groups: dict[tuple, SlotPool] = {}

    def group_for(self, compiled, capacity: Optional[int] = None) -> SlotPool:
        key = (compiled.program.fingerprint, compiled.target.fingerprint)
        group = self.groups.get(key)
        if group is None:
            group = SlotPool(
                key=key,
                compiled=compiled,
                capacity=int(capacity or self.slots_per_group),
            )
            self.groups[key] = group
        return group

    def enqueue(self, group: SlotPool, request: StencilRequest) -> None:
        request.status = QUEUED
        group.queue.append(request)

    def admit(self, group: SlotPool) -> list:
        """Move queued requests into free slots (FIFO); returns the newly
        admitted requests.  Called at the top of every engine step and
        again right after reclaim, so a freed slot is refilled within the
        same engine step — continuous admission."""
        admitted = []
        while group.queue and group.free:
            req = group.queue.popleft()
            slot = group.free.pop(0)
            req.slot = slot
            req.status = RUNNING
            req.started_at = now()
            # next cadence mark strictly after the steps already done —
            # a migrated request (steps_done > 0 at admission) continues
            # its frame schedule instead of restarting it
            req.next_frame_at = (
                req.frame_every * (req.steps_done // req.frame_every + 1)
                if req.frame_every
                else 0
            )
            group.write_slot(slot, req.state)
            group.active[slot] = req
            admitted.append(req)
        return admitted

    def reclaim(self, group: SlotPool, slot: int) -> None:
        """Free a finished request's slot for immediate reuse."""
        del group.active[slot]
        group.free.append(slot)

    def retire_idle(self, idle_limit: int, busy=()) -> list:
        """Retire buckets idle (no active slots, empty queue, and not in
        ``busy`` — keys that dispatched this very step) for
        ``idle_limit`` consecutive engine steps: release their pooled
        device arrays and drop them from ``groups``, so ``total_slots``
        and ``utilization`` reflect only live traffic.  Returns the
        retired bucket keys.  A retired fingerprint that returns later
        simply gets a fresh bucket from ``group_for``."""
        retired = []
        for key, group in list(self.groups.items()):
            if group.active or group.queue or key in busy:
                group.idle_steps = 0
                continue
            group.idle_steps += 1
            if group.idle_steps >= idle_limit:
                group.release()
                del self.groups[key]
                retired.append(key)
        return retired

    # -- introspection ---------------------------------------------------
    def queue_depths(self) -> dict:
        return {
            f"{k[0]}/{k[1]}": len(g.queue) for k, g in self.groups.items()
        }

    @property
    def total_live(self) -> int:
        return sum(g.live for g in self.groups.values())

    @property
    def total_slots(self) -> int:
        return sum(g.capacity for g in self.groups.values())

    @property
    def total_queued(self) -> int:
        return sum(len(g.queue) for g in self.groups.values())


# --------------------------------------------------------------------------
# queue-depth autoscaling policy
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PoolSizerConfig:
    """Knobs for the queue-depth autoscaler.

    Grow when the *queued-per-slot* EWMA exceeds ``grow_queue_per_slot``
    (demand outruns the pool); shrink when the utilization EWMA falls
    below ``shrink_utilization`` with an empty queue (pool outruns
    demand).  ``cooldown_steps`` of hysteresis follow every resize —
    each resize re-specializes the bucket's pooled executable (the
    compile cache keys on pool width), so back-to-back flapping would
    thrash the cache for no throughput win.
    """

    min_capacity: int = 1
    max_capacity: int = 64
    grow_queue_per_slot: float = 0.5
    shrink_utilization: float = 0.25
    grow_factor: float = 2.0
    shrink_factor: float = 0.5
    ewma_alpha: float = 0.5
    cooldown_steps: int = 3

    def __post_init__(self) -> None:
        if not 1 <= self.min_capacity <= self.max_capacity:
            raise ValueError(
                f"need 1 <= min_capacity <= max_capacity, got "
                f"[{self.min_capacity}, {self.max_capacity}]"
            )
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha in (0, 1], got {self.ewma_alpha}")
        if self.grow_factor <= 1.0 or not 0.0 < self.shrink_factor < 1.0:
            raise ValueError(
                f"need grow_factor > 1 and 0 < shrink_factor < 1, got "
                f"{self.grow_factor}/{self.shrink_factor}"
            )


class PoolSizer:
    """Per-bucket capacity policy driven by queue-depth and utilization
    EWMAs.  ``observe(group)`` is called once per engine step per bucket;
    it returns ``(new_capacity, provenance)`` when the bucket should
    resize (the engine then drains → rebuilds → readmits) or ``None`` to
    hold.  Provenance carries the EWMAs and raw signals that justified
    the decision — the serve_load benchmark records it verbatim."""

    def __init__(self, config: Optional[PoolSizerConfig] = None) -> None:
        self.config = config or PoolSizerConfig()
        self._queue_ewma: dict = {}
        self._util_ewma: dict = {}
        self._cooldown: dict = {}

    def observe(self, group: SlotPool) -> Optional[tuple]:
        cfg = self.config
        key = group.key
        a = cfg.ewma_alpha
        queued_per_slot = len(group.queue) / max(1, group.capacity)
        util = group.live / max(1, group.capacity)
        qe = self._queue_ewma[key] = a * queued_per_slot + (1.0 - a) * (
            self._queue_ewma.get(key, queued_per_slot)
        )
        ue = self._util_ewma[key] = a * util + (1.0 - a) * (
            self._util_ewma.get(key, util)
        )
        cooling = self._cooldown.get(key, 0)
        if cooling > 0:
            self._cooldown[key] = cooling - 1
            return None
        cap = group.capacity
        new = action = None
        if qe > cfg.grow_queue_per_slot and cap < cfg.max_capacity:
            new = min(
                cfg.max_capacity,
                max(cap + 1, int(round(cap * cfg.grow_factor))),
            )
            action = "grow"
        elif (
            ue < cfg.shrink_utilization
            and not group.queue
            and (group.live or group.active)  # idle buckets retire instead
            and cap > max(cfg.min_capacity, group.live)
        ):
            new = max(
                cfg.min_capacity,
                group.live,
                int(round(cap * cfg.shrink_factor)),
            )
            action = "shrink"
        if new is None or new == cap:
            return None
        self._cooldown[key] = cfg.cooldown_steps
        return new, {
            "action": action,
            "bucket": f"{key[0]}/{key[1]}",
            "from_capacity": cap,
            "to_capacity": new,
            "queue_depth": len(group.queue),
            "live": group.live,
            "queue_ewma": qe,
            "utilization_ewma": ue,
        }
