from repro.serve.engine import Engine, EngineConfig, Request  # noqa: F401
from repro.serve.stencil import (  # noqa: F401
    Frame,
    RequestHandle,
    StencilEngine,
    StencilEngineConfig,
    StencilRequest,
)
