from repro.serve.engine import Engine, EngineConfig, Request  # noqa: F401
