"""Three DSL frontends sharing one compilation stack (paper fig. 1b).

- ``devito_like``   — symbolic finite differences (Grid/TimeFunction/Eq);
- ``psyclone_like`` — loop-nest kernels with *stencil recognition*;
- ``oec_like``      — direct stencil-dialect construction.

All three emit the same ``stencil`` IR as a ``repro.api.Program``
(``Operator.program`` / ``recognize(...)`` / ``ProgramBuilder.finish()``)
and compile through the one shared surface ``repro.api.compile(program,
target)``.
"""
