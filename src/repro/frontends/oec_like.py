"""Open-Earth-Compiler-like frontend: direct stencil-dialect construction
(the paper's third DSL reuses the stencil IR as its own input level).

    p = ProgramBuilder("jacobi", shape=(64, 64))
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply([t], lambda b, u: (u.at(-1, 0) + u.at(1, 0)
                                   + u.at(0, -1) + u.at(0, 1)) * 0.25)
    p.store(r, out)
    prog = p.finish(boundary="periodic")          # repro.api.Program
    step = repro.api.compile(prog, target)
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from repro.api import Program
from repro.core import ir
from repro.core.builder import build_apply
from repro.core.dialects import stencil


class ProgramBuilder:
    def __init__(self, name: str, shape: Sequence[int]):
        self.name = name
        self.shape = tuple(shape)
        self.core = stencil.Bounds.from_shape(self.shape)
        self._arg_types: list = []
        self._arg_names: list[str] = []
        self._pending: list[Callable[[ir.FuncOp], None]] = []
        self._finished: Optional[ir.FuncOp] = None
        self._handles: dict[str, int] = {}

    # -- declarations ----------------------------------------------------
    def input(self, name: str) -> str:
        return self._field(name)

    def output(self, name: str) -> str:
        return self._field(name)

    def _field(self, name: str) -> str:
        assert name not in self._handles, f"duplicate field {name}"
        self._handles[name] = len(self._arg_types)
        self._arg_types.append(stencil.FieldType(self.core))
        self._arg_names.append(name)
        return name

    # -- ops (recorded, materialized at finish) ---------------------------
    def load(self, field: str):
        token = _Token()

        def emit(func, env):
            op = func.body.add_op(
                stencil.LoadOp(func.body.args[self._handles[field]])
            )
            env[token] = op.results[0]

        self._pending.append(emit)
        return token

    def apply(self, args: Sequence, fn: Callable, n_results: int = 1):
        tokens = [_Token() for _ in range(n_results)]

        def emit(func, env):
            op = build_apply(
                func.body, [env[a] for a in args], self.core, fn,
                n_results=n_results if n_results > 1 else None,
            )
            for t, r in zip(tokens, op.results):
                env[t] = r

        self._pending.append(emit)
        return tokens[0] if n_results == 1 else tokens

    def store(self, value, field: str):
        def emit(func, env):
            func.body.add_op(
                stencil.StoreOp(
                    env[value], func.body.args[self._handles[field]], self.core
                )
            )

        self._pending.append(emit)

    # -- finish ------------------------------------------------------------
    def build_func(self) -> ir.FuncOp:
        func = ir.FuncOp(self.name, self._arg_types)
        env: dict = {}
        for emit in self._pending:
            emit(func, env)
        func.body.add_op(ir.ReturnOp([]))
        ir.verify_module(func)
        return func

    def finish(self, boundary: str = "zero") -> Program:
        return Program(
            self.build_func(),
            boundary=boundary,
            field_names=tuple(self._arg_names),
            name=self.name,
        )


class _Token:
    __slots__ = ()
