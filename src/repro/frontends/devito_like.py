"""Devito-like symbolic frontend (paper sec. 5.1, listing 5).

A miniature symbolic layer in the spirit of Devito's SymPy DSL:

    grid = Grid(shape=(128, 128), extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=grid, space_order=4)
    eq = Eq(u.dt, 0.5 * u.laplace)          # mathematician-style
    op = Operator(eq, dt=1e-4)              # solves for u.forward
    state = op.zero_state()
    state = op.apply(state, timesteps=100, mesh=mesh, strategy=strategy)

Derivatives expand to central FD coefficient taps (``repro.core.fd``);
the lowering emits the shared ``stencil`` dialect and everything below
(fusion, dmp decomposition, ppermute halo exchanges, pallas backend) is
the common stack.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro import api
from repro.api import Program, Target
from repro.core import fd, ir
from repro.core.builder import ApplyArgHandle, Expr, IRBuilder, build_apply
from repro.core.dialects import stencil
from repro.core.program import CompileOptions, time_loop  # noqa: F401  (re-export)
from repro.core.passes.decompose import SlicingStrategy


# --------------------------------------------------------------------------
# Symbolic expressions
# --------------------------------------------------------------------------


class Node:
    def __add__(self, o):  # noqa: D105
        return BinOp("+", self, _c(o))

    __radd__ = __add__

    def __sub__(self, o):
        return BinOp("-", self, _c(o))

    def __rsub__(self, o):
        return BinOp("-", _c(o), self)

    def __mul__(self, o):
        return BinOp("*", self, _c(o))

    __rmul__ = __mul__

    def __truediv__(self, o):
        return BinOp("/", self, _c(o))

    def __neg__(self):
        return BinOp("-", Const(0.0), self)


def _c(v) -> "Node":
    return v if isinstance(v, Node) else Const(float(v))


@dataclasses.dataclass
class Const(Node):
    value: float


@dataclasses.dataclass
class BinOp(Node):
    op: str
    lhs: Node
    rhs: Node


@dataclasses.dataclass
class Tap(Node):
    """A read of ``fn`` at time offset ``t_off`` and spatial ``offsets``."""

    fn: "TimeFunction"
    t_off: int
    offsets: tuple


@dataclasses.dataclass
class Deriv(Node):
    """Unexpanded derivative; expanded at lowering with the fn's order."""

    fn: "TimeFunction"
    t_off: int
    kind: str  # "laplace" | f"dx{dim}" | f"dx2{dim}" | "dt" | "dt2"


class Grid:
    def __init__(self, shape: Sequence[int], extent: Optional[Sequence[float]] = None):
        self.shape = tuple(int(s) for s in shape)
        self.extent = tuple(float(e) for e in (extent or self.shape))
        self.spacing = tuple(e / s for e, s in zip(self.extent, self.shape))

    @property
    def ndim(self) -> int:
        return len(self.shape)


class TimeFunction(Node):
    """A time-varying field on a grid; reads default to time t, center."""

    def __init__(self, name: str, grid: Grid, space_order: int = 2, time_order: int = 1):
        self.name = name
        self.grid = grid
        self.space_order = space_order
        self.time_order = time_order

    # time taps
    @property
    def forward(self) -> Tap:
        return Tap(self, +1, tuple([0] * self.grid.ndim))

    @property
    def backward(self) -> Tap:
        return Tap(self, -1, tuple([0] * self.grid.ndim))

    def at(self, *offsets: int) -> Tap:
        return Tap(self, 0, tuple(offsets))

    def shifted(self, dim: int, k: int) -> Tap:
        off = [0] * self.grid.ndim
        off[dim] = k
        return Tap(self, 0, tuple(off))

    # derivatives (time t)
    @property
    def laplace(self) -> Deriv:
        return Deriv(self, 0, "laplace")

    @property
    def dt(self) -> Deriv:
        return Deriv(self, 0, "dt")

    @property
    def dt2(self) -> Deriv:
        return Deriv(self, 0, "dt2")

    def dx2(self, dim: int) -> Deriv:
        return Deriv(self, 0, f"dx2:{dim}")

    def dx(self, dim: int) -> Deriv:
        return Deriv(self, 0, f"dx:{dim}")

    # reading `u` plain = tap at (t, center)
    def _as_tap(self) -> Tap:
        return Tap(self, 0, tuple([0] * self.grid.ndim))


@dataclasses.dataclass
class Eq:
    lhs: Node
    rhs: Node


# --------------------------------------------------------------------------
# Operator: symbolic → stencil IR → shared stack
# --------------------------------------------------------------------------


class Operator:
    """Compiles one or more update equations into a time-steppable program.

    Supported equation shapes (per TimeFunction):
      - ``Eq(u.forward, expr)``            explicit update;
      - ``Eq(u.dt, expr)``   (time_order 1) → u⁺ = u + dt·expr;
      - ``Eq(u.dt2, expr)``  (time_order 2) → u⁺ = 2u − u⁻ + dt²·expr —
        the paper's heat / acoustic-wave benchmarks.
    """

    def __init__(
        self,
        eqs: Union[Eq, Sequence[Eq]],
        dt: float = 1.0,
        boundary: str = "zero",
    ) -> None:
        self.eqs = [eqs] if isinstance(eqs, Eq) else list(eqs)
        self.dt = float(dt)
        self.boundary = boundary
        self._build()

    # -- symbolic rewrite to explicit updates ---------------------------
    def _build(self) -> None:
        updates: list[tuple[TimeFunction, Node]] = []
        for eq in self.eqs:
            lhs, rhs = eq.lhs, eq.rhs
            if isinstance(lhs, Tap) and lhs.t_off == 1:
                updates.append((lhs.fn, rhs))
            elif isinstance(lhs, Deriv) and lhs.kind == "dt":
                u = lhs.fn
                updates.append((u, u._as_tap() + Const(self.dt) * rhs))
            elif isinstance(lhs, Deriv) and lhs.kind == "dt2":
                u = lhs.fn
                updates.append(
                    (
                        u,
                        Const(2.0) * u._as_tap()
                        - Tap(u, -1, tuple([0] * u.grid.ndim))
                        + Const(self.dt**2) * rhs,
                    )
                )
            else:
                raise ValueError(
                    "equation LHS must be u.forward, u.dt or u.dt2"
                )
        self.updates = updates
        self.grid = updates[0][0].grid

        # which time slots does each function need?
        self.slots: dict[TimeFunction, tuple[int, int]] = {}

        def scan(n: Node) -> None:
            if isinstance(n, (Tap, Deriv)):
                lo, hi = self.slots.get(n.fn, (0, 0))
                self.slots[n.fn] = (min(lo, n.t_off), max(hi, n.t_off))
            if isinstance(n, BinOp):
                scan(n.lhs)
                scan(n.rhs)

        for fn_, rhs in updates:
            self.slots.setdefault(fn_, (0, 0))
            scan(rhs)
        self._build_ir()

    # -- IR construction -------------------------------------------------
    def _build_ir(self) -> None:
        grid = self.grid
        core = stencil.Bounds.from_shape(grid.shape)
        arg_types = []
        self.arg_layout: list[tuple[TimeFunction, int]] = []  # (fn, t_off)
        for fn_, (lo, hi) in self.slots.items():
            for t in range(lo, 1):  # inputs: oldest → newest (t ≤ 0)
                arg_types.append(stencil.FieldType(core))
                self.arg_layout.append((fn_, t))
        updated = [fn_ for fn_, _ in self.updates]
        out_base = len(arg_types)
        for fn_ in updated:
            arg_types.append(stencil.FieldType(core))

        func = ir.FuncOp("devito_op", arg_types)
        loads: dict[tuple, ir.SSAValue] = {}
        for (fn_, t), arg in zip(self.arg_layout, func.body.args):
            load = func.body.add_op(stencil.LoadOp(arg))
            loads[(fn_.name, t)] = load.results[0]

        for i, (fn_, rhs) in enumerate(self.updates):
            expanded = self._expand(rhs, fn_)
            taps = _collect_taps(expanded)
            operands, index_of = [], {}
            for t in taps:
                key = (t.fn.name, t.t_off)
                if key not in index_of:
                    index_of[key] = len(operands)
                    operands.append(loads[key])

            def body(b: IRBuilder, *handles: ApplyArgHandle) -> Expr:
                return _emit(expanded, b, handles, index_of)

            apply_op = build_apply(func.body, operands, core, body)
            out_field = func.body.args[out_base + i]
            func.body.add_op(
                stencil.StoreOp(apply_op.results[0], out_field, core)
            )
        func.body.add_op(ir.ReturnOp([]))
        self.func = func
        names = [f"{fn_.name}@t{t:+d}" for fn_, t in self.arg_layout] + [
            f"{fn_.name}@t+1" for fn_ in updated
        ]
        self.program = Program(
            func, boundary=self.boundary, field_names=names, name=func.sym_name
        )

    def _expand(self, n: Node, ctx_fn: TimeFunction) -> Node:
        """Expand Deriv nodes into FD tap combinations."""
        if isinstance(n, Deriv):
            fn_ = n.fn
            h = fn_.grid.spacing
            if n.kind == "laplace":
                out: Node = Const(0.0)
                for d in range(fn_.grid.ndim):
                    offs, coeffs = fd.second_derivative(fn_.space_order, h[d])
                    for o, c in zip(offs, coeffs):
                        off = tuple(o if k == d else 0 for k in range(fn_.grid.ndim))
                        out = out + Const(c) * Tap(fn_, n.t_off, off)
                return out
            if n.kind.startswith("dx2:"):
                d = int(n.kind.split(":")[1])
                offs, coeffs = fd.second_derivative(fn_.space_order, h[d])
                out = Const(0.0)
                for o, c in zip(offs, coeffs):
                    off = tuple(o if k == d else 0 for k in range(fn_.grid.ndim))
                    out = out + Const(c) * Tap(fn_, n.t_off, off)
                return out
            if n.kind.startswith("dx:"):
                d = int(n.kind.split(":")[1])
                offs, coeffs = fd.first_derivative(
                    min(fn_.space_order, 4), h[d]
                )
                out = Const(0.0)
                for o, c in zip(offs, coeffs):
                    if c == 0.0:
                        continue
                    off = tuple(o if k == d else 0 for k in range(fn_.grid.ndim))
                    out = out + Const(c) * Tap(fn_, n.t_off, off)
                return out
            raise ValueError(f"cannot expand derivative {n.kind} on RHS")
        if isinstance(n, BinOp):
            return BinOp(n.op, self._expand(n.lhs, ctx_fn), self._expand(n.rhs, ctx_fn))
        if isinstance(n, TimeFunction):
            return n._as_tap()
        return n

    # -- execution --------------------------------------------------------
    @property
    def computation(self):
        """DEPRECATED: the old StencilComputation shim over ``.program``
        (built lazily, once — its last_local/last_timings state persists
        across accesses like the old stored attribute did)."""
        if getattr(self, "_computation", None) is None:
            from repro.core.program import StencilComputation

            self._computation = StencilComputation(
                self.func, boundary=self.boundary
            )
        return self._computation

    def _target(
        self,
        mesh=None,
        strategy: Optional[SlicingStrategy] = None,
        options: Optional[CompileOptions] = None,
        target: Optional[Target] = None,
    ) -> Target:
        if target is not None:
            if mesh is not None or strategy is not None or options is not None:
                raise ValueError(
                    "pass either target= or the legacy mesh/strategy/options, "
                    "not both"
                )
            return target
        opts = options or CompileOptions()
        return opts.to_target(mesh=mesh, strategy=strategy)

    def compile_step(
        self,
        mesh=None,
        strategy: Optional[SlicingStrategy] = None,
        options: Optional[CompileOptions] = None,
        target: Optional[Target] = None,
    ):
        """Step over the *input* time buffers only; output buffers (fully
        overwritten every step) are supplied internally.  Prefer
        ``target=``; mesh/strategy/options are the legacy spelling."""
        artifact = api.compile(
            self.program, self._target(mesh, strategy, options, target)
        )
        return artifact.step()

    def zero_state(self, dtype=jnp.float32) -> list:
        return [
            jnp.zeros(self.grid.shape, dtype) for _ in self.arg_layout
        ]

    def apply(
        self,
        state: Sequence,
        timesteps: int,
        mesh=None,
        strategy: Optional[SlicingStrategy] = None,
        options: Optional[CompileOptions] = None,
        target: Optional[Target] = None,
    ):
        """Run ``timesteps`` with time-buffer rotation (oldest→newest).

        ``timesteps`` counts single time steps; a
        ``Target(exchange_every=k)`` artifact advances k steps per call,
        so the loop runs in epochs (``CompiledStencil.time_loop``)."""
        artifact = api.compile(
            self.program, self._target(mesh, strategy, options, target)
        )
        return artifact.time_loop(tuple(state), timesteps)


def _collect_taps(n: Node) -> list:
    out: list[Tap] = []

    def go(m: Node) -> None:
        if isinstance(m, Tap):
            out.append(m)
        elif isinstance(m, BinOp):
            go(m.lhs)
            go(m.rhs)

    go(n)
    return out


def _emit(n: Node, b: IRBuilder, handles, index_of) -> Expr:
    if isinstance(n, Const):
        return Expr(b, b.const(n.value))
    if isinstance(n, Tap):
        h = handles[index_of[(n.fn.name, n.t_off)]]
        return h.at(*n.offsets)
    if isinstance(n, TimeFunction):
        h = handles[index_of[(n.name, 0)]]
        return h.at(*([0] * n.grid.ndim))
    if isinstance(n, BinOp):
        lhs = _emit(n.lhs, b, handles, index_of)
        rhs = _emit(n.rhs, b, handles, index_of)
        return {
            "+": lambda: lhs + rhs,
            "-": lambda: lhs - rhs,
            "*": lambda: lhs * rhs,
            "/": lambda: lhs / rhs,
        }[n.op]()
    raise NotImplementedError(type(n))
