"""PSyclone-like loop frontend with stencil *recognition* (paper sec. 5.2).

PSyclone parses Fortran loop nests and recognizes stencils, which are then
"represented in the PSy-IR dialect which is then lowered to SSA form" and
on into the shared stencil dialect.  Here the kernel source is a Python
function whose body is a sequence of whole-array loop-nest assignments —
the same DAG-of-array-statements shape as the NEMO/PW-advection kernels —
and recognition happens on the Python AST:

    def pw_advect(su, sv, sw, u, v, w):
        su[i, j, k] = u[i, j, k] * (w[i, j, k - 1] - w[i - 1, j, k]) * 0.5
        sv[i, j, k] = v[i, j, k] * (w[i, j, k + 1] - w[i, j - 1, k]) * 0.5
        sw[i, j, k] = w[i, j, k] * (u[i, j, k] + v[i, j, k])

    prog = recognize(pw_advect, shape=(64, 64, 32))   # repro.api.Program
    step = repro.api.compile(prog, target)

Index expressions must be loop indices ± integer constants — exactly the
affine accesses PSyclone's stencil recognizer accepts.  Assignments to a
name that is later read become *intermediate temps* (chained applies —
tracer advection's "18 individual stencil regions due to dependencies");
the fusion pass then merges what dependencies allow, reproducing the
paper's PW-advection 3→1 fusion.
"""
from __future__ import annotations

import ast
import inspect
import textwrap
from typing import Callable, Optional, Sequence

from repro.api import Program
from repro.core import ir
from repro.core.builder import ApplyArgHandle, Expr, IRBuilder, build_apply
from repro.core.dialects import stencil

_INDEX_NAMES = ("i", "j", "k", "l")


class RecognitionError(Exception):
    pass


def recognize(
    kernel: Callable,
    shape: Sequence[int],
    boundary: str = "zero",
) -> Program:
    """Recognize a loop-style kernel function into a ``repro.api.Program``."""
    func_ir = build_stencil_func(kernel, shape)
    names = [
        a.name_hint for a in func_ir.body.args
        if isinstance(a.type, stencil.FieldType)
    ]
    return Program(
        func_ir,
        boundary=boundary,
        field_names=names,
        name=func_ir.sym_name,
    )


def build_stencil_func(kernel: Callable, shape: Sequence[int]) -> ir.FuncOp:
    src = textwrap.dedent(inspect.getsource(kernel))
    tree = ast.parse(src)
    fdef = tree.body[0]
    if not isinstance(fdef, ast.FunctionDef):
        raise RecognitionError("expected a function definition")
    params = [a.arg for a in fdef.args.args]
    ndim = len(shape)
    idx_names = _INDEX_NAMES[:ndim]
    core = stencil.Bounds.from_shape(tuple(shape))

    # classify statements
    stmts: list[tuple[str, ast.expr]] = []
    for node in fdef.body:
        if isinstance(node, ast.Expr) and isinstance(node.value, ast.Constant):
            continue  # docstring
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            raise RecognitionError(
                f"line {node.lineno}: only single-target array assignments "
                "are recognizable as stencils"
            )
        tgt = node.targets[0]
        name, off = _parse_access(tgt, idx_names)
        if any(o != 0 for o in off):
            raise RecognitionError(
                f"line {node.lineno}: stores must be at the loop point "
                f"(got offset {off})"
            )
        stmts.append((name, node.value))

    written = [n for n, _ in stmts]
    read_names: set[str] = set()
    for _, rhs in stmts:
        read_names |= _array_reads(rhs, idx_names)

    # function arguments that are read before (or never) written are inputs;
    # every written argument is also an output field.
    input_fields = [
        p for p in params if p in read_names and p not in written
    ] + [p for p in params if p in written and _read_before_write(p, stmts, idx_names)]
    output_fields = [p for p in params if p in written]

    arg_names = list(dict.fromkeys(input_fields + output_fields))
    func = ir.FuncOp(
        f"psy_{kernel.__name__}",
        [stencil.FieldType(core) for _ in arg_names],
    )
    for n, a in zip(arg_names, func.body.args):
        a.name_hint = n
    field_of = {n: a for n, a in zip(arg_names, func.body.args)}

    # value environment: name -> temp SSA value (loaded field or apply result)
    env: dict[str, ir.SSAValue] = {}

    def value_of(name: str) -> ir.SSAValue:
        if name not in env:
            if name not in field_of:
                raise RecognitionError(f"unknown array '{name}'")
            load = func.body.add_op(stencil.LoadOp(field_of[name]))
            env[name] = load.results[0]
        return env[name]

    for name, rhs in stmts:
        reads = sorted(_array_reads(rhs, idx_names))
        operands = [value_of(r) for r in reads]
        index_of = {r: k for k, r in enumerate(reads)}

        def body(b: IRBuilder, *handles: ApplyArgHandle) -> Expr:
            return _emit_expr(rhs, b, handles, index_of, idx_names)

        apply_op = build_apply(func.body, operands, core, body)
        env[name] = apply_op.results[0]

    for name in output_fields:
        func.body.add_op(stencil.StoreOp(env[name], field_of[name], core))
    func.body.add_op(ir.ReturnOp([]))
    ir.verify_module(func)
    return func


# -- AST helpers -------------------------------------------------------------


def _parse_access(node: ast.expr, idx_names) -> tuple[str, tuple]:
    """``u[i-1, j, k+2]`` → ("u", (-1, 0, +2))."""
    if not isinstance(node, ast.Subscript) or not isinstance(node.value, ast.Name):
        raise RecognitionError(f"not an array access: {ast.dump(node)}")
    name = node.value.id
    idx = node.slice
    elts = idx.elts if isinstance(idx, ast.Tuple) else [idx]
    if len(elts) != len(idx_names):
        raise RecognitionError(
            f"access to '{name}' has {len(elts)} indices, expected {len(idx_names)}"
        )
    offsets = []
    for e, expected in zip(elts, idx_names):
        offsets.append(_parse_index(e, expected, name))
    return name, tuple(offsets)


def _parse_index(e: ast.expr, expected: str, arr: str) -> int:
    if isinstance(e, ast.Name):
        if e.id != expected:
            raise RecognitionError(
                f"'{arr}': index '{e.id}' where '{expected}' expected — "
                "non-affine or transposed accesses are not recognizable"
            )
        return 0
    if isinstance(e, ast.BinOp) and isinstance(e.left, ast.Name):
        if e.left.id != expected or not isinstance(e.right, ast.Constant):
            raise RecognitionError(f"'{arr}': unrecognizable index {ast.dump(e)}")
        c = int(e.right.value)
        if isinstance(e.op, ast.Add):
            return c
        if isinstance(e.op, ast.Sub):
            return -c
    raise RecognitionError(f"'{arr}': index must be <loop-var> ± <const>")


def _array_reads(node: ast.expr, idx_names) -> set:
    out: set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Subscript) and isinstance(sub.value, ast.Name):
            out.add(sub.value.id)
    return out


def _read_before_write(name: str, stmts, idx_names) -> bool:
    for tgt, rhs in stmts:
        if name in _array_reads(rhs, idx_names):
            return True
        if tgt == name:
            return False
    return False


def _emit_expr(node: ast.expr, b: IRBuilder, handles, index_of, idx_names) -> Expr:
    if isinstance(node, ast.Constant):
        return Expr(b, b.const(float(node.value)))
    if isinstance(node, ast.Subscript):
        name, off = _parse_access(node, idx_names)
        return handles[index_of[name]].at(*off)
    if isinstance(node, ast.BinOp):
        lhs = _emit_expr(node.left, b, handles, index_of, idx_names)
        rhs = _emit_expr(node.right, b, handles, index_of, idx_names)
        if isinstance(node.op, ast.Add):
            return lhs + rhs
        if isinstance(node.op, ast.Sub):
            return lhs - rhs
        if isinstance(node.op, ast.Mult):
            return lhs * rhs
        if isinstance(node.op, ast.Div):
            return lhs / rhs
        raise RecognitionError(f"unsupported operator {node.op}")
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_emit_expr(node.operand, b, handles, index_of, idx_names)
    raise RecognitionError(f"unsupported expression {ast.dump(node)}")
