"""Step builders + input specs for every (arch × shape) cell.

``input_specs(cfg, shape, mesh, rules)`` returns ShapeDtypeStruct
stand-ins for every model input (weak-type-correct, sharded, no device
allocation); ``build_step`` returns the function the dry-run lowers with
its in/out sharding trees.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import param_specs as pspecs
from repro.dist.sharding import ShardingRules, _valid_spec, default_rules, use_mesh
from repro.models import lm
from repro.train import optimizer as opt_mod
from repro.train.train_step import TrainOptions, init_train_state, make_train_step


import os

# Serving weight residency (§Perf hillclimb A): deployments keep bf16
# weights resident — halves the per-step parameter read, the dominant
# memory term at decode.  Default keeps the training dtype (f32 master)
# so the baseline roofline table is paper-faithful; set
# REPRO_SERVE_PARAMS_DTYPE=bfloat16 for the optimized variant.
SERVE_PARAMS_DTYPE = os.environ.get("REPRO_SERVE_PARAMS_DTYPE", "float32")


def _serving_param_shapes(params_shapes):
    if SERVE_PARAMS_DTYPE != "bfloat16":
        return params_shapes
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s,
        params_shapes,
    )


def _batch_axes(rules: ShardingRules):
    return rules.physical("batch")


def _ax_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = axes if isinstance(axes, tuple) else (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


# --------------------------------------------------------------------------
# cache sharding policy
# --------------------------------------------------------------------------


def kv_cache_spec(shape: tuple, mesh: Mesh, rules: ShardingRules) -> P:
    """[cells, B, T, KH, HD] cache sharding.

    Policy (DESIGN.md §6): shard batch over the batch axes when it
    divides; shard KV heads over "model" when they divide, else shard the
    *sequence* dim over "model" (domain decomposition of the KV domain —
    decode softmax/PV reductions become small all-reduces).  Tiny-batch
    long-context (long_500k) shards the sequence over everything
    available.
    """
    from repro.dist.sharding import kv_cache_layout

    cells, B, T, KH, HD = shape
    batch_ax = _batch_axes(rules)
    layout = kv_cache_layout(B, T, KH, mesh, rules)
    if layout == "heads":
        return P(None, batch_ax, None, "model", None)
    if layout == "seq":
        return P(None, batch_ax, "model", None, None)
    if layout == "batch":
        return P(None, batch_ax, None, None, None)
    if layout == "seq_all":
        seq_axes = tuple(
            a for a in (batch_ax if isinstance(batch_ax, tuple) else (batch_ax,))
            if a
        ) + ("model",)
        return P(None, None, seq_axes, None, None)
    return P()


def cache_pspecs(cfg: ModelConfig, cache_shapes, mesh: Mesh, rules: ShardingRules):
    batch_ax = _batch_axes(rules)

    def one(path, leaf):
        names = pspecs._path_names(path)
        last = names[-1]
        if last in ("k", "v", "ck", "cv"):
            return kv_cache_spec(tuple(leaf.shape), mesh, rules)
        # ssm/xlstm states: [cells, B, ...] — batch when divisible
        entries = [None] * len(leaf.shape)
        if len(leaf.shape) >= 2:
            entries[1] = batch_ax
        if last == "conv" and len(leaf.shape) == 4:
            entries[3] = "model"  # d_inner
        return _valid_spec(mesh, P(*entries), tuple(leaf.shape))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------


def input_specs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    rules: Optional[ShardingRules] = None,
) -> dict:
    """ShapeDtypeStruct stand-ins for the step inputs of this cell."""
    rules = rules or default_rules(multi_pod="pod" in mesh.axis_names)
    batch_ax = _batch_axes(rules)
    B, S = shape.global_batch, shape.seq_len

    def sds(shp, dtype, spec):
        return jax.ShapeDtypeStruct(
            shp, dtype, sharding=NamedSharding(mesh, _valid_spec(mesh, spec, shp))
        )

    if shape.kind in ("train", "prefill"):
        n_text = S - (cfg.num_modality_tokens if cfg.modality == "vision" else 0)
        batch = {"tokens": sds((B, n_text), jnp.int32, P(batch_ax, None))}
        if cfg.modality == "vision":
            batch["modality"] = sds(
                (B, cfg.num_modality_tokens, cfg.modality_dim),
                jnp.float32,
                P(batch_ax, None, None),
            )
        elif cfg.modality == "audio":
            batch["modality"] = sds(
                (B, S, cfg.modality_dim), jnp.float32, P(batch_ax, None, None)
            )
        return batch

    # decode: one token against a seq_len cache
    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(
            cfg, B, S, memory_len=S if cfg.is_encoder_decoder else 0
        )
    )
    cspecs = cache_pspecs(cfg, cache_shapes, mesh, rules)
    cache = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)
        ),
        cache_shapes,
        cspecs,
    )
    return {
        "token": sds((B,), jnp.int32, P(batch_ax)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               rules: Optional[ShardingRules] = None,
               train_options: Optional[TrainOptions] = None):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    rules = rules or default_rules(multi_pod="pod" in mesh.axis_names)
    specs = input_specs(cfg, shape, mesh, rules)

    if shape.kind == "train":
        opt_cfg = opt_mod.OptimizerConfig()
        options = train_options or TrainOptions(
            q_chunk=min(1024, shape.seq_len)
        )
        step = make_train_step(cfg, opt_cfg, options)

        def wrapped(state, batch):
            with use_mesh(mesh, rules):
                return step(state, batch)

        state_shapes = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), cfg)
        )
        st_specs = pspecs.state_pspecs(state_shapes, rules, mesh)
        state_in = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)
            ),
            state_shapes,
            st_specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        in_shardings = (
            jax.tree.map(lambda sp: NamedSharding(mesh, sp), st_specs),
            jax.tree.map(lambda s: s.sharding, specs),
        )
        out_shardings = (
            jax.tree.map(lambda sp: NamedSharding(mesh, sp), st_specs),
            None,
        )
        return wrapped, (state_in, specs), in_shardings, out_shardings

    if shape.kind == "prefill":

        def prefill(params, batch):
            with use_mesh(mesh, rules):
                return lm.forward_prefill(
                    params, cfg, batch["tokens"], batch.get("modality"),
                    q_chunk=min(1024, shape.seq_len),
                )

        params_shapes = _serving_param_shapes(jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), cfg)
        ))
        p_specs = pspecs.param_pspecs(params_shapes, rules, mesh)
        params_in = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)
            ),
            params_shapes,
            p_specs,
        )
        in_sh = (
            jax.tree.map(lambda sp: NamedSharding(mesh, sp), p_specs),
            jax.tree.map(lambda s: s.sharding, specs),
        )
        return prefill, (params_in, specs), in_sh, None

    # decode
    def serve_step(params, batch):
        with use_mesh(mesh, rules):
            return lm.decode_step(
                params, cfg, batch["token"], batch["pos"], batch["cache"]
            )

    params_shapes = _serving_param_shapes(jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg)
    ))
    p_specs = pspecs.param_pspecs(params_shapes, rules, mesh)
    params_in = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)
        ),
        params_shapes,
        p_specs,
    )
    in_sh = (
        jax.tree.map(lambda sp: NamedSharding(mesh, sp), p_specs),
        jax.tree.map(lambda s: getattr(s, "sharding", None), specs),
    )
    # explicit out shardings: logits batch-sharded; the new cache keeps the
    # input cache layout (without this, sharding propagation can replicate
    # the seq-sharded cache on output — 26 GiB/dev for yi-9b decode_32k)
    batch_ax = _batch_axes(rules)
    logits_sh = NamedSharding(
        mesh, _valid_spec(mesh, P(batch_ax), (shape.global_batch,))
    )
    cache_sh = jax.tree.map(lambda s: s.sharding, specs["cache"])
    out_sh = (logits_sh, cache_sh)
    return serve_step, (params_in, specs), in_sh, out_sh
