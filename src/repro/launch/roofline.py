"""Three-term roofline analysis from the dry-run's compiled artifacts.

    PYTHONPATH=src python -m repro.launch.roofline [--outdir results/dryrun]
                                                   [--markdown]

Terms (TPU v5e per chip: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

    compute    = HLO_FLOPs_per_device   / peak_FLOPs
    memory     = HLO_bytes_per_device   / HBM_bw
    collective = collective_bytes_per_device / link_bw

NOTE on units: XLA's ``compiled.cost_analysis()`` for an SPMD module
reports the *partitioned per-device* program (verified: doubling the mesh
halves reported FLOPs), so each term is per-chip seconds directly — no
further division by chip count.  MODEL_FLOPS (6·N·D, active params for
MoE) is a *global* quantity; the useful-compute ratio therefore compares
against HLO_FLOPs × n_devices.

The modeled step time is ``max(terms)`` with perfect overlap and
``sum(terms)`` without; the dominant term is the bottleneck the §Perf
loop iterates on.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
from dataclasses import dataclass, field
from typing import Optional

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # bytes/s / chip
LINK_BW = 50e9        # bytes/s / ICI link
LINK_LATENCY = 2e-6   # per-message launch latency (collective-permute hop)

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum operand bytes of every collective in the (optimized) HLO."""
    out: dict[str, float] = {}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")

    def shape_bytes(sig: str) -> float:
        total = 0.0
        for m in shape_re.finditer(sig):
            dt, dims = m.group(1), m.group(2)
            sz = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                  "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8}.get(dt)
            if sz is None:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * sz
        return total

    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        # operand bytes: shapes on the RHS of the op name
        rhs = line.split("=", 1)[1]
        # result shape is the first shape on the RHS; operands follow in parens
        paren = rhs.find("(")
        operand_sig = rhs[paren:] if paren >= 0 else rhs
        out[kind] = out.get(kind, 0.0) + shape_bytes(operand_sig)
    return out


@dataclass
class RooflineTerms:
    """Generic three-term roofline of one compiled executable — the
    ``CompiledStencil.cost()`` payload (per-device quantities in, per-chip
    seconds out).

    The optional temporal-tiling terms describe the message-count vs
    redundant-compute tradeoff of deep-halo epochs
    (``Target(exchange_every=k)``): ``messages_per_epoch`` exchanges fire
    *once* per epoch regardless of depth (their per-message launch latency
    amortizes as 1/k), while every non-final step of the epoch computes a
    shrinking frame of redundant boundary points
    (``redundant_compute_factor``).  ``recommend_exchange_every`` picks
    the k that minimizes the modeled per-step time, subject to the deep
    halo fitting the shard."""

    flops: float
    bytes_accessed: float
    collectives: dict = field(default_factory=dict)
    exchange_every: int = 1
    messages_per_epoch: int = 0
    step_halo: tuple = ()     # per-dim per-step halo width (max of lo/hi)
    local_shape: tuple = ()   # local shard core extents

    def __post_init__(self) -> None:
        self.flops = float(self.flops)
        self.bytes_accessed = float(self.bytes_accessed)
        self.collectives = dict(self.collectives)
        self.exchange_every = int(self.exchange_every)
        self.messages_per_epoch = int(self.messages_per_epoch)
        self.step_halo = tuple(self.step_halo)
        self.local_shape = tuple(self.local_shape)

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.collectives.values()))

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_overlapped(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_serial(self) -> float:
        return self.t_compute + self.t_memory + self.t_collective

    # -- temporal-tiling tradeoff (message latency vs redundant compute) --
    @property
    def t_latency(self) -> float:
        """Per-step exchange launch latency: one message volley per epoch,
        amortized over the epoch's steps."""
        return (
            self.messages_per_epoch * LINK_LATENCY
            / max(self.exchange_every, 1)
        )

    def redundant_compute_factor(self, k: Optional[int] = None) -> float:
        """Mean compute volume of an epoch's steps relative to the core:
        step j of k computes ``prod(n_d + 2·(k-j)·w_d)`` points, so the
        factor is 1.0 at k=1 and grows with depth (surface/volume)."""
        k = self.exchange_every if k is None else int(k)
        if k <= 1 or not self.step_halo or not self.local_shape:
            return 1.0
        core = 1.0
        for n in self.local_shape:
            core *= n
        if core == 0:
            return 1.0
        total = 0.0
        for j in range(k):  # j = remaining growth steps (k-1 … 0)
            vol = 1.0
            for n, w in zip(self.local_shape, self.step_halo):
                vol *= n + 2.0 * j * w
            total += vol
        return total / (k * core)

    def feasible_exchange_every(self, k: int) -> bool:
        """Deep halo of depth k must come out of the neighbour's core."""
        if not self.step_halo or not self.local_shape:
            return k == 1
        return all(
            w * k <= n for w, n in zip(self.step_halo, self.local_shape) if w
        )

    def step_time(self, k: int) -> float:
        """Modeled per-step seconds at epoch depth ``k``, extrapolated from
        this artifact's terms: work scales by the redundant-compute factor,
        exchange *bytes* per step stay ~constant (k× deeper, 1/k as often),
        exchange *latency* amortizes as 1/k.

        The measured terms describe one *call* — a whole epoch of
        ``self.exchange_every`` steps (its flops carry that depth's
        redundancy, its collective bytes the depth-K halo) — so they are
        normalized back to one clean step before extrapolating to k."""
        depth = max(self.exchange_every, 1)
        per_step_work = max(self.t_compute, self.t_memory) / (
            depth * max(self.redundant_compute_factor(depth), 1.0)
        )
        t_lat = self.messages_per_epoch * LINK_LATENCY / max(k, 1)
        return (
            per_step_work * self.redundant_compute_factor(k)
            + t_lat
            + self.t_collective / depth
        )

    def ranked_exchange_every(self, max_k: int = 8) -> list:
        """Every feasible epoch depth with its modeled per-step seconds,
        best first (ties resolve to the shallower epoch).  ``[(1,
        step_time(1))]`` when the tiling terms are unavailable — the
        ranking the autotuner (``repro.tune``) and the fig8 ``--tune``
        sweep print."""
        if not self.step_halo or not self.local_shape or not any(self.step_halo):
            return [(1, self.step_time(1))]
        pairs = [(1, self.step_time(1))] + [
            (k, self.step_time(k))
            for k in range(2, max(int(max_k), 1) + 1)
            if self.feasible_exchange_every(k)
        ]
        return sorted(pairs, key=lambda kt: (kt[1], kt[0]))

    def recommend_exchange_every(self, max_k: int = 8) -> int:
        """The epoch depth minimizing the modeled per-step time; 1 when
        tiling cannot win (or the terms are not available)."""
        return self.ranked_exchange_every(max_k)[0][0]

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "t_latency": self.t_latency,
            "t_overlapped": self.t_overlapped,
            "t_serial": self.t_serial,
            "dominant": self.dominant,
            "exchange_every": self.exchange_every,
            "messages_per_epoch": self.messages_per_epoch,
            "redundant_compute_factor": self.redundant_compute_factor(),
            "recommended_exchange_every": self.recommend_exchange_every(),
        }



SHAPE_TOKENS = {
    "train_4k": 4_096 * 256,
    "prefill_32k": 32_768 * 32,
    "decode_32k": 128,          # one token per sequence
    "long_500k": 1,
}
TRAIN_MULT = {"train_4k": 3.0}  # fwd+bwd ≈ 3× forward FLOPs

_DIMS_CACHE: dict = {}


def _arch_dims(arch: str) -> tuple:
    if arch not in _DIMS_CACHE:
        try:
            from repro.configs import get_config

            cfg = get_config(arch)
            _DIMS_CACHE[arch] = (cfg.d_model, cfg.n_layers)
        except Exception:
            _DIMS_CACHE[arch] = (4096, 32)
    return _DIMS_CACHE[arch]


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collectives: dict
    params: int
    active_params: int
    arg_bytes: float = 0.0  # per-device resident args (params + caches)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_memory_analytic(self) -> float:
        """Algorithmic minimum HBM traffic (per device), used for
        bottleneck classification.  The HLO-derived ``t_memory`` is kept
        for completeness but the CPU backend inflates it 10–50×
        (bf16 ops emulated via f32 copies, unfused elementwise chains,
        gathers billed at full-operand size) — measured in EXPERIMENTS.md
        §Roofline 'bytes fidelity'.

        train:   3 passes over the params at 4 B (fwd read, bwd read,
                 update r/w of param+m+v ≈ 12 B) + layer activation
                 checkpoints (2 B, written fwd + read bwd) + logits.
        prefill: params once (2 B) + activations once + KV cache write.
        decode:  resident state once (params + caches ≈ arg_bytes).
        """
        d_model, n_layers = _arch_dims(self.arch)
        toks = SHAPE_TOKENS.get(self.shape, 0) / self.n_devices
        if self.shape.startswith("train"):
            # params spread by FSDP(data)×TP(model): the whole mesh shares one copy
            param_traffic = self.active_params * 24.0 / self.n_devices
            act_traffic = 4.0 * toks * 2.0 * d_model * n_layers
            return (param_traffic + act_traffic) / HBM_BW
        if self.shape.startswith("prefill"):
            p_dev = 2.0 * self.active_params / 16  # bf16, TP-sharded; DP replicates
            act_traffic = 4.0 * toks * 2.0 * d_model * n_layers
            return (p_dev + act_traffic) / HBM_BW
        return max(self.arg_bytes, 1.0) / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory_analytic,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_overlapped(self) -> float:
        return max(self.t_compute, self.t_memory_analytic, self.t_collective)

    @property
    def t_serial(self) -> float:
        return self.t_compute + self.t_memory_analytic + self.t_collective

    @property
    def model_flops(self) -> float:
        tokens = SHAPE_TOKENS.get(self.shape, 0)
        mult = TRAIN_MULT.get(self.shape, 1.0)
        return 2.0 * self.active_params * tokens * mult  # 2ND/token fwd

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips) — how much compiled compute
        is 'useful'.  <1 ⇒ remat/recompute overhead; >1 ⇒ HLO under-counts
        (e.g. fused ops) or model-FLOPs overestimates (MoE drops)."""
        total_hlo = self.flops * self.n_devices
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def is_decode(self) -> bool:
        return self.shape.startswith(("decode", "long"))

    @property
    def roofline_fraction(self) -> float:
        """Fraction of modeled (overlapped) step time that is *irreducible*
        on this hardware — the score.

        train/prefill (compute-limited regime): ideal = useful model FLOPs
        at peak MXU throughput.  decode/long (bandwidth-limited regime):
        ideal = one read of the resident state (params + caches) at full
        HBM bandwidth — FLOPs are immaterial at batch-per-chip ≤ 1."""
        if self.t_overlapped == 0:
            return 0.0
        if self.is_decode:
            if not self.arg_bytes:
                return 0.0
            # ideal = one read of the resident state; score against the
            # HLO-memory-based modeled time (conservative: the CPU
            # backend inflates HLO bytes — see §Roofline bytes-fidelity)
            t_ideal = self.arg_bytes / HBM_BW
            t_model = max(self.t_compute, self.t_memory, self.t_collective)
        else:
            t_ideal = self.model_flops / self.n_devices / PEAK_FLOPS
            t_model = self.t_overlapped
        return min(1.0, t_ideal / t_model)


def advice(c: Cell) -> str:
    if c.dominant == "collective":
        kinds = sorted(c.collectives, key=c.collectives.get, reverse=True)
        top = kinds[0] if kinds else "?"
        return (f"cut {top} volume (resharding/fusion of collectives, "
                "overlap with compute)")
    if c.dominant == "memory":
        if c.shape.startswith("decode") or c.shape.startswith("long"):
            return "KV/state residency: smaller cache dtype, fused decode reads"
        return "remat policy / fusion to cut HBM round-trips"
    return "MXU utilization: larger per-chip matmul tiles, less padding"


def load_cells(outdir: str, delta_dir: str = None) -> list:
    """Load dry-run records; when a delta-extrapolation record exists for
    the same cell (exact scan-corrected FLOPs/collectives — see
    ``dryrun.run_cell_delta``), its cost numbers override the scan-mode
    record's (which count while-loop bodies once)."""
    delta_dir = delta_dir or outdir.rstrip("/") + "_delta"
    overrides = {}
    for path in glob.glob(os.path.join(delta_dir, "*.json")):
        with open(path) as f:
            d = json.load(f)
        if d.get("ok"):
            overrides[(d["arch"], d["shape"], d["mesh"])] = d

    cells = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if not d.get("ok"):
            continue
        key = (d["arch"], d["shape"], d["mesh"])
        src = overrides.get(key, d)
        coll = src.get("collective_bytes", {})
        mem = d.get("memory") or {}
        cells.append(
            Cell(
                arch=d["arch"],
                shape=d["shape"],
                mesh=d["mesh"],
                n_devices=d["n_devices"],
                flops=src["cost"]["flops"] or 0.0,
                bytes_accessed=src["cost"]["bytes_accessed"] or 0.0,
                collective_bytes=sum(coll.values()),
                collectives=coll,
                params=d.get("params", 0),
                active_params=d.get("active_params", 0) or d.get("params", 0),
                # memory_analysis reports the per-device partitioned module
                # (verified: 2× mesh ⇒ ½ argument bytes)
                arg_bytes=mem.get("argument_bytes") or 0.0,
            )
        )
    return cells


def fmt_s(t: float) -> str:
    if t >= 1.0:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t*1e3:.1f}ms"
    return f"{t*1e6:.0f}µs"


def report(cells: list, markdown: bool = False, mesh: str = "16x16") -> str:
    rows = []
    for c in cells:
        if c.mesh != mesh:
            continue
        rows.append(
            (
                c.arch, c.shape,
                fmt_s(c.t_compute), fmt_s(c.t_memory_analytic),
                fmt_s(c.t_memory), fmt_s(c.t_collective),
                c.dominant,
                f"{c.useful_ratio:.2f}",
                f"{c.roofline_fraction*100:.0f}%",
                advice(c),
            )
        )
    headers = ["arch", "shape", "t_comp", "t_mem", "t_mem(hlo)", "t_coll",
               "dominant", "useful", "roofline", "to improve"]
    if markdown:
        out = ["| " + " | ".join(headers) + " |",
               "|" + "|".join("---" for _ in headers) + "|"]
        out += ["| " + " | ".join(str(x) for x in r) + " |" for r in rows]
        return "\n".join(out)
    widths = [max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
              for i, h in enumerate(headers)]
    out = ["  ".join(h.ljust(w) for h, w in zip(headers, widths))]
    out += ["  ".join(str(c).ljust(w) for c, w in zip(r, widths)) for r in rows]
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    cells = load_cells(args.outdir)
    print(report(cells, markdown=args.markdown, mesh=args.mesh))
    # summary: the three §Perf hillclimb candidates
    sp = [c for c in cells if c.mesh == args.mesh]
    if sp:
        worst = min(sp, key=lambda c: c.roofline_fraction)
        coll = max(sp, key=lambda c: c.t_collective / max(c.t_overlapped, 1e-12))
        print(f"\nworst roofline fraction : {worst.arch} × {worst.shape} "
              f"({worst.roofline_fraction*100:.0f}%)")
        print(f"most collective-bound   : {coll.arch} × {coll.shape} "
              f"(t_coll {fmt_s(coll.t_collective)})")


if __name__ == "__main__":
    main()
