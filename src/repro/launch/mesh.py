"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before any jax initialization.
"""
from __future__ import annotations

import jax


def _make(shape, axes):
    # newer jax takes axis_types (Auto = sharding propagation decides);
    # older jax has no AxisType and make_mesh defaults to the same
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape),
            tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    return _make(shape, axes)
