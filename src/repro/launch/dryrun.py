import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both

For each cell: ``jax.jit(step, in_shardings, out_shardings).lower(...)
.compile()`` on the production mesh; prints ``memory_analysis()`` (proves
it fits) and ``cost_analysis()`` (FLOPs/bytes for §Roofline) and appends
a JSON record to ``results/dryrun/<cell>.json``.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import LM_SHAPES, get_config, get_shape  # noqa: E402
from repro.configs.registry import ARCHS, shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_step  # noqa: E402

# Shared with repro.api's CompiledStencil.cost(); lives in roofline.py
# because importing this module forces the 512-device XLA flag.
from repro.launch.roofline import collective_bytes_from_hlo  # noqa: E402,F401
from repro.dist.sharding import default_rules  # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             unroll: bool = False) -> dict:
    """One dry-run cell.  ``unroll=True`` unrolls the supercell/chunk
    scans at trace time so ``cost_analysis`` (which counts a while-loop
    body ONCE — verified against a hand-built loop) reports exact
    whole-model FLOPs/bytes/collectives; used for the §Roofline table.
    The default (scan) mode is the production compile path."""
    from repro.models.flags import set_unroll_scans

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(multi_pod=multi_pod)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.size,
        "unrolled": unroll,
    }
    t0 = time.time()
    with set_unroll_scans(unroll):
        fn, args, in_sh, out_sh = build_step(cfg, shape, mesh, rules)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
    record["lower_s"] = round(time.time() - t0, 1)

    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    record["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per program
        cost = cost[0] if cost else {}
    record["cost"] = {
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "transcendentals": cost.get("transcendentals"),
    }
    t2 = time.time()
    hlo = compiled.as_text()
    record["collective_bytes"] = collective_bytes_from_hlo(hlo)
    record["hlo_analysis_s"] = round(time.time() - t2, 1)
    record["params"] = cfg.param_count()
    record["active_params"] = cfg.active_param_count()
    record["ok"] = True

    os.makedirs(outdir, exist_ok=True)
    cell = f"{arch}__{shape_name}__{record['mesh']}"
    with open(os.path.join(outdir, cell + ".json"), "w") as f:
        json.dump(record, f, indent=1)
    return record


def run_cell_delta(arch: str, shape_name: str, multi_pod: bool, outdir: str) -> dict:
    """Exact whole-model cost analysis by supercell-delta extrapolation.

    XLA's cost analysis counts a while-loop body once, so the scan-mode
    records under-count FLOPs/collectives by the trip count.  Full
    unrolling is exact but compiles for ~15 min/cell.  Instead: lower the
    SAME step for 1-supercell and 2-supercell model variants with ALL
    scans unrolled (cheap — the supercell scan has trip count 1/2, and
    inner chunk scans unroll within one cell), then extrapolate linearly:

        cost(n) = cost(1) + (cost(2) - cost(1)) · (n - 1)

    Exact because every supercell is an identical compute/communication
    unit (verified against full unrolls in EXPERIMENTS.md §Dry-run).
    Memory analysis is NOT extrapolated — the scan-mode record (full
    model) already reports true per-device residency.
    """
    import dataclasses as dc

    from repro.models.flags import set_unroll_scans

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(multi_pod=multi_pod)
    cell_len = len(cfg.block_pattern)
    n_cells = cfg.n_layers // cell_len

    def one(k: int) -> dict:
        over = {"n_layers": cell_len * k}
        if cfg.is_encoder_decoder:
            over["n_encoder_layers"] = k
        cfg_k = dc.replace(cfg, **over)
        with set_unroll_scans(True):
            fn, args, in_sh, out_sh = build_step(cfg_k, shape, mesh, rules)
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        return {
            "flops": cost.get("flops") or 0.0,
            "bytes_accessed": cost.get("bytes accessed") or 0.0,
            "collectives": collective_bytes_from_hlo(compiled.as_text()),
        }

    t0 = time.time()
    c1 = one(1)
    c2 = one(2)

    def extrap(a, b):
        return a + (b - a) * (n_cells - 1)

    kinds = set(c1["collectives"]) | set(c2["collectives"])
    coll = {
        k: extrap(c1["collectives"].get(k, 0.0), c2["collectives"].get(k, 0.0))
        for k in kinds
    }
    # encoder layers scale with supercells only when counts match; for
    # enc-dec models n_encoder_layers is scaled alongside, so the delta
    # carries (1 decoder cell + 1 encoder layer) — exact when
    # n_encoder_layers == n_supercells (true for seamless: 24/24).
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.size,
        "method": "delta-extrapolation",
        "n_supercells": n_cells,
        "analysis_s": round(time.time() - t0, 1),
        "cost": {
            "flops": extrap(c1["flops"], c2["flops"]),
            "bytes_accessed": extrap(c1["bytes_accessed"], c2["bytes_accessed"]),
        },
        "collective_bytes": coll,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "ok": True,
    }
    os.makedirs(outdir, exist_ok=True)
    cell = f"{arch}__{shape_name}__{record['mesh']}"
    with open(os.path.join(outdir, cell + ".json"), "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (or --all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument(
        "--multi-pod", choices=["off", "on", "both"], default="off"
    )
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument(
        "--unroll", action="store_true",
        help="unroll scans for exact cost analysis (roofline mode)",
    )
    ap.add_argument(
        "--delta", action="store_true",
        help="exact cost analysis via supercell-delta extrapolation (fast)",
    )
    ap.add_argument(
        "--skip-existing", action="store_true",
        help="resume: skip cells whose record already exists in outdir",
    )
    args = ap.parse_args()

    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in LM_SHAPES] if not args.shape else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            ok, reason = shape_applicable(arch, shape_name)
            if not ok:
                print(f"SKIP  {arch} × {shape_name}: {reason}")
                continue
            for mp in pods:
                tag = f"{arch} × {shape_name} × {'2x16x16' if mp else '16x16'}"
                cell_file = os.path.join(
                    args.outdir,
                    f"{arch}__{shape_name}__{'2x16x16' if mp else '16x16'}.json",
                )
                if args.skip_existing and os.path.exists(cell_file):
                    print(f"SKIP  {tag}: record exists")
                    continue
                try:
                    if args.delta:
                        rec = run_cell_delta(arch, shape_name, mp, args.outdir)
                        print(f"OK    {tag}: analysis={rec['analysis_s']}s "
                              f"flops={rec['cost']['flops']:.3e} (delta)")
                        continue
                    rec = run_cell(arch, shape_name, mp, args.outdir,
                                   unroll=args.unroll)
                    m = rec["memory"]
                    # memory_analysis reports the per-device module already
                    per_dev = (m["argument_bytes"] or 0) / 2**30
                    print(
                        f"OK    {tag}: compile={rec['compile_s']}s "
                        f"flops={rec['cost']['flops']:.3e} "
                        f"args/dev={per_dev:.2f}GiB"
                    )
                except Exception as e:
                    failures += 1
                    print(f"FAIL  {tag}: {type(e).__name__}: {e}")
                    traceback.print_exc(limit=4)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
