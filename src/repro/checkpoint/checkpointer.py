"""Sharded checkpointing with manifest + async writes + elastic restore.

Layout:  <dir>/step_<n>/
            manifest.json           — tree structure, shapes, dtypes,
                                      plus caller-provided ``extra``
                                      metadata (the resilience driver
                                      records program fingerprint, step,
                                      rotation phase, ret_indices here)
            <leaf-key>.npy          — one file per leaf
            COMMITTED               — written last; partial checkpoints
                                      (preemption mid-write) are ignored

Elastic restore: leaves are loaded as host arrays and ``jax.device_put``
with the *target* sharding — the saved mesh and the restore mesh are
independent, so a run checkpointed on 512 chips restores onto 256 (or a
CPU smoke test) unchanged.  Async saves run on a daemon thread; ``wait``
joins before the next save or shutdown.

Retention and crash hygiene: after each successful COMMITTED save, the
``keep_last`` newest committed snapshots are retained and older ones
pruned; construction garbage-collects leftovers of preempted writers —
``step_*.tmp`` staging dirs and uncommitted ``step_*`` dirs.  The
per-instance ``stats`` counters (saves / prunes / gcs) are truthful:
a prune is a committed snapshot aged out, a gc is a partial dir removed.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_token(p) for p in path)
        out[key] = leaf
    return out


def _path_token(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


@dataclasses.dataclass
class CheckpointStats:
    """Per-Checkpointer counters: committed saves, retention prunes of
    committed snapshots, and startup garbage collections of partial
    (uncommitted / staging) directories."""

    saves: int = 0
    prunes: int = 0
    gcs: int = 0
    restores: int = 0

    def as_dict(self) -> dict:
        return {
            "saves": self.saves,
            "prunes": self.prunes,
            "gcs": self.gcs,
            "restores": self.restores,
        }


# Process-wide mirror for ``repro.obs.snapshot()``'s ``checkpoint.*``
# namespace: every instance bump also lands here (``_bump``), so the
# unified registry sees checkpoint traffic without holding references to
# short-lived Checkpointer instances.
_GLOBAL_STATS = CheckpointStats()


def global_stats() -> CheckpointStats:
    return _GLOBAL_STATS


class Checkpointer:
    def __init__(
        self,
        directory: str,
        keep: int = 3,
        keep_last: Optional[int] = None,
    ):
        self.dir = directory
        # ``keep_last`` is the canonical retention knob; ``keep`` remains
        # as the original spelling (same meaning) for existing callers
        self.keep = int(keep_last if keep_last is not None else keep)
        if self.keep < 1:
            raise ValueError(f"keep_last must be >= 1, got {self.keep}")
        self.stats = CheckpointStats()
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._startup_gc()

    def _bump(self, field: str) -> None:
        # per-instance truth plus the process-wide mirror obs reads
        setattr(self.stats, field, getattr(self.stats, field) + 1)
        setattr(_GLOBAL_STATS, field, getattr(_GLOBAL_STATS, field) + 1)

    def _startup_gc(self) -> None:
        """Remove leftovers of a preempted writer: ``step_*.tmp`` staging
        dirs and ``step_*`` dirs missing their COMMITTED marker.  A torn
        write is already *invisible* to restore; this reclaims its disk
        and keeps the directory listing honest."""
        for name in os.listdir(self.dir):
            path = os.path.join(self.dir, name)
            if re.fullmatch(r"step_\d+\.tmp", name):
                shutil.rmtree(path, ignore_errors=True)
                self._bump("gcs")
            elif re.fullmatch(r"step_\d+", name) and not os.path.exists(
                os.path.join(path, "COMMITTED")
            ):
                shutil.rmtree(path, ignore_errors=True)
                self._bump("gcs")

    # -- save ------------------------------------------------------------
    def save(
        self,
        step: int,
        tree,
        blocking: bool = False,
        extra: Optional[dict] = None,
    ) -> None:
        """Snapshot ``tree`` as ``step``.  ``extra`` is a JSON-able dict
        merged into the manifest under ``"extra"`` — metadata a resumer
        needs but that is not an array leaf."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            flat = _flatten(host)
            manifest: dict = {"step": step, "leaves": {}}
            if extra is not None:
                manifest["extra"] = extra
            for key, leaf in flat.items():
                fname = key.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fname), leaf)
                manifest["leaves"][key] = {
                    "file": fname,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            shutil.rmtree(path, ignore_errors=True)
            os.rename(tmp, path)
            self._bump("saves")
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.available_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True
            )
            self._bump("prunes")

    # -- restore ----------------------------------------------------------
    def available_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def manifest(self, step: Optional[int] = None) -> dict:
        """The manifest of ``step`` (default: latest committed) — leaf
        metadata plus whatever ``extra`` the saver recorded."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def restore(self, tree_like, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``tree_like``.

        ``shardings``: optional matching pytree of Shardings (elastic
        restore to a different mesh); default keeps host arrays.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten(tree_like)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for key in flat_like:
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(path, meta["file"]))
            if key in flat_shard:
                arr = jax.device_put(arr, flat_shard[key])
            loaded[key] = arr
        # rebuild via the treedef of tree_like
        paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = [
            loaded["/".join(_path_token(p) for p in path)] for path, _ in paths
        ]
        self._bump("restores")
        return jax.tree_util.tree_unflatten(treedef, leaves)
