"""Sharded checkpointing with manifest + async writes + elastic restore.

Layout:  <dir>/step_<n>/
            manifest.json           — tree structure, shapes, dtypes
            <leaf-key>.npy          — one file per leaf
            COMMITTED               — written last; partial checkpoints
                                      (preemption mid-write) are ignored

Elastic restore: leaves are loaded as host arrays and ``jax.device_put``
with the *target* sharding — the saved mesh and the restore mesh are
independent, so a run checkpointed on 512 chips restores onto 256 (or a
CPU smoke test) unchanged.  Async saves run on a daemon thread; ``wait``
joins before the next save or shutdown.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_token(p) for p in path)
        out[key] = leaf
    return out


def _path_token(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        structure = jax.tree_util.tree_structure(tree)

        def write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            flat = _flatten(host)
            manifest = {"step": step, "leaves": {}}
            for key, leaf in flat.items():
                fname = key.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fname), leaf)
                manifest["leaves"][key] = {
                    "file": fname,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            shutil.rmtree(path, ignore_errors=True)
            os.rename(tmp, path)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.available_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True
            )

    # -- restore ----------------------------------------------------------
    def available_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``tree_like``.

        ``shardings``: optional matching pytree of Shardings (elastic
        restore to a different mesh); default keeps host arrays.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten(tree_like)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for key in flat_like:
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(path, meta["file"]))
            if key in flat_shard:
                arr = jax.device_put(arr, flat_shard[key])
            loaded[key] = arr
        # rebuild via the treedef of tree_like
        paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = [
            loaded["/".join(_path_token(p) for p in path)] for path, _ in paths
        ]
        return jax.tree_util.tree_unflatten(treedef, leaves)
