"""Resumable, fault-tolerant driver around compiled stencils.

``CompiledStencil.time_loop`` is a fire-and-forget ``lax.fori_loop``:
any interruption loses the run, and a restart cannot change the mesh
factorization.  ``ResilientLoop`` refactors the same arithmetic
(``CompiledStencil.epochs`` / ``advance`` — one rotation rule shared
with ``time_loop``) into an epoch-granular driver that

- snapshots the **global** state through ``repro.checkpoint`` every
  ``checkpoint_every`` epochs.  Snapshots are *epoch-aligned*: they only
  happen at ``step % exchange_every == 0``, which is the invariant that
  keeps deep-halo temporal tiling consistent — mid-epoch there is no
  globally-meaningful state to save (redundant boundary compute is in
  flight);
- records ``(program fingerprint, step, time-buffer rotation phase,
  ret_indices)`` in the checkpoint manifest, so a resumer can verify it
  is continuing the *same* simulation with the *same* rotation
  arithmetic;
- on ``resume(program, dir, new_target)`` re-compiles for a **different**
  mesh factorization / rank count and reshards the restored host arrays
  through ``dist/sharding.reshard`` — the distribution layer is bitwise
  (tests/dist_worker.py), so a killed-and-resumed run across a mesh
  change ends bitwise-identical to the uninterrupted run.

Fault injection (``faults.FaultPlan``) hooks the epoch boundary and the
post-checkpoint moment, so kill / straggle / torn-write scenarios are
deterministic and testable.
"""
from __future__ import annotations

import time
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro import api
from repro.checkpoint.checkpointer import Checkpointer
from repro.obs import trace as _obs
from repro.resilience.faults import FaultPlan, SimulatedFault


class ResumeError(ValueError):
    """A checkpoint directory that cannot continue this run: wrong
    program, epoch-misaligned step for the new target, or a manifest
    without resilience metadata."""


class ResilientLoop:
    """An epoch-granular, checkpointing time loop over one compiled
    stencil.

    ``state`` is the input buffers oldest → newest (exactly what
    ``CompiledStencil.time_loop`` takes); ``n_steps`` counts single time
    steps and must be a whole number of the target's epochs.
    ``checkpoint_every`` counts *epochs* between snapshots (0 — or no
    ``directory`` — disables checkpointing).  ``run()`` drives the loop
    to ``n_steps`` and returns the final state; an injected or real
    fault leaves the last committed snapshot on disk for ``resume``.
    """

    def __init__(
        self,
        program,
        target=None,
        state: Sequence[Any] = (),
        n_steps: int = 0,
        *,
        directory: Optional[str] = None,
        checkpoint_every: int = 1,
        keep_last: int = 3,
        fault_plan: Optional[FaultPlan] = None,
        async_saves: bool = False,
        start_step: int = 0,
        _rotation_phase: int = 0,
        _resumed_from: Optional[int] = None,
    ) -> None:
        self.program = program
        self.target = target if target is not None else api.Target()
        self.compiled = api.compile(program, self.target)
        self.n_steps = int(n_steps)
        self.k = self.compiled.target.exchange_every
        self.total_epochs = self.compiled.epochs(self.n_steps)
        if start_step % self.k != 0:
            raise ResumeError(
                f"start_step={start_step} is not an epoch boundary of "
                f"Target(exchange_every={self.k}); checkpoints are "
                "epoch-aligned, so a resumable step must be a multiple of k"
            )
        if not 0 <= start_step <= self.n_steps:
            raise ValueError(
                f"start_step={start_step} outside [0, n_steps={self.n_steps}]"
            )
        inputs = self.compiled.input_indices
        state = tuple(state)
        if len(state) != len(inputs):
            raise ValueError(
                f"program {program.name!r} takes {len(inputs)} input "
                f"buffer(s) (oldest → newest), got {len(state)}"
            )
        for arr, idx in zip(state, inputs):
            want = tuple(program.field_args[idx].type.bounds.shape)
            if tuple(np.shape(arr)) != want:
                raise ValueError(
                    f"input buffer for field {program.field_names[idx]!r} "
                    f"has shape {tuple(np.shape(arr))}, expected {want}"
                )
        self.state = self._place(state)
        self.step_count = int(start_step)
        self.checkpoint_every = int(checkpoint_every)
        self.fault_plan = fault_plan
        self.async_saves = bool(async_saves)
        self.resumed_from = _resumed_from
        self._phase = int(_rotation_phase) % max(1, len(state))
        self._epoch_fn = None
        self.events: list = []
        self.checkpointer = (
            Checkpointer(directory, keep_last=keep_last)
            if directory and self.checkpoint_every > 0
            else None
        )

    # -- state placement -------------------------------------------------
    def _place(self, state: tuple) -> tuple:
        """Put (possibly host) input arrays onto the target's mesh with
        the compiled partition specs — the resharding seam that makes
        resume-onto-a-different-mesh work (``dist/sharding.reshard``)."""
        from repro.dist.sharding import reshard

        specs = tuple(
            self.compiled.partition_specs[i]
            for i in self.compiled.input_indices
        )
        mesh = (
            self.compiled.target.mesh
            if self.compiled.target.distributed
            else None
        )
        return reshard(state, mesh, specs)

    # -- driving ---------------------------------------------------------
    @property
    def epoch(self) -> int:
        """The absolute epoch index the loop will advance next."""
        return self.step_count // self.k

    @property
    def done(self) -> bool:
        return self.step_count >= self.n_steps

    def advance_epoch(self) -> None:
        """One epoch: fault hooks, compiled advance + rotation, and the
        epoch-aligned checkpoint when the cadence lands."""
        e, step = self.epoch, self.step_count
        if self.fault_plan is not None:
            try:
                self.fault_plan.before_epoch(e, step)
            except SimulatedFault:
                # "the node died": whatever save was in flight either
                # committed or is a torn partial — settle it so the test
                # harness sees a deterministic directory, then propagate
                if self.checkpointer is not None:
                    self.checkpointer.wait()
                self.events.append(("fault", e, step))
                raise
        if self._epoch_fn is None:
            self._epoch_fn = self.compiled.step()
        if _obs.enabled():
            with _obs.span("epoch", cat="dispatch", rank=None,
                           program=self.program.name, epoch=e, step_begin=step,
                           k=self.k, ranks=self.compiled._n_ranks):
                outs = self._epoch_fn(*self.state)
                outs = outs if isinstance(outs, tuple) else (outs,)
                jax.block_until_ready(outs)
        else:
            outs = self._epoch_fn(*self.state)
            outs = outs if isinstance(outs, tuple) else (outs,)
        self.state = tuple(self.state[len(outs):]) + tuple(outs)
        self._phase = (self._phase + len(outs)) % max(1, len(self.state))
        self.step_count += self.k
        self.events.append(("epoch", e, self.step_count))
        if self._checkpoint_due():
            self.save_checkpoint()

    def _checkpoint_due(self) -> bool:
        if self.checkpointer is None:
            return False
        return (self.step_count // self.k) % self.checkpoint_every == 0

    def save_checkpoint(self) -> None:
        """Snapshot the global state at the current (epoch-aligned) step.
        The manifest carries everything a resumer verifies: program
        fingerprint, step, rotation phase and ret_indices."""
        assert self.step_count % self.k == 0, "checkpoints are epoch-aligned"
        tree = {"state": {f"b{i}": a for i, a in enumerate(self.state)}}
        extra = {
            "program_fingerprint": self.program.fingerprint,
            "program_name": self.program.name,
            "step": self.step_count,
            "n_steps": self.n_steps,
            "exchange_every": self.k,
            "rotation_phase": self._phase,
            "ret_indices": list(self.compiled.ret_indices),
            "input_indices": list(self.compiled.input_indices),
            "target_fingerprint": self.compiled.target.fingerprint,
        }
        t0 = time.perf_counter()
        with _obs.span("checkpoint.save", cat="checkpoint",
                       step=self.step_count, blocking=not self.async_saves):
            self.checkpointer.save(
                self.step_count, tree, blocking=not self.async_saves,
                extra=extra,
            )
        self.events.append(
            ("checkpoint", self.step_count, time.perf_counter() - t0)
        )
        if self.fault_plan is not None:
            self.fault_plan.after_checkpoint(self.checkpointer, self.step_count)

    def run(self, max_epochs: Optional[int] = None) -> tuple:
        """Drive to ``n_steps`` (or ``max_epochs`` more epochs) and
        return the final state tuple.  Joins any pending async save
        before returning, so a completed ``run`` never leaves a torn
        write behind."""
        budget = max_epochs if max_epochs is not None else self.total_epochs
        advanced = 0
        while not self.done and advanced < budget:
            self.advance_epoch()
            advanced += 1
        if self.checkpointer is not None:
            self.checkpointer.wait()
        return self.state


def resume(
    program,
    directory: str,
    target=None,
    *,
    step: Optional[int] = None,
    n_steps: Optional[int] = None,
    checkpoint_every: int = 1,
    keep_last: int = 3,
    fault_plan: Optional[FaultPlan] = None,
    async_saves: bool = False,
) -> ResilientLoop:
    """Resume a checkpointed run from ``directory`` onto ``target``.

    ``target`` may describe a **different** mesh factorization / rank
    count than the killed run: the snapshot holds *global* host arrays,
    which are resharded through ``dist/sharding`` for the new
    decomposition — and the distribution layer is bitwise, so the
    resumed run's final state equals the uninterrupted run's.

    The manifest is verified before anything compiles: the checkpoint
    must carry resilience metadata, belong to the same program
    (fingerprint), and sit on an epoch boundary of the *new* target's
    ``exchange_every``.
    """
    ckpt = Checkpointer(directory, keep_last=keep_last)  # startup GC runs
    manifest = ckpt.manifest(step)
    meta = manifest.get("extra")
    if not meta or "program_fingerprint" not in meta:
        raise ResumeError(
            f"checkpoint at step {manifest.get('step')} in {directory} "
            "carries no resilience metadata (not written by ResilientLoop)"
        )
    if meta["program_fingerprint"] != program.fingerprint:
        raise ResumeError(
            f"checkpoint belongs to program {meta.get('program_name')!r} "
            f"(fingerprint {meta['program_fingerprint']}), not "
            f"{program.name!r} ({program.fingerprint}); resuming a "
            "different simulation would be silent corruption"
        )
    saved_step = int(meta["step"])
    total = int(n_steps if n_steps is not None else meta["n_steps"])
    target = target if target is not None else api.Target()
    k = target.exchange_every
    if saved_step % k != 0 or (total - saved_step) % k != 0:
        raise ResumeError(
            f"checkpointed step {saved_step} of {total} cannot resume onto "
            f"Target(exchange_every={k}): both the resume point and the "
            f"remaining {total - saved_step} steps must be whole epochs "
            f"(the killed run used exchange_every="
            f"{meta.get('exchange_every')})"
        )
    # restore host arrays in the saved buffer order
    leaves = manifest["leaves"]
    n_bufs = len(leaves)
    want_inputs = meta.get("input_indices")
    tree_like = {
        "state": {f"b{i}": np.zeros(()) for i in range(n_bufs)}
    }
    with _obs.span("checkpoint.restore", cat="checkpoint", step=saved_step,
                   program=program.name):
        restored = ckpt.restore(tree_like, step=saved_step)
    state = tuple(restored["state"][f"b{i}"] for i in range(n_bufs))
    loop = ResilientLoop(
        program,
        target,
        state,
        total,
        directory=directory,
        checkpoint_every=checkpoint_every,
        keep_last=keep_last,
        fault_plan=fault_plan,
        async_saves=async_saves,
        start_step=saved_step,
        _rotation_phase=int(meta.get("rotation_phase", 0)),
        _resumed_from=saved_step,
    )
    if want_inputs is not None and list(loop.compiled.input_indices) != list(
        want_inputs
    ):
        raise ResumeError(
            f"input buffer layout changed: checkpoint holds fields "
            f"{want_inputs}, the new target consumes "
            f"{list(loop.compiled.input_indices)}"
        )
    return loop
