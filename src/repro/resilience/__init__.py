"""repro.resilience — elastic, fault-tolerant time loops.

Production multi-day stencil runs (Devito/PSyclone's deployment reality)
survive preemption by checkpointing and resume *elastically* — possibly
onto a different mesh factorization or rank count.  This package is that
robustness layer over the PR 3 compile surface:

    from repro.resilience import ResilientLoop, resume, FaultPlan

    loop = ResilientLoop(program, target, (u0,), 256,
                         directory="ckpt/", checkpoint_every=4)
    final = loop.run()                       # snapshots every 4 epochs

    # ... killed mid-run (preemption, or an injected FaultPlan) ...

    loop = resume(program, "ckpt/", new_target)   # e.g. 4 ranks -> 2
    final = loop.run()       # bitwise-equal to the uninterrupted run

- ``driver.py``   — ``ResilientLoop`` / ``resume``: the epoch-aligned
  checkpointing loop and the reshard-and-recompile resume path.
- ``faults.py``   — ``FaultPlan`` / ``SimulatedFault``: deterministic
  kill / straggler / torn-write injection for tests and the soak
  benchmark.
- ``migrate.py``  — ``evacuate`` / ``admit``: request migration between
  stencil-serving engines (``StencilEngine.evacuate`` delegates here).

Also reachable as ``repro.api.resilient_loop`` / ``repro.api.resume``.
"""
from repro.resilience.driver import ResilientLoop, ResumeError, resume
from repro.resilience.faults import FaultPlan, SimulatedFault, truncate_snapshot
from repro.resilience.migrate import admit, evacuate

__all__ = [
    "FaultPlan",
    "ResilientLoop",
    "ResumeError",
    "SimulatedFault",
    "admit",
    "evacuate",
    "resume",
    "truncate_snapshot",
]
