"""Request migration for the stencil-serving engine.

The serve layer's first evacuation primitive: a ``StencilEngine`` under
drain (autoscaling down, host preemption notice, rebalancing) writes
every live request of a fingerprint bucket to epoch-aligned checkpoints
(``evacuate``), and a *second* engine — possibly in another process, on
different hardware — admits them mid-run (``admit``): the restored state
is resubmitted with ``start_step`` at the evacuated step count, so each
request finishes with a final state bitwise-equal to an unmigrated run.

Layout: one checkpoint directory per request under the evacuation root,

    <root>/req_<rid>/step_<steps_done>/...

with the manifest's ``extra`` carrying the request's identity (program
fingerprint, serialized Target via ``tune.cache.target_to_dict``,
n_steps, steps_done, frame cadence, tenant).  ``admit`` rebuilds the
Target against the *receiving* engine's device inventory
(``target_from_dict``) unless the caller overrides it — migration across
a mesh change composes with the resilience driver's resharding story.

Frame callbacks (``on_frame``) are process-local closures and do not
migrate; an evacuated request resumes with buffered (pull-iterator)
frames only.
"""
from __future__ import annotations

import os
import re
from typing import Optional

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.resilience.driver import ResumeError


def evacuate(engine, program_fingerprint: str, directory: str) -> list:
    """Drain every bucket of ``program_fingerprint`` in ``engine`` to
    checkpoints under ``directory``; returns the evacuated requests.

    Running requests are snapshotted at their current (epoch-aligned)
    ``steps_done`` and their slots reclaimed; queued requests are
    evacuated at step 0.  Each request's status becomes ``"evacuated"``
    and it no longer occupies the engine.
    """
    from repro.serve.stencil.request import EVACUATED
    from repro.tune.cache import target_to_dict

    evacuated = []
    for key, group in list(engine.scheduler.groups.items()):
        if key[0] != program_fingerprint:
            continue
        # running slots first (epoch-aligned state lives in the pool)
        for slot, req in sorted(group.active.items()):
            _save_request(
                directory, req, group.read_slot(slot), target_to_dict
            )
            engine.scheduler.reclaim(group, slot)
            req.status = EVACUATED
            req.slot = -1
            evacuated.append(req)
        # queued requests still hold their submitted state
        while group.queue:
            req = group.queue.popleft()
            _save_request(directory, req, req.state, target_to_dict)
            req.status = EVACUATED
            evacuated.append(req)
    engine.metrics.requests_evacuated += len(evacuated)
    return evacuated


def _save_request(directory: str, req, state, target_to_dict) -> None:
    ckpt = Checkpointer(
        os.path.join(directory, f"req_{req.rid}"), keep_last=1
    )
    tree = {"state": {f"b{i}": a for i, a in enumerate(state)}}
    ckpt.save(
        req.steps_done,
        tree,
        blocking=True,
        extra={
            "program_fingerprint": req.program.fingerprint,
            "program_name": req.program.name,
            "target": target_to_dict(req.target),
            "n_steps": req.n_steps,
            "steps_done": req.steps_done,
            "frame_every": req.frame_every,
            "tenant": req.tenant,
            "rid": req.rid,
        },
    )


def admit(engine, directory: str, programs, target=None) -> list:
    """Admit every evacuated request under ``directory`` into ``engine``.

    ``programs`` resolves checkpoint fingerprints back to ``Program``
    objects (a single Program, an iterable, or a {fingerprint: Program}
    dict — IR is code, not data, so the admitting process must hold it).
    ``target`` overrides the serialized Target for every admitted
    request (e.g. migrating onto a different mesh); by default the saved
    Target is rebuilt against this process's device inventory.  Returns
    the new ``RequestHandle``s, in rid order of the evacuated originals.
    """
    from repro.tune.cache import target_from_dict

    by_fp = _program_index(programs)
    handles = []
    try:
        listing = os.listdir(directory)
    except OSError:
        listing = []
    names = sorted(
        (n for n in listing if re.fullmatch(r"req_\d+", n)),
        key=lambda n: int(n.split("_")[1]),
    )
    if not names:
        raise ResumeError(f"no evacuated requests under {directory}")
    for name in names:
        ckpt = Checkpointer(os.path.join(directory, name))
        manifest = ckpt.manifest()
        meta = manifest.get("extra") or {}
        fp = meta.get("program_fingerprint")
        program = by_fp.get(fp)
        if program is None:
            raise ResumeError(
                f"evacuated request {name} is program "
                f"{meta.get('program_name')!r} ({fp}); no matching Program "
                f"was provided (have {sorted(by_fp)})"
            )
        req_target = (
            target if target is not None else target_from_dict(meta["target"])
        )
        n_bufs = len(manifest["leaves"])
        tree_like = {"state": {f"b{i}": np.zeros(()) for i in range(n_bufs)}}
        restored = ckpt.restore(tree_like)
        state = tuple(restored["state"][f"b{i}"] for i in range(n_bufs))
        handles.append(
            engine.submit(
                program,
                state,
                int(meta["n_steps"]),
                target=req_target,
                frame_every=int(meta.get("frame_every", 0)),
                tenant=meta.get("tenant"),
                start_step=int(meta["steps_done"]),
            )
        )
        engine.metrics.requests_resumed += 1
    return handles


# --------------------------------------------------------------------------
# in-process drain/readmit — the slot-pool *resize* path
# --------------------------------------------------------------------------


def drain_group(engine, group, directory: str) -> list:
    """Checkpoint every active request of ``group`` at its epoch-aligned
    ``steps_done`` and release its slot, keeping the request objects —
    unlike ``evacuate``, handles, ``on_frame`` callbacks and buffered
    frames all stay valid, because the same objects readmit into the
    rebuilt pool (``readmit_group``).  This is the engine's pool-resize
    primitive: the checkpoint roundtrip is exactly PR 8's migration
    contract, so results after a resize stay bitwise-equal."""
    from repro.tune.cache import target_to_dict

    drained = []
    for slot, req in sorted(group.active.items()):
        _save_request(directory, req, group.read_slot(slot), target_to_dict)
        engine.scheduler.reclaim(group, slot)
        req.slot = -1
        drained.append(req)
    engine.metrics.requests_evacuated += len(drained)
    return drained


def readmit_group(engine, group, directory: str, requests) -> list:
    """Restore each drained request's checkpointed state and requeue the
    SAME object at the front of ``group``'s queue (rid order), ahead of
    requests that arrived during the resize — a resize must never reorder
    a running request behind the backlog that triggered it.  Admission
    recomputes the frame cadence from the preserved ``steps_done``, so
    streamed frame ``step`` values stay strictly increasing across the
    hop.  Returns the readmitted requests."""
    from repro.serve.stencil.request import QUEUED

    restored = []
    for req in sorted(requests, key=lambda r: r.rid):
        ckpt = Checkpointer(os.path.join(directory, f"req_{req.rid}"))
        manifest = ckpt.manifest()
        n_bufs = len(manifest["leaves"])
        tree_like = {"state": {f"b{i}": np.zeros(()) for i in range(n_bufs)}}
        tree = ckpt.restore(tree_like)
        req.state = tuple(tree["state"][f"b{i}"] for i in range(n_bufs))
        req.status = QUEUED
        restored.append(req)
    group.queue.extendleft(reversed(restored))
    engine.metrics.requests_resumed += len(restored)
    return restored


def _program_index(programs) -> dict:
    if hasattr(programs, "fingerprint"):  # a single Program
        return {programs.fingerprint: programs}
    if isinstance(programs, dict):
        return dict(programs)
    return {p.fingerprint: p for p in programs}
