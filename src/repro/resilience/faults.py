"""Deterministic fault injection for the resilient time-loop driver.

Production long runs die in three characteristic ways; a ``FaultPlan``
reproduces each one *deterministically* so tests and the soak benchmark
(``benchmarks/resilience_soak.py``) can assert recovery instead of
hoping for it:

- **kill-at-epoch** — the process is preempted at an epoch boundary:
  ``before_epoch`` raises ``SimulatedFault`` right before epoch
  ``kill_at_epoch`` would advance (absolute epoch index — a resumed run
  that passes the same plan will NOT re-raise for epochs it already
  completed, because the driver resumes past them);
- **slow rank** — a straggler: ``delay_s`` seconds of sleep before every
  ``delay_every``-th epoch, for measuring how checkpoint cadence and
  stragglers compose;
- **checkpoint-write truncation** — a torn write: after the snapshot at
  ``truncate_step`` commits, its COMMITTED marker is removed and one
  leaf file is cut in half.  Restore must fall back to the previous
  committed snapshot, and ``Checkpointer`` startup GC must reclaim the
  wreck.

The plan is pure configuration (frozen dataclass); the driver calls the
hooks.  Nothing here is random — a FaultPlan replayed over the same run
produces the same fault at the same point.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional


class SimulatedFault(RuntimeError):
    """A deterministic injected failure (stands in for preemption /
    node loss); carries the epoch it struck at."""

    def __init__(self, epoch: int, step: int) -> None:
        super().__init__(
            f"simulated fault: killed before epoch {epoch} (step {step})"
        )
        self.epoch = epoch
        self.step = step


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault schedule for one driver run."""

    #: raise SimulatedFault before advancing this absolute epoch index
    kill_at_epoch: Optional[int] = None
    #: straggler delay injected before epochs (0.0 = none)
    delay_s: float = 0.0
    #: apply the delay before every Nth epoch (1 = every epoch)
    delay_every: int = 1
    #: corrupt the committed snapshot written at this *step* count
    truncate_step: Optional[int] = None

    def __post_init__(self) -> None:
        if self.delay_every < 1:
            raise ValueError(
                f"delay_every must be >= 1, got {self.delay_every}"
            )
        if self.delay_s < 0.0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")

    # -- driver hooks ----------------------------------------------------
    def before_epoch(self, epoch: int, step: int) -> None:
        """Called by the driver before advancing absolute epoch
        ``epoch`` (the run is at ``step`` completed time steps)."""
        if self.delay_s > 0.0 and epoch % self.delay_every == 0:
            time.sleep(self.delay_s)
        if self.kill_at_epoch is not None and epoch == self.kill_at_epoch:
            raise SimulatedFault(epoch, step)

    def after_checkpoint(self, checkpointer, step: int) -> bool:
        """Called after the snapshot at ``step`` committed; returns True
        when this plan truncated it."""
        if self.truncate_step is None or step != self.truncate_step:
            return False
        checkpointer.wait()  # the async writer must finish before we maim it
        truncate_snapshot(checkpointer.dir, step)
        return True


def truncate_snapshot(directory: str, step: int) -> None:
    """Simulate a torn checkpoint write: drop the COMMITTED marker and
    halve the first leaf file of the ``step`` snapshot.  Restore-side
    code must treat the result exactly like a writer preempted mid-save."""
    path = os.path.join(directory, f"step_{step:08d}")
    marker = os.path.join(path, "COMMITTED")
    if os.path.exists(marker):
        os.unlink(marker)
    for name in sorted(os.listdir(path)):
        if name.endswith(".npy"):
            leaf = os.path.join(path, name)
            size = os.path.getsize(leaf)
            with open(leaf, "r+b") as f:
                f.truncate(size // 2)
            break
