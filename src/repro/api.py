"""One compile surface: ``Program`` / ``Target`` / ``compile``.

The paper's central claim is that three stencil DSLs share one
compilation stack; this module is the one *API* they share, following
MLIR's module → pass-pipeline → target structure and Devito's
Operator-as-cached-artifact design:

    prog   = oec_like.ProgramBuilder(...).finish(boundary="periodic")
    target = Target(mesh=mesh, strategy=make_strategy_2d((4, 2)))
    step   = compile(prog, target)      # CompiledStencil
    u1 = step(u0, out0)                 # global arrays in / out
    step.pipeline_report                # per-pass timings
    step.local_ir                       # the comm-lowered rank-local IR
    step.cost()                         # roofline terms (launch/roofline)

- ``Program``  — the frontend-neutral IR artifact every frontend
  produces: a verified ``func.func`` of stencil ops plus metadata
  (boundary condition, field names, rank) and a stable fingerprint.
- ``Target``   — a frozen description of *where and how* to compile:
  device mesh, decomposition strategy, compute backend, pass-pipeline
  spec, pallas/donation knobs.  Mismatches (unknown backend, strategy
  grid vs mesh axes) are rejected at construction, not deep inside
  lowering.
- ``compile(program, target) -> CompiledStencil`` — runs the shared
  pass pipeline and wraps the interpreter in ``shard_map``/``jit``.
  Results are cached process-wide on ``(program.fingerprint,
  target.fingerprint)``, so sweep loops (benchmarks), the serve engine
  and ``repro.dist`` never re-run passes or re-trace for a program +
  target they have already compiled.  ``cache_stats()`` reports
  hits/misses; ``clear_cache()`` resets.

``repro.core.program.StencilComputation`` remains as a thin deprecated
shim over this surface (see DESIGN.md §1 for the migration table).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
from collections import OrderedDict
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import ir
from repro.core.dialects import stencil
from repro.core.lowering import StencilInterpreter
from repro.obs import trace as _obs
from repro.core.passes import (
    PassManager,
    PipelineContext,
    build_pipeline,
)
from repro.core.passes.decompose import SlicingStrategy


class TargetError(ValueError):
    """A target description that can never compile (bad backend, strategy
    grid not matching the mesh, decomposed dim outside the program rank)."""


# --------------------------------------------------------------------------
# Program — the frontend-neutral IR artifact
# --------------------------------------------------------------------------


class Program:
    """A verified stencil program plus the metadata compilation needs.

    All three frontends produce this: ``devito_like.Operator.program``,
    ``psyclone_like.recognize(...)``, ``oec_like.ProgramBuilder.finish()``.
    The fingerprint is taken at construction (stable textual IR +
    boundary), so mutate the ``FuncOp`` *before* wrapping it.
    """

    def __init__(
        self,
        func: ir.FuncOp,
        boundary: str = "zero",
        field_names: Optional[Sequence[str]] = None,
        name: Optional[str] = None,
    ) -> None:
        if boundary not in ("zero", "periodic"):
            raise ValueError(f"unknown boundary condition {boundary!r}")
        ir.verify_module(func)
        self.func = func
        self.boundary = boundary
        self.name = name or func.sym_name
        self.field_args = [
            a for a in func.body.args if isinstance(a.type, stencil.FieldType)
        ]
        self.field_names = tuple(
            field_names
            if field_names is not None
            else (f"field{i}" for i in range(len(self.field_args)))
        )
        if len(self.field_names) != len(self.field_args):
            raise ValueError(
                f"{len(self.field_names)} field names for "
                f"{len(self.field_args)} field arguments"
            )
        # metadata is part of the identity: a cache hit must hand back an
        # artifact whose .program matches in name/fields, not just in IR
        self._salt = (
            f"boundary={boundary}",
            f"name={self.name}",
            "fields=" + ",".join(self.field_names),
        )
        self.fingerprint = ir.fingerprint(func, *self._salt)

    @property
    def rank(self) -> int:
        return self.field_args[0].type.bounds.rank if self.field_args else 0

    @property
    def output_fields(self) -> list:
        """Field arguments that are stored to, in first-store order."""
        return _stored_fields(self.func)

    def ir_text(self) -> str:
        """The stable textual IR (what the fingerprint hashes)."""
        return ir.print_module(self.func)

    def global_zeros(self, dtype=jnp.float32) -> list:
        return [jnp.zeros(f.type.bounds.shape, dtype) for f in self.field_args]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Program({self.name!r}, rank={self.rank}, "
            f"fields={list(self.field_names)}, boundary={self.boundary!r}, "
            f"fingerprint={self.fingerprint})"
        )


# --------------------------------------------------------------------------
# Target — where and how to compile
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Target:
    """Frozen bundle of everything 'backend' about a compile.

    ``mesh``/``strategy`` describe the decomposition (both ``None`` =
    single device); ``backend`` picks the compute lowering; ``pipeline``
    is an explicit pass spec (DESIGN.md §2 grammar) overriding the
    ``fuse``/``cse``/``diagonal``/``overlap`` flags; the remaining knobs
    control pallas codegen and jit wrapping.  Validation happens here, at
    construction — a constructed Target either compiles or exposes a
    program-shape mismatch (checked against the program in ``compile``).
    """

    mesh: Optional[Mesh] = None
    strategy: Optional[SlicingStrategy] = None
    backend: str = "jnp"  # "jnp" | "pallas"
    pipeline: Optional[str] = None
    fuse: bool = True
    cse: bool = True
    overlap: bool = False
    diagonal: bool = False
    # Deep-halo temporal tiling (temporal-tile pass): exchange a depth-k
    # halo once, then run k stencil steps with redundant boundary compute
    # before the next exchange.  One call of the compiled artifact is one
    # *epoch* of k time steps; ``time_loop`` keeps counting single steps
    # and iterates in epochs.  1 = one exchange per step (the baseline).
    exchange_every: int = 1
    # Fuse each epoch's apply chain into ONE Pallas megakernel
    # (fuse-epoch-kernel pass + kernels/epoch_kernel.py): the k sub-steps'
    # intermediates stay in fast memory, one pallas_call dispatch per
    # epoch instead of k.  Requires backend="pallas"; incompatible with
    # overlap (split frame applies cannot fuse into one kernel).
    fused_epoch: bool = False
    # Slot mesh axis (serving/ensemble batching): name of a mesh axis that
    # carries a leading *batch* ("slot") dimension instead of an array
    # dimension.  The compiled step then takes arrays of shape
    # ``[B, *field_shape]`` and runs as ONE ``shard_map`` over
    # ``(slot, *spatial)`` — the batch dim is sharded over the slot axis
    # and each device block vmaps the rank-local stencil over its rows,
    # so halo exchanges stay per-slot-correct (collectives only ever run
    # over the spatial axes).  ``B`` must divide by the slot-axis size at
    # call time.  Factored out of the device inventory with
    # ``pooled_target`` / ``dist.sharding.factor_slot_mesh``; this is how
    # the serve engine dispatches a whole distributed slot pool as one
    # pooled call (DESIGN.md §9).
    slot_axis: Optional[str] = None
    # None resolves via kernels.default_interpret(): interpret mode on
    # CPU-only hosts (the correctness oracle), native Pallas when an
    # accelerator is present; REPRO_PALLAS_INTERPRET overrides.
    pallas_interpret: Optional[bool] = None
    pallas_tile: Optional[tuple] = None
    # Donate every field buffer to jit (classic double-buffer rotation:
    # the caller hands over ownership; inputs are invalidated after the
    # call).  Off by default — only safe when the caller rotates buffers.
    donate: bool = False
    jit: bool = True

    def __post_init__(self) -> None:
        if self.backend not in ("jnp", "pallas"):
            raise TargetError(
                f"unknown backend {self.backend!r}; expected 'jnp' or 'pallas'"
            )
        if self.pallas_tile is not None:
            object.__setattr__(self, "pallas_tile", tuple(self.pallas_tile))
        if self.pallas_interpret is None:
            from repro.kernels import default_interpret

            object.__setattr__(self, "pallas_interpret", default_interpret())
        else:
            object.__setattr__(
                self, "pallas_interpret", bool(self.pallas_interpret)
            )
        if self.fused_epoch:
            if self.backend != "pallas":
                raise TargetError(
                    f"Target(fused_epoch=True) requires backend='pallas' "
                    f"(the epoch megakernel IS a pallas_call), got "
                    f"backend={self.backend!r}"
                )
            if self.overlap:
                raise TargetError(
                    "Target(fused_epoch=True) is incompatible with "
                    "overlap=True: split interior/frame applies cannot fuse "
                    "into one epoch kernel"
                )
        if int(self.exchange_every) != self.exchange_every or self.exchange_every < 1:
            raise TargetError(
                f"exchange_every must be a positive integer (1 = exchange "
                f"every step), got {self.exchange_every!r}"
            )
        object.__setattr__(self, "exchange_every", int(self.exchange_every))
        if self.pipeline is not None:
            from repro.core.passes import parse_pipeline

            stages = parse_pipeline(self.pipeline)  # raises if malformed
            # an explicit pipeline must agree with exchange_every: the
            # time_loop epoch arithmetic is driven by the Target knob
            k_spec = 1
            has_fuse_stage = any(
                name == "fuse-epoch-kernel" for name, _ in stages
            )
            if has_fuse_stage != self.fused_epoch:
                raise TargetError(
                    f"explicit pipeline "
                    f"{'contains' if has_fuse_stage else 'lacks'} the "
                    f"fuse-epoch-kernel stage but "
                    f"Target(fused_epoch={self.fused_epoch}); set both "
                    "consistently (the kernel routing is driven by the "
                    "Target knob)"
                )
            for name, opts in stages:
                if name == "temporal-tile":
                    try:
                        k_spec = int(opts.get("k", self.exchange_every))
                    except ValueError:
                        raise TargetError(
                            f"pipeline stage temporal-tile: k must be an "
                            f"integer, got {opts.get('k')!r}"
                        )
            if k_spec != self.exchange_every:
                raise TargetError(
                    f"pipeline stage temporal-tile{{k={k_spec}}} disagrees "
                    f"with Target(exchange_every={self.exchange_every}); "
                    "set both to the same epoch depth"
                )
        if self.slot_axis is not None:
            # validated here like exchange_every: a slot-axis target either
            # compiles or names the mismatch at construction
            if not isinstance(self.slot_axis, str) or not self.slot_axis:
                raise TargetError(
                    f"slot_axis must be a mesh axis name, got "
                    f"{self.slot_axis!r}"
                )
            if self.mesh is None:
                raise TargetError(
                    f"Target(slot_axis={self.slot_axis!r}) needs a mesh "
                    "carrying that axis; factor one out of the device "
                    "inventory with api.pooled_target / "
                    "dist.sharding.factor_slot_mesh"
                )
            if self.slot_axis not in self.mesh.axis_names:
                raise TargetError(
                    f"slot_axis {self.slot_axis!r} not in mesh axes "
                    f"{tuple(self.mesh.axis_names)}"
                )
            if self.strategy is not None and self.slot_axis in tuple(
                self.strategy.axis_names
            ):
                raise TargetError(
                    f"slot_axis {self.slot_axis!r} is already a spatial "
                    f"decomposition axis of the strategy "
                    f"{tuple(self.strategy.axis_names)}; the slot axis "
                    "carries the batch dimension, not an array dimension"
                )
        s = self.strategy
        if s is not None:
            decomposed = [
                (g, ax) for g, ax in zip(s.grid_shape, s.axis_names) if g > 1
            ]
            if decomposed and self.mesh is None:
                raise TargetError(
                    f"strategy decomposes over {[ax for _, ax in decomposed]} "
                    "but no mesh was given"
                )
            for g, ax in decomposed:
                if ax not in (self.mesh.axis_names if self.mesh else ()):
                    raise TargetError(
                        f"strategy axis {ax!r} not in mesh axes "
                        f"{tuple(self.mesh.axis_names)}"
                    )
                if self.mesh.shape[ax] != g:
                    raise TargetError(
                        f"strategy grid size {g} on axis {ax!r} != mesh size "
                        f"{self.mesh.shape[ax]}"
                    )

    # ------------------------------------------------------------------
    @classmethod
    def auto(cls, ranks: Optional[int] = None, **overrides) -> "Target":
        """Device discovery: decompose 1-D over the available devices
        (or the first ``ranks`` of them); single-device target when only
        one device exists."""
        import numpy as np

        from repro.core.passes.decompose import make_strategy_1d

        devices = jax.devices()
        n = len(devices) if ranks is None else int(ranks)
        if n > len(devices):
            raise TargetError(f"requested {n} ranks, have {len(devices)} devices")
        if n <= 1:
            return cls(**overrides)
        return cls(
            mesh=Mesh(np.array(devices[:n]), ("x",)),
            strategy=make_strategy_1d(n),
            **overrides,
        )

    @classmethod
    def tuned(
        cls,
        program: "Program",
        ranks: Optional[int] = None,
        *,
        measure: bool = True,
        cache: bool = True,
        **tune_kwargs,
    ) -> "Target":
        """The autotuned target for ``program`` on this machine
        (``repro.tune``): enumerate the mesh/overlap/exchange_every/
        backend/tile space, score it with the roofline model, optionally
        measure the survivors, and return the winner — persisted on disk
        so a second call (any process, same hardware) is a cache hit."""
        from repro.tune import tune

        return tune(
            program, ranks=ranks, measure=measure, cache=cache, **tune_kwargs
        ).target

    # ------------------------------------------------------------------
    def pipeline_spec(self) -> str:
        """The pass-pipeline spec this target denotes (explicit ``pipeline``
        or the canonical flag expansion, fig. 4): [fuse,cse] → decompose →
        swap-elim → [temporal-tile] → [diagonal] → [overlap] → lower-comm."""
        if self.pipeline is not None:
            return self.pipeline
        stages: list[str] = []
        if self.fuse:
            stages.append("fuse")
        if self.cse:
            stages += ["cse", "dce"]
        stages += ["decompose", "swap-elim"]
        if self.exchange_every > 1:
            stages.append(f"temporal-tile{{k={self.exchange_every}}}")
        if self.diagonal:
            stages.append("diagonal")
        if self.overlap:
            stages.append("overlap")
        stages.append("lower-comm")
        if self.fused_epoch:
            # after lower-comm: the fused region holds only apply +
            # boundary_mask ops; exchanges stay outside the kernel
            stages.append("fuse-epoch-kernel")
        return ",".join(stages)

    @property
    def distributed(self) -> bool:
        """True when compilation wraps the step in ``shard_map`` — a
        spatial decomposition with > 1 rank, a slot mesh axis, or both."""
        if self.mesh is not None and self.slot_axis is not None:
            return True
        return self.mesh is not None and self.strategy is not None and any(
            g > 1 for g in self.strategy.grid_shape
        )

    @property
    def spatial_ranks(self) -> int:
        """Devices per slot: the product of the spatial decomposition grid
        (1 for an undecomposed target)."""
        if self.strategy is None:
            return 1
        out = 1
        for g in self.strategy.grid_shape:
            out *= int(g)
        return out

    @property
    def fingerprint(self) -> str:
        mesh_desc = "none"
        if self.mesh is not None:
            mesh_desc = (
                f"axes={tuple(self.mesh.axis_names)}"
                f"shape={tuple(self.mesh.shape[a] for a in self.mesh.axis_names)}"
                f"devices={tuple((d.platform, d.id) for d in self.mesh.devices.flat)}"
            )
        s = self.strategy
        strat_desc = (
            "none" if s is None
            else f"grid={tuple(s.grid_shape)}axes={tuple(s.axis_names)}dims={tuple(s.dims)}"
        )
        text = "\n".join(
            [
                f"mesh={mesh_desc}",
                f"strategy={strat_desc}",
                f"backend={self.backend}",
                f"pipeline={self.pipeline_spec()}",
                # explicit even though the default spec carries it: an
                # explicit ``pipeline`` must still produce distinct cached
                # artifacts per epoch depth (time_loop arithmetic differs)
                f"exchange_every={self.exchange_every}",
                # explicit even though the mesh desc carries the axis: a
                # slot-axis artifact has a different calling convention
                # ([B, *shape] arrays), so it must never collide with its
                # spatial-only sibling in the compile cache
                f"slot_axis={self.slot_axis}",
                f"fused_epoch={self.fused_epoch}",
                f"pallas_interpret={self.pallas_interpret}",
                f"pallas_tile={self.pallas_tile}",
                f"donate={self.donate}",
                f"jit={self.jit}",
            ]
        )
        return hashlib.sha256(text.encode()).hexdigest()[:16]


# --------------------------------------------------------------------------
# CompiledStencil — the reusable artifact
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    """What the pass pipeline did for one compile: the resolved spec and
    per-pass wall-clock timings."""

    spec: str
    timings: tuple  # ((pass name, seconds), ...)

    def __str__(self) -> str:
        lines = [f"pipeline: {self.spec}"]
        for name, sec in self.timings:
            lines.append(f"  {name:<16} {sec * 1e3:8.2f} ms")
        return "\n".join(lines)


class CompiledStencil:
    """A compiled stencil step: callable over *global* arrays, plus the
    artifacts a user inspects — the rank-local comm-lowered IR, the
    pipeline report, partition specs, AOT lowering and roofline cost."""

    def __init__(
        self,
        program: Program,
        target: Target,
        strategy: SlicingStrategy,
        local_ir: ir.FuncOp,
        pipeline_report: PipelineReport,
        fn: Callable,
        partition_specs: tuple,
        donate_argnums: tuple,
        raw_fn: Callable,
        ret_indices: Optional[tuple] = None,
    ) -> None:
        self.program = program
        self.target = target
        self.strategy = strategy
        self.local_ir = local_ir
        self.pipeline_report = pipeline_report
        self.partition_specs = partition_specs
        self.donate_argnums = donate_argnums
        self._fn = fn
        self._raw_fn = raw_fn  # pre-jit (shard_map'd) callable, for .lower()
        # buffers step() allocates internally: the program's stored fields
        self._out_indices = tuple(
            program.field_args.index(f) for f in program.output_fields
        )
        # field-arg positions of the values a call RETURNS (first-store
        # order of the local IR) — equals _out_indices except for epoched
        # carried-state programs (wave, p > q), whose epochs also hand
        # back the rotated-through intermediate buffers
        self._ret_indices = (
            ret_indices if ret_indices is not None else self._out_indices
        )

    # -- execution -------------------------------------------------------
    def __call__(self, *arrays):
        return self._fn(*arrays)

    @property
    def input_indices(self) -> tuple:
        """Field-arg positions ``step()`` consumes (the time-loop state,
        oldest → newest); the complement of the internally-allocated
        output buffers."""
        outs = set(self._out_indices)
        return tuple(
            i for i in range(len(self.program.field_args)) if i not in outs
        )

    @property
    def ret_indices(self) -> tuple:
        """Field-arg positions of the values one call RETURNS (first-store
        order of the local IR).  Equals the program's stored fields except
        for epoched carried-state programs (wave, p > q), whose epochs
        also hand back the rotated-through intermediate buffers.  The
        resilience driver records this in checkpoint manifests — the
        rotation arithmetic of a resumed run must match the killed one."""
        return self._ret_indices

    def step(self, dtype=None) -> Callable:
        """A step over the *input* fields only: output buffers (fully
        overwritten every call) are allocated internally — the shape
        ``time_loop`` rotation wants.  With ``Target(exchange_every=k)``
        one call advances a whole k-step epoch.  A slot-axis target takes
        (and allocates) ``[B, *field_shape]`` arrays — one pooled call
        advances ``B`` independent simulations."""
        return self._step_over(self._fn, dtype)

    def _step_over(self, call: Callable, dtype=None) -> Callable:
        """``step()``'s input-only calling convention wrapped around an
        arbitrary executable of the full field signature — ``self._fn``
        for the jitted step, ``self._raw_fn`` for the traced eager path
        (``repro.obs``: the interpreter re-executes per epoch, so
        exchange/apply spans land once per epoch, not once per trace)."""
        outs = set(self._out_indices)
        pooled = self.target.slot_axis is not None

        def fn(*inputs):
            it = iter(inputs)
            dt = dtype or (inputs[0].dtype if inputs else jnp.float32)
            lead = (inputs[0].shape[0],) if (pooled and inputs) else ()
            args = [
                jnp.zeros(lead + tuple(f.type.bounds.shape), dt)
                if i in outs
                else next(it)
                for i, f in enumerate(self.program.field_args)
            ]
            rest = list(it)
            assert not rest, f"{len(rest)} extra input arrays"
            return call(*args)

        return fn

    @property
    def _n_ranks(self) -> int:
        mesh = self.target.mesh
        if mesh is None:
            return 1
        n = 1
        for name in mesh.axis_names:
            if name != self.target.slot_axis:
                n *= int(mesh.shape[name])
        return n

    def epochs(self, n_steps: int) -> int:
        """``n_steps`` time steps as a whole number of epochs of this
        artifact — the shared validation for every driver (``time_loop``,
        ``repro.resilience``, the serve engine's admission check): a
        depth-k artifact advances k steps per call, so ``n_steps`` must
        divide evenly (a partial epoch has no compiled form)."""
        k = self.target.exchange_every
        if n_steps % k != 0:
            raise ValueError(
                f"n_steps={n_steps} with "
                f"Target(exchange_every={k}): n_steps must be a multiple of "
                f"the epoch depth (each call advances {k} steps)"
            )
        return n_steps // k

    def advance(self, state: Sequence[Any]) -> tuple:
        """One epoch with time-buffer rotation applied: consume ``state``
        (oldest → newest), return the rotated state after ``exchange_every``
        time steps — exactly one iteration of ``time_loop``'s body, exposed
        so epoch-granular drivers (``repro.resilience.ResilientLoop``, the
        serve engine) and the fori-loop driver share one rotation rule."""
        if _obs.enabled():
            with _obs.span("epoch", cat="dispatch", rank=None,
                           program=self.program.name,
                           k=self.target.exchange_every,
                           ranks=self._n_ranks):
                outs = self.step()(*state)
                outs = outs if isinstance(outs, tuple) else (outs,)
                jax.block_until_ready(outs)
        else:
            outs = self.step()(*state)
            outs = outs if isinstance(outs, tuple) else (outs,)
        return tuple(state[len(outs):]) + tuple(outs)

    def time_loop(self, state: Sequence[Any], n_steps: int, unroll: int = 1):
        """Iterate ``n_steps`` *time steps* with time-buffer rotation
        (``state`` ordered oldest→newest) under one ``lax.fori_loop``.

        ``n_steps`` always counts single time steps regardless of the
        target's ``exchange_every``: the loop runs ``self.epochs(n_steps)``
        epochs.  For a checkpointable / fault-tolerant loop with the same
        arithmetic, see ``repro.resilience.ResilientLoop``.

        With tracing on (``repro.obs``) the fori-loop is replaced by a
        host-driven epoch loop over the *eager* (unjitted) executable:
        each epoch re-executes the interpreter, so every epoch records
        its own exchange window and apply spans with real wall-clock
        timestamps — the timeline `lax.fori_loop`'s single trace cannot
        produce.  Same arithmetic, host-loop dispatch overhead applies
        (the resilience driver proved the python-epoch loop equivalent
        in PR 8); benchmark numbers should be taken untraced."""
        if _obs.enabled():
            return self._traced_time_loop(tuple(state), n_steps)
        return time_loop(
            self.step(), tuple(state), self.epochs(n_steps), unroll=unroll
        )

    def _traced_time_loop(self, state: tuple, n_steps: int) -> tuple:
        n_epochs = self.epochs(n_steps)
        k = self.target.exchange_every
        step = self._step_over(self._raw_fn)
        for e in range(n_epochs):
            with _obs.span("epoch", cat="dispatch", rank=None,
                           program=self.program.name, epoch=e,
                           step_begin=e * k, k=k, ranks=self._n_ranks):
                outs = step(*state)
                outs = outs if isinstance(outs, tuple) else (outs,)
                jax.block_until_ready(outs)
            state = tuple(state[len(outs):]) + tuple(outs)
        return state

    # -- inspection ------------------------------------------------------
    @property
    def kernel_dispatches(self) -> dict:
        """Static kernel-op census of one epoch of the compiled program:
        how many fused-epoch megakernels and how many standalone applies
        the local IR executes per call.  With ``Target(fused_epoch=True)``
        an epoched program reads ``{"fused_epoch": 1, "apply": 0, ...}`` —
        one kernel dispatch per epoch (cross-checked at trace time by
        ``repro.kernels.dispatch_stats``)."""
        fused = sum(
            1
            for op in self.local_ir.body.ops
            if isinstance(op, stencil.FusedEpochOp)
        )
        applies = sum(
            1
            for op in self.local_ir.body.ops
            if isinstance(op, stencil.ApplyOp)
        )
        return {
            "fused_epoch": fused,
            "apply": applies,
            "total": fused + applies,
        }

    def lower(self, dtype=jnp.float32):
        """AOT-lower with ShapeDtypeStruct inputs (no allocation) — the
        dry-run entry point: ``.lower().compile().memory_analysis()``."""
        # a slot-axis artifact takes [B, *shape]: lower at one row per
        # slot-axis shard, the narrowest batch the mesh can carry
        lead = (
            (int(self.target.mesh.shape[self.target.slot_axis]),)
            if self.target.slot_axis is not None
            else ()
        )
        args = []
        for f, spec in zip(self.program.field_args, self.partition_specs):
            sharding = (
                NamedSharding(self.target.mesh, spec)
                if self.target.mesh is not None
                else None
            )
            args.append(
                jax.ShapeDtypeStruct(
                    lead + tuple(f.type.bounds.shape), dtype, sharding=sharding
                )
            )
        return jax.jit(self._raw_fn).lower(*args)

    def cost(self, dtype=jnp.float32):
        """Roofline terms of the compiled executable (launch/roofline):
        per-device FLOPs / HBM bytes / collective bytes → seconds per
        term, dominant bottleneck, overlapped/serial step time — plus the
        temporal-tiling tradeoff terms (message count per epoch, per-step
        halo widths, shard extents) so ``.cost().recommend_exchange_every()``
        can pick the epoch depth that balances amortized exchange latency
        against redundant boundary compute."""
        from repro.core.dialects import comm
        from repro.launch.roofline import RooflineTerms, collective_bytes_from_hlo

        compiled = self.lower(dtype).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax: one dict per program
            cost = cost[0] if cost else {}
        from repro.core.passes.temporal import TemporalTilingError, epoch_halo

        step_halo: tuple = ()
        try:
            lo1, hi1 = epoch_halo(self.program.func, 1)
            step_halo = tuple(max(l, h) for l, h in zip(lo1, hi1))
        except TemporalTilingError:
            pass  # non-epochable program shapes carry no tiling terms
        local_shape: tuple = ()
        if self.program.field_args:
            local_shape = self.strategy.local_bounds(
                self.program.field_args[0].type.bounds
            ).shape
        messages = sum(
            1
            for op in self.local_ir.body.ops
            if isinstance(op, comm.ExchangeStartOp)
        )
        return RooflineTerms(
            flops=cost.get("flops") or 0.0,
            bytes_accessed=cost.get("bytes accessed") or 0.0,
            collectives=collective_bytes_from_hlo(compiled.as_text()),
            exchange_every=self.target.exchange_every,
            messages_per_epoch=messages,
            step_halo=step_halo,
            local_shape=local_shape,
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"CompiledStencil({self.program.name!r}, "
            f"backend={self.target.backend!r}, "
            f"distributed={self.target.distributed}, "
            f"pipeline={self.pipeline_report.spec!r})"
        )


# --------------------------------------------------------------------------
# compile + the process-wide cache
# --------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }


# LRU-bounded: a long-lived serving process compiles an open-ended stream
# of (program, target) pairs; without a bound the process-wide cache —
# and every XLA executable it pins — grows monotonically.  Capacity is
# generous (sweeps and the serve engine fit comfortably); override with
# REPRO_COMPILE_CACHE_CAP or set_cache_capacity().
_DEFAULT_CAPACITY = 256
_CACHE: "OrderedDict[tuple, Any]" = OrderedDict()
_CAPACITY = max(
    1, int(os.environ.get("REPRO_COMPILE_CACHE_CAP", _DEFAULT_CAPACITY))
)
_STATS = CacheStats()
# Global lock guards the dicts only (held briefly); builds run under a
# per-key lock, so concurrent compiles of the SAME key return the same
# artifact ("second is first" is part of the contract) while unrelated
# compiles — and the serve engine's per-request lookups — stay parallel.
_LOCK = threading.RLock()
_KEY_LOCKS: dict[tuple, threading.Lock] = {}


def cache_stats() -> CacheStats:
    """Process-wide compile-cache counters (shared by ``compile``,
    ``lower_ir`` and ``cached_callable``) — truthful hit/miss/eviction
    counts of the LRU-bounded cache."""
    return _STATS


def cache_capacity() -> int:
    return _CAPACITY


def set_cache_capacity(n: int) -> int:
    """Bound the process-wide compile cache to ``n`` entries (LRU
    eviction; evicting frees the artifact for GC).  Returns the previous
    capacity.  ``n`` must be >= 1 — a serving process needs at least the
    artifact it is currently dispatching."""
    global _CAPACITY
    if int(n) < 1:
        raise ValueError(f"cache capacity must be >= 1, got {n!r}")
    with _LOCK:
        prev, _CAPACITY = _CAPACITY, int(n)
        _evict_over_capacity()
    return prev


def _evict_over_capacity() -> None:
    # caller holds _LOCK
    while len(_CACHE) > _CAPACITY:
        key, _ = _CACHE.popitem(last=False)
        _KEY_LOCKS.pop(key, None)
        _STATS.evictions += 1


def clear_cache() -> None:
    with _LOCK:
        _CACHE.clear()
        _KEY_LOCKS.clear()
        _STATS.hits = 0
        _STATS.misses = 0
        _STATS.evictions = 0


def _cached(key: tuple, build: Callable[[], Any]) -> Any:
    with _LOCK:
        if key in _CACHE:
            _STATS.hits += 1
            _CACHE.move_to_end(key)  # LRU freshness
            return _CACHE[key]
        key_lock = _KEY_LOCKS.setdefault(key, threading.Lock())
    with key_lock:
        with _LOCK:
            if key in _CACHE:  # built by the thread we waited on
                _STATS.hits += 1
                _CACHE.move_to_end(key)
                return _CACHE[key]
        out = build()
        with _LOCK:
            _STATS.misses += 1
            _CACHE[key] = out
            _evict_over_capacity()
        return out


def trivial_strategy(rank: int) -> SlicingStrategy:
    names = ("x", "y", "z", "w")[:rank]
    return SlicingStrategy((1,) * rank, names, tuple(range(rank)))


def compile(
    program: Program,
    target: Optional[Target] = None,
    *,
    tune=None,
) -> CompiledStencil:
    """Compile ``program`` for ``target`` (default: single device).

    ``tune=True`` (or a dict of ``repro.tune.tune`` keyword arguments)
    picks the target automatically via the autotuner instead —
    mutually exclusive with an explicit ``target``.

    Cached process-wide on ``(program.fingerprint, target.fingerprint)``:
    a repeated compile of the same program + target returns the same
    ``CompiledStencil`` without re-running the pass pipeline or
    re-tracing, and its jit cache carries over."""
    if tune:
        if target is not None:
            raise ValueError(
                "pass either target= or tune=, not both (tune selects "
                "the target)"
            )
        target = Target.tuned(
            program, **(tune if isinstance(tune, dict) else {})
        )
    target = target or Target()
    _validate_for_program(program, target)
    # the fingerprint is taken at Program construction; a func mutated
    # afterwards would poison the cache under a stale key — refuse it
    if ir.fingerprint(program.func, *program._salt) != program.fingerprint:
        raise ValueError(
            f"Program {program.name!r}: IR was mutated after construction; "
            "run rewrites on the FuncOp first, then wrap it in a Program"
        )
    key = ("compile", program.fingerprint, target.fingerprint)
    if _obs.enabled():
        with _LOCK:
            hit = key in _CACHE
        with _obs.span("api.compile", cat="compile", program=program.name,
                       cache="hit" if hit else "miss"):
            return _cached(key, lambda: _build(program, target))
    return _cached(key, lambda: _build(program, target))


def _validate_for_program(program: Program, target: Target) -> None:
    s = target.strategy
    if s is not None:
        for g, d in zip(s.grid_shape, s.dims):
            if d >= program.rank:
                raise TargetError(
                    f"strategy decomposes dim {d} of a rank-{program.rank} "
                    f"program {program.name!r}"
                )
            if g > 1:
                for f in program.field_args:
                    extent = f.type.bounds.shape[d]
                    if extent % g != 0:
                        raise TargetError(
                            f"dim {d} extent {extent} of {program.name!r} not "
                            f"divisible by grid size {g}"
                        )
    if target.backend == "pallas" and target.pallas_tile is not None:
        _validate_pallas_tile(program, target)
    if target.exchange_every > 1:
        _validate_exchange_every(program, target)


def _validate_pallas_tile(program: Program, target: Target) -> None:
    """A user tile must divide the *local shard* shape the kernel will
    see — caught here with a named error, not by the divisibility assert
    deep inside ``core/lowering``.  Split-overlapped and epoch-tiled
    applies re-tile automatically (their per-part shapes vary), so only
    their tile *rank* is checked."""
    tile = target.pallas_tile
    if not program.field_args:
        return
    rank = program.rank
    if len(tile) != rank:
        raise TargetError(
            f"pallas_tile {tile} has {len(tile)} dims but program "
            f"{program.name!r} is rank-{rank}"
        )
    if any(int(t) < 1 for t in tile):
        raise TargetError(f"pallas_tile {tile} must be positive")
    spec = target.pipeline_spec()
    if "overlap" in spec or "temporal-tile" in spec or "fuse-epoch-kernel" in spec:
        return  # lowering auto-tiles split/epoched/fused applies that mismatch
    s = target.strategy
    grid_of_dim = {}
    if s is not None:
        for g, ax, d in zip(s.grid_shape, s.axis_names, s.dims):
            grid_of_dim[d] = (g, ax)
    shape = program.field_args[0].type.bounds.shape
    local = tuple(
        shape[d] // grid_of_dim.get(d, (1, None))[0] for d in range(rank)
    )
    for d in range(rank):
        if local[d] % tile[d] != 0:
            g, ax = grid_of_dim.get(d, (1, None))
            where = (
                f"decomposed over mesh axis {ax!r} (grid {g})"
                if ax is not None and g > 1
                else "undecomposed"
            )
            raise TargetError(
                f"pallas_tile {tile} does not divide the local shard "
                f"shape {local} of program {program.name!r}: dim {d} "
                f"extent {local[d]} is not a multiple of tile {tile[d]} "
                f"({where}); pick a tile dividing the shard or drop "
                f"pallas_tile for auto-tiling"
            )


def _validate_exchange_every(program: Program, target: Target) -> None:
    """A depth-k epoch exchanges a k-times-accumulated halo in one shot;
    the send slab must come out of the neighbour's core, so the deep width
    cannot exceed the local shard extent on any axis."""
    from repro.core.passes.temporal import TemporalTilingError, epoch_halo

    k = target.exchange_every
    try:
        lo1, hi1 = epoch_halo(program.func, 1)
        lok, hik = epoch_halo(program.func, k)
    except TemporalTilingError as e:
        raise TargetError(
            f"Target(exchange_every={k}) cannot epoch program "
            f"{program.name!r}: {e}"
        )
    s = target.strategy
    grid_of_dim = {}
    if s is not None:
        for g, ax, d in zip(s.grid_shape, s.axis_names, s.dims):
            grid_of_dim[d] = (g, ax)
    if not program.field_args:
        return
    shape = program.field_args[0].type.bounds.shape
    for d in range(program.rank):
        g, ax = grid_of_dim.get(d, (1, None))
        local_n = shape[d] // g
        deep = max(lok[d], hik[d])
        step = max(lo1[d], hi1[d])
        if deep > local_n:
            where = (
                f"mesh axis {ax!r}" if ax is not None else "undecomposed"
            )
            max_k = local_n // step if step else k
            raise TargetError(
                f"Target(exchange_every={k}) on {program.name!r}: deep halo "
                f"{deep} (inferred per-step depth {step}, accumulated over "
                f"{k} steps) along dim {d} ({where}) exceeds the local shard "
                f"extent {local_n}; use exchange_every <= {max_k} or "
                f"decompose dim {d} over fewer ranks"
            )


def pooled_target(
    target: Target,
    slots: int = 1,
    axis: str = "slot",
    devices: Optional[Sequence] = None,
) -> Target:
    """The slot-axis sibling of a distributed ``target``: the same spatial
    decomposition plus a leading slot mesh axis of size ``slots`` factored
    out of the device inventory (``dist.sharding.factor_slot_mesh``).

    The sibling's compiled step takes ``[B, *field_shape]`` arrays
    (``B % slots == 0``) and advances every row in ONE ``shard_map``
    dispatch over ``(slot, *spatial)`` — the serve engine's batched
    distributed dispatch, and the ensemble axis of the ROADMAP (one
    compiled stencil over ``B`` perturbed initial conditions).
    """
    from repro.dist.sharding import factor_slot_mesh

    if target.mesh is None:
        raise TargetError(
            "pooled_target needs a distributed target (mesh + strategy); "
            "a single-device pool is just jax.vmap over the step"
        )
    if target.slot_axis is not None:
        raise TargetError(
            f"target already carries slot axis {target.slot_axis!r}"
        )
    mesh = factor_slot_mesh(target.mesh, slots, axis=axis, devices=devices)
    return dataclasses.replace(target, mesh=mesh, slot_axis=axis)


def partition_specs(program: Program, strategy: SlicingStrategy) -> list:
    """PartitionSpec per field argument, from the decomposition map."""
    specs = []
    for f in program.field_args:
        rank = f.type.bounds.rank
        entries: list = [None] * rank
        for gax, d in enumerate(strategy.dims):
            if d < rank and strategy.grid_shape[gax] > 1:
                entries[d] = strategy.axis_names[gax]
        specs.append(P(*entries))
    return specs


def _build(program: Program, target: Target) -> CompiledStencil:
    with _obs.span("api.build", cat="compile", program=program.name,
                   backend=target.backend, k=target.exchange_every):
        return _build_inner(program, target)


def _build_inner(program: Program, target: Target) -> CompiledStencil:
    strategy = target.strategy or trivial_strategy(program.rank)
    spec = target.pipeline_spec()
    ctx = PipelineContext(
        strategy=strategy,
        boundary=program.boundary,
        exchange_every=target.exchange_every,
    )
    pm = PassManager(build_pipeline(spec, ctx))
    local = pm.run(_clone_func(program.func))
    report = PipelineReport(spec=spec, timings=tuple(pm.timings))

    distributed = target.distributed
    axis_sizes = (
        {name: target.mesh.shape[name] for name in target.mesh.axis_names}
        if target.mesh is not None
        else {}
    )
    interp = StencilInterpreter(
        local,
        axis_sizes=axis_sizes,
        distributed=distributed,
        backend=target.backend,
        pallas_interpret=target.pallas_interpret,
        pallas_tile=target.pallas_tile,
    )
    specs = partition_specs(program, strategy)
    # return arity/order comes from the LOCAL IR (first-store order):
    # an epoched carried-state program (wave, p > q) stores — and returns
    # — more buffers per call than the single-step program does
    local_fields = [
        a for a in local.body.args if isinstance(a.type, stencil.FieldType)
    ]
    ret_indices = tuple(
        local_fields.index(f) for f in _stored_fields(local)
    )

    raw: Callable = interp
    if distributed:
        from repro.dist.sharding import shard_map  # version-portable

        body: Callable = interp
        if target.slot_axis is not None:
            # slot-axis calling convention: every field carries a leading
            # batch dim sharded over the slot axis; each device block
            # vmaps the rank-local step over its rows.  Collectives
            # (ppermute halo exchanges, axis_index boundary masks) bind
            # the *spatial* axis names, which vmap batches through — each
            # row sees exactly the solo exchange pattern, so the pooled
            # dispatch stays bitwise-equal to per-slot solo dispatches.
            body = jax.vmap(interp)
            specs = [P(target.slot_axis, *tuple(s)) for s in specs]
        out_specs = tuple(specs[i] for i in ret_indices)
        raw = shard_map(
            body,
            mesh=target.mesh,
            in_specs=tuple(specs),
            out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
            check_vma=False,  # pallas_call outputs carry no vma info
        )
    fn = raw
    # the old StencilComputation computed this tuple but never passed it
    # to jax.jit; donation is now honored (all field buffers — output
    # buffers alias outputs, dead input time-buffers free their storage)
    donate = (
        tuple(range(len(program.field_args)))
        if (target.donate and target.jit)
        else ()
    )
    if target.jit:
        fn = jax.jit(raw, donate_argnums=donate)
    return CompiledStencil(
        program=program,
        target=target,
        strategy=strategy,
        local_ir=local,
        pipeline_report=report,
        fn=fn,
        partition_specs=tuple(specs),
        donate_argnums=donate,
        raw_fn=raw,
        ret_indices=ret_indices,
    )


# --------------------------------------------------------------------------
# Cache entry points for the other subsystems
# --------------------------------------------------------------------------


def lower_ir(
    func: ir.FuncOp,
    pipeline: str,
    strategy: Optional[SlicingStrategy] = None,
    boundary: str = "zero",
) -> ir.FuncOp:
    """Run a pass-pipeline spec over generated IR through the process-wide
    cache (keyed on the IR fingerprint + spec) — how ``repro.dist``'s
    sequence-halo exchanges skip re-lowering (`dist/context_parallel`)."""
    s = strategy
    strat_desc = (
        "none" if s is None
        else f"{tuple(s.grid_shape)}{tuple(s.axis_names)}{tuple(s.dims)}"
    )
    key = (
        "lower_ir",
        ir.fingerprint(func, f"boundary={boundary}"),
        pipeline,
        strat_desc,
    )

    def build() -> ir.FuncOp:
        pm = PassManager(
            build_pipeline(pipeline, PipelineContext(strategy=s, boundary=boundary))
        )
        return pm.run(_clone_func(func))

    return _cached(key, build)


def cached_callable(key: tuple, build: Callable[[], Callable]) -> Callable:
    """Process-wide cache for compiled callables keyed by explicit
    fingerprints — the serve engine keys its prefill/decode executables on
    (model-config repr, bucket) so engine restarts skip re-tracing."""
    return _cached(("callable",) + tuple(key), build)


# --------------------------------------------------------------------------
# Shared helpers (also used by the StencilComputation shim)
# --------------------------------------------------------------------------


def _stored_fields(func: ir.FuncOp) -> list:
    out = []
    for op in func.body.ops:
        if isinstance(op, stencil.StoreOp) and op.field not in out:
            out.append(op.field)
    return out


def _clone_func(func: ir.FuncOp) -> ir.FuncOp:
    new = ir.FuncOp(func.sym_name, [a.type for a in func.body.args])
    vmap: dict[ir.SSAValue, ir.SSAValue] = {}
    for oa, na in zip(func.body.args, new.body.args):
        vmap[oa] = na
    for op in func.body.ops:
        new.body.add_op(op.clone_into(vmap))
    return new


# --------------------------------------------------------------------------
# Time-loop driver (paper benchmarks iterate stencils over timesteps)
# --------------------------------------------------------------------------


def time_loop(
    step: Callable,
    state: Sequence[Any],
    n_steps: int,
    unroll: int = 1,
) -> tuple:
    """Iterate ``step`` with time-buffer rotation.

    ``state`` is ordered oldest→newest; each call consumes the full state
    and produces the newest buffer(s), which rotate in:
    ``state' = state[k:] + outs``.  Runs under ``lax.fori_loop`` so the
    whole simulation is one XLA computation.
    """
    state = tuple(state)

    def body(_, s):
        outs = step(*s)
        outs = outs if isinstance(outs, tuple) else (outs,)
        return tuple(s[len(outs):]) + outs

    return jax.lax.fori_loop(0, n_steps, body, state, unroll=unroll)


# --------------------------------------------------------------------------
# Resilience entry points (repro.resilience)
# --------------------------------------------------------------------------


def resilient_loop(program, target=None, state=(), n_steps=0, **kwargs):
    """A checkpointing, fault-tolerant ``time_loop``: epoch-aligned
    snapshots every ``checkpoint_every`` epochs, killable and resumable —
    see ``repro.resilience.ResilientLoop``."""
    from repro.resilience import ResilientLoop

    return ResilientLoop(program, target, state, n_steps, **kwargs)


def resume(program, directory: str, target=None, **kwargs):
    """Resume a checkpointed run from ``directory`` onto ``target`` — a
    *different* mesh factorization / rank count is allowed: the restored
    host arrays are resharded through ``dist/sharding`` and the program
    recompiled.  See ``repro.resilience.resume``."""
    from repro.resilience import resume as _resume

    return _resume(program, directory, target, **kwargs)
