"""Kernel layer: Pallas code generation for stencil compute hot-spots.

Shared here (imported by ``api``, ``tune`` and the kernels themselves):

- :func:`has_accelerator` / :func:`default_interpret` — the one source of
  truth for whether Pallas kernels run in interpret mode.  Interpret is
  the CPU-container default and the correctness oracle; on a real GPU/TPU
  the default flips to the native (non-interpret) path.  Overridable with
  ``REPRO_PALLAS_INTERPRET=0|1``.
- :func:`dispatch_stats` — trace-time kernel-dispatch counters.  Every
  ``pl.pallas_call`` the backend traces bumps a counter, so a test can
  assert "one epoch == ONE kernel dispatch" by resetting, tracing one
  epoch, and reading the deltas (under ``jit`` the counters move at trace
  time, once per compilation, which is exactly the dispatch count of the
  compiled program).
"""
from __future__ import annotations

import dataclasses
import os


def has_accelerator() -> bool:
    """True when JAX sees a GPU/TPU device."""
    import jax

    try:
        return any(d.platform in ("gpu", "tpu") for d in jax.devices())
    except Exception:  # noqa: BLE001 - no backend at all counts as "no"
        return False


def default_interpret() -> bool:
    """Resolved default for ``Target.pallas_interpret=None``: interpret on
    CPU-only hosts, native Pallas when an accelerator is present.
    ``REPRO_PALLAS_INTERPRET`` (0/1) overrides the device probe."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off")
    return not has_accelerator()


@dataclasses.dataclass
class DispatchStats:
    """Counts of Pallas kernels *traced* since the last reset."""

    apply_calls: int = 0        # per-apply kernels (kernels/stencil_apply.py)
    fused_epoch_calls: int = 0  # epoch megakernels (kernels/epoch_kernel.py)

    @property
    def pallas_calls(self) -> int:
        return self.apply_calls + self.fused_epoch_calls

    def as_dict(self) -> dict:
        return {
            "apply_calls": self.apply_calls,
            "fused_epoch_calls": self.fused_epoch_calls,
            "pallas_calls": self.pallas_calls,
        }


_DISPATCH = DispatchStats()


def dispatch_stats() -> DispatchStats:
    return _DISPATCH


def reset_dispatch_stats() -> None:
    _DISPATCH.apply_calls = 0
    _DISPATCH.fused_epoch_calls = 0
