"""Jit'd wrappers around the Pallas kernels — the stable public surface.

Each op takes halo-inclusive inputs and returns the core, mirroring the
post-swap calling convention of the lowering (halos are filled by dmp/comm
upstream).

``interpret`` defaults to ``None`` — resolved through the same
:func:`repro.kernels.default_interpret` the compile surface uses for
``Target.pallas_interpret``, so ops-level callers and compiled programs
agree on one flag source (interpret on CPU hosts, native Pallas on
GPU/TPU, ``REPRO_PALLAS_INTERPRET`` overriding both).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ir
from repro.core.builder import build_apply
from repro.core.dialects import stencil
from repro.core.fd import laplacian_star, radius
from repro.kernels import default_interpret
from repro.kernels.stencil_apply import run_apply_pallas


def _star_apply_ir(coeffs: Dict[Tuple[int, ...], float], core: tuple, halo: tuple):
    """Build a one-operand apply op computing the weighted-star sum."""
    rank = len(core)
    func = ir.FuncOp("star", [])
    operand_bounds = stencil.Bounds(
        tuple(-h for h in halo), tuple(c + h for c, h in zip(core, halo))
    )
    # fabricate a block argument typed as the halo-grown temp
    holder = ir.Block([stencil.TempType(operand_bounds)])
    rb = stencil.Bounds.from_shape(core)

    def body(b, u):
        acc = None
        for off, c in sorted(coeffs.items()):
            term = u.at(*off) * float(c)
            acc = term if acc is None else acc + term
        return acc

    apply_op = build_apply(func.body, [holder.args[0]], rb, body)
    return apply_op, operand_bounds


def star_stencil(
    x,
    coeffs: Dict[Tuple[int, ...], float],
    halo: Tuple[int, ...],
    tile=None,
    interpret: Optional[bool] = None,
):
    """Apply a star/box stencil with static coefficients via Pallas."""
    if interpret is None:
        interpret = default_interpret()
    core = tuple(s - 2 * h for s, h in zip(x.shape, halo))
    apply_op, ob = _star_apply_ir(coeffs, core, halo)
    rb = stencil.Bounds.from_shape(core)
    (out,) = run_apply_pallas(
        apply_op, [x], [ob.lb], rb, tile=tile, interpret=interpret
    )
    return out


@partial(jax.jit, static_argnames=("order", "halo", "interpret"))
def laplacian(
    x, order: int = 2, halo: int = None, interpret: Optional[bool] = None  # type: ignore[assignment]
):
    h = halo if halo is not None else radius(order)
    star = laplacian_star(x.ndim, order)
    return star_stencil(x, star, (h,) * x.ndim, interpret=interpret)


@partial(jax.jit, static_argnames=("alpha", "order", "interpret"))
def heat_step(u, alpha: float, order: int = 2, interpret: Optional[bool] = None):
    """Fused u + alpha∇²u (one kernel, one VMEM round-trip)."""
    h = radius(order)
    star = dict(laplacian_star(u.ndim, order))
    star = {k: alpha * v for k, v in star.items()}
    center = tuple([0] * u.ndim)
    star[center] = star.get(center, 0.0) + 1.0
    return star_stencil(u, star, (h,) * u.ndim, interpret=interpret)


def wave_step(
    u_t, u_tm1_core, c2dt2: float, order: int = 2, interpret: Optional[bool] = None
):
    """2 u_t - u_{t-1} + c²dt² ∇²u_t; u_t halo-inclusive, u_{t-1} core."""
    h = radius(order)
    star = {k: c2dt2 * v for k, v in laplacian_star(u_t.ndim, order).items()}
    center = tuple([0] * u_t.ndim)
    star[center] = star.get(center, 0.0) + 2.0
    lap2u = star_stencil(u_t, star, (h,) * u_t.ndim, interpret=interpret)
    return lap2u - u_tm1_core
