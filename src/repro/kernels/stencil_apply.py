"""Pallas TPU kernel backend for ``stencil.apply`` (DESIGN.md §2).

The paper lowers stencil kernels to GPU (CUDA via MLIR) and FPGA (HLS);
the TPU-native analogue is a Pallas kernel with explicit BlockSpec VMEM
tiling.  Rather than hand-writing one kernel per stencil, the apply op's
*point function is code-generated into the kernel body*: operand blocks
are fetched to VMEM as overlapping windows (``pl.Element`` block dims —
window = tile + access extent), accesses become static slices of the
resident block, and the arithmetic DAG is emitted verbatim — the same
"domain information drives the lowering" story the paper tells for GPUs,
retargeted at the MXU/VPU memory hierarchy:

    HBM --(BlockSpec window, overlapping)--> VMEM block --(slices)--> VPU

Tiles keep the minor (lane) dimension contiguous and whole where it fits
(it maps to the 128-wide vector lanes), and split the leading dimensions
to bound the VMEM working set; hardware-aligned sizes (multiples of 8 /
128) are preferred.

Validated against ``repro.kernels.ref`` in ``interpret=True`` mode (this
container is CPU-only; TPU is the target).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dialects import stencil
from repro.kernels import _DISPATCH
from repro.obs import trace as _obs


VMEM_BUDGET_BYTES = 4 * 1024 * 1024  # per-operand working-set target


def _divisors_desc(n: int) -> list:
    out = [d for d in range(1, n + 1) if n % d == 0]
    return sorted(out, reverse=True)


def choose_tile(
    shape: tuple, spans: Sequence[tuple], budget: int = VMEM_BUDGET_BYTES
) -> tuple:
    """Pick a tile: minor dim whole (lane alignment), leading dims split
    until every operand window fits the VMEM budget."""
    rank = len(shape)
    tile = list(shape)

    def worst_window_bytes() -> int:
        w = 0
        for lo, hi in spans:
            numel = 1
            for d in range(rank):
                numel *= tile[d] + (hi[d] - lo[d])
            w = max(w, numel * 4)
        return w

    # split leading dims first; never split the minor dim unless huge
    for d in range(rank - 1):
        for div in _divisors_desc(shape[d]):
            tile[d] = div
            if worst_window_bytes() <= budget:
                break
        if worst_window_bytes() <= budget:
            break
    if worst_window_bytes() > budget and rank >= 1:
        d = rank - 1
        for div in _divisors_desc(shape[d]):
            if div % 128 == 0 or div == 1 or div == shape[d]:
                tile[d] = div
                if worst_window_bytes() <= budget:
                    break
    return tuple(tile)


def build_apply_kernel(
    apply_op: stencil.ApplyOp,
    operand_shapes: Sequence[tuple],
    operand_origins: Sequence[tuple],
    result_bounds: stencil.Bounds,
    tile: Optional[tuple] = None,
    interpret: bool = True,
):
    """Code-generate a pallas_call for one stencil.apply.

    ``operand_origins[k]`` is the logical coordinate of ``arrays[k][0…0]``
    (post-swap temps have origin = core.lb - halo_lo).
    """
    from repro.core.lowering import eval_apply_body  # shared evaluator

    rb = result_bounds
    rank = rb.rank
    shape = rb.shape
    exts = apply_op.access_extents()
    n_in = len(apply_op.operands)
    zero = (tuple([0] * rank), tuple([0] * rank))
    spans = [exts.get(k, zero) for k in range(n_in)]

    tile = tuple(tile) if tile else choose_tile(shape, spans)
    assert all(s % t == 0 for s, t in zip(shape, tile)), (
        f"tile {tile} must divide result shape {shape}"
    )
    grid = tuple(s // t for s, t in zip(shape, tile))

    in_specs = []
    window_origins = []
    for k in range(n_in):
        lo, hi = spans[k]
        base = tuple(
            rl + l - og
            for rl, l, og in zip(rb.lb, lo, operand_origins[k])
        )
        window = tuple(t + (h - l) for t, l, h in zip(tile, lo, hi))
        assert all(b >= 0 for b in base), (
            f"operand {k} window starts at {base} before array origin "
            f"(halo missing — run the decompose pass first)"
        )

        def index_map(*ids, _base=base):
            return tuple(
                i * t + b for i, t, b in zip(ids, tile, _base)
            )

        # overlapping element-indexed windows: newer jax spells this
        # pl.Element block dims, older jax an Unblocked indexing mode
        if hasattr(pl, "Element"):
            spec = pl.BlockSpec(
                tuple(pl.Element(w) for w in window), index_map
            )
        else:
            spec = pl.BlockSpec(
                window, index_map, indexing_mode=pl.unblocked
            )
        in_specs.append(spec)
        window_origins.append(tuple(lo))

    out_specs = [
        pl.BlockSpec(tile, lambda *ids: ids) for _ in apply_op.results
    ]
    out_shape = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _ in apply_op.results
    ]
    tile_bounds = stencil.Bounds.from_shape(tile)

    def kernel(*refs):
        in_refs = refs[:n_in]
        out_refs = refs[n_in:]
        blocks = [r[...] for r in in_refs]
        outs = eval_apply_body(apply_op, blocks, window_origins, tile_bounds)
        for o_ref, val in zip(out_refs, outs):
            o_ref[...] = val

    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shape if len(out_shape) > 1 else out_shape[0],
        interpret=interpret,
    )
    return call


def run_apply_pallas(
    apply_op: stencil.ApplyOp,
    arrays: Sequence,
    origins: Sequence[tuple],
    result_bounds: stencil.Bounds,
    tile: Optional[tuple] = None,
    interpret: bool = True,
) -> list:
    """Entry point used by the lowering's pallas backend.  Each call is
    one traced pallas_call (counted in ``kernels.dispatch_stats``)."""
    with _obs.span("pallas:apply", cat="kernel", rank=None,
                   interpret=interpret):
        call = build_apply_kernel(
            apply_op,
            [tuple(a.shape) for a in arrays],
            origins,
            result_bounds,
            tile=tile,
            interpret=interpret,
        )
        _DISPATCH.apply_calls += 1
        out = call(*[a.astype(jnp.float32) for a in arrays])
    return list(out) if isinstance(out, (tuple, list)) else [out]
