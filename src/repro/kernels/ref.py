"""Pure-jnp oracles for every kernel in this package.

Independent implementations (no shared code with the kernels) used by the
allclose test sweeps.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np


def star_stencil_ref(x, coeffs: Dict[Tuple[int, ...], float], halo: Tuple[int, ...]):
    """Weighted sum of shifted reads.

    ``x`` is halo-inclusive; the output is the core (x minus ``halo`` on
    both sides per dim).  Out-of-core values come from the halo content —
    boundary semantics live in whoever filled the halo.
    """
    rank = x.ndim
    core = tuple(s - 2 * h for s, h in zip(x.shape, halo))
    out = jnp.zeros(core, x.dtype)
    for off, c in coeffs.items():
        idx = tuple(
            slice(h + o, h + o + n) for h, o, n in zip(halo, off, core)
        )
        out = out + jnp.asarray(c, x.dtype) * x[idx]
    return out


def heat_step_ref(u, alpha: float, order: int, halo: int):
    """u_core + alpha * laplacian(u) — Jacobi-like heat-diffusion update."""
    from repro.core.fd import laplacian_star

    rank = u.ndim
    star = laplacian_star(rank, order)
    lap = star_stencil_ref(u, star, (halo,) * rank)
    core = tuple(slice(halo, s - halo) for s in u.shape)
    return u[core] + jnp.asarray(alpha, u.dtype) * lap


def wave_step_ref(u_t, u_tm1, c2dt2: float, order: int, halo: int):
    """2nd-order-in-time acoustic update:
    u_{t+1} = 2 u_t - u_{t-1} + c²dt² ∇²u_t."""
    from repro.core.fd import laplacian_star

    rank = u_t.ndim
    star = laplacian_star(rank, order)
    lap = star_stencil_ref(u_t, star, (halo,) * rank)
    core = tuple(slice(halo, s - halo) for s in u_t.shape)
    return (
        2.0 * u_t[core]
        - u_tm1[core]
        + jnp.asarray(c2dt2, u_t.dtype) * lap
    )


def sliding_window_attention_ref(q, k, v, window: int, causal: bool = True):
    """O(S·W) oracle via explicit masking of full attention (small shapes).

    q,k,v: [heads, seq, dim] (kv may have fewer heads — GQA broadcast).
    Token i attends to [i-window+1, i] (causal sliding window).
    """
    hq, s, d = q.shape
    hk = k.shape[0]
    rep = hq // hk
    k = jnp.repeat(k, rep, axis=0)
    v = jnp.repeat(v, rep, axis=0)
    scores = jnp.einsum("hsd,htd->hst", q, k) / np.sqrt(d)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = (j > i) if causal else jnp.zeros((s, s), bool)
    mask = mask | (j <= i - window)
    scores = jnp.where(mask[None], -jnp.inf, scores)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hst,htd->hsd", p, v)


import jax  # noqa: E402  (used by sliding_window_attention_ref)
