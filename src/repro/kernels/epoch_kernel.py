"""Pallas epoch megakernel: ONE ``pl.pallas_call`` per deep-halo epoch.

Code-generates the whole region of a :class:`stencil.FusedEpochOp` — the
k-times-unrolled apply chain ``temporal-tile{k}`` produces, plus its
``comm.boundary_mask`` re-zeroing — into a single Pallas kernel body
(DESIGN.md §10).  Where ``kernels/stencil_apply.py`` dispatches one
kernel per apply (k HBM round-trips per epoch), here the k sub-steps'
intermediates are values *inside* the kernel: XLA/Mosaic keeps them in
VMEM/registers, time-buffer rotation is value rebinding, and the
shrinking redundant-boundary frames are just each sub-step's (smaller)
result bounds.

Two kernel modes, selected per call:

- **whole-shard** (default): a grid-free ``pallas_call`` whose refs are
  the full shard arrays; every sub-step computes its full grown frame.
  Always applicable — this is the mode the CPU interpret oracle runs.
- **tiled**: when every escaping value shares one core bounds ``C`` and
  the tile divides ``C``, the kernel runs on a grid over ``C`` with
  overlapping element-indexed input windows sized by the *accumulated*
  epoch halo demand (window = tile + (value bounds − C) per value); each
  tile redundantly recomputes its neighbours' frame overlap — the
  standard overlapped-tiling time-tile trade.

Boundary masks are precomputed OUTSIDE the kernel (they need the rank's
grid position via ``lax.axis_index``, unavailable in a kernel body) and
passed in as 0/1 float arrays; inside, masking is a ``jnp.where`` —
bitwise-identical to the interpreter's ``_exec_boundary_mask``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dialects import comm, stencil
from repro.kernels import _DISPATCH
from repro.obs import trace as _obs
from repro.kernels.stencil_apply import choose_tile


def _region_values(fused_op: stencil.FusedEpochOp) -> list:
    """Every SSA value live in the region: block args + member results."""
    vals = list(fused_op.body.args)
    for op in fused_op.body.ops:
        vals.extend(op.results)
    return vals


def _uses_index(fused_op: stencil.FusedEpochOp) -> bool:
    return any(
        isinstance(inner, stencil.IndexOp)
        for op in fused_op.body.ops
        if isinstance(op, stencil.ApplyOp)
        for inner in op.body.ops
    )


def _emit_region(fused_op, inputs, mask_blocks, bounds_of) -> list:
    """Evaluate the fused region over arrays/blocks.  ``bounds_of`` maps a
    region value to the bounds its array covers — actual logical bounds in
    whole-shard mode, tile-relative bounds in tiled mode.  The same code
    runs on jnp arrays (interpreter fallback) and on VMEM blocks.

    Bitwise caveat: under ``jit`` the fused kernel is exactly the k
    inlined per-step bodies the unfused path traces, so results are
    bitwise-identical.  *Eagerly* (``Target(jit=False)``) the unfused
    path compiles one XLA module per step while the fused kernel is one
    module for all k — XLA CPU's per-module codegen (FMA contraction)
    then drifts ~1ulp on non-power-of-two coefficients, and an
    ``optimization_barrier`` between sub-steps does not stop it.  The
    bitwise oracle therefore compares jitted targets."""
    from repro.core.lowering import eval_apply_body

    env = dict(zip(fused_op.body.args, inputs))
    mask_idx = 0
    for op in fused_op.body.ops:
        if isinstance(op, stencil.ApplyOp):
            arrays = [env[o] for o in op.operands]
            origins = [bounds_of(o).lb for o in op.operands]
            outs = eval_apply_body(op, arrays, origins, bounds_of(op.results[0]))
            for res, val in zip(op.results, outs):
                env[res] = val
        elif isinstance(op, comm.BoundaryMaskOp):
            mask = mask_blocks[mask_idx]
            mask_idx += 1
            x = env[op.temp]
            env[op.results[0]] = jnp.where(mask != 0, x, jnp.zeros_like(x))
        elif isinstance(op, stencil.FusedYieldOp):
            return [env[o] for o in op.operands]
        else:  # pragma: no cover - FusedEpochOp.verify_ rejects these
            raise NotImplementedError(f"fused region op {op.name}")
    raise AssertionError("fused_epoch region missing stencil.fused_yield")


def _rel_bounds(b: stencil.Bounds, core: stencil.Bounds, tile: tuple):
    """Tile-relative bounds: where value ``b`` sits around one core tile.
    The window a tile reads/computes of ``b`` is the tile grown by the
    value's overhang beyond the core: shape = tile + (b.shape - core.shape),
    starting ``core.lb - b.lb`` before the tile origin."""
    return stencil.Bounds(
        tuple(bl - cl for bl, cl in zip(b.lb, core.lb)),
        tuple(t + (bu - cu) for t, bu, cu in zip(tile, b.ub, core.ub)),
    )


def _window_spec(window: tuple, index_map):
    # overlapping element-indexed windows: newer jax spells this
    # pl.Element block dims, older jax an Unblocked indexing mode
    if hasattr(pl, "Element"):
        return pl.BlockSpec(tuple(pl.Element(w) for w in window), index_map)
    return pl.BlockSpec(window, index_map, indexing_mode=pl.unblocked)


def build_epoch_kernel(
    fused_op: stencil.FusedEpochOp,
    mask_shapes: Sequence[tuple],
    tile: Optional[tuple] = None,
    interpret: bool = True,
):
    """Code-generate one pallas_call for a whole fused epoch.

    Returns a callable taking ``(*external_arrays, *mask_arrays)`` (the
    op's operands in order, then one 0/1 keep-mask per boundary_mask op in
    region order) and returning the escape arrays (the op's results)."""
    mask_ops = [
        op for op in fused_op.body.ops if isinstance(op, comm.BoundaryMaskOp)
    ]
    assert len(mask_shapes) == len(mask_ops)
    n_in = len(fused_op.operands)
    n_mask = len(mask_ops)
    escape_bounds = [r.type.bounds for r in fused_op.results]

    core = escape_bounds[0] if escape_bounds else None
    tiled_ok = (
        core is not None
        and all(b == core for b in escape_bounds)
        and not _uses_index(fused_op)  # stencil.index needs logical coords
    )
    if tiled_ok:
        # VMEM working set: every region value's window (externals carry
        # the accumulated epoch halo; intermediates the shrinking frames)
        spans = [
            (
                tuple(vl - cl for vl, cl in zip(v.type.bounds.lb, core.lb)),
                tuple(vu - cu for vu, cu in zip(v.type.bounds.ub, core.ub)),
            )
            for v in _region_values(fused_op)
            if isinstance(v.type, stencil.TempType)
        ]
        if tile is None:
            tile = choose_tile(core.shape, spans)
        tile = tuple(tile)
        if len(tile) != core.rank or any(
            t < 1 or s % t for s, t in zip(core.shape, tile)
        ):
            tiled_ok = False  # fall back rather than mis-tile an epoch
        elif tile == tuple(core.shape):
            tiled_ok = False  # one tile == whole shard: skip the windows

    if not tiled_ok:
        # -- whole-shard mode: grid-free, refs are the full arrays ------
        def bounds_of(v):
            return v.type.bounds

        def kernel(*refs):
            inputs = [r[...] for r in refs[:n_in]]
            masks = [r[...] for r in refs[n_in : n_in + n_mask]]
            outs = _emit_region(fused_op, inputs, masks, bounds_of)
            for o_ref, val in zip(refs[n_in + n_mask :], outs):
                o_ref[...] = val

        out_shape = [
            jax.ShapeDtypeStruct(b.shape, jnp.float32) for b in escape_bounds
        ]
        return pl.pallas_call(
            kernel,
            out_shape=out_shape if len(out_shape) > 1 else out_shape[0],
            interpret=interpret,
        )

    # -- tiled mode: grid over core, overlapping epoch-halo windows -----
    grid = tuple(s // t for s, t in zip(core.shape, tile))
    rel = {
        v: _rel_bounds(v.type.bounds, core, tile)
        for v in _region_values(fused_op)
        if isinstance(v.type, stencil.TempType)
    }

    def tile_origin(*ids):
        return tuple(i * t for i, t in zip(ids, tile))

    in_specs = [
        _window_spec(rel[arg].shape, tile_origin) for arg in fused_op.body.args
    ] + [
        _window_spec(rel[m.results[0]].shape, tile_origin) for m in mask_ops
    ]
    out_specs = [pl.BlockSpec(tile, lambda *ids: ids) for _ in escape_bounds]
    out_shape = [
        jax.ShapeDtypeStruct(core.shape, jnp.float32) for _ in escape_bounds
    ]

    def kernel(*refs):
        inputs = [r[...] for r in refs[:n_in]]
        masks = [r[...] for r in refs[n_in : n_in + n_mask]]
        # escapes all have bounds == core, so rel(escape) == [0, tile):
        # each yielded value IS exactly this tile's output block
        outs = _emit_region(fused_op, inputs, masks, lambda v: rel[v])
        for o_ref, val in zip(refs[n_in + n_mask :], outs):
            o_ref[...] = val

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shape if len(out_shape) > 1 else out_shape[0],
        interpret=interpret,
    )


def run_epoch_pallas(
    fused_op: stencil.FusedEpochOp,
    arrays: Sequence,
    masks: Sequence,
    tile: Optional[tuple] = None,
    interpret: bool = True,
) -> list:
    """Entry point used by the lowering's pallas backend: one traced
    pallas_call per fused epoch (counted in ``kernels.dispatch_stats``)."""
    if not fused_op.results:
        return []
    with _obs.span("pallas:fused_epoch", cat="kernel", rank=None,
                   interpret=interpret):
        call = build_epoch_kernel(
            fused_op,
            [tuple(m.shape) for m in masks],
            tile=tile,
            interpret=interpret,
        )
        _DISPATCH.fused_epoch_calls += 1
        out = call(
            *[a.astype(jnp.float32) for a in arrays],
            *[m.astype(jnp.float32) for m in masks],
        )
    return list(out) if isinstance(out, (tuple, list)) else [out]
