"""``repro.dist`` — the shared distributed-memory substrate for the
LM/serving layers.

The paper's thesis is that distributed-memory abstractions should be
*shared infrastructure*, not re-derived per compiler: the stencil stack
expresses decomposition declaratively (``dmp`` dialect), lowers it once
(``comm`` dialect → ``lax.ppermute`` under ``shard_map``) and every DSL
frontend reuses it.  This package is the same argument applied to the
model half of the codebase:

- ``sharding``        — mesh context + logical→physical axis rules (the
                        model-layer analogue of ``dmp.GridAttr``);
- ``param_specs``     — PartitionSpec assignment for parameter/optimizer
                        trees (the analogue of the decomposition pass);
- ``compression``     — gradient compressors for bandwidth-bound meshes;
- ``context_parallel``— sequence-dimension halo exchange for Mamba /
                        sliding-window attention, built ON the stencil
                        ``dmp``/``comm`` machinery (a 1-D ``GridAttr``
                        over the sequence axis) rather than a bespoke
                        parallel path — see DESIGN.md §7.
"""
from repro.dist.sharding import (  # noqa: F401
    ShardingRules,
    active_mesh,
    active_rules,
    default_rules,
    kv_cache_layout,
    shard,
    use_mesh,
)
