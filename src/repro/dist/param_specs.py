"""PartitionSpec assignment for parameter / train-state trees.

The model-layer analogue of the stencil stack's decomposition pass
(``core/passes/decompose.py``): given the declarative mapping
(``ShardingRules``) and the topology (``Mesh``), walk the tree and emit a
concrete layout per leaf.  Leaves are classified by their tree path —
every parameter name in ``models/*.py`` appears in the table below — and
unknown leaves replicate, so new blocks degrade gracefully instead of
failing to launch.

All specs pass through ``_valid_spec``: an axis that does not divide a
dimension (e.g. 2 KV heads on a 16-way model axis) is dropped, never an
error — the launch layer decides layouts per (arch × shape) cell, and
the same table must serve all of them.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.sharding import ShardingRules, _valid_spec


def _path_names(path) -> Tuple[str, ...]:
    """Tree path → tuple of plain string names (dict keys, attr names,
    sequence indices)."""
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def _logical_axes(names: Tuple[str, ...], ndim: int) -> tuple:
    """Logical axis names (resolved through the rules table) per dim of
    the parameter leaf at tree path ``names``.

    Stacked leaves (``cells/slotN/...`` carry a leading supercell dim,
    ``encoder/layers/...`` a leading layer dim) are handled by the
    caller, which strips the stack dim before lookup.
    """
    last = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""

    if ndim <= 1:
        return (None,) * ndim

    # embedding / unembedding: [Vpad, D] — vocab rows over "model"
    if last in ("embed", "unembed"):
        return ("vocab", None)

    if parent in ("attn", "cross"):
        table = {
            "wq": ("embed", "q_heads_p", None),
            "wk": ("embed", "kv_heads_p", None),
            "wv": ("embed", "kv_heads_p", None),
            "wo": ("q_heads_p", None, "embed"),
            "bq": ("q_heads_p", None),
            "bk": ("kv_heads_p", None),
            "bv": ("kv_heads_p", None),
        }
        if last in table:
            return table[last]

    if parent == "moe":
        # expert weights are EP-resident over the expert dim — matching
        # moe_apply's shard_map in_specs P("model", None, None).  "mlp"
        # would collide with "expert" (both map to "model"); _valid_spec
        # keeps the first use of an axis, so expert wins as intended.
        table = {
            "router": (None, None),
            "wi": ("expert", None, "mlp"),
            "wu": ("expert", None, "mlp"),
            "wo": ("expert", "mlp", None),
        }
        if last in table:
            return table[last]

    if parent == "ffn":
        table = {
            "wi": ("embed", "mlp"),
            "wu": ("embed", "mlp"),
            "wo": ("mlp", "embed"),
        }
        if last in table:
            return table[last]

    if parent == "mamba":
        table = {
            "in_proj": ("embed", "mlp"),
            "out_proj": ("mlp", "embed"),
            "conv_w": (None, "mlp"),
            "dt_proj": ("embed", None),
            "B_proj": ("embed", None),
            "C_proj": ("embed", None),
        }
        if last in table:
            return table[last]

    if parent == "mlstm":
        # TP layout (models/xlstm.py): only hd_v is shardable — v/z
        # projections sharded on their last dim, down_proj row-parallel,
        # q/k/gates replicated.
        table = {
            "up_x": ("embed", None),
            "up_z": ("embed", None, "mlp"),
            "wv": (None, None, "mlp"),
            "down_proj": (None, "mlp", "embed"),
        }
        if last in table:
            return table[last]
        return (None,) * ndim

    if parent == "slstm":
        table = {
            "w_gates": ("embed", None, "heads", None),
            "r_gates": (None, "heads", None, None),
            "b_gates": (None, "heads", None),
            "up1": ("embed", "mlp"),
            "up2": ("embed", "mlp"),
            "down": ("mlp", "embed"),
        }
        if last in table:
            return table[last]

    if parent == "projector":
        return ("embed", None) if ndim == 2 else (None,) * ndim

    return (None,) * ndim


# Leaves stacked over supercells / encoder layers carry one extra leading
# dim that the logical table does not know about.
_STACKED_ROOTS = ("cells", "layers")


def _leaf_spec(names: Tuple[str, ...], shape: tuple,
               rules: ShardingRules, mesh: Mesh) -> P:
    stacked = any(r in names for r in _STACKED_ROOTS)
    ndim = len(shape) - (1 if stacked else 0)
    logical = _logical_axes(names, ndim)
    if stacked:
        logical = (None,) + tuple(logical)
    entries = tuple(
        rules.physical(a) if isinstance(a, str) else a for a in logical
    )
    return _valid_spec(mesh, P(*entries), tuple(shape))


def param_pspecs(shapes, rules: ShardingRules, mesh: Mesh):
    """PartitionSpec tree matching a parameter-shape tree.

    ``shapes`` is the pytree from ``jax.eval_shape(lm.init_params, ...)``
    (or the params themselves); every leaf gets a valid spec.
    """
    def one(path, leaf):
        return _leaf_spec(_path_names(path), tuple(leaf.shape), rules, mesh)

    return jax.tree_util.tree_map_with_path(one, shapes)


# prefixes stripped so optimizer moments inherit their parameter's spec
_STATE_WRAPPERS = ("params", "opt_state", "m", "v", "mu", "nu")


def state_pspecs(state_shapes, rules: ShardingRules, mesh: Mesh):
    """Specs for a full train state ``{params, opt_state{m,v,count}, step}``.

    AdamW moments mirror their parameter's layout (ZeRO-1 falls out of
    the parameter shardings for free); scalar counters replicate.
    """
    def one(path, leaf):
        names = _path_names(path)
        while names and names[0] in _STATE_WRAPPERS:
            names = names[1:]
        if not names or len(leaf.shape) == 0:
            return P()
        return _leaf_spec(names, tuple(leaf.shape), rules, mesh)

    return jax.tree_util.tree_map_with_path(one, state_shapes)
