"""Gradient compressors — the bandwidth lever for DCN-bound meshes.

Multi-pod training all-reduces gradients over DCN, which is an order of
magnitude slower than ICI; these compressors trade precision for wire
bytes on that hop.  Both operate leaf-wise on arbitrary pytrees and are
pure (roundtrip in one step) so they compose with ``lax.scan``-based
microbatching and stay pjit-able.

- ``int8_roundtrip``  — symmetric per-leaf int8 quantization; worst-case
  error ≤ max|x| / 127 (one quantization step), 4× fewer bytes than f32.
- ``topk_sparsify``   — magnitude top-k masking; keeps the largest
  ``keep_fraction`` of entries per leaf and zeroes the rest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _int8_leaf(x):
    if not jnp.issubdtype(x.dtype, jnp.floating) or x.ndim == 0:
        return x
    scale = jnp.max(jnp.abs(x)) / 127.0
    # all-zero leaf: keep scale finite so dequantization returns zeros
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    return (q.astype(x.dtype) * safe).astype(x.dtype)


def int8_roundtrip(tree):
    """Quantize every floating leaf to int8 and back (symmetric, per-leaf
    scale).  |out - in| ≤ max|in| / 127 · (1/2 rounding + clip slack)."""
    return jax.tree.map(_int8_leaf, tree)


def _topk_leaf(x, keep_fraction: float):
    if not jnp.issubdtype(x.dtype, jnp.floating) or x.ndim == 0:
        return x
    n = x.size
    k = max(1, int(n * keep_fraction))
    flat = x.reshape(-1)
    if k >= n:
        return x
    # threshold at the k-th largest magnitude: everything strictly above
    # it is kept unconditionally; ties AT the threshold are broken by
    # index so exactly k entries survive (tie-breaking must not touch
    # the strictly-above set, or a sparse leaf with thresh == 0 would
    # zero its actual nonzeros)
    mag = jnp.abs(flat)
    thresh = jax.lax.top_k(mag, k)[0][-1]
    above = mag > thresh
    ties = mag == thresh
    budget = k - above.sum()
    keep_ties = ties & (jnp.cumsum(ties.astype(jnp.int32)) <= budget)
    return jnp.where(above | keep_ties, flat, 0).reshape(x.shape)


def topk_sparsify(tree, keep_fraction: float = 0.01):
    """Zero all but the top ``keep_fraction`` entries (by magnitude) of
    every floating leaf."""
    return jax.tree.map(lambda x: _topk_leaf(x, keep_fraction), tree)
