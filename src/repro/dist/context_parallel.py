"""Sequence-dimension context parallelism via the stencil halo stack.

The paper's thesis — distributed-memory abstractions as *shared
infrastructure* — applied to the model layer: a Mamba causal conv reads
``[t-(K-1), t]`` and sliding-window attention reads ``[t-(W-1), t]``;
both are **stencils on the sequence axis** (DESIGN.md §4).  Under
sequence parallelism their shard-boundary reads are therefore halo
exchanges, and this module expresses them through exactly the machinery
the stencil DSLs use, instead of a bespoke ring path:

1. declare the exchange as a ``dmp.swap`` over a **1-D GridAttr whose
   grid axis is the sequence dimension** (``_build_swap_func``);
2. lower it with the shared ``lower_dmp_to_comm`` pass — the *canonical*
   dmp → comm (≈ MPI) step every stencil program takes — yielding
   ``comm.halo_pad`` + ``comm.exchange_start`` + ``comm.wait`` ops;
3. execute those comm ops with the shared comm-level executor
   (``run_func_dataflow`` / ``StencilInterpreter``) inside
   ``shard_map``, which turns each ``exchange_start`` into a
   ``lax.ppermute`` whose pairs come from the one shared
   ``comm.permute_pairs`` construction.

One exchange abstraction drives stencil *and* model parallelism — the
distribution-correctness guarantees of ``tests/test_distributed.py``
transfer to the LM layers by construction.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import api
from repro.core import ir
from repro.core.dialects import dmp, stencil
from repro.core.lowering import run_func_dataflow
from repro.core.passes.decompose import make_strategy_1d
from repro.dist.sharding import shard_map


@dataclasses.dataclass(frozen=True)
class SeqHaloSpec:
    """Declarative description of one sequence-halo exchange.

    ``halo_lo`` elements arrive from the left (earlier-sequence)
    neighbour, ``halo_hi`` from the right; ``boundary`` fills physical
    sequence edges ("zero" = causal start-of-sequence state).
    """

    axis: str
    n_shards: int
    halo_lo: int
    halo_hi: int = 0
    seq_dim: int = 1
    boundary: str = "zero"


def _build_swap_func(local_shape: tuple, spec: SeqHaloSpec) -> ir.FuncOp:
    """IR for the exchange: a temp of local core bounds flowing through a
    ``dmp.swap`` whose grid is 1-D over the sequence axis.

    This is the same declarative payload a decomposed stencil program
    carries (GridAttr + ExchangeDecls), built by the same strategy
    object (``make_strategy_1d``) — not a re-implementation.
    """
    strategy = make_strategy_1d(spec.n_shards, axis=spec.axis, dim=spec.seq_dim)
    core = stencil.Bounds.from_shape(local_shape)
    lo = tuple(spec.halo_lo if d == spec.seq_dim else 0
               for d in range(len(local_shape)))
    hi = tuple(spec.halo_hi if d == spec.seq_dim else 0
               for d in range(len(local_shape)))
    decls, schedule = strategy.exchanges(core, lo, hi, corners=False)
    func = ir.FuncOp("seq_halo", [stencil.TempType(core)])
    swap = dmp.SwapOp(
        func.body.args[0],
        strategy.grid,
        decls,
        result_bounds=core.grow(lo, hi),
        boundary=spec.boundary,
        schedule=schedule,
    )
    func.body.add_op(swap)
    func.body.add_op(ir.ReturnOp([swap.results[0]]))
    return func


@lru_cache(maxsize=128)
def _comm_func(local_shape: tuple, spec: SeqHaloSpec) -> ir.FuncOp:
    """The exchange after the shared dmp→comm lowering (paper fig. 4):
    ``comm.halo_pad`` + per-round ``comm.exchange_start``/``comm.wait``.

    Lowered through ``repro.api``'s process-wide fingerprint-keyed cache
    — the same cache stencil compiles use, visible in
    ``repro.api.cache_stats()`` — with a thin shape-keyed lru memo on
    top so the per-trace hot path skips even the IR build + hash."""
    return api.lower_ir(
        _build_swap_func(local_shape, spec), "lower-comm", boundary=spec.boundary
    )


def comm_ir_text(local_shape: tuple, spec: SeqHaloSpec) -> str:
    """Printable comm-dialect IR of the exchange (debug / DESIGN.md)."""
    func = _comm_func(tuple(local_shape), spec)
    return "\n".join(op.name for op in func.body.ops)


def seq_halo_exchange(x_loc, spec: SeqHaloSpec, *, distributed: bool = True):
    """Halo-grow one rank's sequence shard.

    ``x_loc``: the local shard (called inside ``shard_map`` when
    ``distributed``); returns the shard grown by (halo_lo, halo_hi)
    along ``seq_dim``, halos filled by neighbour exchange (``ppermute``)
    or the boundary condition at physical edges.

    With ``distributed=False`` the exchange runs in local-emulation mode
    (the single-rank path the stencil lowering uses for meshless
    compiles): zero-BC halos stay zero, periodic halos wrap locally.
    """
    func = _comm_func(tuple(x_loc.shape), spec)
    (out,) = run_func_dataflow(
        func,
        [x_loc],
        axis_sizes={spec.axis: spec.n_shards},
        distributed=distributed,
    )
    return out


def context_parallel(
    fn: Callable,
    mesh: Mesh,
    spec: SeqHaloSpec,
    *,
    out_seq_dim: Optional[int] = None,
) -> Callable:
    """Lift a *local window function* to a sequence-parallel global one.

    ``fn(x_halo, shard_start, *rest)`` receives the halo-grown local
    shard plus the global sequence offset of its core's first element,
    and returns the core-shaped local output.  The wrapper shard_maps it
    over ``spec.axis`` with the halo exchange (dmp/comm machinery)
    prepended; ``rest`` operands are replicated (weights).
    """
    out_dim = spec.seq_dim if out_seq_dim is None else out_seq_dim

    def global_fn(x, *rest):
        n = spec.n_shards
        S = x.shape[spec.seq_dim]
        assert S % n == 0, (S, n)
        in_entries = [None] * x.ndim
        in_entries[spec.seq_dim] = spec.axis
        x_spec = P(*in_entries)

        def local(x_loc, *rest_loc):
            xh = seq_halo_exchange(x_loc, spec, distributed=n > 1)
            start = jax.lax.axis_index(spec.axis) * (S // n)
            return fn(xh, start, *rest_loc)

        if n <= 1:
            # meshless / single-rank reference path — same code, local
            # emulation of the exchange (mirrors the stencil lowering)
            return fn(seq_halo_exchange(x, spec, distributed=False),
                      jnp.int32(0), *rest)

        local_in = jax.ShapeDtypeStruct(
            tuple(s // n if d == spec.seq_dim else s
                  for d, s in enumerate(x.shape)),
            x.dtype,
        )
        out_shape = jax.eval_shape(
            lambda xl, *r: fn(
                seq_halo_exchange(xl, spec, distributed=False),
                jnp.int32(0), *r,
            ),
            local_in,
            *rest,
        )

        def out_spec_of(s):
            entries = [None] * len(s.shape)
            if out_dim < len(s.shape):
                entries[out_dim] = spec.axis
            return P(*entries)

        out_specs = jax.tree.map(out_spec_of, out_shape)
        return shard_map(
            local,
            mesh=mesh,
            in_specs=(x_spec,) + tuple(P() for _ in rest),
            out_specs=out_specs,
            check_vma=False,
        )(x, *rest)

    return global_fn


# --------------------------------------------------------------------------
# Concrete context-parallel layers
# --------------------------------------------------------------------------


def causal_conv_cp(x, w, b, mesh: Mesh, axis: str):
    """Sequence-parallel Mamba causal conv (``models.mamba._causal_conv``
    distributed over ``axis``).

    The conv reads ``[t-(K-1), t]`` — halo K-1, one-sided — so the left
    halo *is* the conv's stitching state: the local kernel is literally
    the single-device ``_causal_conv`` with the exchanged halo passed as
    its ``state``.  x: [B, S, C] (global), w: [K, C], b: [C].
    """
    from repro.models.mamba import _causal_conv

    K = w.shape[0]
    spec = SeqHaloSpec(
        axis=axis, n_shards=int(mesh.shape.get(axis, 1)),
        halo_lo=K - 1, halo_hi=0, seq_dim=1, boundary="zero",
    )

    def local(xh, start, w_l, b_l):
        state, core = xh[:, : K - 1], xh[:, K - 1:]
        y, _ = _causal_conv(core, w_l, b_l, state)
        return y

    return context_parallel(local, mesh, spec)(x, w, b)


def sliding_window_attention_cp(q, k, v, window: int, mesh: Mesh, axis: str):
    """Sequence-parallel sliding-window self-attention.

    q/k/v: [B, S, H, D] (MHA; global arrays).  Each query attends the
    causal window ``[t-W+1, t]`` — a radius-(W-1) one-sided sequence
    stencil — so K/V need a left halo of W-1 and *no* score entry ever
    crosses more than one shard boundary.  The windows are gathered
    explicitly ([B, S_loc, W] score blocks), making the arithmetic per
    query independent of the decomposition — distributed equals
    single-device bitwise, the same guarantee the stencil tests assert.
    """
    W = int(window)
    n = int(mesh.shape.get(axis, 1))

    def local(kv_h, start, q_l):
        k_h, v_h = kv_h[0], kv_h[1]
        B, S_loc = q_l.shape[0], q_l.shape[1]
        D = q_l.shape[-1]
        # window gather: win[t, w] = halo-extended seq index t + w,
        # i.e. absolute position (start + t) - (W-1) + w
        idx = jnp.arange(S_loc)[:, None] + jnp.arange(W)[None, :]
        kw = jnp.take(k_h, idx, axis=1)   # [B, S_loc, W, H, D]
        vw = jnp.take(v_h, idx, axis=1)
        s = jnp.einsum("bthd,btwhd->bthw", q_l, kw) / jnp.sqrt(
            jnp.float32(D)
        ).astype(q_l.dtype)
        abs_kv = (start + jnp.arange(S_loc))[:, None] - (W - 1) + jnp.arange(W)
        s = jnp.where(abs_kv[None, :, None, :] >= 0, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bthw,btwhd->bthd", p, vw)

    # k and v share one exchange (stacked leading dim)
    kv = jnp.stack([k, v], axis=0)
    kv_spec = SeqHaloSpec(axis=axis, n_shards=n, halo_lo=W - 1, halo_hi=0,
                          seq_dim=2, boundary="zero")

    if n <= 1:
        kv_h = seq_halo_exchange(kv, kv_spec, distributed=False)
        return local(kv_h, jnp.int32(0), q)

    S = q.shape[1]
    assert S % n == 0, (S, n)

    def shard_local(kv_loc, q_loc):
        kv_h = seq_halo_exchange(kv_loc, kv_spec, distributed=True)
        start = jax.lax.axis_index(axis) * (S // n)
        return local(kv_h, start, q_loc)

    return shard_map(
        shard_local,
        mesh=mesh,
        in_specs=(P(None, None, axis), P(None, axis)),
        out_specs=P(None, axis),
        check_vma=False,
    )(kv, q)


def mamba_conv_exchange_bytes(cfg, B: int, seq_shards: int) -> int:
    """Wire bytes per layer for the Mamba conv halo under sequence
    parallelism — the roofline-table hook (DESIGN.md §7): (K-1) steps ×
    d_inner channels × batch, once per direction boundary."""
    d_inner = cfg.ssm_expand * cfg.d_model
    return 4 * B * (cfg.ssm_conv_width - 1) * d_inner * max(seq_shards - 1, 0)
