"""Mesh context + logical→physical sharding rules.

The model layers annotate tensors with *logical* axis names ("batch",
"embed", "mlp", ...) via ``shard``; a ``ShardingRules`` table maps those
to physical mesh axes.  This mirrors how the stencil stack separates the
declarative decomposition (``dmp.GridAttr``: which array dim maps to
which mesh axis) from its lowering — one rules table serves every
architecture, and moving a deployment from a (data, model) mesh to a
(pod, data, model) mesh is a rules swap, not a model edit.

``shard`` is a no-op without an active mesh, so the same model code runs
on single-device CPU tests and 512-chip pods unchanged.

Every constraint goes through ``_valid_spec``, which drops mesh axes
that do not divide the corresponding array dimension — the moral
equivalent of the stencil decomposition's divisibility check, applied
permissively (replicate instead of erroring) because model shapes vary
per architecture.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Mapping, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: A physical mapping for one logical axis: a mesh axis name, a tuple of
#: mesh axis names (sharded over their product), or None (replicated).
Physical = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis → physical-mesh-axis table."""

    table: Mapping[str, Physical]

    def physical(self, logical: Optional[str]) -> Physical:
        if logical is None:
            return None
        return self.table.get(logical)

    def replace(self, **updates: Physical) -> "ShardingRules":
        return ShardingRules({**self.table, **updates})


def default_rules(multi_pod: bool = False) -> ShardingRules:
    """The production rules: batch over the data axes (FSDP-style), every
    contracted model dimension over "model" (megatron-style TP).

    Multi-pod runs add a leading "pod" axis to the batch group — DCN
    traffic stays data-parallel only (gradient all-reduce), ICI carries
    the TP collectives.
    """
    batch: Physical = ("pod", "data") if multi_pod else "data"
    return ShardingRules(
        {
            # activations
            "batch": batch,
            "seq": None,
            "embed_act": None,
            "mlp_act": "model",
            "vocab_act": "model",
            "heads": "model",
            "kv_heads": "model",
            # weights
            "embed": None,
            "vocab": "model",
            "q_heads_p": "model",
            "kv_heads_p": "model",
            "mlp": "model",
            "expert": "model",
        }
    )


# --------------------------------------------------------------------------
# mesh context
# --------------------------------------------------------------------------

_STATE = threading.local()


def _stack() -> list:
    if not hasattr(_STATE, "stack"):
        _STATE.stack = []
    return _STATE.stack


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[ShardingRules] = None):
    """Activate ``mesh``/``rules`` for every ``shard`` call in scope.

    Entered *inside* the jitted step function (the context only needs to
    cover tracing), mirroring how the stencil lowering scopes its
    ``shard_map`` to one compiled program.
    """
    rules = rules or default_rules(multi_pod="pod" in mesh.axis_names)
    _stack().append((mesh, rules))
    try:
        yield mesh
    finally:
        _stack().pop()


def active_mesh() -> Optional[Mesh]:
    s = _stack()
    return s[-1][0] if s else None


def active_rules() -> Optional[ShardingRules]:
    s = _stack()
    return s[-1][1] if s else None


# --------------------------------------------------------------------------
# spec validation
# --------------------------------------------------------------------------


def _valid_spec(mesh: Mesh, spec: P, shape: tuple) -> P:
    """Clamp ``spec`` to what ``shape`` supports on ``mesh``.

    Per dimension, mesh axes are kept (in order) only while the product
    of their sizes still divides the dimension; axes unknown to the mesh
    or already used by an earlier dimension are dropped.  The result is
    always a legal NamedSharding spec — the permissive counterpart of the
    stencil decomposition's hard divisibility error.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used: set = set()
    out = []
    for dim, entry in zip(shape, entries[: len(shape)]):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            if a is None or a not in mesh.shape or a in used:
                continue
            size = mesh.shape[a]
            if dim % (prod * size) == 0:
                kept.append(a)
                prod *= size
                used.add(a)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-portable ``shard_map``.

    Newer jax exposes ``jax.shard_map(..., check_vma=...)``; older
    releases ship ``jax.experimental.shard_map`` with the ``check_rep``
    spelling.  Every manual-SPMD call site in the repo (flash-decode,
    MoE expert parallelism, context parallelism) routes through here so
    the version split lives in exactly one place.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def factor_slot_mesh(
    mesh: Mesh,
    slots: int = 1,
    axis: str = "slot",
    devices=None,
) -> Mesh:
    """Extend a spatial ``mesh`` with a leading slot axis of size
    ``slots`` factored out of the device inventory.

    The slot axis carries a *batch* dimension (pooled serving slots, or
    ensemble members), not an array dimension: collectives keep binding
    the spatial axis names, so each slot block of ``slots × spatial``
    devices runs the exact solo exchange pattern.  ``slots == 1`` reuses
    the mesh's own devices (shard_map over ``(slot=1, *spatial)`` — the
    vmap inside still pools the batch); ``slots > 1`` takes the first
    ``slots * spatial`` devices of ``devices`` (default: the process
    inventory), slot-major, so slot block 0 is the original mesh's
    device prefix.
    """
    import numpy as np

    if int(slots) != slots or slots < 1:
        raise ValueError(f"slots must be a positive integer, got {slots!r}")
    slots = int(slots)
    if axis in mesh.axis_names:
        raise ValueError(
            f"slot axis {axis!r} collides with mesh axes "
            f"{tuple(mesh.axis_names)}"
        )
    spatial_shape = tuple(mesh.shape[a] for a in mesh.axis_names)
    names = (axis,) + tuple(mesh.axis_names)
    if slots == 1:
        devs = mesh.devices.reshape((1,) + spatial_shape)
        return Mesh(devs, names)
    n_spatial = int(np.prod(spatial_shape))
    pool = list(devices) if devices is not None else jax.devices()
    need = slots * n_spatial
    if need > len(pool):
        raise ValueError(
            f"slot axis of {slots} over a {n_spatial}-rank spatial mesh "
            f"needs {need} devices, have {len(pool)}"
        )
    devs = np.array(pool[:need]).reshape((slots,) + spatial_shape)
    return Mesh(devs, names)


def reshard(arrays, mesh: Optional[Mesh], specs) -> tuple:
    """Place host arrays onto ``mesh`` with one ``PartitionSpec`` each —
    the elastic-restore path: state checkpointed under one mesh
    factorization is ``device_put`` under a *different* one (or none),
    so a killed 4-rank run resumes onto 2 ranks unchanged.  ``mesh`` is
    ``None`` for a single-device restore (plain device_put)."""
    arrays = tuple(arrays)
    if mesh is None:
        return tuple(jax.device_put(a) for a in arrays)
    if len(arrays) != len(tuple(specs)):
        raise ValueError(
            f"{len(arrays)} arrays for {len(tuple(specs))} partition specs"
        )
    return tuple(
        jax.device_put(a, NamedSharding(mesh, spec))
        for a, spec in zip(arrays, specs)
    )


def shard(x, *logical: Optional[str]):
    """Constrain ``x`` to the active rules' layout for ``logical`` axes.

    No-op without an active mesh — model code is annotation-transparent
    on single-device runs.  Entries may be logical names or ``None``.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    rules = active_rules() or default_rules(multi_pod="pod" in mesh.axis_names)
    entries = tuple(
        rules.physical(a) if isinstance(a, str) else a for a in logical
    )
    spec = _valid_spec(mesh, P(*entries), tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# KV-cache layout policy
# --------------------------------------------------------------------------


def _batch_axis_size(mesh: Mesh, rules: ShardingRules) -> int:
    batch_ax = rules.physical("batch")
    axes = batch_ax if isinstance(batch_ax, tuple) else (batch_ax,)
    return math.prod(mesh.shape.get(a, 1) for a in axes if a)


def kv_cache_layout(
    B: int, T: int, Kh: int, mesh: Optional[Mesh],
    rules: Optional[ShardingRules] = None,
) -> str:
    """Pick the decode-cache layout for a [B, T, Kh, hd] cache.

    Policy (DESIGN.md §6):

    - ``"heads"``   — KV heads divide the model axis: classic TP.
    - ``"seq"``     — they don't; shard the *sequence* dim over "model"
      instead — the paper's domain decomposition applied to the KV
      domain (decode softmax/PV reductions become small all-reduces).
    - ``"seq_all"`` — tiny-batch long-context: batch can't shard, so the
      sequence dim is spread over every available axis.
    - ``"batch"``   — no model axis (or nothing else fits) but batch
      divides the data axes.
    - ``"flat"``    — replicate (single device / nothing divides).
    """
    if mesh is None:
        return "flat"
    rules = rules or default_rules(multi_pod="pod" in mesh.axis_names)
    n_b = _batch_axis_size(mesh, rules)
    model = mesh.shape.get("model", 1)
    batch_ok = n_b <= 1 or B % n_b == 0
    if model > 1:
        if Kh % model == 0 and batch_ok:
            return "heads"
        if batch_ok and n_b > 1 and T % model == 0:
            return "seq"
        if T % (max(n_b, 1) * model) == 0:
            return "seq_all"
    if n_b > 1 and B % n_b == 0:
        return "batch"
    return "flat"
