"""AdamW with decoupled weight decay, global-norm clipping, and
warmup+cosine schedule — from scratch (no optax in this environment).

Optimizer state inherits each parameter's sharding (ZeRO-1 falls out of
the FSDP'd parameter shardings for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * (step + 1.0) / max(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.peak_lr * (
        cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def _decay_mask(path: tuple) -> bool:
    """No weight decay on norms/biases/1-d scales."""
    last = str(path[-1]) if path else ""
    return not any(tok in last for tok in ("norm", "bias", "b_gates", "bf", "bq", "bk", "bv", "A_log", "D", "dt_bias"))


def adamw_update(cfg: OptimizerConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    count = opt_state["count"] + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, opt_state["m"], grads
    )
    new_v = jax.tree.map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2) * jnp.square(g),
        opt_state["v"],
        grads,
    )

    paths_params, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree.leaves(new_m)
    flat_v = jax.tree.leaves(new_v)
    new_leaves = []
    for (path, p), m, v in zip(paths_params, flat_m, flat_v):
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if _decay_mask(path):
            update = update + cfg.weight_decay * p
        new_leaves.append(p - lr * update)
    new_params = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return (
        new_params,
        {"m": new_m, "v": new_v, "count": count},
        {"grad_norm": gnorm, "lr": lr},
    )
