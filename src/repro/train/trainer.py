"""Fault-tolerant training driver.

Production behaviors implemented (and exercised by tests):
- **checkpoint/restart**: periodic async checkpoints; on start, resume
  from the latest COMMITTED step; the data pipeline is keyed by step so
  the token stream resumes exactly;
- **preemption handling**: SIGTERM triggers a final blocking checkpoint
  before exit (the TPU-pod eviction contract);
- **NaN guard**: non-finite loss skips the update (state rollback is the
  checkpoint) and counts toward an abort threshold;
- **straggler/step-time watchdog**: a rolling step-time median flags
  outlier steps (on real pods: report the slow host for replacement —
  here, logged);
- **elastic restart**: restore maps checkpointed host arrays onto the
  *current* mesh's shardings, so the same run continues on a different
  device count.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, PrefetchLoader, make_source


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    max_nan_steps: int = 5
    straggler_factor: float = 3.0


class Trainer:
    def __init__(
        self,
        train_step: Callable,
        init_state: Callable[[], Any],
        data_cfg: DataConfig,
        cfg: TrainerConfig,
        state_shardings=None,
        put_batch: Optional[Callable] = None,
    ):
        self.train_step = train_step
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.state_shardings = state_shardings
        self.put_batch = put_batch or (lambda b: b)
        self.ckpt = (
            Checkpointer(cfg.checkpoint_dir) if cfg.checkpoint_dir else None
        )
        self._preempted = False
        self._nan_steps = 0
        self._step_times: deque = deque(maxlen=32)
        self.metrics_log: list = []

        # resume or init
        start = self.ckpt.latest_step() if self.ckpt else None
        if start is not None:
            template = jax.eval_shape(init_state)
            template = jax.tree.map(
                lambda s: np.zeros(s.shape, s.dtype), template
            )
            self.state = self.ckpt.restore(
                template, shardings=self.state_shardings
            )
            self.start_step = start
        else:
            self.state = init_state()
            self.start_step = 0

    # -- preemption --------------------------------------------------------
    def install_signal_handler(self) -> None:
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    # -- main loop ----------------------------------------------------------
    def run(self) -> dict:
        source = make_source(self.data_cfg)
        loader = PrefetchLoader(source, start_step=self.start_step)
        it = iter(loader)
        step = self.start_step
        try:
            while step < self.cfg.total_steps:
                data_step, batch = next(it)
                assert data_step == step, (data_step, step)
                t0 = time.perf_counter()
                new_state, metrics = self.train_step(
                    self.state, self.put_batch(batch)
                )
                loss = float(jax.device_get(metrics["loss"]))
                dt = time.perf_counter() - t0

                if not np.isfinite(loss):
                    # NaN guard: drop the update, keep the old state
                    self._nan_steps += 1
                    if self._nan_steps > self.cfg.max_nan_steps:
                        raise FloatingPointError(
                            f"{self._nan_steps} non-finite steps — aborting; "
                            f"restart will resume from the last checkpoint"
                        )
                else:
                    self.state = new_state
                    self._nan_steps = 0

                self._watch_stragglers(step, dt)
                step += 1
                if step % self.cfg.log_every == 0 or step == self.cfg.total_steps:
                    self.metrics_log.append(
                        {"step": step, "loss": loss, "time_s": dt}
                    )
                if self.ckpt and step % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step, self.state)
                if self._preempted:
                    if self.ckpt:
                        self.ckpt.save(step, self.state, blocking=True)
                    break
        finally:
            loader.stop()
            if self.ckpt:
                self.ckpt.wait()
        return {"final_step": step, "metrics": self.metrics_log}

    def _watch_stragglers(self, step: int, dt: float) -> None:
        if len(self._step_times) >= 8:
            med = float(np.median(self._step_times))
            if dt > self.cfg.straggler_factor * med:
                self.metrics_log.append(
                    {
                        "step": step,
                        "straggler_s": dt,
                        "median_s": med,
                        "action": "flagged (real pods: drain+replace host)",
                    }
                )
        self._step_times.append(dt)
