"""Loss + train step construction.

``make_train_step`` builds the pjit-able ``(state, batch) → (state,
metrics)`` function: next-token cross-entropy (+ z-loss + MoE aux),
optional gradient-accumulation microbatching (``lax.scan`` over
microbatches — compile-size-free), global-norm clip, AdamW.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.train import optimizer as opt

Z_LOSS = 1e-4
MOE_LB_WEIGHT = 1e-2


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    remat: bool = True
    q_chunk: int = 1024
    microbatches: int = 1
    grad_compression: Optional[str] = None  # None | "int8" (dist/compression)


def cross_entropy_loss(cfg: ModelConfig, logits, tokens):
    """Next-token CE over text positions (skips modality prefix)."""
    V = logits.shape[-1]
    S_tok = tokens.shape[1]
    prefix = logits.shape[1] - S_tok  # vision tokens prepended
    logits = logits[:, prefix:, :]
    pred = logits[:, :-1]
    tgt = tokens[:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    zloss = Z_LOSS * jnp.square(logz).mean()
    return ce, zloss


def make_loss_fn(cfg: ModelConfig, options: TrainOptions):
    def loss_fn(params, batch):
        logits, aux = lm.forward_train(
            params,
            cfg,
            batch["tokens"],
            batch.get("modality"),
            remat=options.remat,
            q_chunk=options.q_chunk,
        )
        ce, zloss = cross_entropy_loss(cfg, logits, batch["tokens"])
        loss = ce + zloss
        metrics = {"ce": ce, "z_loss": zloss}
        if aux:
            loss = loss + MOE_LB_WEIGHT * aux["moe_lb_loss"] + aux["moe_z_loss"]
            metrics.update(aux)
        return loss, metrics

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: opt.OptimizerConfig,
    options: Optional[TrainOptions] = None,
):
    options = options or TrainOptions()
    loss_fn = make_loss_fn(cfg, options)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if options.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        n = options.microbatches

        def micro(carry, mb):
            acc, = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g / n, acc, grads)
            return (acc,), (loss, metrics)

        zero = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        mbs = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch
        )
        (grads,), (losses, metricses) = jax.lax.scan(micro, (zero,), mbs)
        metrics = jax.tree.map(lambda m: m.mean(), metricses)
        return losses.mean(), metrics, grads

    def train_step(state, batch):
        params, opt_state = state["params"], state["opt_state"]
        loss, metrics, grads = compute_grads(params, batch)
        if options.grad_compression == "int8":
            from repro.dist.compression import int8_roundtrip

            grads = int8_roundtrip(grads)
        new_params, new_opt_state, om = opt.adamw_update(
            opt_cfg, grads, opt_state, params
        )
        metrics = dict(metrics, loss=loss, **om)
        new_state = {
            "params": new_params,
            "opt_state": new_opt_state,
            "step": state["step"] + 1,
        }
        return new_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig) -> dict:
    params = lm.init_params(key, cfg)
    return {
        "params": params,
        "opt_state": opt.init_opt_state(params),
        "step": jnp.zeros((), jnp.int32),
    }
