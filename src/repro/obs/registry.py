"""One snapshot over the stack's counter islands.

Before ``repro.obs`` each subsystem kept truthful but *disjoint*
counters: the compile cache (``api.cache_stats``), the pass pipeline
(``PassManager.runs_completed``), kernel dispatch
(``kernels.dispatch_stats``), the serve engine (per-instance
``EngineMetrics``), checkpointing (per-``Checkpointer``
``CheckpointStats``) and the tune cache (``tune.cache.cache_stats``).
``snapshot()`` unifies them behind one namespaced dict —

    {"compile": {...}, "kernel": {...}, "serve": {...},
     "checkpoint": {...}, "tune": {...}, "trace": {...}}

— without changing any per-subsystem API: the islands remain the source
of truth and this module only *reads* them (per-instance islands are
aggregated through lightweight process-wide hooks:
``serve.stencil.metrics.global_counters`` sums over live engines via a
weak set, ``checkpoint.checkpointer.global_stats`` mirrors every
instance bump).  Imports are lazy so ``import repro.obs`` stays cheap
and cycle-free.
"""
from __future__ import annotations

NAMESPACES = ("compile", "kernel", "serve", "checkpoint", "tune")


def snapshot(flat: bool = False) -> dict:
    """All counter islands, namespaced.  ``flat=True`` flattens to
    dotted keys (``{"compile.hits": 3, ...}``) for log lines."""
    from repro import api
    from repro import kernels
    from repro.checkpoint import checkpointer as _ckpt
    from repro.core.passes import PassManager
    from repro.obs import trace as _trace
    from repro.serve.stencil import metrics as _serve_metrics
    from repro.tune import cache as _tune_cache

    out = {
        "compile": {
            **api.cache_stats().as_dict(),
            "cache_capacity": api.cache_capacity(),
            "pipeline_runs": int(PassManager.runs_completed),
        },
        "kernel": kernels.dispatch_stats().as_dict(),
        "serve": _serve_metrics.global_counters(),
        "checkpoint": _ckpt.global_stats().as_dict(),
        "tune": _tune_cache.cache_stats().as_dict(),
        "trace": _trace.tracer().counters(),
    }
    if not flat:
        return out
    return {
        f"{ns}.{key}": val
        for ns, counters in out.items()
        for key, val in counters.items()
    }
