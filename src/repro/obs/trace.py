"""Low-overhead span tracer: the timeline behind ``repro.obs``.

Spans are wall-clock windows — a pass in ``PassManager.run``, one epoch
of a time loop, one exchange_start→wait window, one pooled serve
dispatch — collected in a thread-safe **bounded ring buffer** and tagged
with a rank so multi-process runs merge into one Perfetto timeline
(``repro.obs.export``).

Design constraints (DESIGN.md §12):

* **Off by default, near-zero cost when off.**  ``span()`` returns a
  shared no-op context manager after a single attribute check; no dict
  is built, nothing is allocated, nothing is locked.  Hot paths that
  want to skip even argument construction guard with ``enabled()``.
* **Nestable + thread-safe.**  Depth bookkeeping is thread-local; the
  ring buffer append is guarded by a lock.  ``tid`` is a *lane*, not an
  OS thread: lane 0 carries synchronous execute spans, lane 1 carries
  async comm windows (which overlap lane-0 spans — that overlap IS the
  measurement).
* **Rank/process tagged.**  ``rank=None`` marks an SPMD span: the
  interpreter traces one program for every rank, so the span is true of
  each of them; the exporter replicates it onto every rank's track.

Enable with ``REPRO_TRACE=1`` in the environment or ``obs.enable()`` at
runtime; ``REPRO_TRACE_RANK`` / ``set_rank()`` pins the process rank;
``REPRO_TRACE_CAPACITY`` bounds the ring buffer (default 65536 spans,
oldest dropped first, drops counted truthfully).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

# lanes: Chrome complete events on one tid must nest properly, but an
# exchange window deliberately OVERLAPS the interior-apply span it hides.
# Putting comm windows on their own lane keeps both visible in Perfetto.
LANE_EXECUTE = 0
LANE_COMM = 1
LANE_NAMES = {LANE_EXECUTE: "execute", LANE_COMM: "comm"}


@dataclasses.dataclass
class Span:
    """One closed interval on the timeline.

    ``ts`` is wall-clock seconds (``time.time`` epoch — comparable across
    processes, which is what lets ``merge_traces`` interleave per-rank
    files); ``dur`` is measured with ``time.perf_counter`` so short spans
    keep full resolution.
    """

    name: str
    cat: str = "misc"
    ts: float = 0.0
    dur: float = 0.0
    rank: Optional[int] = None  # None = SPMD: true of every rank
    tid: int = LANE_EXECUTE
    depth: int = 0
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.ts + self.dur

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "cat": self.cat,
            "ts": self.ts,
            "dur": self.dur,
            "rank": self.rank,
            "tid": self.tid,
            "depth": self.depth,
            "args": dict(self.args),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(
            name=d["name"],
            cat=d.get("cat", "misc"),
            ts=float(d.get("ts", 0.0)),
            dur=float(d.get("dur", 0.0)),
            rank=d.get("rank"),
            tid=int(d.get("tid", LANE_EXECUTE)),
            depth=int(d.get("depth", 0)),
            args=dict(d.get("args") or {}),
        )


class _NullSpan:
    """Shared do-nothing context manager: the entire cost of a disabled
    ``with obs.span(...):`` is one attribute check and returning this."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    @property
    def args(self) -> dict:  # writes to a disabled span go nowhere
        return {}


_NULL = _NullSpan()


class _SpanHandle:
    """Live span context manager; commits the span on exit."""

    __slots__ = ("_tracer", "_span", "_t0")

    def __init__(self, tracer: "Tracer", span: Span) -> None:
        self._tracer = tracer
        self._span = span
        self._t0 = 0.0

    @property
    def args(self) -> dict:
        return self._span.args

    def __enter__(self) -> "_SpanHandle":
        tls = self._tracer._tls
        self._span.depth = getattr(tls, "depth", 0)
        tls.depth = self._span.depth + 1
        self._span.ts = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self._span.dur = time.perf_counter() - self._t0
        tls = self._tracer._tls
        tls.depth = max(0, getattr(tls, "depth", 1) - 1)
        self._tracer._commit(self._span)
        return False


class Tracer:
    """Thread-safe bounded span collector (see module docstring)."""

    def __init__(self, capacity: Optional[int] = None) -> None:
        if capacity is None:
            capacity = int(os.environ.get("REPRO_TRACE_CAPACITY", 65536))
        self.capacity = max(1, int(capacity))
        self._buf: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._tls = threading.local()
        self.dropped = 0
        self.enabled = os.environ.get("REPRO_TRACE", "") not in ("", "0")
        rank_env = os.environ.get("REPRO_TRACE_RANK", "")
        self.rank: Optional[int] = int(rank_env) if rank_env else None

    # -- control ---------------------------------------------------------
    def enable(self, capacity: Optional[int] = None) -> None:
        if capacity is not None and int(capacity) != self.capacity:
            with self._lock:
                self.capacity = max(1, int(capacity))
                self._buf = deque(self._buf, maxlen=self.capacity)
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0

    def set_rank(self, rank: Optional[int]) -> None:
        self.rank = None if rank is None else int(rank)

    # -- recording -------------------------------------------------------
    def span(self, name: str, cat: str = "misc", rank: Any = "inherit",
             tid: int = LANE_EXECUTE, **args):
        """Context manager timing a block.  ``rank=None`` marks the span
        SPMD (replicated to every rank's track on export); the default
        inherits the tracer's process rank."""
        if not self.enabled:
            return _NULL
        r = self.rank if rank == "inherit" else rank
        return _SpanHandle(self, Span(name=name, cat=cat, rank=r, tid=tid,
                                      args=args))

    def instant(self, name: str, cat: str = "misc", rank: Any = "inherit",
                tid: int = LANE_EXECUTE, **args) -> None:
        """A zero-duration event (autoscaler decision, evacuation, ...)."""
        if not self.enabled:
            return
        r = self.rank if rank == "inherit" else rank
        self._commit(Span(name=name, cat=cat, ts=time.time(), dur=0.0,
                          rank=r, tid=tid,
                          depth=getattr(self._tls, "depth", 0), args=args))

    def begin_window(self, name: str, cat: str = "comm", rank: Any = "inherit",
                     tid: int = LANE_COMM, **args) -> Optional[dict]:
        """Open an *async* window (exchange_start → wait spans that cannot
        be expressed as a ``with`` block).  Returns an opaque token to
        pass to ``end_window``; ``None`` when tracing is disabled."""
        if not self.enabled:
            return None
        r = self.rank if rank == "inherit" else rank
        return {
            "span": Span(name=name, cat=cat, ts=time.time(), rank=r, tid=tid,
                         depth=getattr(self._tls, "depth", 0), args=args),
            "t0": time.perf_counter(),
        }

    def end_window(self, token: Optional[dict], **extra_args) -> None:
        if token is None:
            return
        sp: Span = token["span"]
        sp.dur = time.perf_counter() - token["t0"]
        if extra_args:
            sp.args.update(extra_args)
        self._commit(sp)

    def _commit(self, span: Span) -> None:
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self.dropped += 1
            self._buf.append(span)

    # -- reading ---------------------------------------------------------
    def spans(self) -> list:
        """Snapshot of the ring buffer, oldest first."""
        with self._lock:
            return list(self._buf)

    def counters(self) -> dict:
        with self._lock:
            n = len(self._buf)
        return {
            "enabled": self.enabled,
            "spans": n,
            "dropped": self.dropped,
            "capacity": self.capacity,
            "rank": self.rank,
        }


# --------------------------------------------------------------------------
# Module-level singleton API (what the instrumented subsystems import)
# --------------------------------------------------------------------------

_TRACER = Tracer()


def tracer() -> Tracer:
    return _TRACER


def enable(capacity: Optional[int] = None) -> None:
    _TRACER.enable(capacity)


def disable() -> None:
    _TRACER.disable()


def enabled() -> bool:
    return _TRACER.enabled


def clear() -> None:
    _TRACER.clear()


def set_rank(rank: Optional[int]) -> None:
    _TRACER.set_rank(rank)


def spans() -> list:
    return _TRACER.spans()


def span(name: str, cat: str = "misc", rank: Any = "inherit",
         tid: int = LANE_EXECUTE, **args):
    if not _TRACER.enabled:  # fast path: no kwargs dict reaches the tracer
        return _NULL
    return _TRACER.span(name, cat=cat, rank=rank, tid=tid, **args)


def instant(name: str, cat: str = "misc", rank: Any = "inherit",
            tid: int = LANE_EXECUTE, **args) -> None:
    _TRACER.instant(name, cat=cat, rank=rank, tid=tid, **args)


def begin_window(name: str, cat: str = "comm", rank: Any = "inherit",
                 tid: int = LANE_COMM, **args) -> Optional[dict]:
    return _TRACER.begin_window(name, cat=cat, rank=rank, tid=tid, **args)


def end_window(token: Optional[dict], **extra_args) -> None:
    _TRACER.end_window(token, **extra_args)


def traced(name_or_fn: Any = None, cat: str = "func") -> Callable:
    """Decorator form: ``@traced`` or ``@traced("custom.name", cat=...)``.
    Adds one boolean check per call when tracing is disabled."""

    def deco(fn: Callable, _name: Optional[str] = None) -> Callable:
        label = _name or getattr(fn, "__qualname__", fn.__name__)

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not _TRACER.enabled:
                return fn(*a, **kw)
            with _TRACER.span(label, cat=cat):
                return fn(*a, **kw)

        return wrapper

    if callable(name_or_fn):  # bare @traced
        return deco(name_or_fn)
    return lambda fn: deco(fn, name_or_fn)
