"""``repro.obs`` — unified observability: span tracing, metrics
registry, and roofline-drift detection (DESIGN.md §12).

Quickstart::

    from repro import api, obs

    obs.enable()                       # or REPRO_TRACE=1 in the env
    step = api.compile(prog, api.Target(exchange_every=4))
    out = step.time_loop((u0,), 32)    # traced: one span per epoch,
                                       # exchange windows on the comm lane
    obs.write_chrome("trace.json")     # open in https://ui.perfetto.dev
    print(obs.drift_report(terms=step.cost()))   # model vs measured
    print(obs.snapshot())              # every subsystem's counters

Tracing is off by default and the disabled path costs one attribute
check per instrumented site — see ``repro.obs.trace``.  Summarize a
saved trace offline with ``python -m repro.obs trace.json``.
"""
from repro.obs.drift import DriftReport, drift_report
from repro.obs.export import (
    load_spans,
    merge_traces,
    to_chrome,
    write_chrome,
    write_jsonl,
    write_rank_traces,
)
from repro.obs.registry import NAMESPACES, snapshot
from repro.obs.trace import (
    LANE_COMM,
    LANE_EXECUTE,
    Span,
    Tracer,
    begin_window,
    clear,
    disable,
    enable,
    enabled,
    end_window,
    instant,
    set_rank,
    span,
    spans,
    traced,
    tracer,
)

__all__ = [
    "DriftReport",
    "drift_report",
    "load_spans",
    "merge_traces",
    "to_chrome",
    "write_chrome",
    "write_jsonl",
    "write_rank_traces",
    "NAMESPACES",
    "snapshot",
    "LANE_COMM",
    "LANE_EXECUTE",
    "Span",
    "Tracer",
    "begin_window",
    "clear",
    "disable",
    "enable",
    "enabled",
    "end_window",
    "instant",
    "set_rank",
    "span",
    "spans",
    "traced",
    "tracer",
]
