"""Roofline-drift detection: measured epochs vs the performance model.

The stack *models* throughput (``launch/roofline.RooflineTerms.step_time``)
and *restructures* IR for comm/compute overlap
(``core/passes/overlap.split_overlapped_applies``) — this module closes
the loop by comparing what the tracer measured against both:

* **step-time drift** — the median traced ``epoch`` span, divided by the
  epoch depth ``k``, against ``terms.step_time(k)``.  ``drift_ratio``
  above 1 means the machine is slower than the model (untracked
  overheads, interpreter dispatch, cache misses); persistent drift on
  one phase is the signal the model's constants need re-measuring
  (ROADMAP: measured ``t_latency`` per interconnect).
* **achieved overlap** — the fraction of exchange-window time
  (``cat="comm"`` spans, exchange_start→wait) covered by interior-apply
  spans (``name="apply:interior"``).  The overlap pass promises the
  interior compute hides the exchange; this measures whether it did.

``drift_report()`` reads the live tracer by default; pass
``spans=load_spans(path)`` to analyze a saved trace offline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


def _median(xs: Sequence[float]) -> float:
    xs = sorted(xs)
    n = len(xs)
    mid = n // 2
    return xs[mid] if n % 2 else 0.5 * (xs[mid - 1] + xs[mid])


def _covered(window, intervals) -> float:
    """Length of ``window`` covered by the union of ``intervals``."""
    lo, hi = window
    clipped = sorted(
        (max(lo, a), min(hi, b)) for a, b in intervals if b > lo and a < hi
    )
    total, cursor = 0.0, lo
    for a, b in clipped:
        a = max(a, cursor)
        if b > a:
            total += b - a
            cursor = b
    return total


@dataclasses.dataclass
class DriftReport:
    """Model-vs-measured summary of one traced run."""

    epochs: int                      # traced epoch spans found
    exchange_every: int              # epoch depth k the measurement ran at
    measured_step_s: Optional[float]   # median epoch wall time / k
    modeled_step_s: Optional[float]    # RooflineTerms.step_time(k)
    drift_ratio: Optional[float]       # measured / modeled (>1: slower)
    error_pct: Optional[float]         # |measured-modeled| / modeled * 100
    overlap_windows: int               # exchange windows considered
    achieved_overlap: Optional[float]  # covered fraction of exchange time
    per_phase_s: dict                  # span category -> total seconds

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        def fmt(v, unit=""):
            return "-" if v is None else f"{v:.3g}{unit}"

        rows = [
            ("epochs traced", str(self.epochs)),
            ("exchange_every", str(self.exchange_every)),
            ("measured step", fmt(self.measured_step_s, " s")),
            ("modeled step", fmt(self.modeled_step_s, " s")),
            ("drift ratio", fmt(self.drift_ratio, "x")),
            ("model error", fmt(self.error_pct, " %")),
            ("exchange windows", str(self.overlap_windows)),
            ("achieved overlap", fmt(
                None if self.achieved_overlap is None
                else self.achieved_overlap * 100, " %")),
        ]
        width = max(len(k) for k, _ in rows)
        lines = ["roofline drift", "-" * 14]
        lines += [f"{k:<{width}}  {v}" for k, v in rows]
        if self.per_phase_s:
            lines.append("per-phase totals:")
            for cat, sec in sorted(self.per_phase_s.items(),
                                   key=lambda kv: -kv[1]):
                lines.append(f"  {cat:<12} {sec * 1e3:10.3f} ms")
        return "\n".join(lines)


def drift_report(spans=None, terms=None,
                 exchange_every: Optional[int] = None) -> DriftReport:
    """Build a :class:`DriftReport` from traced spans.

    ``terms`` is a ``repro.launch.roofline.RooflineTerms`` (e.g. from
    ``CompiledStencil.cost()``); without it the report carries measured
    numbers only (``modeled_step_s``/``drift_ratio`` are ``None``).
    ``exchange_every`` defaults to the ``k`` tag on the epoch spans.
    """
    if spans is None:
        from repro.obs.trace import tracer

        spans = tracer().spans()
    spans = list(spans)

    epoch_spans = [s for s in spans if s.name == "epoch"]
    k = int(exchange_every or next(
        (int(s.args["k"]) for s in epoch_spans if "k" in s.args), 1
    ))
    measured = None
    if epoch_spans:
        measured = _median([s.dur for s in epoch_spans]) / max(1, k)

    modeled = drift = err = None
    if terms is not None:
        modeled = float(terms.step_time(k))
        if measured is not None and modeled > 0:
            drift = measured / modeled
            err = abs(measured - modeled) / modeled * 100.0

    comm = [s for s in spans if s.cat == "comm" and s.dur > 0]
    interior = [(s.ts, s.end) for s in spans if s.name == "apply:interior"]
    achieved = None
    if comm:
        total = sum(s.dur for s in comm)
        covered = sum(_covered((s.ts, s.end), interior) for s in comm)
        achieved = covered / total if total > 0 else None

    per_phase: dict = {}
    for s in spans:
        per_phase[s.cat] = per_phase.get(s.cat, 0.0) + s.dur

    return DriftReport(
        epochs=len(epoch_spans),
        exchange_every=k,
        measured_step_s=measured,
        modeled_step_s=modeled,
        drift_ratio=drift,
        error_pct=err,
        overlap_windows=len(comm),
        achieved_overlap=achieved,
        per_phase_s=per_phase,
    )
