"""Trace summarizer: ``python -m repro.obs <trace.json|trace.jsonl>``.

Prints the top spans by total time, per-phase (category) totals, and
the roofline-drift table the trace supports (measured-only offline —
pass the modeled step time with ``--modeled-step`` to get drift ratios
against a run's ``CompiledStencil.cost().step_time(k)``).

``python -m repro.obs --snapshot`` prints the live process's unified
counter registry instead (mostly useful under a REPL/driver that has
already exercised the stack).
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

from repro.obs.drift import drift_report
from repro.obs.export import load_spans
from repro.obs.registry import snapshot


def _table(title: str, rows: list, headers: list) -> str:
    widths = [
        max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
        for i, h in enumerate(headers)
    ]
    out = [title, "-" * len(title),
           "  ".join(str(h).ljust(w) for h, w in zip(headers, widths))]
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def summarize(path: str, top: int = 15,
              modeled_step: float = 0.0) -> str:
    spans = load_spans(path)
    if not spans:
        return f"{path}: no spans"
    lines = [f"{path}: {len(spans)} spans"]

    by_name: dict = defaultdict(lambda: [0, 0.0])
    by_cat: dict = defaultdict(float)
    for s in spans:
        row = by_name[s.name]
        row[0] += 1
        row[1] += s.dur
        by_cat[s.cat] += s.dur

    rows = sorted(by_name.items(), key=lambda kv: -kv[1][1])[:top]
    lines.append(_table(
        f"top spans (by total time, showing {len(rows)})",
        [(name, n, f"{tot * 1e3:.3f}", f"{tot / n * 1e3:.3f}")
         for name, (n, tot) in rows],
        ["span", "count", "total ms", "mean ms"],
    ))
    lines.append(_table(
        "per-phase totals",
        [(cat, f"{tot * 1e3:.3f}")
         for cat, tot in sorted(by_cat.items(), key=lambda kv: -kv[1])],
        ["phase", "total ms"],
    ))

    class _FixedTerms:  # offline stand-in for RooflineTerms
        def step_time(self, k):
            return modeled_step

    report = drift_report(
        spans, terms=_FixedTerms() if modeled_step > 0 else None
    )
    lines.append(str(report))
    return "\n\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", nargs="?", help="Chrome .json or .jsonl trace")
    ap.add_argument("--top", type=int, default=15,
                    help="how many span names to list (default 15)")
    ap.add_argument("--modeled-step", type=float, default=0.0,
                    help="modeled seconds/step for drift ratios")
    ap.add_argument("--snapshot", action="store_true",
                    help="print the live unified counter registry")
    args = ap.parse_args(argv)

    if args.snapshot:
        print(json.dumps(snapshot(), indent=1, default=str))
        return 0
    if not args.trace:
        ap.error("give a trace file or --snapshot")
    print(summarize(args.trace, top=args.top,
                    modeled_step=args.modeled_step))
    return 0


if __name__ == "__main__":
    sys.exit(main())
