"""Trace export: Chrome trace-event JSON (Perfetto) and structured JSONL.

Chrome format (the ``{"traceEvents": [...]}`` container):

* one **pid per rank** — Perfetto renders each rank as its own process
  track, named via ``process_name`` metadata events;
* two **tids (lanes) per rank** — lane 0 "execute" for synchronous
  spans, lane 1 "comm" for async exchange windows, so an exchange
  window and the interior apply it hides are both visible and their
  overlap can be read off the timeline;
* ``ph: "X"`` complete events with ``ts``/``dur`` in microseconds
  (wall-clock epoch — comparable across processes).

SPMD spans (``rank=None``: the interpreter traces one program for every
rank) are **replicated** onto each rank's track with ``args.spmd: true``
— honest, because every rank executes exactly that program.

``merge_traces`` stitches per-rank trace files (written by separate
processes, e.g. ``tests/dist_worker.py`` subprocess ranks or a future
MPI backend where each host traces locally) into one timeline: wall
clocks are shared, so events interleave without offset surgery.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Iterable, Optional, Sequence, Union

from repro.obs.trace import LANE_NAMES, Span, tracer


def _span_ranks(spans: Sequence[Span], default_ranks: Optional[int]) -> int:
    """How many rank tracks the trace spans: the largest explicit rank
    tag, or the largest ``ranks`` arg an SPMD span carries."""
    n = int(default_ranks or 1)
    for s in spans:
        if s.rank is not None:
            n = max(n, int(s.rank) + 1)
        else:
            n = max(n, int(s.args.get("ranks", 1)))
    return n


def _event(s: Span, pid: int, spmd: bool) -> dict:
    args = dict(s.args)
    if spmd:
        args["spmd"] = True
    return {
        "name": s.name,
        "cat": s.cat,
        "ph": "X",
        "ts": s.ts * 1e6,
        "dur": s.dur * 1e6,
        "pid": pid,
        "tid": s.tid,
        "args": args,
    }


def _metadata(pids: Iterable[int]) -> list:
    out = []
    for pid in sorted(set(pids)):
        out.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                    "args": {"name": f"rank {pid}"}})
        for tid, lane in LANE_NAMES.items():
            out.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "args": {"name": lane}})
    return out


def to_chrome(spans: Optional[Sequence[Span]] = None,
              ranks: Optional[int] = None) -> dict:
    """Spans (default: the live tracer's buffer) → Chrome trace dict."""
    spans = list(tracer().spans() if spans is None else spans)
    n = _span_ranks(spans, ranks)
    events = _metadata(range(n))
    for s in spans:
        if s.rank is not None:
            events.append(_event(s, int(s.rank), spmd=False))
        else:
            targets = range(int(s.args.get("ranks", n)))
            for r in targets:
                events.append(_event(s, r, spmd=n > 1))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome(path: str, spans: Optional[Sequence[Span]] = None,
                 ranks: Optional[int] = None) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(to_chrome(spans, ranks=ranks), f)
    return path


def write_jsonl(path: str, spans: Optional[Sequence[Span]] = None) -> str:
    """Structured export: one span dict per line (``ts``/``dur`` in
    seconds, ``rank`` possibly null) — the machine-readable sibling."""
    spans = list(tracer().spans() if spans is None else spans)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        for s in spans:
            f.write(json.dumps(s.as_dict()) + "\n")
    return path


def write_rank_traces(directory: str,
                      spans: Optional[Sequence[Span]] = None,
                      ranks: Optional[int] = None,
                      prefix: str = "trace_rank") -> list:
    """One Chrome trace file per rank track (``<prefix><r>.json``) — the
    per-process shape a multi-host run produces natively, reassembled by
    ``merge_traces``.  SPMD spans land in every rank's file."""
    spans = list(tracer().spans() if spans is None else spans)
    n = _span_ranks(spans, ranks)
    os.makedirs(directory, exist_ok=True)
    paths = []
    for r in range(n):
        mine = []
        for s in spans:
            if s.rank is None:
                if r < int(s.args.get("ranks", n)):
                    mine.append(_event(s, r, spmd=n > 1))
            elif int(s.rank) == r:
                mine.append(_event(s, r, spmd=False))
        path = os.path.join(directory, f"{prefix}{r}.json")
        with open(path, "w") as f:
            json.dump({"traceEvents": _metadata([r]) + mine,
                       "displayTimeUnit": "ms"}, f)
        paths.append(path)
    return paths


def merge_traces(source: Union[str, Sequence[str]],
                 out: Optional[str] = None) -> dict:
    """Merge per-rank Chrome trace files into one timeline.

    ``source`` is a directory (every ``*.json`` inside) or an explicit
    list of paths.  Ranks keep their pids; metadata events are deduped.
    Wall clocks are shared across local processes, so no time alignment
    is needed.  Writes the merged trace to ``out`` when given.
    """
    if isinstance(source, str):
        paths = sorted(glob.glob(os.path.join(source, "*.json")))
    else:
        paths = list(source)
    if not paths:
        raise ValueError(f"merge_traces: no trace files in {source!r}")
    events: list = []
    seen_meta = set()
    for path in paths:
        with open(path) as f:
            data = json.load(f)
        for ev in data.get("traceEvents", []):
            if ev.get("ph") == "M":
                key = (ev.get("name"), ev.get("pid"), ev.get("tid"))
                if key in seen_meta:
                    continue
                seen_meta.add(key)
            events.append(ev)
    merged = {"traceEvents": events, "displayTimeUnit": "ms"}
    if out is not None:
        os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
        with open(out, "w") as f:
            json.dump(merged, f)
    return merged


def load_spans(path: str) -> list:
    """Read spans back from a trace file (Chrome ``.json`` or ``.jsonl``)
    for offline analysis (``python -m repro.obs``, ``drift_report``)."""
    spans = []
    if path.endswith(".jsonl"):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    spans.append(Span.from_dict(json.loads(line)))
        return spans
    with open(path) as f:
        data = json.load(f)
    events = data.get("traceEvents", data) if isinstance(data, dict) else data
    for ev in events:
        if ev.get("ph") != "X":
            continue
        spans.append(Span(
            name=ev.get("name", "?"),
            cat=ev.get("cat", "misc"),
            ts=float(ev.get("ts", 0.0)) / 1e6,
            dur=float(ev.get("dur", 0.0)) / 1e6,
            rank=ev.get("pid"),
            tid=int(ev.get("tid", 0)),
            args=dict(ev.get("args") or {}),
        ))
    return spans
