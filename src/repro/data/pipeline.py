"""Token data pipeline: synthetic stream + memory-mapped file backend,
sharded per data-parallel rank, with background host prefetch.

Determinism: the synthetic stream is keyed by (seed, step), so restarts
resume bit-identically from the checkpointed step — a fault-tolerance
requirement, not a convenience.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    path: Optional[str] = None        # tokenized uint32 flat file (memmap)
    modality_tokens: int = 0
    modality_dim: int = 0
    modality_is_frames: bool = False  # audio: frames span the whole seq


class SyntheticTokens:
    """Deterministic synthetic batches keyed by step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        n_text = cfg.seq_len - (
            0 if cfg.modality_is_frames else cfg.modality_tokens
        )
        out = {
            "tokens": rng.integers(
                0, cfg.vocab_size, (cfg.global_batch, n_text), dtype=np.int32
            )
        }
        if cfg.modality_tokens or cfg.modality_is_frames:
            m = cfg.seq_len if cfg.modality_is_frames else cfg.modality_tokens
            out["modality"] = rng.standard_normal(
                (cfg.global_batch, m, cfg.modality_dim), dtype=np.float32
            )
        return out


class FileTokens:
    """Flat uint32 token file, read as non-overlapping windows."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        self.windows = len(self.data) // cfg.seq_len

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        idx = (
            np.arange(cfg.global_batch) + step * cfg.global_batch
        ) % self.windows
        toks = np.stack(
            [
                self.data[i * cfg.seq_len : (i + 1) * cfg.seq_len]
                for i in idx
            ]
        ).astype(np.int32)
        return {"tokens": np.minimum(toks, cfg.vocab_size - 1)}


class PrefetchLoader:
    """Background-thread prefetch of host batches (depth-bounded)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        while True:
            step, batch = self.q.get()
            yield step, batch

    def stop(self) -> None:
        self._stop.set()


def make_source(cfg: DataConfig):
    return FileTokens(cfg) if cfg.path else SyntheticTokens(cfg)
