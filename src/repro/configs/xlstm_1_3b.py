"""xlstm-1.3b [arXiv:2405.04517; unverified] — xLSTM[7:1]: 7 mLSTM blocks
per sLSTM block, d_ff = 0 (projections live inside the blocks)."""
from repro.configs.base import MLSTM, ModelConfig, SLSTM

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=512,
    block_pattern=(MLSTM, MLSTM, MLSTM, MLSTM, MLSTM, MLSTM, MLSTM, SLSTM),
)
