"""seamless-m4t-large-v2 [arXiv:2308.11596; hf] — encoder-decoder
multimodal backbone (speech encoder + text decoder), MHA (kv=16).

The audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings at d_model.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    block_pattern=(ATTN,),
    is_encoder_decoder=True,
    n_encoder_layers=24,
    modality="audio",
    modality_dim=1024,
)
