"""Unified architecture config covering the 10 assigned families.

A model is a repeating *supercell* of block kinds (``block_pattern``), so
heterogeneous stacks (jamba's 1 attention : 7 mamba, gemma2's
local/global alternation, xlstm's 7 mLSTM : 1 sLSTM) scan over stacked
per-slot parameters with one compiled supercell body.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax.numpy as jnp

# block kinds
ATTN = "attn"          # global attention
ATTN_LOCAL = "attn_local"  # sliding-window attention (stencil on sequence!)
MAMBA = "mamba"
MLSTM = "mlstm"
SLSTM = "slstm"


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    block_pattern: tuple = (ATTN,)   # repeating supercell of block kinds
    moe: Optional[MoEConfig] = None
    moe_every: int = 0               # every k-th layer is MoE (0 = never)
    # attention details
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    logit_softcap: float = 0.0       # gemma2: 30 (attn) handled separately
    attn_softcap: float = 0.0
    local_window: int = 0            # sliding window for ATTN_LOCAL blocks
    # encoder-decoder
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # ssm (mamba) details
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # frontend stubs
    modality: Optional[str] = None   # "audio" | "vision" | None
    num_modality_tokens: int = 0     # e.g. 256 vision patches
    modality_dim: int = 0            # raw frontend embedding dim
    # norms / misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def supercell(self) -> tuple:
        return self.block_pattern

    @property
    def n_supercells(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not a multiple of "
            f"supercell {len(self.block_pattern)}"
        )
        return self.n_layers // len(self.block_pattern)

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def layer_is_moe(self, i: int) -> bool:
        return self.moe is not None and self.moe_every > 0 and (
            i % self.moe_every == self.moe_every - 1
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_
        total = v * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind in (ATTN, ATTN_LOCAL):
                qkvo = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
                total += qkvo
            elif kind == MAMBA:
                di = self.ssm_expand * self.d_model
                total += 2 * d * di + di * self.ssm_conv_width
                total += di * self.ssm_state_dim * 2 + di  # dt/B/C projections (approx)
                total += di * d
            elif kind in (MLSTM, SLSTM):
                di = 2 * d if kind == MLSTM else d
                total += 4 * d * di + di * d
            if dff > 0:
                ffn = 3 * d * dff  # SwiGLU
                if self.layer_is_moe(i):
                    assert self.moe is not None
                    total += ffn * self.moe.num_experts + d * self.moe.num_experts
                else:
                    total += ffn
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k of experts)."""
        if self.moe is None or self.moe_every == 0:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        full = self.param_count()
        n_moe = sum(1 for i in range(self.n_layers) if self.layer_is_moe(i))
        inactive = n_moe * 3 * d * dff * (self.moe.num_experts - self.moe.top_k)
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    cell = len(cfg.block_pattern)
    small = dict(
        n_layers=cell if cfg.n_layers >= cell else cfg.n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        d_ff=128 if cfg.d_ff > 0 else 0,
        vocab_size=128,
        head_dim=16,
        ssm_state_dim=8,
        num_modality_tokens=4 if cfg.num_modality_tokens else 0,
        # audio frames enter the encoder at d_model; vision keeps a distinct
        # frontend width exercised through the projector
        modality_dim=(64 if cfg.modality_dim == cfg.d_model else 32)
        if cfg.modality_dim
        else 0,
        n_encoder_layers=2 if cfg.is_encoder_decoder else 0,
        local_window=8 if cfg.local_window else 0,
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(
            num_experts=4, top_k=min(cfg.moe.top_k, 2), capacity_factor=2.0
        )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
