"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from importlib import import_module

from repro.configs.base import ModelConfig

_MODULES = {
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "yi-9b": "repro.configs.yi_9b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
}

ARCHS = tuple(_MODULES)

# long_500k applicability (DESIGN.md §5): sub-quadratic (SSM/hybrid/local)
# archs run it; pure full-attention archs skip.
LONG_CONTEXT_ARCHS = ("jamba-v0.1-52b", "gemma2-27b", "xlstm-1.3b")


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch '{arch}'; have {list(_MODULES)}")
    return import_module(_MODULES[arch]).CONFIG


def shape_applicable(arch: str, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, (
            "pure full-attention arch: 500k context needs sub-quadratic "
            "attention (DESIGN.md §5)"
        )
    return True, ""
