"""olmoe-1b-7b [arXiv:2409.02060; hf] — MoE, 64 experts top-8 every layer."""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    block_pattern=(ATTN,),
    moe=MoEConfig(num_experts=64, top_k=8),
    moe_every=1,
)
