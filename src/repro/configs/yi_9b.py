"""yi-9b [arXiv:2403.04652; hf] — llama-arch dense GQA (kv=4)."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    block_pattern=(ATTN,),
    rope_theta=10000.0,
)
