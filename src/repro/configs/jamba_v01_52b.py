"""jamba-v0.1-52b [arXiv:2403.19887; hf] — hybrid Mamba+attention 1:7
interleave with MoE (16 experts, top-2) every other layer.

Supercell of 8: attention at slot 4 (mid-block, per the Jamba paper),
Mamba elsewhere; MoE on odd slots (moe_every=2).
"""
from repro.configs.base import ATTN, MAMBA, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    block_pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    moe=MoEConfig(num_experts=16, top_k=2),
    moe_every=2,
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
)
