"""gemma2-27b [arXiv:2408.00118; hf] — local/global alternating attention
with logit softcaps.

The local layers (sliding window 4096) are the paper-technique showcase:
a bounded stencil on the sequence axis → KV halo exchange under sequence
parallelism (DESIGN.md §4).
"""
from repro.configs.base import ATTN, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    block_pattern=(ATTN_LOCAL, ATTN),
    local_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
)
