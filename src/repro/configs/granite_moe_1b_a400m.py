"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base] — MoE,
32 experts top-8 every layer."""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    block_pattern=(ATTN,),
    moe=MoEConfig(num_experts=32, top_k=8),
    moe_every=1,
)
