"""internvl2-2b [arXiv:2404.16821; hf] — InternViT + InternLM2 backbone.

The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (256 tokens at InternViT width 1024), which
the MLP projector maps into the LM's embedding space.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    head_dim=128,
    block_pattern=(ATTN,),
    modality="vision",
    num_modality_tokens=256,
    modality_dim=1024,
)
