from repro.configs.base import (  # noqa: F401
    LM_SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    get_shape,
    reduced_config,
)
from repro.configs.registry import ARCHS, get_config  # noqa: F401
