"""starcoder2-7b [arXiv:2402.19173; hf] — dense GQA (kv=4), RoPE."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    block_pattern=(ATTN,),
    rope_theta=1000000.0,
)
