"""Finite-difference coefficient tables (central differences on uniform
grids) — shared by the Devito-like frontend and the kernel library.

``second_derivative(order)`` returns ``(offsets, coeffs)`` for d²/dx² with
the given *space discretization order* (SDO ∈ {2, 4, 8} in the paper's
evaluation, radius = order/2), normalized to unit grid spacing.
"""
from __future__ import annotations

from fractions import Fraction


_D2_COEFFS = {
    2: [1, -2, 1],
    4: [Fraction(-1, 12), Fraction(4, 3), Fraction(-5, 2), Fraction(4, 3), Fraction(-1, 12)],
    6: [
        Fraction(1, 90), Fraction(-3, 20), Fraction(3, 2), Fraction(-49, 18),
        Fraction(3, 2), Fraction(-3, 20), Fraction(1, 90),
    ],
    8: [
        Fraction(-1, 560), Fraction(8, 315), Fraction(-1, 5), Fraction(8, 5),
        Fraction(-205, 72), Fraction(8, 5), Fraction(-1, 5), Fraction(8, 315),
        Fraction(-1, 560),
    ],
}

_D1_COEFFS = {
    2: [Fraction(-1, 2), 0, Fraction(1, 2)],
    4: [Fraction(1, 12), Fraction(-2, 3), 0, Fraction(2, 3), Fraction(-1, 12)],
}


def second_derivative(order: int, spacing: float = 1.0):
    """(offsets, coeffs) for d²/dx², offsets in [-order/2, order/2]."""
    if order not in _D2_COEFFS:
        raise ValueError(f"unsupported space order {order} (have {sorted(_D2_COEFFS)})")
    c = _D2_COEFFS[order]
    r = order // 2
    offsets = list(range(-r, r + 1))
    coeffs = [float(x) / spacing**2 for x in c]
    return offsets, coeffs


def first_derivative(order: int, spacing: float = 1.0):
    if order not in _D1_COEFFS:
        raise ValueError(f"unsupported space order {order} (have {sorted(_D1_COEFFS)})")
    c = _D1_COEFFS[order]
    r = order // 2
    offsets = list(range(-r, r + 1))
    coeffs = [float(x) / spacing for x in c]
    return offsets, coeffs


def laplacian_star(ndim: int, order: int, spacing: float = 1.0) -> dict:
    """Star-stencil {offset_tuple: coeff} for the n-D Laplacian."""
    offsets, coeffs = second_derivative(order, spacing)
    star: dict[tuple, float] = {}
    for d in range(ndim):
        for o, c in zip(offsets, coeffs):
            key = tuple(o if k == d else 0 for k in range(ndim))
            star[key] = star.get(key, 0.0) + c
    return star


def radius(order: int) -> int:
    return order // 2
