"""Redundant-exchange elimination (paper sec. 4.2).

"While this may generate redundant data exchanges, a subsequent pass
eliminates them via a further pass analyzing the SSA data flow."

Because our IR is pure SSA (temps are immutable values), redundancy shows
up as *structurally identical* swaps of the same value, loads of the same
field with no intervening store, and identity swaps (no exchanges, no halo
growth).  All three fall to simple dataflow analysis over the single block.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.dialects import dmp, stencil


def eliminate_redundant_swaps(func: ir.FuncOp) -> None:
    block = func.body

    # 1. CSE loads: same field, no store to that field in between.
    current_load: dict[ir.SSAValue, ir.SSAValue] = {}
    for op in list(block.ops):
        if isinstance(op, stencil.StoreOp):
            current_load.pop(op.field, None)
        elif isinstance(op, stencil.LoadOp):
            prev = current_load.get(op.field)
            if prev is not None and prev.type == op.results[0].type:
                op.results[0].replace_all_uses_with(prev)
            else:
                current_load[op.field] = op.results[0]

    # 2. Dedupe structurally identical swaps of the same value.
    seen: dict[tuple, ir.SSAValue] = {}
    for op in list(block.ops):
        if isinstance(op, dmp.SwapOp):
            key = (
                id(op.temp),
                op.grid,
                op.exchanges,
                op.boundary,
                op.schedule,
                op.result_bounds,
            )
            prev = seen.get(key)
            if prev is not None:
                op.results[0].replace_all_uses_with(prev)
            else:
                seen[key] = op.results[0]

    # 3. Identity swaps: no exchanges and no halo growth.
    for op in list(block.ops):
        if isinstance(op, dmp.SwapOp):
            lo, hi = op.halo_widths()
            if not op.exchanges and all(w == 0 for w in lo + hi):
                op.results[0].replace_all_uses_with(op.temp)

    # 4. DCE of dead loads/swaps (and anything else without effects).
    _dce_block(block)


def _has_side_effects(op: ir.Operation) -> bool:
    return isinstance(op, (stencil.StoreOp, ir.ReturnOp, ir.FuncOp))


def _dce_block(block: ir.Block) -> None:
    changed = True
    while changed:
        changed = False
        for op in list(reversed(block.ops)):
            if _has_side_effects(op):
                continue
            if all(not r.uses for r in op.results):
                op.erase()
                changed = True


def shrink_swaps_to_consumers(func: ir.FuncOp) -> None:
    """Trim each swap's halo to what its consumers actually access.

    Decomposition sizes halos from the *pre-fusion* union of consumer
    extents; after fusion or DCE some consumers disappear, leaving swaps
    wider than needed.  Rebuilding the swap (and its consumer applies,
    whose region argument types embed the operand bounds) recovers the
    minimal exchange volume.
    """
    block = func.body
    for op in list(block.ops):
        if not isinstance(op, dmp.SwapOp):
            continue
        res = op.results[0]
        rank = res.type.bounds.rank
        lo = [0] * rank
        hi = [0] * rank
        shrinkable = True
        for use in res.uses:
            user = use.operation
            if isinstance(user, stencil.ApplyOp):
                ext = user.access_extents().get(use.index)
                if ext is None:
                    continue
                lo = [min(l, e) for l, e in zip(lo, ext[0])]
                hi = [max(h, e) for h, e in zip(hi, ext[1])]
            else:
                shrinkable = False  # stores/returns want the value as-is
                break
        if not shrinkable:
            continue
        cur_lo, cur_hi = op.halo_widths()
        want_lo = tuple(-l for l in lo)
        want_hi = tuple(hi)
        if want_lo == cur_lo and want_hi == cur_hi:
            continue
        core: stencil.Bounds = op.temp.type.bounds
        corners = op.schedule == "sequential"  # preserve the corner regime
        # re-derive exchanges with the shrunk widths via the same strategy math
        from repro.core.passes.decompose import SlicingStrategy

        strat = SlicingStrategy(op.grid.shape, op.grid.axis_names, op.grid.dims)
        decls, schedule = strat.exchanges(core, want_lo, want_hi, corners)
        new_swap = dmp.SwapOp(
            op.temp,
            op.grid,
            decls,
            result_bounds=core.grow(want_lo, want_hi),
            boundary=op.boundary,
            schedule=schedule,
        )
        block.insert_op_after(new_swap, op)
        _rebuild_consumers_with(res, new_swap.results[0], block)
        if not res.uses:
            op.erase()


def _rebuild_consumers_with(
    old: ir.SSAValue, new: ir.SSAValue, block: ir.Block
) -> None:
    """Replace ``old`` with ``new`` in consumer applies, rebuilding their
    region argument types (which embed operand bounds)."""
    for use in list(old.uses):
        user = use.operation
        assert isinstance(user, stencil.ApplyOp)
        new_operands = [new if o is old else o for o in user.operands]
        rebuilt = stencil.ApplyOp(
            new_operands,
            user.result_bounds,
            n_results=len(user.results),
            element_type=user.results[0].type.element_type,
        )
        vmap: dict[ir.SSAValue, ir.SSAValue] = {}
        for ob, nb in zip(user.body.args, rebuilt.body.args):
            vmap[ob] = nb
        for body_op in user.body.ops:
            rebuilt.body.add_op(body_op.clone_into(vmap))
        block.insert_op_after(rebuilt, user)
        for old_res, new_res in zip(user.results, rebuilt.results):
            old_res.replace_all_uses_with(new_res)
        user.erase()
