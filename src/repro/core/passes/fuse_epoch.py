"""Fuse a deep-halo epoch's apply chain into one kernel op.

``temporal-tile{k}`` unrolls an epoch into k grown ``stencil.apply``
clones (interleaved with ``comm.boundary_mask`` re-zeroing for the zero
boundary condition) — but each apply still lowers to its own kernel
dispatch.  This pass packages every **maximal contiguous run** of
apply/boundary-mask ops into a single :class:`stencil.FusedEpochOp`:

    loads … exchange … [apply, mask, apply, mask, …]  store …
                        └───── one fused_epoch ─────┘

The region holds clones of the run's ops in program order; values the
run reads from outside become block arguments, values read after the
run become results (carried through a ``stencil.fused_yield``).  The
kernel backend (``kernels/epoch_kernel.py``) then code-generates ONE
``pl.pallas_call`` for the whole region, carrying the k sub-steps'
intermediates in fast memory; interpreter backends evaluate the region
inline.

The pass is k-agnostic: it reads the ``epoch_step`` tags temporal-tile
leaves on its clones only to record the epoch depth ``k`` on the fused
op, and fusing an untiled (k=1) apply chain is legal and still collapses
n applies into one dispatch.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.dialects import comm, stencil

_FUSABLE = (stencil.ApplyOp, comm.BoundaryMaskOp)


def _epoch_depth(run: list) -> int:
    """Epoch depth of a run: the max ``epoch_step`` tag (temporal-tile
    numbers its clones 1..k), or 1 for an untagged (untiled) chain."""
    steps = [
        op.attributes["epoch_step"].value
        for op in run
        if "epoch_step" in op.attributes
    ]
    return max(steps) if steps else 1


def fuse_epoch_kernels(func: ir.FuncOp) -> ir.FuncOp:
    """Rewrite every maximal contiguous apply/boundary-mask run into one
    :class:`stencil.FusedEpochOp`.  Pure: returns a new FuncOp."""
    ops = list(func.body.ops)

    runs: list[list] = []
    current: list = []
    for op in ops:
        if isinstance(op, _FUSABLE):
            current.append(op)
        elif current:
            runs.append(current)
            current = []
    if current:
        runs.append(current)
    if not runs:
        return func

    run_start = {id(r[0]): r for r in runs}
    in_run = {id(op) for r in runs for op in r}

    new_func = ir.FuncOp(func.sym_name, [a.type for a in func.body.args])
    value_map: dict = {
        old: new for old, new in zip(func.body.args, new_func.body.args)
    }
    for op in ops:
        run = run_start.get(id(op))
        if run is not None:
            _emit_fused(new_func.body, run, value_map)
        elif id(op) in in_run:
            continue  # non-leading member of an already-emitted run
        else:
            new_func.body.add_op(op.clone_into(value_map))
    return new_func


def _emit_fused(block: ir.Block, run: list, value_map: dict) -> None:
    member_results = {id(r) for op in run for r in op.results}
    run_ids = {id(op) for op in run}

    # Externals: values the run reads that are defined outside it
    # (loaded/exchanged temps, fields).  Order = first-read order.
    externals: list = []
    seen = set()
    for op in run:
        for operand in op.operands:
            if id(operand) in member_results or id(operand) in seen:
                continue
            seen.add(id(operand))
            externals.append(operand)

    # Escapes: run-produced values still read after the run ends.
    escapes: list = []
    for op in run:
        for res in op.results:
            if any(id(u.operation) not in run_ids for u in res.uses):
                escapes.append(res)

    fused = stencil.FusedEpochOp(
        [value_map.get(e, e) for e in externals],
        [e.type for e in escapes],
        k=_epoch_depth(run),
    )
    inner: dict = dict(zip(externals, fused.body.args))
    for op in run:
        fused.body.add_op(op.clone_into(inner))
    fused.body.add_op(stencil.FusedYieldOp([inner[e] for e in escapes]))
    block.add_op(fused)
    for old, new in zip(escapes, fused.results):
        value_map[old] = new
