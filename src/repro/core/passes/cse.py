"""Common-subexpression elimination and dead-code elimination for apply
bodies — the paper reuses MLIR's ``cse`` out of the box; this is the same
value-numbering scheme restricted to the pure ops stencil bodies contain."""
from __future__ import annotations

from repro.core import ir
from repro.core.dialects import stencil


_PURE = (
    ir.ConstantOp,
    ir.AddOp,
    ir.SubOp,
    ir.MulOp,
    ir.DivOp,
    ir.NegOp,
    ir.AbsOp,
    ir.SqrtOp,
    ir.ExpOp,
    stencil.AccessOp,
    stencil.IndexOp,
)

_COMMUTATIVE = (ir.AddOp, ir.MulOp)


def _key(op: ir.Operation) -> tuple:
    operand_ids = tuple(id(o) for o in op.operands)
    if isinstance(op, _COMMUTATIVE):
        operand_ids = tuple(sorted(operand_ids))
    attrs = tuple(sorted(op.attributes.items(), key=lambda kv: kv[0]))
    return (op.name, operand_ids, attrs)


def cse_apply_bodies(func: ir.FuncOp) -> None:
    for op in func.walk():
        if isinstance(op, stencil.ApplyOp):
            _cse_block(op.body)


def _cse_block(block: ir.Block) -> None:
    seen: dict[tuple, ir.Operation] = {}
    for op in list(block.ops):
        if not isinstance(op, _PURE):
            continue
        k = _key(op)
        prev = seen.get(k)
        if prev is not None:
            for old_r, new_r in zip(op.results, prev.results):
                old_r.replace_all_uses_with(new_r)
            op.erase()
        else:
            seen[k] = op


def dce(func: ir.FuncOp) -> None:
    from repro.core.passes.swap_elim import _dce_block

    for op in func.walk():
        if isinstance(op, stencil.ApplyOp):
            _dce_pure_block(op.body)
    _dce_block(func.body)


def _dce_pure_block(block: ir.Block) -> None:
    changed = True
    while changed:
        changed = False
        for op in list(reversed(block.ops)):
            if isinstance(op, stencil.StencilReturnOp):
                continue
            if all(not r.uses for r in op.results):
                op.erase()
                changed = True
