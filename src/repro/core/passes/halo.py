"""Halo inference (paper sec. 4.1/4.2).

"It is possible for subsequent transforms to determine the minimal halo
shape and size that is required for distributed memory by scanning the
stencil.access offsets which are used on inputs of a stencil.apply."

``infer_apply_halo`` gives per-operand (lo, hi) extents of one apply;
``infer_field_halos`` propagates those requirements backwards through the
dataflow of a whole function, so chained applies (e.g. tracer advection's
24 dependent stencils) accumulate the halo each *value* must provide.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.dialects import stencil


def infer_apply_halo(apply_op: stencil.ApplyOp) -> dict[int, tuple]:
    """Per-operand-index minimal halo: ``{idx: (lo, hi)}`` with lo <= 0 <= hi."""
    return apply_op.access_extents()


def _max_extent(a: tuple, b: tuple) -> tuple:
    lo = tuple(min(x, y) for x, y in zip(a[0], b[0]))
    hi = tuple(max(x, y) for x, y in zip(a[1], b[1]))
    return (lo, hi)


def infer_value_halos(func: ir.FuncOp) -> dict[ir.SSAValue, tuple]:
    """For every stencil temp/field *value* in ``func``, the halo (lo, hi)
    that its consumers read beyond the point they compute.

    This is a backward dataflow over the SSA graph: an apply that reads
    operand k with extent (lo, hi) imposes that halo on the operand value;
    a value consumed by several applies gets the union.  Store/loads
    propagate between temps and fields.
    """
    halos: dict[ir.SSAValue, tuple] = {}

    def rank_of(v: ir.SSAValue) -> int:
        return v.type.bounds.rank  # type: ignore[attr-defined]

    def zero(v: ir.SSAValue) -> tuple:
        r = rank_of(v)
        return (tuple([0] * r), tuple([0] * r))

    ops = list(func.body.ops)
    # reverse pass: consumers before producers
    for op in reversed(ops):
        if isinstance(op, stencil.ApplyOp):
            extents = infer_apply_halo(op)
            for idx, operand in enumerate(op.operands):
                ext = extents.get(idx, zero(operand))
                cur = halos.get(operand, zero(operand))
                halos[operand] = _max_extent(cur, ext)
        elif isinstance(op, stencil.LoadOp):
            # what the load's temp needs, its field must hold
            need = halos.get(op.results[0])
            if need is not None:
                cur = halos.get(op.field, zero(op.field))
                halos[op.field] = _max_extent(cur, need)
    return halos


def infer_field_halos(func: ir.FuncOp) -> dict[ir.SSAValue, tuple]:
    """Halo required per *field argument* of ``func`` (function inputs)."""
    value_halos = infer_value_halos(func)
    out: dict[ir.SSAValue, tuple] = {}
    for arg in func.body.args:
        if isinstance(arg.type, stencil.FieldType):
            r = arg.type.bounds.rank
            out[arg] = value_halos.get(arg, (tuple([0] * r), tuple([0] * r)))
    return out


def halo_widths(extent: tuple) -> tuple:
    """(lo, hi) signed extents -> (lo_width, hi_width) nonnegative widths."""
    lo, hi = extent
    return tuple(-l for l in lo), tuple(h for h in hi)


def needs_corners(func: ir.FuncOp, decomposed_dims: tuple) -> bool:
    """True when any access has nonzero offsets in 2+ decomposed dims
    (a *box* stencil) — then corner halo regions are read and the exchange
    schedule must fill them (sequential axis sweeps or diagonal sends)."""
    for op in func.walk():
        if isinstance(op, stencil.AccessOp):
            nz = sum(1 for d in decomposed_dims if d < len(op.offset) and op.offset[d] != 0)
            if nz >= 2:
                return True
    return False
