"""Diagonal (corner) exchanges — **beyond-paper**.

The paper's standard strategy fills corner halos by *sequential* axis
sweeps (later axes forward earlier axes' halos), which serializes the
exchange rounds; it notes Devito's "3D diagonal exchanges leading to more
robust and efficient scaling" as the technique its dmp dialect cannot yet
express (sec. 6.1 / sec. 8 future work).

This pass rewrites a sequential box-stencil swap into a *concurrent* one:
face exchanges are trimmed to core width, and explicit edge/corner
exchanges are added for every combination of decomposed-dim directions.
All messages are then independent (one ppermute round), removing the
round-to-round latency chain at the cost of (tiny) extra messages.
"""
from __future__ import annotations

from itertools import product

from repro.core import ir
from repro.core.dialects import dmp, stencil


def use_diagonal_exchanges(func: ir.FuncOp) -> int:
    """Rewrite sequential swaps to concurrent face+corner swaps.

    Returns the number of swaps rewritten.
    """
    n = 0
    for op in list(func.body.ops):
        if not isinstance(op, dmp.SwapOp):
            continue
        if op.schedule != "sequential" or not op.exchanges:
            continue
        lo, hi = op.halo_widths()
        core: stencil.Bounds = op.temp.type.bounds
        decls = _all_direction_exchanges(op.grid, core, lo, hi)
        new_swap = dmp.SwapOp(
            op.temp,
            op.grid,
            decls,
            result_bounds=op.result_bounds,
            boundary=op.boundary,
            schedule="concurrent",
        )
        if "overlap" in op.attributes:
            new_swap.attributes["overlap"] = op.attributes["overlap"]
        func.body.insert_op_after(new_swap, op)
        op.results[0].replace_all_uses_with(new_swap.results[0])
        op.erase()
        n += 1
    return n


def _all_direction_exchanges(
    grid: dmp.GridAttr, core: stencil.Bounds, lo: tuple, hi: tuple
) -> tuple:
    """One exchange per nonzero direction vector over the decomposed dims
    (3^k - 1 directions for k decomposed dims with nonzero halos)."""
    rank = core.rank
    n = core.shape
    active_axes = [
        g
        for g, d in enumerate(grid.dims)
        if d < rank and (lo[d] > 0 or hi[d] > 0)
    ]
    decls = []
    for direction in product((-1, 0, 1), repeat=len(active_axes)):
        if all(s == 0 for s in direction):
            continue
        nbr = [0] * grid.rank
        recv_off, size, send_off = [0] * rank, [0] * rank, [0] * rank
        # non-decomposed dims and inactive dims: span core + local halo
        for k in range(rank):
            gax = grid.axis_of_dim(k)
            if gax is None or gax not in active_axes:
                recv_off[k] = core.lb[k] - lo[k]
                send_off[k] = core.lb[k] - lo[k]
                size[k] = n[k] + lo[k] + hi[k]
        ok = True
        for step, gax in zip(direction, active_axes):
            d = grid.dims[gax]
            nbr[gax] = step
            if step == -1:
                if lo[d] == 0:
                    ok = False
                    break
                recv_off[d] = core.lb[d] - lo[d]
                send_off[d] = core.lb[d]
                size[d] = lo[d]
            elif step == +1:
                if hi[d] == 0:
                    ok = False
                    break
                recv_off[d] = core.ub[d]
                send_off[d] = core.ub[d] - hi[d]
                size[d] = hi[d]
            else:
                recv_off[d] = core.lb[d]
                send_off[d] = core.lb[d]
                size[d] = n[d]
        if not ok:
            continue
        decls.append(
            dmp.ExchangeDecl(
                tuple(nbr),
                tuple(recv_off),
                tuple(size),
                tuple(send_off),
                tuple(size),
            )
        )
    return tuple(decls)
