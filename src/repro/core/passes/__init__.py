"""Pass infrastructure: a pass is a callable ``FuncOp -> FuncOp`` (pure) or
``FuncOp -> None`` (in-place).  ``PassManager`` chains them with verification
between stages, mirroring mlir-opt pipelines."""
from __future__ import annotations

import time
from typing import Callable, Sequence

from repro.core import ir


class PassManager:
    def __init__(self, passes: Sequence[Callable], verify: bool = True) -> None:
        self.passes = list(passes)
        self.verify = verify
        self.timings: list[tuple[str, float]] = []

    def run(self, func: ir.FuncOp) -> ir.FuncOp:
        for p in self.passes:
            t0 = time.perf_counter()
            out = p(func)
            if out is not None:
                func = out
            self.timings.append((getattr(p, "__name__", repr(p)), time.perf_counter() - t0))
            if self.verify:
                ir.verify_module(func)
        return func


from repro.core.passes.halo import infer_apply_halo, infer_field_halos  # noqa: E402,F401
from repro.core.passes.decompose import (  # noqa: E402,F401
    SlicingStrategy,
    decompose_stencil,
)
from repro.core.passes.swap_elim import eliminate_redundant_swaps  # noqa: E402,F401
from repro.core.passes.fusion import fuse_applies  # noqa: E402,F401
from repro.core.passes.cse import cse_apply_bodies, dce  # noqa: E402,F401
from repro.core.passes.overlap import enable_comm_compute_overlap  # noqa: E402,F401
from repro.core.passes.diagonal import use_diagonal_exchanges  # noqa: E402,F401
