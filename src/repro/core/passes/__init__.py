"""Pass infrastructure: declarative, mlir-opt-style pipelines.

A pass is a callable ``FuncOp -> FuncOp`` (pure) or ``FuncOp -> None``
(in-place); ``PassManager`` chains them with verification and timing
between stages.  On top of that sits a **pass registry** and a parseable
**pipeline spec** (DESIGN.md §2), so the compilation pipeline is data,
not hardcoded control flow:

    "fuse,cse,dce,decompose{grid=4x2},swap-elim,overlap,lower-comm"

Grammar (mlir-opt's textual pipeline, single-level):

    spec   := pass ("," pass)*
    pass   := name ("{" opt ("," opt)* "}")?
    opt    := key "=" value

``decompose`` accepts ``grid=4x2`` (rank-grid shape, optionally suffixed
with axis names: ``grid=2x2xy``), ``dims=0x1`` and ``boundary=zero|
periodic``; ``temporal-tile`` accepts ``k=4`` (epoch depth — exchange a
depth-k halo once, step k times); omitted options fall back to the
``PipelineContext`` the driver supplies.  Dump the IR after every stage
with

    python -m repro.core.passes "<spec>" [--program jacobi|box|chain]
"""
from __future__ import annotations

import dataclasses
import re
import threading
import time
from typing import Callable, Optional, Sequence

from repro.core import ir
from repro.obs import trace as _obs

# Process-wide pipeline tally, read through the ``PassManager.<attr>``
# class shim below.  ``repro.api``'s compile cache is judged against
# ``runs_completed`` (a cache hit must not bump it), and the
# ``python -m repro.core.passes`` dump surfaces ``last_timings``.
_RUNS_LOCK = threading.Lock()
_RUNS_COMPLETED = 0
_LAST_TIMINGS: list = []


class _PassManagerMeta(type):
    """Class-attribute shim: ``PassManager.runs_completed`` /
    ``.last_timings`` used to be class-level *mutable* state, which
    misattributed timings when compiles interleave (the serve engine
    compiles pooled siblings mid-step from worker threads).  The real
    counters are now per-instance; these properties keep the class-level
    reads (scripts/check.sh, ``python -m repro.core.passes``) meaning
    "process-wide totals"."""

    @property
    def runs_completed(cls) -> int:
        return _RUNS_COMPLETED

    @runs_completed.setter
    def runs_completed(cls, value: int) -> None:
        global _RUNS_COMPLETED
        with _RUNS_LOCK:
            _RUNS_COMPLETED = int(value)

    @property
    def last_timings(cls) -> list:
        return list(_LAST_TIMINGS)

    @last_timings.setter
    def last_timings(cls, value: list) -> None:
        global _LAST_TIMINGS
        with _RUNS_LOCK:
            _LAST_TIMINGS = list(value)


class PassManager(metaclass=_PassManagerMeta):
    def __init__(self, passes: Sequence[Callable], verify: bool = True) -> None:
        self.passes = list(passes)
        self.verify = verify
        self.timings: list[tuple[str, float]] = []
        # instance-level mirrors of the process-wide tally: how many times
        # THIS manager ran, and its most recent run's timings
        self.runs_completed = 0
        self.last_timings: list = []

    def run(
        self,
        func: ir.FuncOp,
        after_each: Optional[Callable[[str, ir.FuncOp], None]] = None,
    ) -> ir.FuncOp:
        global _RUNS_COMPLETED, _LAST_TIMINGS
        traced = _obs.enabled()
        for p in self.passes:
            name = getattr(p, "__name__", repr(p))
            t0 = time.perf_counter()
            if traced:
                with _obs.span(f"pass:{name}", cat="compile"):
                    out = p(func)
            else:
                out = p(func)
            if isinstance(out, ir.FuncOp):
                func = out
            self.timings.append((name, time.perf_counter() - t0))
            if self.verify:
                ir.verify_module(func)
            if after_each is not None:
                after_each(name, func)
        self.runs_completed += 1
        self.last_timings = list(self.timings)
        with _RUNS_LOCK:
            _RUNS_COMPLETED += 1
            _LAST_TIMINGS = list(self.timings)
        return func


from repro.core.passes.halo import infer_apply_halo, infer_field_halos  # noqa: E402,F401
from repro.core.passes.decompose import (  # noqa: E402,F401
    SlicingStrategy,
    decompose_stencil,
)
from repro.core.passes.swap_elim import (  # noqa: E402,F401
    eliminate_redundant_swaps,
    shrink_swaps_to_consumers,
)
from repro.core.passes.fusion import fuse_applies  # noqa: E402,F401
from repro.core.passes.cse import cse_apply_bodies, dce  # noqa: E402,F401
from repro.core.passes.overlap import (  # noqa: E402,F401
    enable_comm_compute_overlap,
    split_overlapped_applies,
)
from repro.core.passes.diagonal import use_diagonal_exchanges  # noqa: E402,F401
from repro.core.passes.lower_comm import lower_dmp_to_comm  # noqa: E402,F401
from repro.core.passes.temporal import (  # noqa: E402,F401
    TemporalTilingError,
    epoch_halo,
    temporal_tile,
)
from repro.core.passes.fuse_epoch import fuse_epoch_kernels  # noqa: E402,F401


# --------------------------------------------------------------------------
# Pipeline specs: parse + build against a registry
# --------------------------------------------------------------------------


@dataclasses.dataclass
class PipelineContext:
    """Driver-supplied defaults for passes whose options are objects the
    textual spec cannot carry (the decomposition strategy, boundary), plus
    the epoch depth ``temporal-tile`` falls back to when the spec omits
    ``k=`` (``repro.api.compile`` passes ``Target.exchange_every``)."""

    strategy: Optional[SlicingStrategy] = None
    boundary: str = "zero"
    exchange_every: int = 1


class PipelineError(ValueError):
    pass


_PASS_RE = re.compile(r"^([\w-]+)(?:\{(.*)\})?$")
_GRID_RE = re.compile(r"^(\d+(?:x\d+)*)([a-zA-Z]*)$")


def parse_pipeline(spec: str) -> list:
    """``"a,b{k=v,k2=v2},c"`` → ``[("a", {}), ("b", {...}), ("c", {})]``."""
    out: list[tuple[str, dict]] = []
    depth, token, tokens = 0, "", []
    for ch in spec:
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth < 0:
                raise PipelineError(f"unbalanced '}}' in pipeline spec: {spec!r}")
        if ch == "," and depth == 0:
            tokens.append(token)
            token = ""
        else:
            token += ch
    if depth != 0:
        raise PipelineError(f"unbalanced '{{' in pipeline spec: {spec!r}")
    tokens.append(token)
    for tok in tokens:
        tok = tok.strip()
        if not tok:
            continue
        m = _PASS_RE.match(tok)
        if m is None:
            raise PipelineError(f"cannot parse pipeline stage {tok!r}")
        name, raw_opts = m.group(1), m.group(2)
        opts: dict[str, str] = {}
        if raw_opts:
            for item in raw_opts.split(","):
                if "=" not in item:
                    raise PipelineError(
                        f"stage {name!r}: option {item!r} is not key=value"
                    )
                k, v = item.split("=", 1)
                opts[k.strip()] = v.strip()
        out.append((name, opts))
    return out


def _parse_grid(value: str) -> tuple:
    """``"4x2"`` → shape (4,2); ``"2x2xy"`` → shape (2,2), axes ("x","y")."""
    m = _GRID_RE.match(value)
    if m is None:
        raise PipelineError(f"cannot parse grid spec {value!r}")
    shape = tuple(int(s) for s in m.group(1).split("x"))
    axes = tuple(m.group(2)) if m.group(2) else None
    if axes is not None and len(axes) != len(shape):
        raise PipelineError(
            f"grid spec {value!r}: {len(axes)} axis names for "
            f"{len(shape)} grid dims"
        )
    return shape, axes


def _check_opts(name: str, opts: dict, allowed: tuple = ()) -> None:
    unknown = sorted(set(opts) - set(allowed))
    if unknown:
        raise PipelineError(
            f"stage {name!r}: unknown option(s) {', '.join(unknown)}; "
            f"allowed: {', '.join(allowed) if allowed else '(none)'}"
        )


def _strategy_from_opts(opts: dict, ctx: PipelineContext) -> SlicingStrategy:
    if "grid" not in opts:
        if ctx.strategy is None:
            raise PipelineError(
                "decompose: no grid= option and no strategy in context"
            )
        return ctx.strategy
    shape, axes = _parse_grid(opts["grid"])
    axes = axes or ("x", "y", "z", "w")[: len(shape)]
    dims = (
        tuple(int(d) for d in opts["dims"].split("x"))
        if "dims" in opts
        else None
    )
    return SlicingStrategy(shape, axes, dims)


def _named(name: str, fn: Callable) -> Callable:
    def run(func: ir.FuncOp):
        out = fn(func)
        return out if isinstance(out, ir.FuncOp) else None

    run.__name__ = name
    return run


def _tag_and_split(func: ir.FuncOp):
    enable_comm_compute_overlap(func)
    return split_overlapped_applies(func)


def _make_decompose(opts: dict, ctx: PipelineContext) -> Callable:
    _check_opts("decompose", opts, ("grid", "dims", "boundary"))
    if "dims" in opts and "grid" not in opts:
        raise PipelineError("decompose: dims= requires grid=")
    strategy = _strategy_from_opts(opts, ctx)
    boundary = opts.get("boundary", ctx.boundary)
    if boundary not in ("zero", "periodic"):
        raise PipelineError(f"decompose: bad boundary {boundary!r}")
    return _named(
        "decompose",
        lambda f: decompose_stencil(f, strategy, boundary=boundary),
    )


def _make_fuse(opts: dict, ctx: PipelineContext) -> Callable:
    _check_opts(
        "fuse", opts, ("horizontal", "vertical", "max_recompute_accesses")
    )
    kw = {}
    for k in ("horizontal", "vertical"):
        if k in opts:
            kw[k] = opts[k] not in ("0", "false", "no")
    if "max_recompute_accesses" in opts:
        kw["max_recompute_accesses"] = int(opts["max_recompute_accesses"])
    return _named("fuse", lambda f: fuse_applies(f, **kw))


def _make_temporal(opts: dict, ctx: PipelineContext) -> Callable:
    _check_opts("temporal-tile", opts, ("k",))
    try:
        k = int(opts["k"]) if "k" in opts else int(ctx.exchange_every)
    except ValueError:
        raise PipelineError(
            f"temporal-tile: k must be an integer, got {opts.get('k')!r}"
        )
    if k < 1:
        raise PipelineError(f"temporal-tile: k must be >= 1, got {k}")
    return _named("temporal-tile", lambda f: temporal_tile(f, k))


def _make_simple(name: str, fn: Callable) -> Callable:
    """Factory for option-less stages; rejects any option (mlir-opt does)."""

    def factory(opts: dict, ctx: PipelineContext) -> Callable:
        _check_opts(name, opts)
        return _named(name, fn)

    return factory


# name -> factory(opts, ctx) -> pass callable
PASS_REGISTRY: dict[str, Callable] = {
    "fuse": _make_fuse,
    "cse": _make_simple("cse", cse_apply_bodies),
    "dce": _make_simple("dce", dce),
    "decompose": _make_decompose,
    "swap-elim": _make_simple("swap-elim", eliminate_redundant_swaps),
    # deep-halo temporal tiling: one exchange epoch, k steps (k=1: identity)
    "temporal-tile": _make_temporal,
    "shrink-swaps": _make_simple("shrink-swaps", shrink_swaps_to_consumers),
    "diagonal": _make_simple("diagonal", use_diagonal_exchanges),
    # "overlap" is tag + split: after it, tagged swaps are already comm ops
    "overlap": _make_simple("overlap", _tag_and_split),
    "overlap-tag": _make_simple("overlap-tag", enable_comm_compute_overlap),
    "split-overlap": _make_simple(
        "split-overlap", split_overlapped_applies
    ),
    "lower-comm": _make_simple("lower-comm", lower_dmp_to_comm),
    # package each epoch's apply chain into ONE stencil.fused_epoch op so
    # the kernel backend emits a single pallas_call per epoch
    "fuse-epoch-kernel": _make_simple("fuse-epoch-kernel", fuse_epoch_kernels),
}


def build_pipeline(
    spec: str, ctx: Optional[PipelineContext] = None
) -> list:
    """Parse ``spec`` and instantiate every stage against the registry."""
    ctx = ctx or PipelineContext()
    passes = []
    for name, opts in parse_pipeline(spec):
        factory = PASS_REGISTRY.get(name)
        if factory is None:
            raise PipelineError(
                f"unknown pass {name!r}; registered: "
                f"{', '.join(sorted(PASS_REGISTRY))}"
            )
        passes.append(factory(opts, ctx))
    return passes


def run_pipeline(
    func: ir.FuncOp,
    spec: str,
    ctx: Optional[PipelineContext] = None,
    verify: bool = True,
    after_each: Optional[Callable[[str, ir.FuncOp], None]] = None,
) -> tuple:
    """Run a pipeline spec over ``func``; returns (result, timings)."""
    pm = PassManager(build_pipeline(spec, ctx), verify=verify)
    out = pm.run(func, after_each=after_each)
    return out, pm.timings
