"""Stencil-apply fusion (paper sec. 6.2).

"for the PW advection benchmark the three stencil computations are fused
into one single stencil region by xDSL, but with tracer advection there
are 18 individual stencil regions due to dependencies."

Two flavours, both operating on the *global* (pre-decomposition) function
so that halo inference afterwards sees the fused access patterns:

- **horizontal** fusion merges independent applies with identical result
  bounds into one multi-result apply (PW advection's 3 → 1);
- **vertical** fusion inlines a producer apply into its sole consumer,
  shifting the producer's accesses by the consumer's access offset
  (classic OEC value-semantics inlining; trades recompute for locality
  and, after decomposition, fewer exchanges with deeper halos).
"""
from __future__ import annotations

from typing import Optional

from repro.core import ir
from repro.core.dialects import stencil


def fuse_applies(
    func: ir.FuncOp,
    horizontal: bool = True,
    vertical: bool = True,
    max_recompute_accesses: int = 64,
) -> None:
    changed = True
    while changed:
        changed = False
        if vertical and _fuse_one_vertical(func, max_recompute_accesses):
            changed = True
        if horizontal and _fuse_one_horizontal(func):
            changed = True
    _dce(func)


# -- helpers ----------------------------------------------------------------


def _applies(func: ir.FuncOp) -> list:
    return [op for op in func.body.ops if isinstance(op, stencil.ApplyOp)]


def _transitively_depends(later: ir.Operation, earlier: ir.Operation, block: ir.Block) -> bool:
    """Does ``later`` (transitively) consume any result of ``earlier``?"""
    earlier_vals = set(earlier.results)
    start = block.ops.index(earlier)
    stop = block.ops.index(later)
    for op in block.ops[start + 1 : stop + 1]:
        if any(o in earlier_vals for o in op.operands):
            if op is later:
                return True
            earlier_vals.update(op.results)
    return False


def _fuse_one_horizontal(func: ir.FuncOp) -> bool:
    applies = _applies(func)
    for i, a in enumerate(applies):
        for b in applies[i + 1 :]:
            if a.result_bounds != b.result_bounds:
                continue
            if a.results[0].type.element_type != b.results[0].type.element_type:
                continue
            if _transitively_depends(b, a, func.body):
                continue
            # dominance: merged apply sits at b's position, so every use of
            # a's results must occur after b
            b_pos = func.body.ops.index(b)
            uses_ok = all(
                func.body.ops.index(u.operation) > b_pos
                for r in a.results
                for u in r.uses
                if u.operation.parent_block is func.body
            )
            if not uses_ok:
                continue
            _merge_applies(func, a, b)
            return True
    return False


def _merge_applies(func: ir.FuncOp, a: stencil.ApplyOp, b: stencil.ApplyOp) -> None:
    operands: list[ir.SSAValue] = []
    for o in (*a.operands, *b.operands):
        if o not in operands:
            operands.append(o)
    merged = stencil.ApplyOp(
        operands,
        a.result_bounds,
        n_results=len(a.results) + len(b.results),
        element_type=a.results[0].type.element_type,
    )
    vmap: dict[ir.SSAValue, ir.SSAValue] = {}
    for src in (a, b):
        for old_barg, operand in zip(src.body.args, src.operands):
            vmap[old_barg] = merged.body.args[operands.index(operand)]
    rets: list[ir.SSAValue] = []
    for src in (a, b):
        for body_op in src.body.ops:
            if isinstance(body_op, stencil.StencilReturnOp):
                rets.extend(vmap.get(v, v) for v in body_op.operands)
            else:
                merged.body.add_op(body_op.clone_into(vmap))
    merged.body.add_op(stencil.StencilReturnOp(rets))
    # insert where b was (both values dominate uses: b is the later one)
    func.body.insert_op_before(merged, b)
    for idx, old_res in enumerate((*a.results, *b.results)):
        old_res.replace_all_uses_with(merged.results[idx])
    a.erase()
    b.erase()


def _sole_consumer_apply(op: stencil.ApplyOp) -> Optional[stencil.ApplyOp]:
    consumer: Optional[stencil.ApplyOp] = None
    for res in op.results:
        for use in res.uses:
            if not isinstance(use.operation, stencil.ApplyOp):
                return None
            if consumer is None:
                consumer = use.operation
            elif consumer is not use.operation:
                return None
    return consumer


def _fuse_one_vertical(func: ir.FuncOp, max_recompute_accesses: int) -> bool:
    for producer in _applies(func):
        consumer = _sole_consumer_apply(producer)
        if consumer is None or consumer is producer:
            continue
        if producer.result_bounds != consumer.result_bounds:
            continue
        n_sites = sum(
            1
            for acc in consumer.accesses()
            if consumer.operands[acc.temp.index] in producer.results
        )
        n_prod_accesses = len(producer.accesses())
        if n_sites * n_prod_accesses > max_recompute_accesses:
            continue
        _inline_producer(func, producer, consumer)
        return True
    return False


def _inline_producer(
    func: ir.FuncOp, producer: stencil.ApplyOp, consumer: stencil.ApplyOp
) -> None:
    prod_ret = producer.body.ops[-1]
    assert isinstance(prod_ret, stencil.StencilReturnOp)

    # new operand list: consumer's (minus producer results) + producer's
    new_operands: list[ir.SSAValue] = []
    for o in consumer.operands:
        if o not in producer.results and o not in new_operands:
            new_operands.append(o)
    for o in producer.operands:
        if o not in new_operands:
            new_operands.append(o)

    fused = stencil.ApplyOp(
        new_operands,
        consumer.result_bounds,
        n_results=len(consumer.results),
        element_type=consumer.results[0].type.element_type,
    )

    def new_arg_for(operand: ir.SSAValue) -> ir.SSAValue:
        return fused.body.args[new_operands.index(operand)]

    vmap: dict[ir.SSAValue, ir.SSAValue] = {}
    for old_barg, operand in zip(consumer.body.args, consumer.operands):
        if operand not in producer.results:
            vmap[old_barg] = new_arg_for(operand)

    def inline_producer_at(offset: tuple, result_idx: int) -> ir.SSAValue:
        """Clone producer body shifted by ``offset``; return its result_idx value."""
        pmap: dict[ir.SSAValue, ir.SSAValue] = {}
        for p_barg, p_operand in zip(producer.body.args, producer.operands):
            pmap[p_barg] = new_arg_for(p_operand)
        out: Optional[ir.SSAValue] = None
        for body_op in producer.body.ops:
            if isinstance(body_op, stencil.StencilReturnOp):
                out = pmap.get(body_op.operands[result_idx], body_op.operands[result_idx])
                break
            if isinstance(body_op, stencil.AccessOp):
                shifted = stencil.AccessOp(
                    pmap[body_op.temp],
                    tuple(o + d for o, d in zip(body_op.offset, offset)),
                )
                fused.body.add_op(shifted)
                pmap[body_op.results[0]] = shifted.results[0]
            else:
                fused.body.add_op(body_op.clone_into(pmap))
        assert out is not None
        return out

    for body_op in consumer.body.ops:
        if isinstance(body_op, stencil.AccessOp):
            operand = consumer.operands[body_op.temp.index]
            if operand in producer.results:
                r_idx = producer.results.index(operand)
                vmap[body_op.results[0]] = inline_producer_at(body_op.offset, r_idx)
                continue
        fused.body.add_op(body_op.clone_into(vmap))

    func.body.insert_op_before(fused, consumer)
    for old_res, new_res in zip(consumer.results, fused.results):
        old_res.replace_all_uses_with(new_res)
    consumer.erase()
    if all(not r.uses for r in producer.results):
        producer.erase()


def _dce(func: ir.FuncOp) -> None:
    from repro.core.passes.swap_elim import _dce_block

    _dce_block(func.body)
