"""dmp → comm lowering (the paper's dmp → mpi step, fig. 4).

This is the **canonical** lowering every distributed compile takes: each
``dmp.swap`` becomes ``comm.halo_pad`` + per-round ``comm.exchange_start``
ops + a ``comm.wait`` per round, with sequential rounds chained through
the waited value (corner forwarding).  It is the explicit IR-level
analogue of the paper's temporary buffers + MPI_Isend/Irecv + Waitall.

After this pass no ``dmp.swap`` remains; the interpreter
(``core/lowering.py``) executes comm ops only — there is exactly one
exchange execution path.  Overlapped swaps are consumed earlier by
``split_overlapped_applies`` (``core/passes/overlap.py``), which emits
the same comm ops with the consumer apply split around the wait.
"""
from __future__ import annotations

import warnings

from repro.core import ir
from repro.core.dialects import comm, dmp


def exchange_start_for(
    decl: dmp.ExchangeDecl, swap: dmp.SwapOp, cur: ir.SSAValue
) -> comm.ExchangeStartOp:
    """Build the comm.exchange_start for one ExchangeDecl of ``swap``,
    reading the (padded) value ``cur``."""
    core_shape = swap.temp.type.bounds.shape
    shifts = tuple(
        (swap.grid.axis_names[g], step)
        for g, step in enumerate(decl.neighbor)
        if step != 0
    )
    start = comm.ExchangeStartOp(
        cur,
        shifts,
        decl.extract_offset(swap.grid, core_shape),
        decl.recv_offset,
        decl.recv_size,
    )
    start.attributes["periodic"] = ir.IntAttr(int(swap.boundary == "periodic"))
    return start


def emit_exchange_rounds(
    block: ir.Block,
    swap: dmp.SwapOp,
    cur: ir.SSAValue,
    rounds: list,
) -> ir.SSAValue:
    """Emit start*/wait per round, chaining sequential rounds through the
    waited value; returns the fully exchanged value."""
    for rnd in rounds:
        starts = [block.add_op(exchange_start_for(e, swap, cur)) for e in rnd]
        wait = comm.WaitOp(cur, [s.results[0] for s in starts])
        block.add_op(wait)
        cur = wait.results[0]
    return cur


def lower_dmp_to_comm(func: ir.FuncOp) -> ir.FuncOp:
    """Replace every dmp.swap with halo_pad + exchange_start/wait rounds.

    Preserves ``sym_name`` — the canonical lowering must not rename the
    function, so dry-runs and tests keyed by name keep working.
    """
    new_func = ir.FuncOp(func.sym_name, [a.type for a in func.body.args])
    vmap: dict[ir.SSAValue, ir.SSAValue] = {}
    for oa, na in zip(func.body.args, new_func.body.args):
        vmap[oa] = na
    block = new_func.body
    for op in func.body.ops:
        if not isinstance(op, dmp.SwapOp):
            block.add_op(op.clone_into(vmap))
            continue
        a = op.attributes.get("overlap")
        if a is not None and a.value == 1:
            warnings.warn(
                f"{func.sym_name}: overlap-tagged dmp.swap lowered as a "
                "blocking exchange — run split-overlap (or the combined "
                "'overlap' stage) before lower-comm to keep the overlap",
                stacklevel=2,
            )
        pad = comm.HaloPadOp(
            vmap[op.temp], op.result_bounds, op.boundary, op.grid
        )
        block.add_op(pad)
        vmap[op.results[0]] = emit_exchange_rounds(
            block, op, pad.results[0], op.rounds()
        )
    return new_func
