"""Pipeline dump mode — mlir-opt for the repro stack.

    python -m repro.core.passes "fuse,cse,dce,decompose{grid=2x2},swap-elim,overlap,lower-comm"

Runs the spec over a demo stencil program (or --program box|chain),
printing the IR after every stage plus the PassManager timing table.
``--quiet`` prints only the op-count trajectory and timings (the CI
pipeline smoke in scripts/check.sh).
"""
from __future__ import annotations

import argparse
import sys

from repro.core import ir
from repro.core.passes import PassManager, PipelineContext, run_pipeline

DEFAULT_SPEC = (
    "fuse,cse,dce,decompose{grid=2x2},swap-elim,overlap,lower-comm"
)


def _demo_program(kind: str, shape: tuple) -> ir.FuncOp:
    from repro.frontends.oec_like import ProgramBuilder

    p = ProgramBuilder(kind, shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    if kind == "jacobi":
        r = p.apply(
            [t],
            lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1))
            * 0.25,
        )
    elif kind == "box":
        r = p.apply(
            [t],
            lambda b, u: u.at(-1, -1) + u.at(1, 1) * 0.5 + u.at(-1, 1) * 0.25
            + u.at(0, 0),
        )
    elif kind == "chain":
        a = p.apply([t], lambda b, u: (u.at(-1, 0) + u.at(1, 0)) * 0.5)
        r = p.apply([t, a], lambda b, u, a: u.at(0, 0) + a.at(0, 0) * 0.1)
    else:
        raise SystemExit(f"unknown --program {kind!r}")
    p.store(r, out)
    return p.build_func()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.core.passes")
    ap.add_argument("spec", nargs="?", default=DEFAULT_SPEC,
                    help="pipeline spec (see DESIGN.md §2 for the grammar)")
    ap.add_argument("--program", default="jacobi",
                    choices=["jacobi", "box", "chain"])
    ap.add_argument("--shape", default="32x32",
                    help="global domain, e.g. 64x32")
    ap.add_argument("--boundary", default="periodic",
                    choices=["zero", "periodic"])
    ap.add_argument("--quiet", action="store_true",
                    help="op counts + timings only (CI smoke)")
    args = ap.parse_args(argv)

    shape = tuple(int(s) for s in args.shape.split("x"))
    func = _demo_program(args.program, shape)
    ctx = PipelineContext(boundary=args.boundary)

    print(f"// input: {args.program} {args.shape} boundary={args.boundary}")
    if not args.quiet:
        print(ir.print_module(func))

    def dump(name: str, f: ir.FuncOp) -> None:
        if args.quiet:
            print(f"// after {name}: {len(f.body.ops)} top-level ops")
            return
        print(f"\n// ----- after {name} " + "-" * (40 - len(name)))
        print(ir.print_module(f))

    out, _ = run_pipeline(func, args.spec, ctx, after_each=dump)

    # the process-wide surface every driver shares (shim: last_timings)
    print(f"\n// pass timings (PassManager.last_timings, "
          f"run #{PassManager.runs_completed})")
    for name, sec in PassManager.last_timings:
        print(f"//   {name:<16} {sec * 1e3:8.2f} ms")
    counts: dict[str, int] = {}
    for op in out.body.ops:
        counts[op.name] = counts.get(op.name, 0) + 1
    print("// final op mix: " + ", ".join(
        f"{k}×{v}" for k, v in sorted(counts.items())
    ))
    return 0


if __name__ == "__main__":
    sys.exit(main())
