"""Global→local decomposition (paper sec. 4.2).

"We offer a shared pass that automatically prepares stencil programs for
distributed execution.  This pass is parameterized by information on the
topology of MPI ranks in the computation, along with a decomposition
strategy. ... Given this information, we equally decompose the domain
represented in stencil to a 'local' data domain ... The stencil dialect is
also responsible for adding the necessary halos to local domains.
Subsequently, dmp.swap operations are inserted, ensuring that neighboring
ranks hold the updated data before proceeding to the following stencil
computation."

The pass rewrites a *global-domain* stencil function into a *rank-local*
function (SPMD: identical on all ranks) whose temps carry local bounds and
whose halo needs are satisfied by inserted ``dmp.swap`` ops.  Halo shapes
come from ``infer_value_halos`` (access-offset scanning); swaps are
inserted for every value an apply reads with nonzero extent — including
intermediate temps between chained applies (tracer advection) — and the
redundant ones are removed by ``eliminate_redundant_swaps``.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Optional, Sequence

from repro.core import ir
from repro.core.dialects import dmp, stencil
from repro.core.passes.halo import halo_widths, infer_value_halos, needs_corners


@dataclass
class SlicingStrategy:
    """The paper's extensible decomposition-strategy interface, with the
    standard 1D/2D/3D equal-slicing implementation.

    ``grid_shape[i]`` ranks decompose array dimension ``dims[i]`` and map to
    JAX mesh axis ``axis_names[i]``.
    """

    grid_shape: tuple
    axis_names: tuple
    dims: Optional[tuple] = None  # default: leading len(grid_shape) dims

    def __post_init__(self) -> None:
        if self.dims is None:
            self.dims = tuple(range(len(self.grid_shape)))
        assert len(self.grid_shape) == len(self.axis_names) == len(self.dims)

    @property
    def grid(self) -> dmp.GridAttr:
        return dmp.GridAttr(
            tuple(self.grid_shape), tuple(self.axis_names), tuple(self.dims)
        )

    # -- the strategy interface the paper describes --------------------
    def local_bounds(self, global_bounds: stencil.Bounds) -> stencil.Bounds:
        """Rank-local core bounds of an equally-sliced global domain."""
        lb = list(global_bounds.lb)
        ub = list(global_bounds.ub)
        for g, d in zip(self.grid_shape, self.dims):
            if d >= len(lb):
                continue
            extent = ub[d] - lb[d]
            if lb[d] != 0:
                raise ValueError(
                    f"decomposition requires zero-based domains, got lb={lb[d]} "
                    f"in dim {d} (encode physical ghosts via boundary fill)"
                )
            if extent % g != 0:
                raise ValueError(
                    f"dim {d} extent {extent} not divisible by grid size {g}"
                )
            ub[d] = extent // g
        return stencil.Bounds(tuple(lb), tuple(ub))

    def exchanges(
        self,
        core: stencil.Bounds,
        halo_lo: tuple,
        halo_hi: tuple,
        corners: bool,
    ) -> tuple:
        """Halo-exchange declarations for a core grown by (halo_lo, halo_hi).

        Returns ``(decls, schedule)``.  Standard strategy: one exchange per
        (decomposed dim, direction).  If ``corners`` (box stencil), later
        axes span the already-filled halos of earlier axes and the schedule
        is *sequential* — the classic corner-forwarding sweep, matching the
        paper's one-exchange-per-halo baseline.  Star stencils get
        *concurrent* core-width exchanges.
        """
        rank = core.rank
        n = core.shape
        decls: list[dmp.ExchangeDecl] = []
        grid_axes_in_order = sorted(range(len(self.dims)), key=lambda i: self.dims[i])
        for round_idx, gax in enumerate(grid_axes_in_order):
            d = self.dims[gax]
            if d >= rank or (halo_lo[d] == 0 and halo_hi[d] == 0):
                continue
            # span of the rectangle in the other dims
            span_off = []
            span_size = []
            for k in range(rank):
                if k == d:
                    span_off.append(0)  # placeholder, set below
                    span_size.append(0)
                    continue
                gax_k = self.grid.axis_of_dim(k)
                earlier = (
                    gax_k is not None
                    and grid_axes_in_order.index(gax_k) < round_idx
                )
                if corners and (earlier or gax_k is None):
                    # include already-filled halos (corner forwarding)
                    span_off.append(core.lb[k] - halo_lo[k])
                    span_size.append(n[k] + halo_lo[k] + halo_hi[k])
                elif gax_k is None:
                    # undecomposed dim: include its (locally-filled) halo
                    span_off.append(core.lb[k] - halo_lo[k])
                    span_size.append(n[k] + halo_lo[k] + halo_hi[k])
                else:
                    span_off.append(core.lb[k])
                    span_size.append(n[k])

            def rect(offset_d: int, size_d: int) -> tuple:
                off = list(span_off)
                size = list(span_size)
                off[d] = offset_d
                size[d] = size_d
                return tuple(off), tuple(size)

            def nbr(step: int) -> tuple:
                v = [0] * len(self.grid_shape)
                v[gax] = step
                return tuple(v)

            if halo_lo[d] > 0:
                # receive my low halo from neighbour -1; send my low core slab
                recv_off, size = rect(core.lb[d] - halo_lo[d], halo_lo[d])
                send_off, _ = rect(core.lb[d], halo_lo[d])
                decls.append(
                    dmp.ExchangeDecl(nbr(-1), recv_off, size, send_off, size)
                )
            if halo_hi[d] > 0:
                # receive my high halo from neighbour +1; send my high core slab
                recv_off, size = rect(core.ub[d], halo_hi[d])
                send_off, _ = rect(core.ub[d] - halo_hi[d], halo_hi[d])
                decls.append(
                    dmp.ExchangeDecl(nbr(+1), recv_off, size, send_off, size)
                )
        schedule = "sequential" if corners else "concurrent"
        return tuple(decls), schedule


def make_strategy_1d(nranks: int, axis: str = "x", dim: int = 0) -> SlicingStrategy:
    return SlicingStrategy((nranks,), (axis,), (dim,))


def make_strategy_2d(shape: tuple, axes: tuple = ("x", "y"), dims=(0, 1)) -> SlicingStrategy:
    return SlicingStrategy(tuple(shape), tuple(axes), tuple(dims))


def make_strategy_3d(shape: tuple, axes: tuple = ("x", "y", "z"), dims=(0, 1, 2)) -> SlicingStrategy:
    return SlicingStrategy(tuple(shape), tuple(axes), tuple(dims))


def _localize(
    bounds: stencil.Bounds, strategy: SlicingStrategy
) -> stencil.Bounds:
    return strategy.local_bounds(bounds)


def decompose_stencil(
    func: ir.FuncOp,
    strategy: SlicingStrategy,
    boundary: str = "zero",
) -> ir.FuncOp:
    """Rewrite a global stencil function into its rank-local SPMD version."""
    value_halos = infer_value_halos(func)
    corners = needs_corners(func, strategy.dims)

    new_args: list[ir.TypeAttribute] = []
    for arg in func.body.args:
        t = arg.type
        if isinstance(t, (stencil.FieldType, stencil.TempType)):
            new_args.append(type(t)(_localize(t.bounds, strategy), t.element_type))
        else:
            new_args.append(t)
    new_func = ir.FuncOp(func.sym_name + "_local", new_args)

    vmap: dict[ir.SSAValue, ir.SSAValue] = {}
    swapped: dict[ir.SSAValue, ir.SSAValue] = {}  # old value -> swapped new value
    for old_arg, new_arg in zip(func.body.args, new_func.body.args):
        vmap[old_arg] = new_arg

    def maybe_swap(old_val: ir.SSAValue, new_val: ir.SSAValue) -> None:
        """Insert a dmp.swap after the local definition of ``new_val`` if any
        consumer reads ``old_val`` beyond its core."""
        ext = value_halos.get(old_val)
        if ext is None:
            return
        lo_w, hi_w = halo_widths(ext)
        if all(w == 0 for w in lo_w) and all(w == 0 for w in hi_w):
            return
        core: stencil.Bounds = new_val.type.bounds
        grown = core.grow(lo_w, hi_w)
        decls, schedule = strategy.exchanges(core, lo_w, hi_w, corners)
        swap = dmp.SwapOp(
            new_val,
            strategy.grid,
            decls,
            result_bounds=grown,
            boundary=boundary,
            schedule=schedule,
        )
        new_func.body.add_op(swap)
        swapped[old_val] = swap.results[0]

    def mapped_operand(old: ir.SSAValue, want_halo: bool) -> ir.SSAValue:
        if want_halo and old in swapped:
            return swapped[old]
        return vmap[old]

    for op in func.body.ops:
        if isinstance(op, stencil.LoadOp):
            new_load = stencil.LoadOp(vmap[op.field])
            new_func.body.add_op(new_load)
            vmap[op.results[0]] = new_load.results[0]
            maybe_swap(op.results[0], new_load.results[0])
        elif isinstance(op, stencil.ApplyOp):
            local_rb = _localize(op.result_bounds, strategy)
            new_operands = [
                mapped_operand(o, want_halo=True) for o in op.operands
            ]
            new_apply = stencil.ApplyOp(
                new_operands,
                local_rb,
                n_results=len(op.results),
                element_type=op.results[0].type.element_type,
            )
            body_map: dict[ir.SSAValue, ir.SSAValue] = {}
            for old_barg, new_barg in zip(op.body.args, new_apply.body.args):
                body_map[old_barg] = new_barg
            for body_op in op.body.ops:
                new_apply.body.add_op(body_op.clone_into(body_map))
            new_func.body.add_op(new_apply)
            for old_res, new_res in zip(op.results, new_apply.results):
                vmap[old_res] = new_res
                maybe_swap(old_res, new_res)
        elif isinstance(op, stencil.StoreOp):
            new_store = stencil.StoreOp(
                mapped_operand(op.temp, want_halo=False),
                vmap[op.field],
                _localize(op.bounds, strategy),
            )
            new_func.body.add_op(new_store)
        elif isinstance(op, ir.ReturnOp):
            new_func.body.add_op(
                ir.ReturnOp([mapped_operand(o, want_halo=False) for o in op.operands])
            )
        elif isinstance(op, dmp.SwapOp):
            raise ValueError("decompose_stencil expects an undecomposed function")
        else:
            cloned = op.clone_into(vmap)
            new_func.body.add_op(cloned)
    return new_func
