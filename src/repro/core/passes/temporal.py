"""Deep-halo temporal tiling — exchange once, step ``k`` times.

The paper's halo machinery (sec. 4.1/4.2) infers the *minimal* halo per
value, but one neighbor exchange per time step is still the dominant cost
at scale (the fig. 8 regime; Devito's haloupdate-placement analysis makes
the same observation).  Classic distributed-stencil practice amortizes it:
exchange a *depth-k* halo once, then run ``k`` steps of the stencil with
redundant boundary compute before the next exchange.

``temporal_tile(func, k)`` expresses that trade as a pure IR transform on
the rank-local decomposed function (after ``decompose``/``swap-elim``,
before ``overlap``/``lower-comm``):

- every per-step ``dmp.swap`` is deleted and replaced by **one deep swap
  per loaded field**, its halo extents scaled to the *accumulated* demand
  of the whole epoch (backward dataflow over the k-times-unrolled apply
  chain — chained applies compound, exactly like the per-step inference);
- the apply chain is cloned ``k`` times with time-buffer rotation at the
  value level (the IR analogue of ``repro.api.time_loop``'s
  ``state' = state[q:] + outs``), each clone's result bounds grown by what
  the *remaining* steps still read — step j computes ``core`` plus a
  shrinking frame of redundant boundary points, step k computes exactly
  ``core``;
- programs whose state carries *more* inputs than outputs (``p > q``,
  e.g. ``time_order >= 2`` wave kernels reading ``u`` and ``u_prev``)
  rotate closed too: the epoch stores the ``p - q`` carried intermediate
  buffers (iterations ``k-q`` … ``k-1``) into the dead oldest input
  buffers and returns the FULL rotated state oldest → newest, so the
  caller's ``state' = state[len(outs):] + outs`` is exact for any depth;
- for ``zero`` (dirichlet) boundaries a ``comm.boundary_mask`` re-applies
  the boundary condition to redundantly-computed points that lie outside
  the *physical* domain (rank-position-aware, no communication), so the
  epoch is bitwise-equal to k single-exchange steps.  Periodic boundaries
  need no mask: deep wrap data makes the redundant points exact.

Corner note: even a *star* stencil composed with itself has a diamond
footprint, so any epoch with ``k >= 2`` over 2+ decomposed dims reads
corner halo data; the deep swap therefore uses the sequential
(corner-forwarding) schedule in that case, which the ``diagonal`` pass
can still rewrite into concurrent corner messages afterwards.

``epoch_halo(func, k)`` exposes the accumulated per-dim widths for
``repro.api``'s Target validation (``Target(exchange_every=k)``) without
running the rewrite.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core import ir
from repro.core.dialects import comm, dmp, stencil
from repro.core.passes.halo import needs_corners


class TemporalTilingError(ValueError):
    """A program shape ``temporal_tile`` cannot epoch: state that does not
    rotate closed (more outputs than inputs), partial stores,
    index-dependent bodies, or unsupported function-level ops."""


# --------------------------------------------------------------------------
# Phase 1 — step-structure extraction (works on global *and* local IR)
# --------------------------------------------------------------------------


@dataclass
class _Step:
    """One time step as the IR states it: loads in, stores out, applies
    between, with any per-step swaps recorded (and looked *through*)."""

    loads: list          # LoadOp, body order
    load_of_field: dict  # field BlockArgument -> load result SSAValue
    swaps: dict          # swap result SSAValue -> dmp.SwapOp
    applies: list        # ApplyOp, body order
    stores: list         # StoreOp, body order
    ret: ir.Operation
    in_fields: list      # non-stored field args, arg order (rotation state)
    out_fields: list     # stored field args, first-store order
    stored_val: dict     # field arg -> stored temp (swap-resolved)


def _unswapped(v: ir.SSAValue, swaps: dict) -> ir.SSAValue:
    while v in swaps:
        v = swaps[v].temp
    return v


def _extract_step(func: ir.FuncOp) -> _Step:
    loads, applies, stores = [], [], []
    load_of_field: dict = {}
    swaps: dict = {}
    ret = None
    for op in func.body.ops:
        if isinstance(op, stencil.LoadOp):
            if op.field in load_of_field:
                raise TemporalTilingError(
                    f"field {op.field.name_hint!r} loaded twice (run swap-elim"
                    " first)"
                )
            if op.results[0].type.bounds != op.field.type.bounds:
                raise TemporalTilingError("partial stencil.load not supported")
            load_of_field[op.field] = op.results[0]
            loads.append(op)
        elif isinstance(op, dmp.SwapOp):
            swaps[op.results[0]] = op
        elif isinstance(op, stencil.ApplyOp):
            for body_op in op.body.ops:
                if isinstance(body_op, (stencil.IndexOp, stencil.DynAccessOp)):
                    raise TemporalTilingError(
                        f"apply body op {body_op.name} is position-dependent; "
                        "redundant boundary compute would change its value"
                    )
            applies.append(op)
        elif isinstance(op, stencil.StoreOp):
            stores.append(op)
        elif isinstance(op, ir.ReturnOp):
            ret = op
        else:
            raise TemporalTilingError(
                f"function-level op {op.name} not supported in an epoch"
            )
    if ret is None:
        raise TemporalTilingError("missing func.return")

    field_args = [
        a for a in func.body.args if isinstance(a.type, stencil.FieldType)
    ]
    stored_val: dict = {}
    out_fields: list = []
    for st_op in stores:
        if st_op.field in stored_val:
            raise TemporalTilingError(
                f"field {st_op.field.name_hint!r} stored twice per step"
            )
        if st_op.bounds != st_op.field.type.bounds:
            raise TemporalTilingError(
                "partial stencil.store not supported: the next step would "
                "read stale points of the output buffer"
            )
        stored_val[st_op.field] = _unswapped(st_op.temp, swaps)
        out_fields.append(st_op.field)
    in_fields = [a for a in field_args if a not in stored_val]
    if len(out_fields) > len(in_fields) or not out_fields:
        raise TemporalTilingError(
            f"state does not rotate closed: {len(in_fields)} input field(s) "
            f"vs {len(out_fields)} output field(s); temporal tiling needs at "
            "least one input buffer per output so the rotation "
            "state' = state[q:] + outs is well-defined"
        )
    for f in in_fields:
        if f not in load_of_field:
            raise TemporalTilingError(
                f"input field {f.name_hint!r} is never loaded"
            )
    for f in out_fields:
        if f in load_of_field:
            raise TemporalTilingError(
                f"field {f.name_hint!r} is both loaded and stored "
                "(read-modify-write steps cannot be epoch-unrolled)"
            )
    # output i rotates into input slot p-q+i (the rotation drops the q
    # oldest buffers): bounds must line up slot-wise, including for
    # time_order >= 2 wave programs where p > q
    shift = len(in_fields) - len(out_fields)
    for i, f in enumerate(out_fields):
        want = load_of_field[in_fields[shift + i]].type.bounds
        have = stored_val[f].type.bounds
        if want != have:
            raise TemporalTilingError(
                f"stored value bounds {have} cannot rotate into input slot "
                f"{shift + i} with bounds {want}"
            )
    return _Step(
        loads=loads,
        load_of_field=load_of_field,
        swaps=swaps,
        applies=applies,
        stores=stores,
        ret=ret,
        in_fields=in_fields,
        out_fields=out_fields,
        stored_val=stored_val,
    )


# --------------------------------------------------------------------------
# Phase 2 — accumulated halo demand over the unrolled epoch
# --------------------------------------------------------------------------


@dataclass
class _Plan:
    step: _Step
    k: int
    growth: dict   # (iteration, ApplyOp) -> (lo widths, hi widths)
    deep: dict     # load result SSAValue -> (lo widths, hi widths)

    def producer(self, j: int, v: ir.SSAValue) -> tuple:
        """Canonical (iteration, value) id of iteration ``j``'s version of
        original value ``v``, resolving time-buffer rotation: a load result
        read in iteration j > 1 is the value rotated in from iteration
        j - 1 (iteration 1 reads the real — deep-swapped — load, id 0)."""
        s = self.step
        slot = self._slot_of_load().get(v)
        if slot is None:
            return (j, v)
        if j == 1:
            return (0, v)
        p, q = len(s.in_fields), len(s.out_fields)
        if slot < p - q:  # carried state (p > q, e.g. wave): rotate through
            return self.producer(j - 1, s.load_of_field[s.in_fields[slot + q]])
        return (j - 1, s.stored_val[s.out_fields[slot - (p - q)]])

    def _slot_of_load(self) -> dict:
        if not hasattr(self, "_slots"):
            self._slots = {
                self.step.load_of_field[f]: i
                for i, f in enumerate(self.step.in_fields)
            }
        return self._slots


def _wmax(a: tuple, b: tuple) -> tuple:
    return (
        tuple(max(x, y) for x, y in zip(a[0], b[0])),
        tuple(max(x, y) for x, y in zip(a[1], b[1])),
    )


def _plan_epoch(func: ir.FuncOp, k: int) -> _Plan:
    """Backward halo-demand accounting over the k-times-unrolled chain.

    Processing iterations k→1 and applies in reverse body order guarantees
    every consumer (later applies of the same iteration, the next
    iteration via rotation, the final stores) is accounted before a
    value's demand is read.
    """
    step = _extract_step(func)
    rank = func.body.args[0].type.bounds.rank if func.body.args else 0
    zero = (tuple([0] * rank), tuple([0] * rank))
    plan = _Plan(step=step, k=k, growth={}, deep={})
    need: dict = {}

    for j in range(k, 0, -1):
        for a in reversed(step.applies):
            g = zero
            for r in a.results:
                g = _wmax(g, need.get((j, r), zero))
            plan.growth[(j, a)] = g
            exts = a.access_extents()
            for idx, o in enumerate(a.operands):
                ov = _unswapped(o, step.swaps)
                lo, hi = exts.get(idx, (tuple([0] * rank), tuple([0] * rank)))
                req = (
                    tuple(gl - l for gl, l in zip(g[0], lo)),
                    tuple(gh + h for gh, h in zip(g[1], hi)),
                )
                cid = plan.producer(j, ov)
                need[cid] = _wmax(need.get(cid, zero), req)

    for load in step.loads:
        plan.deep[load.results[0]] = need.get((0, load.results[0]), zero)
    return plan


def epoch_halo(func: ir.FuncOp, k: int) -> tuple:
    """Per-dim (lo widths, hi widths) the deepest field needs for one
    k-step epoch — the union over loaded fields of the accumulated demand.
    Works on global (pre-decompose) IR; raises ``TemporalTilingError`` for
    shapes the pass cannot epoch.  The ``Target(exchange_every=k)``
    validation entry point."""
    plan = _plan_epoch(func, k)
    rank = func.body.args[0].type.bounds.rank if func.body.args else 0
    out = (tuple([0] * rank), tuple([0] * rank))
    for widths in plan.deep.values():
        out = _wmax(out, widths)
    return out


# --------------------------------------------------------------------------
# Phase 3 — the rewrite
# --------------------------------------------------------------------------


def _clone_apply(
    apply_op: stencil.ApplyOp, operands, bounds: stencil.Bounds, j: int
) -> stencil.ApplyOp:
    new = stencil.ApplyOp(
        operands,
        bounds,
        n_results=len(apply_op.results),
        element_type=apply_op.results[0].type.element_type,
    )
    new.attributes["epoch_step"] = ir.IntAttr(j)
    body_map: dict[ir.SSAValue, ir.SSAValue] = {}
    for oa, na in zip(apply_op.body.args, new.body.args):
        body_map[oa] = na
    for body_op in apply_op.body.ops:
        new.body.add_op(body_op.clone_into(body_map))
    return new


def temporal_tile(func: ir.FuncOp, k: int) -> ir.FuncOp:
    """Rewrite a rank-local decomposed function (dmp.swap level) into one
    k-step exchange epoch; ``k == 1`` is the identity.  Preserves
    ``sym_name`` like the other canonical-path passes."""
    if k <= 1:
        return func
    plan = _plan_epoch(func, k)
    step = plan.step

    grid = boundary = None
    for swap in step.swaps.values():
        grid, boundary = swap.grid, swap.boundary
        break

    new_func = ir.FuncOp(func.sym_name, [a.type for a in func.body.args])
    block = new_func.body
    vmap: dict[ir.SSAValue, ir.SSAValue] = {}
    for oa, na in zip(func.body.args, new_func.body.args):
        vmap[oa] = na
    emitted: dict[tuple, ir.SSAValue] = {}

    # union deep widths decide the corner regime: S∘S of a star is a
    # diamond, so k >= 2 over 2+ decomposed dims reads corner halo data
    rank = func.body.args[0].type.bounds.rank if func.body.args else 0
    union = (tuple([0] * rank), tuple([0] * rank))
    for widths in plan.deep.values():
        union = _wmax(union, widths)
    deep_dims = [d for d in range(len(union[0])) if union[0][d] or union[1][d]]
    if grid is not None:
        decomposed_deep = [d for d in deep_dims if grid.axis_of_dim(d) is not None]
        corners = needs_corners(func, grid.dims) or len(decomposed_deep) >= 2
    else:
        corners = False

    # loads + one deep swap per field that the epoch reads beyond its core
    for load in step.loads:
        new_load = stencil.LoadOp(vmap[load.field])
        block.add_op(new_load)
        cur = new_load.results[0]
        lo, hi = plan.deep[load.results[0]]
        if any(lo) or any(hi):
            if grid is None:
                raise TemporalTilingError(
                    "epoch needs a halo exchange but the function carries no "
                    "dmp.swap to take the grid/boundary from — run decompose "
                    "before temporal-tile"
                )
            from repro.core.passes.decompose import SlicingStrategy

            strat = SlicingStrategy(grid.shape, grid.axis_names, grid.dims)
            decls, schedule = strat.exchanges(cur.type.bounds, lo, hi, corners)
            swap = dmp.SwapOp(
                cur,
                grid,
                decls,
                result_bounds=cur.type.bounds.grow(lo, hi),
                boundary=boundary,
                schedule=schedule,
            )
            block.add_op(swap)
            cur = swap.results[0]
        emitted[(0, load.results[0])] = cur

    shard_core = (
        step.loads[0].results[0].type.bounds if step.loads else None
    )

    # the unrolled chain: k clones with value-level time-buffer rotation
    for j in range(1, k + 1):
        for a in step.applies:
            g_lo, g_hi = plan.growth[(j, a)]
            rb = a.result_bounds.grow(g_lo, g_hi)
            operands = [
                emitted[plan.producer(j, _unswapped(o, step.swaps))]
                for o in a.operands
            ]
            new_apply = _clone_apply(a, operands, rb, j)
            block.add_op(new_apply)
            for r, nr in zip(a.results, new_apply.results):
                val = nr
                if (
                    boundary == "zero"
                    and grid is not None
                    and shard_core is not None
                    and not shard_core.contains(rb)
                ):
                    mask = comm.BoundaryMaskOp(nr, shard_core, grid)
                    block.add_op(mask)
                    val = mask.results[0]
                emitted[(j, r)] = val

    # carried state (p > q, e.g. time_order-2 wave): a k-step epoch must
    # hand back the FULL rotated state, not just iteration k's outputs —
    # the caller's rotation state' = state[len(outs):] + outs then yields
    # (u_{t+k-1}, u_{t+k}) instead of the stale (u_t, u_{t+k}).  The p-q
    # intermediate values are stored into the (dead after the epoch)
    # oldest input buffers, *before* the original stores so first-store
    # order stays oldest → newest.
    p_in, q_out = len(step.in_fields), len(step.out_fields)
    for i in range(p_in - q_out):
        v = emitted[
            plan.producer(k + 1, step.load_of_field[step.in_fields[i]])
        ]
        carry_field = vmap[step.in_fields[i]]
        block.add_op(stencil.StoreOp(v, carry_field, carry_field.type.bounds))
    for st_op in step.stores:
        v = emitted[plan.producer(k, _unswapped(st_op.temp, step.swaps))]
        block.add_op(stencil.StoreOp(v, vmap[st_op.field], st_op.bounds))
    block.add_op(
        ir.ReturnOp(
            [
                emitted[plan.producer(k, _unswapped(o, step.swaps))]
                for o in step.ret.operands
            ]
        )
    )
    return new_func
