"""Communication/computation overlap — **beyond-paper** (sec. 8 lists it as
future work: "Further work includes ... DMP/MPI optimizations, such as
diagonal communications ... and communication/computation overlap").

Two cooperating passes make overlap an *IR-level* transformation:

- ``enable_comm_compute_overlap`` tags eligible ``dmp.swap`` ops
  (``overlap = true``): swaps with exchanges whose result feeds exactly
  one ``stencil.apply`` with a non-empty interior.

- ``split_overlapped_applies`` consumes every tagged swap, rewriting
  ``swap + apply`` into the canonical comm-level sequence

      comm.halo_pad → comm.exchange_start* → stencil.apply (interior)
          → comm.wait → stencil.apply (boundary frames)* → stencil.combine

  The *interior* apply (the consumer's domain shrunk by its access
  extents) reads the padded-but-unexchanged value — every access stays
  inside the core, which the exchange never touches — so it carries no
  data dependence on the waits.  XLA's latency-hiding scheduler then
  rides the ppermute(s) under the interior compute: the dataflow
  analogue of MPI_Isend/Irecv + interior kernel + MPI_Waitall + boundary
  kernel, visible and verifiable in the lowered IR.

Untagged swaps are lowered by the ordinary ``lower_dmp_to_comm`` pass, so
after ``overlap → lower-comm`` there is exactly one exchange execution
path (comm ops) regardless of overlap.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.ir import IntAttr, StringAttr
from repro.core.dialects import comm, dmp, stencil
from repro.core.passes.lower_comm import emit_exchange_rounds, exchange_start_for


def enable_comm_compute_overlap(func: ir.FuncOp) -> int:
    """Tag eligible swaps; returns how many were tagged."""
    n = 0
    for op in func.body.ops:
        if not isinstance(op, dmp.SwapOp):
            continue
        if not op.exchanges:
            continue
        if _split_plan(op) is not None:
            op.attributes["overlap"] = IntAttr(1)
            n += 1
    return n


def overlap_enabled(swap: dmp.SwapOp) -> bool:
    a = swap.attributes.get("overlap")
    return a is not None and a.value == 1  # type: ignore[union-attr]


# --------------------------------------------------------------------------
# The split rewrite
# --------------------------------------------------------------------------


def _bounds_or_none(lb: tuple, ub: tuple):
    """Bounds(lb, ub), or None when empty in any dim (Bounds itself
    asserts non-degeneracy)."""
    if any(u - l <= 0 for l, u in zip(lb, ub)):
        return None
    return stencil.Bounds(tuple(lb), tuple(ub))


def _intersect(a: stencil.Bounds, b: stencil.Bounds):
    """Intersection of two bounds, or None when empty in any dim."""
    return _bounds_or_none(
        tuple(max(x, y) for x, y in zip(a.lb, b.lb)),
        tuple(min(x, y) for x, y in zip(a.ub, b.ub)),
    )


def _split_plan(swap: dmp.SwapOp):
    """The (consumer apply, interior bounds) this swap's split would use,
    or None when ineligible (shared result, non-apply consumer, or empty
    interior).

    The interior is the part of the consumer's domain whose reads stay
    inside the swap's *pre-exchange core* — the exchange only writes
    outside it — intersected with the result bounds.  For the standard
    pipeline the two coincide (result bounds == core); a deep-halo
    temporally-tiled apply computes *beyond* the core, so shrinking only
    the result bounds would race the interior against the in-flight
    exchange."""
    consumers = {u.operation for u in swap.results[0].uses}
    if len(consumers) != 1:
        return None
    apply = next(iter(consumers))
    if not isinstance(apply, stencil.ApplyOp):
        return None
    lo_w, hi_w = _apply_halo_widths(apply)
    rb = apply.result_bounds
    core: stencil.Bounds = swap.temp.type.bounds
    safe = _bounds_or_none(
        tuple(b + w for b, w in zip(core.lb, lo_w)),
        tuple(b - w for b, w in zip(core.ub, hi_w)),
    )
    interior = _intersect(rb, safe) if safe is not None else None
    if interior is None:
        return None
    return apply, interior


def _apply_halo_widths(apply: stencil.ApplyOp) -> tuple:
    """Union access extents of ALL operands → frame widths per dim."""
    rank = apply.result_bounds.rank
    lo = [0] * rank
    hi = [0] * rank
    for _, (l, h) in apply.access_extents().items():
        lo = [min(a, b) for a, b in zip(lo, l)]
        hi = [max(a, b) for a, b in zip(hi, h)]
    return [-l for l in lo], list(hi)


def split_overlapped_applies(func: ir.FuncOp) -> ir.FuncOp:
    """Rewrite every tagged ``swap + apply`` pair into the explicit
    overlapped comm sequence (module docstring); preserves ``sym_name``."""
    plans: dict = {}  # tagged swap -> (apply, interior)
    by_apply: dict = {}  # consumer apply -> [tagged swaps feeding it]
    declined: list = []  # tagged but ineligible: untag, lower-comm handles
    for op in func.body.ops:
        if isinstance(op, dmp.SwapOp) and overlap_enabled(op):
            plan = _split_plan(op)
            if plan is None:
                declined.append(op)
                continue
            plans[op] = plan
            by_apply.setdefault(plan[0], []).append(op)
    # several tagged swaps feeding one apply: the interior safe from ALL
    # in-flight exchanges is the intersection of the per-swap interiors
    interiors: dict = {}
    for apply, swaps in list(by_apply.items()):
        interior = plans[swaps[0]][1]
        for s in swaps[1:]:
            interior = (
                _intersect(interior, plans[s][1])
                if interior is not None
                else None
            )
        if interior is None:
            declined.extend(swaps)
            for s in swaps:
                del plans[s]
            del by_apply[apply]
        else:
            interiors[apply] = interior
    # clearing declined tags keeps the invariant that a tag reaching
    # lower_dmp_to_comm means the split pass never ran (it warns there)
    for op in declined:
        del op.attributes["overlap"]
    if not plans:
        return func

    new_func = ir.FuncOp(func.sym_name, [a.type for a in func.body.args])
    block = new_func.body
    vmap: dict[ir.SSAValue, ir.SSAValue] = {}
    for oa, na in zip(func.body.args, new_func.body.args):
        vmap[oa] = na
    # in-flight state per tagged swap: padded value + round-1 patches
    pending: dict[dmp.SwapOp, dict] = {}

    for op in func.body.ops:
        if op in plans:
            pad = comm.HaloPadOp(
                vmap[op.temp], op.result_bounds, op.boundary, op.grid
            )
            block.add_op(pad)
            rounds = op.rounds()
            starts = [
                block.add_op(exchange_start_for(e, op, pad.results[0]))
                for e in rounds[0]
            ]
            pending[op] = {
                "padded": pad.results[0],
                "patches": [s.results[0] for s in starts],
                "later_rounds": rounds[1:],
            }
            continue
        if isinstance(op, stencil.ApplyOp) and op in by_apply:
            _emit_split_apply(
                block, op, by_apply[op], interiors[op], pending, vmap
            )
            continue
        block.add_op(op.clone_into(vmap))
    return new_func


def _emit_split_apply(block, apply, swaps, interior, pending, vmap) -> None:
    rb = apply.result_bounds
    padded_of = {s.results[0]: pending[s]["padded"] for s in swaps}

    # interior: padded-but-unexchanged operands — no dependence on waits
    pre_operands = [
        padded_of[o] if o in padded_of else vmap.get(o, o)
        for o in apply.operands
    ]
    interior_apply = _clone_apply(apply, pre_operands, interior, "interior")
    block.add_op(interior_apply)

    # waits (and any later sequential rounds), then the exchanged values
    exchanged_of: dict[ir.SSAValue, ir.SSAValue] = {}
    for s in swaps:
        st = pending.pop(s)
        wait = comm.WaitOp(st["padded"], st["patches"])
        block.add_op(wait)
        cur = emit_exchange_rounds(block, s, wait.results[0], st["later_rounds"])
        exchanged_of[s.results[0]] = cur
        vmap[s.results[0]] = cur

    # boundary frames on the fully exchanged operands; the frame widths
    # are whatever rb extends beyond the (possibly core-clipped) interior
    post_operands = [
        exchanged_of[o] if o in exchanged_of else vmap.get(o, o)
        for o in apply.operands
    ]
    eff_lo = [il - rl for il, rl in zip(interior.lb, rb.lb)]
    eff_hi = [ru - iu for ru, iu in zip(rb.ub, interior.ub)]
    frames = []
    for slab in frame_slabs(rb, eff_lo, eff_hi):
        frame = _clone_apply(apply, post_operands, slab, "frame")
        block.add_op(frame)
        frames.append(frame)

    # reassemble: interior + frames tile rb exactly
    for k, res in enumerate(apply.results):
        parts = [interior_apply.results[k]] + [f.results[k] for f in frames]
        combine = stencil.CombineOp(parts, rb, res.type.element_type)
        block.add_op(combine)
        vmap[res] = combine.results[0]


def _clone_apply(apply, operands, bounds, part: str) -> stencil.ApplyOp:
    new = stencil.ApplyOp(
        operands,
        bounds,
        n_results=len(apply.results),
        element_type=apply.results[0].type.element_type,
    )
    new.attributes["part"] = StringAttr(part)
    body_map: dict[ir.SSAValue, ir.SSAValue] = {}
    for oa, na in zip(apply.body.args, new.body.args):
        body_map[oa] = na
    for body_op in apply.body.ops:
        new.body.add_op(body_op.clone_into(body_map))
    return new


def frame_slabs(rb: stencil.Bounds, lo_w, hi_w) -> list:
    """Disjoint onion-peel partition of ``rb`` minus its interior."""
    rank = rb.rank
    slabs = []
    for d in range(rank):
        def bounds_for(d_lo, d_ub):
            lb, ub = [], []
            for k in range(rank):
                if k < d:
                    lb.append(rb.lb[k] + lo_w[k])
                    ub.append(rb.ub[k] - hi_w[k])
                elif k == d:
                    lb.append(d_lo)
                    ub.append(d_ub)
                else:
                    lb.append(rb.lb[k])
                    ub.append(rb.ub[k])
            return stencil.Bounds(tuple(lb), tuple(ub))

        if lo_w[d] > 0:
            slabs.append(bounds_for(rb.lb[d], rb.lb[d] + lo_w[d]))
        if hi_w[d] > 0:
            slabs.append(bounds_for(rb.ub[d] - hi_w[d], rb.ub[d]))
    return [s for s in slabs if all(x > 0 for x in s.shape)]
