"""Communication/computation overlap — **beyond-paper** (sec. 8 lists it as
future work: "Further work includes ... DMP/MPI optimizations, such as
diagonal communications ... and communication/computation overlap").

The rewrite is declarative: swaps whose results feed exactly one apply are
tagged ``overlap = true``; the JAX lowering then splits that apply into an
*interior* application (points whose accesses never touch the halo, i.e.
the core shrunk by the halo width) computed **between** ``exchange_start``
and ``wait``, and a *boundary frame* computed after the halos land.  With
the XLA latency-hiding scheduler, the ppermute(s) then ride under the
interior compute — the dataflow analogue of MPI_Isend/Irecv + interior
kernel + MPI_Waitall + boundary kernel.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.ir import IntAttr
from repro.core.dialects import dmp, stencil


def enable_comm_compute_overlap(func: ir.FuncOp) -> int:
    """Tag eligible swaps; returns how many were tagged."""
    n = 0
    for op in func.body.ops:
        if not isinstance(op, dmp.SwapOp):
            continue
        if not op.exchanges:
            continue
        consumers = {u.operation for u in op.results[0].uses}
        if len(consumers) == 1 and all(
            isinstance(c, stencil.ApplyOp) for c in consumers
        ):
            apply = next(iter(consumers))
            lo, hi = op.halo_widths()
            core = apply.result_bounds
            # interior must be non-empty in every dim
            if all(
                (u - h) - (l + lw) > 0
                for l, u, lw, h in zip(core.lb, core.ub, lo, hi)
            ):
                op.attributes["overlap"] = IntAttr(1)
                n += 1
    return n


def overlap_enabled(swap: dmp.SwapOp) -> bool:
    a = swap.attributes.get("overlap")
    return a is not None and a.value == 1  # type: ignore[union-attr]
