"""IR construction helpers.

``IRBuilder`` manages an insertion point; ``Expr`` gives stencil point
functions a natural arithmetic syntax (the frontends and tests build apply
bodies with it).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Union

from repro.core import ir
from repro.core.dialects import stencil


class IRBuilder:
    def __init__(self, block: ir.Block) -> None:
        self.block = block

    def insert(self, op: ir.Operation) -> ir.Operation:
        return self.block.add_op(op)

    # -- arith conveniences -------------------------------------------------
    def const(self, v: float, type=ir.f32) -> ir.SSAValue:
        return self.insert(ir.ConstantOp(v, type)).results[0]

    def add(self, a, b):
        return self.insert(ir.AddOp(a, b)).results[0]

    def sub(self, a, b):
        return self.insert(ir.SubOp(a, b)).results[0]

    def mul(self, a, b):
        return self.insert(ir.MulOp(a, b)).results[0]

    def div(self, a, b):
        return self.insert(ir.DivOp(a, b)).results[0]


Number = Union[int, float]


class Expr:
    """Arithmetic wrapper over SSA values for building apply bodies."""

    def __init__(self, builder: IRBuilder, value: ir.SSAValue) -> None:
        self.b = builder
        self.value = value

    def _coerce(self, other) -> "Expr":
        if isinstance(other, Expr):
            return other
        return Expr(self.b, self.b.const(float(other), self.value.type))

    def __add__(self, other):
        o = self._coerce(other)
        return Expr(self.b, self.b.add(self.value, o.value))

    __radd__ = __add__

    def __sub__(self, other):
        o = self._coerce(other)
        return Expr(self.b, self.b.sub(self.value, o.value))

    def __rsub__(self, other):
        o = self._coerce(other)
        return Expr(self.b, self.b.sub(o.value, self.value))

    def __mul__(self, other):
        o = self._coerce(other)
        return Expr(self.b, self.b.mul(self.value, o.value))

    __rmul__ = __mul__

    def __truediv__(self, other):
        o = self._coerce(other)
        return Expr(self.b, self.b.div(self.value, o.value))

    def __rtruediv__(self, other):
        o = self._coerce(other)
        return Expr(self.b, self.b.div(o.value, self.value))

    def __neg__(self):
        return Expr(self.b, self.b.insert(ir.NegOp(self.value)).results[0])


class ApplyArgHandle:
    """Handle to a stencil.apply operand inside the point function: ``u.at(±k)``."""

    def __init__(self, builder: IRBuilder, block_arg: ir.BlockArgument) -> None:
        self.b = builder
        self.arg = block_arg

    def at(self, *offset: int) -> Expr:
        assert isinstance(self.arg.type, stencil.TempType)
        rank = self.arg.type.rank
        if len(offset) == 1 and rank != 1 and isinstance(offset[0], (tuple, list)):
            offset = tuple(offset[0])
        assert len(offset) == rank, f"offset rank {len(offset)} != temp rank {rank}"
        acc = self.b.insert(stencil.AccessOp(self.arg, offset))
        return Expr(self.b, acc.results[0])

    def center(self) -> Expr:
        return self.at(*([0] * self.arg.type.rank))


def build_apply(
    parent: ir.Block,
    args: Sequence[ir.SSAValue],
    result_bounds: stencil.Bounds,
    point_fn: Callable[..., Union[Expr, Sequence[Expr]]],
    n_results: Optional[int] = None,
) -> ir.Operation:
    """Create a stencil.apply whose body is built by ``point_fn``.

    ``point_fn(builder, *handles)`` returns one Expr (or a sequence) — the
    value(s) of the stencil at the current point.
    """
    elem = args[0].type.element_type if args else ir.f32
    apply_op = stencil.ApplyOp(
        args, result_bounds, n_results=n_results or 1, element_type=elem
    )
    b = IRBuilder(apply_op.body)
    handles = [ApplyArgHandle(b, a) for a in apply_op.body.args]
    out = point_fn(b, *handles)
    outs = out if isinstance(out, (tuple, list)) else [out]
    if n_results is None and len(outs) != 1:
        # rebuild with correct arity
        apply_op2 = stencil.ApplyOp(
            args, result_bounds, n_results=len(outs), element_type=elem
        )
        b2 = IRBuilder(apply_op2.body)
        handles2 = [ApplyArgHandle(b2, a) for a in apply_op2.body.args]
        out2 = point_fn(b2, *handles2)
        outs2 = list(out2) if isinstance(out2, (tuple, list)) else [out2]
        b2.insert(stencil.StencilReturnOp([e.value for e in outs2]))
        parent.add_op(apply_op2)
        return apply_op2
    b.insert(stencil.StencilReturnOp([e.value for e in outs]))
    parent.add_op(apply_op)
    return apply_op
