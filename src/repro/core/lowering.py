"""Executing the comm-level IR as JAX (paper secs. 4.3 & 5).

The paper lowers ``stencil`` → ``dmp`` → ``mpi`` → LLVM calls.  Here the
final target is XLA: the rank-local function — **after** the canonical
dmp→comm lowering (``core/passes/lower_comm.py``), so it contains comm
ops, never ``dmp.swap`` — is *interpreted into a JAX trace* (every IR op
becomes jnp/lax primitives), the exchanges become ``lax.ppermute`` inside
``jax.shard_map``, and XLA compiles the result.  Two compute backends
share the interpreter's body evaluator:

- ``jnp``    — shifted ``lax.slice`` reads, fused by XLA (the reference);
- ``pallas`` — each ``stencil.apply`` is code-generated into a Pallas TPU
  kernel with explicit BlockSpec VMEM tiling (``repro.kernels``), the TPU
  analogue of the paper's GPU/FPGA backends.

Halo-exchange execution model (DESIGN.md §2) — one op-dispatch level,
one path:

- ``comm.halo_pad``       → boundary-condition pad (zeros, or wrap for
                            periodic dims that are not decomposed);
- ``comm.exchange_start`` → extract the send rectangle, ``lax.ppermute``
                            it toward ``-shift`` (pairs built by the
                            shared ``comm.permute_pairs``);
- ``comm.wait``           → insert received patches
                            (``lax.dynamic_update_slice``);
- ``stencil.combine``     → reassemble split (overlapped) applies.

Comm/compute overlap is *not* a runtime special case: the
``split_overlapped_applies`` pass expresses it in the IR, and the
interpreter just executes what it sees.  Grid axes of size 1 run a local
emulation (self-exchange for periodic wrap, no-op for zero BC), so the
single-device reference path runs the same comm-level program unchanged.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ir
from repro.core.dialects import comm, dmp, stencil
from repro.obs import trace as _obs

# Backwards-compatible re-export: the lowering pass moved to core/passes.
from repro.core.passes.lower_comm import lower_dmp_to_comm  # noqa: F401

# --------------------------------------------------------------------------
# Shared point-function evaluator
# --------------------------------------------------------------------------


def eval_apply_body(
    apply_op: stencil.ApplyOp,
    operand_arrays: Sequence[Any],
    operand_origins: Sequence[tuple],
    result_bounds: stencil.Bounds,
) -> list:
    """Evaluate an apply's point function vectorized over ``result_bounds``.

    ``operand_arrays[k]`` covers logical coords starting at
    ``operand_origins[k]``; an access at offset ``o`` of operand ``k``
    becomes a static slice — identical code runs on jnp arrays (XLA
    backend) and on VMEM blocks inside a Pallas kernel.
    """
    rb = result_bounds
    shape = rb.shape
    env: dict[ir.SSAValue, Any] = {}

    def operand_slice(k: int, offset: tuple):
        start = tuple(
            rl + o - og for rl, o, og in zip(rb.lb, offset, operand_origins[k])
        )
        arr = operand_arrays[k]
        return lax.slice(arr, start, tuple(s + n for s, n in zip(start, shape)))

    for op in apply_op.body.ops:
        if isinstance(op, stencil.AccessOp):
            env[op.results[0]] = operand_slice(op.temp.index, op.offset)
        elif isinstance(op, stencil.IndexOp):
            d = op.dim
            io = lax.broadcasted_iota(jnp.float32, shape, d)
            env[op.results[0]] = io + jnp.float32(rb.lb[d])
        elif isinstance(op, ir.ConstantOp):
            env[op.results[0]] = jnp.float32(op.value)
        elif isinstance(op, ir.AddOp):
            env[op.results[0]] = env[op.operands[0]] + env[op.operands[1]]
        elif isinstance(op, ir.SubOp):
            env[op.results[0]] = env[op.operands[0]] - env[op.operands[1]]
        elif isinstance(op, ir.MulOp):
            env[op.results[0]] = env[op.operands[0]] * env[op.operands[1]]
        elif isinstance(op, ir.DivOp):
            env[op.results[0]] = env[op.operands[0]] / env[op.operands[1]]
        elif isinstance(op, ir.NegOp):
            env[op.results[0]] = -env[op.operands[0]]
        elif isinstance(op, ir.AbsOp):
            env[op.results[0]] = jnp.abs(env[op.operands[0]])
        elif isinstance(op, ir.SqrtOp):
            env[op.results[0]] = jnp.sqrt(env[op.operands[0]])
        elif isinstance(op, ir.ExpOp):
            env[op.results[0]] = jnp.exp(env[op.operands[0]])
        elif isinstance(op, ir.SelectGeZeroOp):
            p, a, b = (env[o] for o in op.operands)
            env[op.results[0]] = jnp.where(p >= 0, a, b)
        elif isinstance(op, stencil.StencilReturnOp):
            return [
                jnp.broadcast_to(env[o], shape)
                for o in op.operands
            ]
        else:
            raise NotImplementedError(f"apply body op {op.name}")
    raise AssertionError("apply body missing stencil.return")


# --------------------------------------------------------------------------
# Boundary-condition fill
# --------------------------------------------------------------------------


def _pad_with_bc(x, lo: tuple, hi: tuple, grid: dmp.GridAttr, boundary: str):
    """Grow ``x`` by halo widths; wrap-fill periodic *undecomposed* dims
    locally, everything else zeros (decomposed dims are filled by
    exchanges; zero-BC edges stay zero because non-cyclic permutes leave
    non-receivers untouched)."""
    rank = x.ndim
    if boundary == "periodic":
        wrap_dims = [
            d
            for d in range(rank)
            if grid.axis_of_dim(d) is None and (lo[d] or hi[d])
        ]
        if wrap_dims:
            pad_widths = [
                (lo[d], hi[d]) if d in wrap_dims else (0, 0) for d in range(rank)
            ]
            x = jnp.pad(x, pad_widths, mode="wrap")
        zero_widths = [
            (0, 0) if d in wrap_dims else (lo[d], hi[d]) for d in range(rank)
        ]
        if any(w != (0, 0) for w in zero_widths):
            x = jnp.pad(x, zero_widths)
        return x
    pad_widths = [(lo[d], hi[d]) for d in range(rank)]
    if any(w != (0, 0) for w in pad_widths):
        x = jnp.pad(x, pad_widths)
    return x


# --------------------------------------------------------------------------
# Function interpreter — one op-dispatch level, comm ops only
# --------------------------------------------------------------------------


class StencilInterpreter:
    """Interprets a rank-local, comm-lowered stencil function into a JAX
    computation.

    Calling convention: positional arrays for every *field* argument of the
    function; returns the updated arrays of every stored-to field, in
    first-store order.  ``dmp.swap`` is rejected — run the dmp→comm
    pipeline (``lower-comm``) first.
    """

    def __init__(
        self,
        func: ir.FuncOp,
        axis_sizes: dict[str, int],
        distributed: bool,
        backend: str = "jnp",
        pallas_interpret: bool = True,
        pallas_tile: Optional[tuple] = None,
    ) -> None:
        assert backend in ("jnp", "pallas")
        self.func = func
        self.axis_sizes = dict(axis_sizes)
        self.distributed = distributed
        self.backend = backend
        self.pallas_interpret = pallas_interpret
        self.pallas_tile = pallas_tile
        self.output_fields: list[ir.SSAValue] = []
        for op in func.body.ops:
            if isinstance(op, stencil.StoreOp) and op.field not in self.output_fields:
                self.output_fields.append(op.field)
        # obs: one track is traced for every rank (SPMD), tagged with the
        # rank count so the exporter can replicate spans honestly
        self._n_ranks = 1
        for n in self.axis_sizes.values():
            self._n_ranks *= int(n)
        # open exchange windows: ExchangeStartOp result -> obs token,
        # closed by the WaitOp consuming that patch (reset per call)
        self._open_exchanges: dict = {}

    # -- public --------------------------------------------------------
    def __call__(self, *arrays):
        args = [a for a in self.func.body.args]
        fields = [a for a in args if isinstance(a.type, stencil.FieldType)]
        assert len(arrays) == len(fields), (
            f"expected {len(fields)} field arrays, got {len(arrays)}"
        )
        env: dict[ir.SSAValue, Any] = {}
        field_state: dict[ir.SSAValue, Any] = {}
        self._open_exchanges = {}
        for arg, arr in zip(fields, arrays):
            expect = arg.type.bounds.shape
            assert tuple(arr.shape) == tuple(expect), (
                f"field {arg.name_hint}: array shape {arr.shape} != local "
                f"bounds shape {expect}"
            )
            field_state[arg] = arr

        for op in self.func.body.ops:
            self._exec(op, env, field_state)
        return tuple(field_state[f] for f in self.output_fields)

    # -- op execution ---------------------------------------------------
    def _exec(self, op: ir.Operation, env, field_state) -> None:
        if isinstance(op, stencil.LoadOp):
            env[op.results[0]] = field_state[op.field]
        elif isinstance(op, stencil.ApplyOp):
            rb = op.result_bounds
            arrays = [env[o] for o in op.operands]
            origins = [o.type.bounds.lb for o in op.operands]
            if _obs.enabled():
                part = op.attributes.get("part")
                name = f"apply:{part.value if part is not None else 'full'}"
                with _obs.span(name, cat="compute", rank=None,
                               ranks=self._n_ranks, shape=list(rb.shape)):
                    outs = self._apply_backend(op, arrays, origins, rb)
            else:
                outs = self._apply_backend(op, arrays, origins, rb)
            for res, arr in zip(op.results, outs):
                env[res] = arr
        elif isinstance(op, stencil.CombineOp):
            env[op.results[0]] = self._exec_combine(op, env)
        elif isinstance(op, stencil.StoreOp):
            temp = env[op.temp]
            field_arr = field_state[op.field]
            tb: stencil.Bounds = op.temp.type.bounds
            fb: stencil.Bounds = op.field.type.bounds
            sb: stencil.Bounds = op.bounds
            start = tuple(s - t for s, t in zip(sb.lb, tb.lb))
            patch = lax.slice(
                temp, start, tuple(s + n for s, n in zip(start, sb.shape))
            )
            dst = tuple(s - f for s, f in zip(sb.lb, fb.lb))
            if sb == fb:
                field_state[op.field] = patch
            else:
                field_state[op.field] = lax.dynamic_update_slice(
                    field_arr, patch, dst
                )
        elif isinstance(op, comm.HaloPadOp):
            env[op.results[0]] = _exec_halo_pad(op, env[op.operands[0]])
        elif isinstance(op, comm.ExchangeStartOp):
            env[op.results[0]] = self._exec_comm_start(op, env[op.temp])
            if _obs.enabled():
                # the exchange window closes at the wait consuming this
                # patch; putting it on the comm lane lets Perfetto show
                # it overlapping the interior apply that hides it
                self._open_exchanges[op.results[0]] = _obs.begin_window(
                    "comm.exchange", cat="comm", rank=None,
                    ranks=self._n_ranks, size=list(op.size),
                )
        elif isinstance(op, comm.WaitOp):
            self._exec_comm_wait(op, env)
            if _obs.enabled():
                for p in op.patches:
                    _obs.end_window(self._open_exchanges.pop(p, None))
        elif isinstance(op, comm.BoundaryMaskOp):
            env[op.results[0]] = self._exec_boundary_mask(op, env[op.temp])
        elif isinstance(op, stencil.FusedEpochOp):
            if _obs.enabled():
                with _obs.span("fused_epoch", cat="compute", rank=None,
                               ranks=self._n_ranks, backend=self.backend):
                    self._exec_fused_epoch(op, env)
            else:
                self._exec_fused_epoch(op, env)
        elif isinstance(op, comm.AllReduceOp):
            v = env[op.operands[0]]
            red = {"sum": lax.psum, "max": lax.pmax, "min": lax.pmin}[op.op]
            env[op.results[0]] = (
                red(v, tuple(op.axes)) if self.distributed else v
            )
        elif isinstance(op, ir.ReturnOp):
            pass
        elif isinstance(op, dmp.SwapOp):
            raise NotImplementedError(
                "dmp.swap reached the interpreter — run the canonical "
                "dmp→comm pipeline (lower-comm pass) before execution"
            )
        else:
            raise NotImplementedError(f"function-level op {op.name}")

    # -- apply backends -------------------------------------------------
    def _apply_backend(self, op, arrays, origins, rb):
        part = op.attributes.get("part")
        if self.backend == "pallas" and (
            part is None or part.value == "interior"
        ):
            from repro.kernels.stencil_apply import run_apply_pallas

            tile = self.pallas_tile
            # a split interior (or an epoch-tiled apply, whose grown frame
            # changes the shape per step) may not fit the user tile —
            # auto-tile it; unsplit applies keep run_apply_pallas's loud
            # divisibility assert so a misconfigured pallas_tile stays
            # diagnosable
            if (
                (part is not None or "epoch_step" in op.attributes)
                and tile is not None
                and any(s % t != 0 for s, t in zip(rb.shape, tile))
            ):
                tile = None
            return run_apply_pallas(
                op,
                arrays,
                origins,
                rb,
                tile=tile,
                interpret=self.pallas_interpret,
            )
        # thin boundary frames go through the jnp evaluator: identical
        # elementwise arithmetic, no per-slab kernel launch
        return eval_apply_body(op, arrays, origins, rb)

    def _exec_combine(self, op: stencil.CombineOp, env):
        rb = op.result_bounds
        parts = [env[o] for o in op.operands]
        out = jnp.zeros(rb.shape, parts[0].dtype)
        for val, part in zip(op.operands, parts):
            idx = tuple(l - b for l, b in zip(val.type.bounds.lb, rb.lb))
            out = lax.dynamic_update_slice(out, part, idx)
        return out

    # -- comm ops (the mpi-level execution path) -------------------------
    def _exec_comm_start(self, op: comm.ExchangeStartOp, x):
        origin = op.temp.type.bounds.lb
        idx = tuple(o - g for o, g in zip(op.send_offset, origin))
        patch = lax.slice(
            x, idx, tuple(i + s for i, s in zip(idx, op.size))
        )
        periodic = bool(op.attributes.get("periodic", ir.IntAttr(0)).value)
        if self.distributed:
            axis_arg, pairs = comm.permute_pairs(
                op.axis_shifts, self.axis_sizes, periodic
            )
            return lax.ppermute(patch, axis_arg, pairs)
        # local emulation: every grid axis has size 1
        return patch if periodic else jnp.zeros_like(patch)

    def _boundary_keep(self, op: comm.BoundaryMaskOp, shape: tuple):
        """Boolean keep-mask over ``shape`` for a boundary_mask op (True =
        inside the physical global domain), or ``None`` when every point
        is inside.  Rank-position-aware (lax.axis_index) but
        communication-free — shared by the inline interpreter path and the
        fused-epoch kernel, which precomputes the mask outside the kernel
        (axis_index is unavailable in a Pallas body)."""
        vb: stencil.Bounds = op.temp.type.bounds
        core: stencil.Bounds = op.core
        grid: dmp.GridAttr = op.grid
        keep = None
        for d in range(vb.rank):
            if core.lb[d] <= vb.lb[d] and vb.ub[d] <= core.ub[d]:
                continue  # no points outside this shard's core along d
            gax = grid.axis_of_dim(d)
            n = core.ub[d] - core.lb[d]
            grid_extent = grid.shape[gax] if gax is not None else 1
            if self.distributed and gax is not None and grid_extent > 1:
                coord = lax.axis_index(grid.axis_names[gax])
            else:
                coord = 0
            pos = lax.broadcasted_iota(jnp.int32, shape, d) + jnp.int32(
                vb.lb[d] - core.lb[d]
            )
            glob = coord * n + pos
            k = (glob >= 0) & (glob < grid_extent * n)
            keep = k if keep is None else keep & k
        return keep

    def _exec_boundary_mask(self, op: comm.BoundaryMaskOp, x):
        """Zero every point outside the physical (global) domain — the
        temporal-tiling analogue of the zero-BC halo_pad, applied to
        redundantly-computed epoch intermediates."""
        keep = self._boundary_keep(op, tuple(x.shape))
        if keep is None:
            return x
        return jnp.where(keep, x, jnp.zeros_like(x))

    def _exec_fused_epoch(self, op: stencil.FusedEpochOp, env) -> None:
        """Route a fused epoch through the megakernel (pallas backend) or
        evaluate its region inline (jnp reference).  Boundary keep-masks
        are materialized as 0/1 arrays here — outside the kernel — and
        passed in as extra inputs."""
        arrays = [env[o] for o in op.operands]
        masks = []
        for inner in op.body.ops:
            if isinstance(inner, comm.BoundaryMaskOp):
                shape = inner.temp.type.bounds.shape
                keep = self._boundary_keep(inner, shape)
                masks.append(
                    jnp.ones(shape, jnp.float32)
                    if keep is None
                    else keep.astype(jnp.float32)
                )
        if self.backend == "pallas":
            from repro.kernels.epoch_kernel import run_epoch_pallas

            outs = run_epoch_pallas(
                op,
                arrays,
                masks,
                tile=self.pallas_tile,
                interpret=self.pallas_interpret,
            )
        else:
            from repro.kernels.epoch_kernel import _emit_region

            outs = _emit_region(
                op,
                [jnp.asarray(a, jnp.float32) for a in arrays],
                masks,
                lambda v: v.type.bounds,
            )
        for res, arr in zip(op.results, outs):
            env[res] = arr

    def _exec_comm_wait(self, op: comm.WaitOp, env) -> None:
        x = env[op.temp]
        origin = op.temp.type.bounds.lb
        for p in op.patches:
            patch = env[p]
            rect: stencil.Bounds = p.type.bounds
            idx = tuple(o - g for o, g in zip(rect.lb, origin))
            x = lax.dynamic_update_slice(x, patch, idx)
        env[op.results[0]] = x


def _exec_halo_pad(op: comm.HaloPadOp, x):
    ib: stencil.Bounds = op.operands[0].type.bounds
    ob: stencil.Bounds = op.results[0].type.bounds
    lo = tuple(i - o for i, o in zip(ib.lb, ob.lb))
    hi = tuple(o - i for o, i in zip(ob.ub, ib.ub))
    return _pad_with_bc(
        x, lo, hi, op.attributes["grid"], op.attributes["boundary"].value
    )


def run_func_dataflow(
    func: ir.FuncOp,
    inputs: Sequence[Any],
    axis_sizes: dict[str, int],
    distributed: bool,
) -> tuple:
    """Execute a *value-returning* comm-level function (temp args in,
    ``func.return`` values out) — the entry point ``repro.dist`` uses to
    run its sequence-halo exchanges through the one shared executor."""
    interp = StencilInterpreter(
        func, axis_sizes=axis_sizes, distributed=distributed
    )
    env: dict[ir.SSAValue, Any] = dict(zip(func.body.args, inputs))
    for op in func.body.ops:
        if isinstance(op, ir.ReturnOp):
            return tuple(env[o] for o in op.operands)
        interp._exec(op, env, {})
    raise AssertionError(f"{func.sym_name}: missing func.return")


# Backwards-compatible alias: HaloPadOp moved into the comm dialect.
HaloPadOp = comm.HaloPadOp
