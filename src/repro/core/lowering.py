"""Lowering the stencil+dmp IR to executable JAX (paper secs. 4.3 & 5).

The paper lowers ``stencil`` → ``dmp`` → ``mpi`` → LLVM calls.  Here the
final target is XLA: the rank-local function is *interpreted into a JAX
trace* (every IR op becomes jnp/lax primitives), the exchanges become
``lax.ppermute`` inside ``jax.shard_map``, and XLA compiles the result.
Two compute backends share the interpreter's body evaluator:

- ``jnp``    — shifted ``lax.slice`` reads, fused by XLA (the reference);
- ``pallas`` — each ``stencil.apply`` is code-generated into a Pallas TPU
  kernel with explicit BlockSpec VMEM tiling (``repro.kernels``), the TPU
  analogue of the paper's GPU/FPGA backends.

Halo-exchange execution model (DESIGN.md §2): ``dmp.swap`` becomes
  1. a *boundary-condition pad* (zeros, or wrap for periodic dims that are
     not decomposed),
  2. per-round ``ppermute`` *starts* — one per ExchangeDecl — each sending
     the decl's send-rectangle to the declared neighbour, and
  3. *waits* that insert received patches (``lax.dynamic_update_slice``).
Sequential schedules chain rounds through dataflow (corner forwarding);
concurrent schedules issue every permute independently.  Swaps tagged by
the overlap pass defer their waits until the consumer's *interior* has
been computed, so the collective rides under the interior compute.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ir
from repro.core.dialects import comm, dmp, stencil
from repro.core.passes.overlap import overlap_enabled

# --------------------------------------------------------------------------
# Shared point-function evaluator
# --------------------------------------------------------------------------


def eval_apply_body(
    apply_op: stencil.ApplyOp,
    operand_arrays: Sequence[Any],
    operand_origins: Sequence[tuple],
    result_bounds: stencil.Bounds,
) -> list:
    """Evaluate an apply's point function vectorized over ``result_bounds``.

    ``operand_arrays[k]`` covers logical coords starting at
    ``operand_origins[k]``; an access at offset ``o`` of operand ``k``
    becomes a static slice — identical code runs on jnp arrays (XLA
    backend) and on VMEM blocks inside a Pallas kernel.
    """
    rb = result_bounds
    shape = rb.shape
    env: dict[ir.SSAValue, Any] = {}

    def operand_slice(k: int, offset: tuple):
        start = tuple(
            rl + o - og for rl, o, og in zip(rb.lb, offset, operand_origins[k])
        )
        arr = operand_arrays[k]
        return lax.slice(arr, start, tuple(s + n for s, n in zip(start, shape)))

    for op in apply_op.body.ops:
        if isinstance(op, stencil.AccessOp):
            env[op.results[0]] = operand_slice(op.temp.index, op.offset)
        elif isinstance(op, stencil.IndexOp):
            d = op.dim
            io = lax.broadcasted_iota(jnp.float32, shape, d)
            env[op.results[0]] = io + jnp.float32(rb.lb[d])
        elif isinstance(op, ir.ConstantOp):
            env[op.results[0]] = jnp.float32(op.value)
        elif isinstance(op, ir.AddOp):
            env[op.results[0]] = env[op.operands[0]] + env[op.operands[1]]
        elif isinstance(op, ir.SubOp):
            env[op.results[0]] = env[op.operands[0]] - env[op.operands[1]]
        elif isinstance(op, ir.MulOp):
            env[op.results[0]] = env[op.operands[0]] * env[op.operands[1]]
        elif isinstance(op, ir.DivOp):
            env[op.results[0]] = env[op.operands[0]] / env[op.operands[1]]
        elif isinstance(op, ir.NegOp):
            env[op.results[0]] = -env[op.operands[0]]
        elif isinstance(op, ir.AbsOp):
            env[op.results[0]] = jnp.abs(env[op.operands[0]])
        elif isinstance(op, ir.SqrtOp):
            env[op.results[0]] = jnp.sqrt(env[op.operands[0]])
        elif isinstance(op, ir.ExpOp):
            env[op.results[0]] = jnp.exp(env[op.operands[0]])
        elif isinstance(op, ir.SelectGeZeroOp):
            p, a, b = (env[o] for o in op.operands)
            env[op.results[0]] = jnp.where(p >= 0, a, b)
        elif isinstance(op, stencil.StencilReturnOp):
            return [
                jnp.broadcast_to(env[o], shape)
                for o in op.operands
            ]
        else:
            raise NotImplementedError(f"apply body op {op.name}")
    raise AssertionError("apply body missing stencil.return")


# --------------------------------------------------------------------------
# Exchange execution (dmp.swap / comm ops → pad + ppermute + insert)
# --------------------------------------------------------------------------


def _perm_for(
    neighbor: tuple,
    grid: dmp.GridAttr,
    axis_sizes: dict[str, int],
    periodic: bool,
) -> tuple[tuple[str, ...], list[tuple[int, int]]]:
    """ppermute permutation for one ExchangeDecl.

    Receiver ``me`` takes data from rank ``me + neighbor`` ⇒ sender ``r``
    delivers to ``r - neighbor``.  Multi-axis neighbours use a linearized
    permutation over the tuple of mesh axes (diagonal exchanges).
    """
    active = [(g, step) for g, step in enumerate(neighbor) if step != 0]
    names = tuple(grid.axis_names[g] for g, _ in active)
    sizes = [axis_sizes[n] for n in names]
    steps = [s for _, s in active]
    total = math.prod(sizes)
    pairs: list[tuple[int, int]] = []
    for lin in range(total):
        # unflatten row-major
        rem, coords = lin, []
        for sz in reversed(sizes):
            coords.append(rem % sz)
            rem //= sz
        coords = coords[::-1]
        dst = [c - s for c, s in zip(coords, steps)]
        if periodic:
            dst = [d % sz for d, sz in zip(dst, sizes)]
        elif any(d < 0 or d >= sz for d, sz in zip(dst, sizes)):
            continue
        lin_dst = 0
        for d, sz in zip(dst, sizes):
            lin_dst = lin_dst * sz + d
        pairs.append((lin, lin_dst))
    axis_arg = names[0] if len(names) == 1 else names
    return axis_arg, pairs


def _pad_with_bc(x, lo: tuple, hi: tuple, grid: dmp.GridAttr, boundary: str):
    """Grow ``x`` by halo widths; wrap-fill periodic *undecomposed* dims
    locally, everything else zeros (decomposed dims are filled by
    exchanges; zero-BC edges stay zero because non-cyclic permutes leave
    non-receivers untouched)."""
    rank = x.ndim
    if boundary == "periodic":
        wrap_dims = [
            d
            for d in range(rank)
            if grid.axis_of_dim(d) is None and (lo[d] or hi[d])
        ]
        if wrap_dims:
            pad_widths = [
                (lo[d], hi[d]) if d in wrap_dims else (0, 0) for d in range(rank)
            ]
            x = jnp.pad(x, pad_widths, mode="wrap")
        zero_widths = [
            (0, 0) if d in wrap_dims else (lo[d], hi[d]) for d in range(rank)
        ]
        if any(w != (0, 0) for w in zero_widths):
            x = jnp.pad(x, zero_widths)
        return x
    pad_widths = [(lo[d], hi[d]) for d in range(rank)]
    if any(w != (0, 0) for w in pad_widths):
        x = jnp.pad(x, pad_widths)
    return x


def _rounds(swap: dmp.SwapOp) -> list[list[dmp.ExchangeDecl]]:
    """Group exchanges into dependency rounds.

    Sequential: one round per grid axis, in sweep order (later rounds read
    halos written by earlier ones — corner forwarding).  Concurrent: all
    exchanges in one round.
    """
    if swap.schedule == "concurrent":
        return [list(swap.exchanges)]
    rounds: dict[int, list[dmp.ExchangeDecl]] = {}
    for e in swap.exchanges:
        active = [g for g, s in enumerate(e.neighbor) if s != 0]
        assert len(active) == 1, "sequential schedule expects face exchanges"
        rounds.setdefault(active[0], []).append(e)
    return [rounds[g] for g in sorted(rounds)]


@dataclass
class ExchangeRuntime:
    """How exchanges execute: distributed (inside shard_map, via ppermute)
    or local emulation (grid axes of size 1 — self-exchange for periodic
    wrap, no-op for zero BC)."""

    axis_sizes: dict[str, int]
    distributed: bool

    def start(
        self,
        x,
        decl: dmp.ExchangeDecl,
        grid: dmp.GridAttr,
        origin: tuple,
        periodic: bool,
        core_shape: tuple,
    ):
        # every rank extracts the mirror of the recv rect (uniform SPMD) and
        # permutes it toward -neighbor; the receiver's recv rect gets filled
        ext = decl.extract_offset(grid, core_shape)
        idx = tuple(o - g for o, g in zip(ext, origin))
        patch = lax.slice(x, idx, tuple(i + s for i, s in zip(idx, decl.send_size)))
        if self.distributed:
            axis_arg, pairs = _perm_for(decl.neighbor, grid, self.axis_sizes, periodic)
            return lax.ppermute(patch, axis_arg, pairs)
        # local emulation: every grid axis has size 1
        if periodic:
            return patch  # self-neighbour wrap
        return jnp.zeros_like(patch)

    def wait_insert(self, x, decl: dmp.ExchangeDecl, patch, origin: tuple):
        idx = tuple(o - g for o, g in zip(decl.recv_offset, origin))
        return lax.dynamic_update_slice(x, patch, idx)


def exec_swap_exchanges(x, swap: dmp.SwapOp, rt: ExchangeRuntime):
    """Run all exchange rounds of a (already padded) swap result."""
    origin = swap.result_bounds.lb
    core_shape = swap.temp.type.bounds.shape
    periodic = swap.boundary == "periodic"
    for rnd in _rounds(swap):
        patches = [
            rt.start(x, e, swap.grid, origin, periodic, core_shape) for e in rnd
        ]
        for e, p in zip(rnd, patches):
            x = rt.wait_insert(x, e, p, origin)
    return x


# --------------------------------------------------------------------------
# Deferred (overlapped) swaps
# --------------------------------------------------------------------------


@dataclass
class PendingSwap:
    """A swap whose exchanges have been *started* but not yet inserted.

    ``padded`` holds the BC-padded core (halos zero/wrapped); consumers may
    compute interior points from it immediately.  ``finish`` inserts the
    in-flight patches.
    """

    swap: dmp.SwapOp
    padded: Any
    rt: ExchangeRuntime

    def finish(self):
        return exec_swap_exchanges(self.padded, self.swap, self.rt)


# --------------------------------------------------------------------------
# Function interpreter
# --------------------------------------------------------------------------


class StencilInterpreter:
    """Interprets a rank-local stencil function into a JAX computation.

    Calling convention: positional arrays for every *field* argument of the
    function; returns the updated arrays of every stored-to field, in
    first-store order.
    """

    def __init__(
        self,
        func: ir.FuncOp,
        axis_sizes: dict[str, int],
        distributed: bool,
        backend: str = "jnp",
        pallas_interpret: bool = True,
        pallas_tile: Optional[tuple] = None,
    ) -> None:
        assert backend in ("jnp", "pallas")
        self.func = func
        self.rt = ExchangeRuntime(axis_sizes, distributed)
        self.backend = backend
        self.pallas_interpret = pallas_interpret
        self.pallas_tile = pallas_tile
        self.output_fields: list[ir.SSAValue] = []
        for op in func.body.ops:
            if isinstance(op, stencil.StoreOp) and op.field not in self.output_fields:
                self.output_fields.append(op.field)

    # -- public --------------------------------------------------------
    def __call__(self, *arrays):
        args = [a for a in self.func.body.args]
        fields = [a for a in args if isinstance(a.type, stencil.FieldType)]
        assert len(arrays) == len(fields), (
            f"expected {len(fields)} field arrays, got {len(arrays)}"
        )
        env: dict[ir.SSAValue, Any] = {}
        field_state: dict[ir.SSAValue, Any] = {}
        for arg, arr in zip(fields, arrays):
            expect = arg.type.bounds.shape
            assert tuple(arr.shape) == tuple(expect), (
                f"field {arg.name_hint}: array shape {arr.shape} != local "
                f"bounds shape {expect}"
            )
            field_state[arg] = arr

        for op in self.func.body.ops:
            self._exec(op, env, field_state)
        return tuple(field_state[f] for f in self.output_fields)

    # -- op execution ---------------------------------------------------
    def _exec(self, op: ir.Operation, env, field_state) -> None:
        if isinstance(op, stencil.LoadOp):
            env[op.results[0]] = field_state[op.field]
        elif isinstance(op, dmp.SwapOp):
            x = self._resolve(env[op.temp])
            lo, hi = op.halo_widths()
            padded = _pad_with_bc(x, lo, hi, op.grid, op.boundary)
            if overlap_enabled(op):
                env[op.results[0]] = PendingSwap(op, padded, self.rt)
            else:
                env[op.results[0]] = exec_swap_exchanges(padded, op, self.rt)
        elif isinstance(op, stencil.ApplyOp):
            self._exec_apply(op, env)
        elif isinstance(op, stencil.StoreOp):
            temp = self._resolve(env[op.temp])
            field_arr = field_state[op.field]
            tb: stencil.Bounds = op.temp.type.bounds
            fb: stencil.Bounds = op.field.type.bounds
            sb: stencil.Bounds = op.bounds
            start = tuple(s - t for s, t in zip(sb.lb, tb.lb))
            patch = lax.slice(
                temp, start, tuple(s + n for s, n in zip(start, sb.shape))
            )
            dst = tuple(s - f for s, f in zip(sb.lb, fb.lb))
            if sb == fb:
                field_state[op.field] = patch
            else:
                field_state[op.field] = lax.dynamic_update_slice(
                    field_arr, patch, dst
                )
        elif isinstance(op, HaloPadOp):
            env[op.results[0]] = _exec_halo_pad(
                op, self._resolve(env[op.operands[0]])
            )
        elif isinstance(op, comm.ExchangeStartOp):
            self._exec_comm_start(op, env)
        elif isinstance(op, comm.WaitOp):
            self._exec_comm_wait(op, env)
        elif isinstance(op, comm.AllReduceOp):
            v = self._resolve(env[op.operands[0]])
            red = {"sum": lax.psum, "max": lax.pmax, "min": lax.pmin}[op.op]
            env[op.results[0]] = (
                red(v, tuple(op.axes)) if self.rt.distributed else v
            )
        elif isinstance(op, ir.ReturnOp):
            pass
        else:
            raise NotImplementedError(f"function-level op {op.name}")

    def _resolve(self, v):
        return v.finish() if isinstance(v, PendingSwap) else v

    # -- apply ----------------------------------------------------------
    def _exec_apply(self, op: stencil.ApplyOp, env) -> None:
        rb = op.result_bounds
        raw = [env[o] for o in op.operands]
        pending = [i for i, r in enumerate(raw) if isinstance(r, PendingSwap)]
        if not pending:
            origins = [o.type.bounds.lb for o in op.operands]
            outs = self._apply_backend(op, raw, origins, rb)
            for res, arr in zip(op.results, outs):
                env[res] = arr
            return

        # --- overlapped path: interior on in-flight data, frame after wait
        exts = op.access_extents()
        rank = rb.rank
        lo = [0] * rank
        hi = [0] * rank
        for _, (l, h) in exts.items():
            lo = [min(a, b) for a, b in zip(lo, l)]
            hi = [max(a, b) for a, b in zip(hi, h)]
        lo_w = [-l for l in lo]
        hi_w = list(hi)
        interior = stencil.Bounds(
            tuple(b + w for b, w in zip(rb.lb, lo_w)),
            tuple(b - w for b, w in zip(rb.ub, hi_w)),
        )
        origins = [o.type.bounds.lb for o in op.operands]
        # interior uses the padded-but-unexchanged arrays: all its accesses
        # stay within the core, which is valid before the waits land.
        pre_arrays = [
            r.padded if isinstance(r, PendingSwap) else r for r in raw
        ]
        interior_out = eval_apply_body(op, pre_arrays, origins, interior)
        # now wait for the halos and compute the boundary frame
        post_arrays = [
            r.finish() if isinstance(r, PendingSwap) else r for r in raw
        ]
        outs = [jnp.zeros(rb.shape, interior_out[0].dtype) for _ in op.results]
        int_idx = tuple(i - b for i, b in zip(interior.lb, rb.lb))
        outs = [
            lax.dynamic_update_slice(o, part, int_idx)
            for o, part in zip(outs, interior_out)
        ]
        for slab in _frame_slabs(rb, lo_w, hi_w):
            slab_out = eval_apply_body(op, post_arrays, origins, slab)
            idx = tuple(i - b for i, b in zip(slab.lb, rb.lb))
            outs = [
                lax.dynamic_update_slice(o, part, idx)
                for o, part in zip(outs, slab_out)
            ]
        for res, arr in zip(op.results, outs):
            env[res] = arr

    def _apply_backend(self, op, arrays, origins, rb):
        if self.backend == "pallas":
            from repro.kernels.stencil_apply import run_apply_pallas

            return run_apply_pallas(
                op,
                arrays,
                origins,
                rb,
                tile=self.pallas_tile,
                interpret=self.pallas_interpret,
            )
        return eval_apply_body(op, arrays, origins, rb)

    # -- comm ops (explicit mpi-level lowering) ---------------------------
    def _exec_comm_start(self, op: comm.ExchangeStartOp, env) -> None:
        x = env[op.temp]
        origin = op.temp.type.bounds.lb
        idx = tuple(o - g for o, g in zip(op.send_offset, origin))
        patch = lax.slice(
            x, idx, tuple(i + s for i, s in zip(idx, op.size))
        )
        periodic = bool(op.attributes.get("periodic", ir.IntAttr(0)).value)
        if self.rt.distributed:
            names = tuple(a for a, _ in op.axis_shifts)
            steps = {a: s for a, s in op.axis_shifts}
            sizes = [self.rt.axis_sizes[n] for n in names]
            pairs: list[tuple[int, int]] = []
            total = math.prod(sizes)
            for lin in range(total):
                rem, coords = lin, []
                for sz in reversed(sizes):
                    coords.append(rem % sz)
                    rem //= sz
                coords = coords[::-1]
                dst = [c - steps[n] for c, n in zip(coords, names)]
                if periodic:
                    dst = [d % sz for d, sz in zip(dst, sizes)]
                elif any(d < 0 or d >= sz for d, sz in zip(dst, sizes)):
                    continue
                lin_dst = 0
                for d, sz in zip(dst, sizes):
                    lin_dst = lin_dst * sz + d
                pairs.append((lin, lin_dst))
            axis_arg = names[0] if len(names) == 1 else names
            env[op.results[0]] = lax.ppermute(patch, axis_arg, pairs)
        else:
            env[op.results[0]] = patch if periodic else jnp.zeros_like(patch)

    def _exec_comm_wait(self, op: comm.WaitOp, env) -> None:
        x = env[op.temp]
        origin = op.temp.type.bounds.lb
        for p in op.patches:
            patch = env[p]
            rect: stencil.Bounds = p.type.bounds
            idx = tuple(o - g for o, g in zip(rect.lb, origin))
            x = lax.dynamic_update_slice(x, patch, idx)
        env[op.results[0]] = x


def _frame_slabs(rb: stencil.Bounds, lo_w, hi_w):
    """Disjoint onion-peel partition of core minus interior."""
    rank = rb.rank
    slabs = []
    for d in range(rank):
        def bounds_for(d_lo, d_ub):
            lb, ub = [], []
            for k in range(rank):
                if k < d:
                    lb.append(rb.lb[k] + lo_w[k])
                    ub.append(rb.ub[k] - hi_w[k])
                elif k == d:
                    lb.append(d_lo)
                    ub.append(d_ub)
                else:
                    lb.append(rb.lb[k])
                    ub.append(rb.ub[k])
            return stencil.Bounds(tuple(lb), tuple(ub))

        if lo_w[d] > 0:
            slabs.append(bounds_for(rb.lb[d], rb.lb[d] + lo_w[d]))
        if hi_w[d] > 0:
            slabs.append(bounds_for(rb.ub[d] - hi_w[d], rb.ub[d]))
    return [s for s in slabs if all(x > 0 for x in s.shape)]


# --------------------------------------------------------------------------
# dmp → comm lowering (the paper's dmp → mpi step, fig. 4)
# --------------------------------------------------------------------------


def lower_dmp_to_comm(func: ir.FuncOp) -> ir.FuncOp:
    """Replace every dmp.swap with halo-pad + comm.exchange_start/wait.

    This is the explicit IR-level analogue of the paper's dmp→mpi lowering
    (temporary buffers + Isend/Irecv + Waitall): each exchange round
    becomes a set of ``exchange_start`` ops followed by a single ``wait``,
    with sequential rounds chained through the waited value.
    """
    new_func = ir.FuncOp(func.sym_name + "_comm", [a.type for a in func.body.args])
    vmap: dict[ir.SSAValue, ir.SSAValue] = {}
    for oa, na in zip(func.body.args, new_func.body.args):
        vmap[oa] = na
    block = new_func.body
    for op in func.body.ops:
        if not isinstance(op, dmp.SwapOp):
            cloned = op.clone_into(vmap)
            block.add_op(cloned)
            continue
        x = vmap[op.temp]
        lo, hi = op.halo_widths()
        pad = HaloPadOp(x, op.result_bounds, op.boundary, op.grid)
        block.add_op(pad)
        cur = pad.results[0]
        periodic = op.boundary == "periodic"
        core_shape = op.temp.type.bounds.shape
        for rnd in _rounds(op):
            patches = []
            for e in rnd:
                shifts = tuple(
                    (op.grid.axis_names[g], step)
                    for g, step in enumerate(e.neighbor)
                    if step != 0
                )
                start = comm.ExchangeStartOp(
                    cur,
                    shifts,
                    e.extract_offset(op.grid, core_shape),
                    e.recv_offset,
                    e.recv_size,
                )
                start.attributes["periodic"] = ir.IntAttr(int(periodic))
                block.add_op(start)
                patches.append(start.results[0])
            wait = comm.WaitOp(cur, patches)
            block.add_op(wait)
            cur = wait.results[0]
        vmap[op.results[0]] = cur
    return new_func


class HaloPadOp(ir.Operation):
    """``%padded = comm.halo_pad %core`` — BC fill of the halo frame."""

    name = "comm.halo_pad"

    def __init__(
        self,
        temp: ir.SSAValue,
        result_bounds: stencil.Bounds,
        boundary: str,
        grid: dmp.GridAttr,
    ) -> None:
        super().__init__(
            operands=[temp],
            result_types=[stencil.TempType(result_bounds, temp.type.element_type)],
            attributes={"boundary": ir.StringAttr(boundary), "grid": grid},
        )


def _exec_halo_pad(op: HaloPadOp, x):
    ib: stencil.Bounds = op.operands[0].type.bounds
    ob: stencil.Bounds = op.results[0].type.bounds
    lo = tuple(i - o for i, o in zip(ib.lb, ob.lb))
    hi = tuple(o - i for o, i in zip(ob.ub, ib.ub))
    return _pad_with_bc(
        x, lo, hi, op.attributes["grid"], op.attributes["boundary"].value
    )
