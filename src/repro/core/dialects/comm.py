"""The ``comm`` dialect — the paper's ``mpi`` dialect adapted to TPU/JAX.

The paper lowers ``dmp.swap`` to MPI_Isend/Irecv/Waitall.  TPU pods have no
MPI; the ICI-native primitive for a cartesian shift is
``jax.lax.ppermute`` inside ``shard_map``.  We keep the paper's
*non-blocking* structure at the IR level so the overlap pass (beyond-paper,
the paper's explicit future work) has something to schedule around:

- ``comm.exchange_start`` extracts the send rectangle and issues the
  permute; its result is the *in-flight* halo patch (the analogue of an
  MPI request + recv buffer).
- ``comm.wait`` consumes in-flight patches and the local array and
  materializes the updated array (the analogue of MPI_Waitall + unpack).

Anything scheduled between start and wait has no data dependence on the
exchange, so XLA's latency-hiding scheduler can overlap the collective —
the dataflow counterpart of the MPI request model.

The dialect also carries the collective subset the paper's mpi dialect
exposes (allreduce, broadcast) for use by drivers (e.g. residual norms).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from repro.core.ir import Attribute, Operation, SSAValue, TypeAttribute, VerificationError
from repro.core.dialects.stencil import Bounds, TempType


def permute_pairs(
    axis_shifts: Sequence[tuple],
    axis_sizes: dict,
    periodic: bool,
) -> tuple:
    """Linearized ``lax.ppermute`` (source, dest) pairs for one exchange.

    ``axis_shifts`` is ``((axis_name, step), ...)`` — the relative offset of
    the rank the data comes *from*: receiver ``me`` takes data from rank
    ``me + step`` ⇒ sender ``r`` delivers to ``r - step``.  Multi-axis
    shifts linearize row-major over the tuple of mesh axes (diagonal
    exchanges).  Non-periodic out-of-grid destinations are dropped, so
    physical-edge ranks simply receive nothing.

    Returns ``(axis_arg, pairs)`` ready for ``lax.ppermute`` — the single
    shared pair construction used by every exchange execution path
    (stencil interpreter and ``repro.dist.context_parallel``).
    """
    names = tuple(a for a, _ in axis_shifts)
    steps = [s for _, s in axis_shifts]
    sizes = [axis_sizes[n] for n in names]
    pairs: list[tuple[int, int]] = []
    for lin in range(math.prod(sizes)):
        rem, coords = lin, []
        for sz in reversed(sizes):
            coords.append(rem % sz)
            rem //= sz
        coords = coords[::-1]
        dst = [c - s for c, s in zip(coords, steps)]
        if periodic:
            dst = [d % sz for d, sz in zip(dst, sizes)]
        elif any(d < 0 or d >= sz for d, sz in zip(dst, sizes)):
            continue
        lin_dst = 0
        for d, sz in zip(dst, sizes):
            lin_dst = lin_dst * sz + d
        pairs.append((lin, lin_dst))
    axis_arg = names[0] if len(names) == 1 else names
    return axis_arg, pairs


class HaloPadOp(Operation):
    """``%padded = comm.halo_pad %core`` — boundary-condition fill of the
    halo frame (zeros, or a local wrap for periodic undecomposed dims);
    decomposed-dim halos are filled by the exchanges that follow."""

    name = "comm.halo_pad"

    def __init__(
        self,
        temp: SSAValue,
        result_bounds: Bounds,
        boundary: str,
        grid,  # dmp.GridAttr
    ) -> None:
        from repro.core.ir import StringAttr

        assert isinstance(temp.type, TempType)
        super().__init__(
            operands=[temp],
            result_types=[TempType(result_bounds, temp.type.element_type)],
            attributes={"boundary": StringAttr(boundary), "grid": grid},
        )

    @property
    def temp(self) -> SSAValue:
        return self.operands[0]

    @property
    def boundary(self) -> str:
        return self.attributes["boundary"].value  # type: ignore[attr-defined]

    def verify_(self) -> None:
        if not self.results[0].type.bounds.contains(self.temp.type.bounds):
            raise VerificationError(
                f"comm.halo_pad result bounds {self.results[0].type.bounds} "
                f"must contain input bounds {self.temp.type.bounds}"
            )


@dataclass(frozen=True)
class InFlightType(TypeAttribute):
    """The type of an in-flight halo patch (MPI request + buffer analogue)."""

    bounds: Bounds  # rectangle being received (local coordinates)
    element_type: object

    def __hash__(self) -> int:
        return hash((InFlightType, self.bounds, self.element_type))


class ExchangeStartOp(Operation):
    """``%patch = comm.exchange_start %t {axis_name, shift, send/recv rects}``

    Sends ``send`` rectangle of ``%t`` to the rank ``shift`` steps along mesh
    axis ``axis_name``; the result is the rectangle received from the
    opposite neighbour, destined for ``recv``.  ``shift`` may be a tuple of
    (axis_name, step) pairs for diagonal exchanges (beyond-paper).
    """

    name = "comm.exchange_start"

    def __init__(
        self,
        temp: SSAValue,
        axis_shifts: Sequence[tuple],  # ((axis_name, step), ...)
        send_offset: tuple,
        recv_offset: tuple,
        size: tuple,
    ) -> None:
        assert isinstance(temp.type, TempType)
        from repro.core.ir import IntAttr, StringAttr, TupleAttr

        rect = Bounds(tuple(recv_offset), tuple(o + s for o, s in zip(recv_offset, size)))
        super().__init__(
            operands=[temp],
            result_types=[InFlightType(rect, temp.type.element_type)],
            attributes={
                "axis_shifts": TupleAttr(
                    tuple(
                        TupleAttr((StringAttr(a), IntAttr(int(s))))
                        for a, s in axis_shifts
                    )
                ),
                "send_offset": TupleAttr(tuple(IntAttr(int(o)) for o in send_offset)),
                "recv_offset": TupleAttr(tuple(IntAttr(int(o)) for o in recv_offset)),
                "size": TupleAttr(tuple(IntAttr(int(s)) for s in size)),
            },
        )

    @property
    def temp(self) -> SSAValue:
        return self.operands[0]

    @property
    def axis_shifts(self) -> tuple:
        return tuple(
            (pair[0].value, pair[1].value) for pair in self.attributes["axis_shifts"]
        )

    @property
    def send_offset(self) -> tuple:
        return tuple(a.value for a in self.attributes["send_offset"])

    @property
    def recv_offset(self) -> tuple:
        return tuple(a.value for a in self.attributes["recv_offset"])

    @property
    def size(self) -> tuple:
        return tuple(a.value for a in self.attributes["size"])


class WaitOp(Operation):
    """``%out = comm.wait %t, %patch…`` — insert received patches into the
    array (MPI_Waitall + halo unpack)."""

    name = "comm.wait"

    def __init__(self, temp: SSAValue, patches: Sequence[SSAValue]) -> None:
        assert isinstance(temp.type, TempType)
        for p in patches:
            assert isinstance(p.type, InFlightType)
        super().__init__(
            operands=[temp, *patches], result_types=[temp.type]
        )

    @property
    def temp(self) -> SSAValue:
        return self.operands[0]

    @property
    def patches(self) -> tuple:
        return tuple(self.operands[1:])

    def verify_(self) -> None:
        bounds: Bounds = self.temp.type.bounds
        for p in self.patches:
            if not bounds.contains(p.type.bounds):
                raise VerificationError(
                    f"comm.wait patch {p.type.bounds} outside array bounds {bounds}"
                )


class BoundaryMaskOp(Operation):
    """``%out = comm.boundary_mask %t {core, grid}`` — re-apply a *zero*
    (dirichlet) boundary condition to redundantly-computed points.

    Emitted by the temporal-tiling pass: an epoch's intermediate applies
    compute into the halo frame, and points that lie outside the
    *physical* (global) domain must read as the boundary value for the
    next step, exactly as a fresh ``comm.halo_pad`` would have provided.
    The op is rank-position-aware but communication-free: a point at
    local logical coordinate ``p`` along dim ``d`` sits at global
    coordinate ``axis_index * n + (p - core.lb)`` and is zeroed when that
    falls outside ``[0, grid_extent * n)``.  Points inside the physical
    domain pass through untouched (bitwise)."""

    name = "comm.boundary_mask"

    def __init__(
        self,
        temp: SSAValue,
        core: Bounds,
        grid,  # dmp.GridAttr
    ) -> None:
        assert isinstance(temp.type, TempType)
        super().__init__(
            operands=[temp],
            result_types=[temp.type],
            attributes={"core": core, "grid": grid},
        )

    @property
    def temp(self) -> SSAValue:
        return self.operands[0]

    @property
    def core(self) -> Bounds:
        return self.attributes["core"]  # type: ignore[return-value]

    @property
    def grid(self):
        return self.attributes["grid"]

    def verify_(self) -> None:
        if self.core.rank != self.temp.type.bounds.rank:
            raise VerificationError(
                f"comm.boundary_mask core rank {self.core.rank} != temp "
                f"rank {self.temp.type.bounds.rank}"
            )


class AllReduceOp(Operation):
    """``%r = comm.allreduce %v {axes, op}`` — MPI_Allreduce analogue
    (lowers to jax.lax.psum/pmax over named mesh axes)."""

    name = "comm.allreduce"

    def __init__(self, value: SSAValue, axis_names: Sequence[str], op: str = "sum") -> None:
        from repro.core.ir import StringAttr, TupleAttr

        assert op in ("sum", "max", "min")
        super().__init__(
            operands=[value],
            result_types=[value.type],
            attributes={
                "axes": TupleAttr(tuple(StringAttr(a) for a in axis_names)),
                "op": StringAttr(op),
            },
        )

    @property
    def axes(self) -> tuple:
        return tuple(a.value for a in self.attributes["axes"])

    @property
    def op(self) -> str:
        return self.attributes["op"].value  # type: ignore[attr-defined]


class BroadcastOp(Operation):
    """``%r = comm.broadcast %v {root, axes}`` — MPI_Bcast analogue."""

    name = "comm.broadcast"

    def __init__(self, value: SSAValue, axis_names: Sequence[str], root: int = 0) -> None:
        from repro.core.ir import IntAttr, StringAttr, TupleAttr

        super().__init__(
            operands=[value],
            result_types=[value.type],
            attributes={
                "axes": TupleAttr(tuple(StringAttr(a) for a in axis_names)),
                "root": IntAttr(root),
            },
        )
