from repro.core.dialects import comm, dmp, stencil  # noqa: F401
