"""The ``stencil`` dialect (paper sec. 4.1).

Mirrors the Open Earth Compiler's stencil dialect with the paper's
enhancements:

- **bounds live in the types** (``FieldType``/``TempType`` carry lower/upper
  bounds), so "any operation using stencil-related types can access this
  information directly through their operands";
- **N-dimensional** (the original dialect was 3-D only);
- value semantics: ``stencil.load`` reads a field into a temp,
  ``stencil.apply`` maps a point function over temps, ``stencil.store``
  writes a temp back to a field over a user-defined range.

Coordinates are *logical*: a field allocated for a ``[0, N)`` domain with
halo ``h`` has bounds ``[-h, N+h)``.  Lowering to memory (JAX arrays) is a
simple shift by ``-lb`` — the paper's motivation for bounds-in-types.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from repro.core.ir import (
    Attribute,
    Operation,
    Region,
    ScalarType,
    SSAValue,
    TypeAttribute,
    VerificationError,
    f32,
)


@dataclass(frozen=True)
class Bounds(Attribute):
    """Logical hyper-rectangle ``[lb, ub)`` per dimension."""

    lb: tuple
    ub: tuple

    def __post_init__(self) -> None:
        assert len(self.lb) == len(self.ub)
        assert all(u >= l for l, u in zip(self.lb, self.ub)), (self.lb, self.ub)

    def __hash__(self) -> int:
        return hash((Bounds, self.lb, self.ub))

    @property
    def rank(self) -> int:
        return len(self.lb)

    @property
    def shape(self) -> tuple:
        return tuple(u - l for l, u in zip(self.lb, self.ub))

    def grow(self, lo: Sequence[int], hi: Sequence[int]) -> "Bounds":
        return Bounds(
            tuple(l - g for l, g in zip(self.lb, lo)),
            tuple(u + g for u, g in zip(self.ub, hi)),
        )

    def contains(self, other: "Bounds") -> bool:
        return all(sl <= ol for sl, ol in zip(self.lb, other.lb)) and all(
            su >= ou for su, ou in zip(self.ub, other.ub)
        )

    @staticmethod
    def from_shape(shape: Sequence[int]) -> "Bounds":
        return Bounds(tuple(0 for _ in shape), tuple(shape))


@dataclass(frozen=True)
class FieldType(TypeAttribute):
    """A memory buffer holding stencil data (``stencil.field`` in the paper)."""

    bounds: Bounds
    element_type: ScalarType = f32

    def __hash__(self) -> int:
        return hash((FieldType, self.bounds, self.element_type))

    @property
    def rank(self) -> int:
        return self.bounds.rank

    @property
    def shape(self) -> tuple:
        return self.bounds.shape


@dataclass(frozen=True)
class TempType(TypeAttribute):
    """Stencil values flowing between loads/applies/stores (value semantics)."""

    bounds: Bounds
    element_type: ScalarType = f32

    def __hash__(self) -> int:
        return hash((TempType, self.bounds, self.element_type))

    @property
    def rank(self) -> int:
        return self.bounds.rank

    @property
    def shape(self) -> tuple:
        return self.bounds.shape


class LoadOp(Operation):
    """``%t = stencil.load %field`` — read a field's values into a temp."""

    name = "stencil.load"

    def __init__(self, field: SSAValue, bounds: Optional[Bounds] = None) -> None:
        ftype = field.type
        assert isinstance(ftype, FieldType), f"stencil.load needs a field, got {ftype}"
        bounds = bounds or ftype.bounds
        super().__init__(
            operands=[field],
            result_types=[TempType(bounds, ftype.element_type)],
        )

    @property
    def field(self) -> SSAValue:
        return self.operands[0]

    def verify_(self) -> None:
        if not self.field.type.bounds.contains(self.results[0].type.bounds):
            raise VerificationError(
                f"stencil.load reads {self.results[0].type.bounds} outside "
                f"field bounds {self.field.type.bounds}"
            )


class StoreOp(Operation):
    """``stencil.store %t to %field over bounds`` — write back to memory."""

    name = "stencil.store"

    def __init__(self, temp: SSAValue, field: SSAValue, bounds: Bounds) -> None:
        assert isinstance(temp.type, TempType)
        assert isinstance(field.type, FieldType)
        super().__init__(operands=[temp, field], attributes={"bounds": bounds})

    @property
    def temp(self) -> SSAValue:
        return self.operands[0]

    @property
    def field(self) -> SSAValue:
        return self.operands[1]

    @property
    def bounds(self) -> Bounds:
        return self.attributes["bounds"]  # type: ignore[return-value]

    def verify_(self) -> None:
        if not self.field.type.bounds.contains(self.bounds):
            raise VerificationError(
                f"stencil.store range {self.bounds} outside field bounds "
                f"{self.field.type.bounds}"
            )
        if not self.temp.type.bounds.contains(self.bounds):
            raise VerificationError(
                f"stencil.store range {self.bounds} outside temp bounds "
                f"{self.temp.type.bounds}"
            )


class ApplyOp(Operation):
    """``%out… = stencil.apply(%in…) ({ point function })``.

    The region's block arguments correspond 1:1 to the operands; the point
    function is evaluated at every point of the result bounds, with
    ``stencil.access`` reading operands at relative offsets.
    """

    name = "stencil.apply"

    def __init__(
        self,
        args: Sequence[SSAValue],
        result_bounds: Bounds,
        n_results: int = 1,
        element_type: ScalarType = f32,
    ) -> None:
        region = Region.empty([a.type for a in args])
        super().__init__(
            operands=list(args),
            result_types=[TempType(result_bounds, element_type)] * n_results,
            regions=[region],
        )

    @property
    def body(self):
        return self.regions[0].block

    @property
    def result_bounds(self) -> Bounds:
        return self.results[0].type.bounds

    def accesses(self) -> list["AccessOp"]:
        return [op for op in self.body.ops if isinstance(op, AccessOp)]

    def access_extents(self) -> dict[int, tuple]:
        """Per-operand-index (lo, hi) access extents — the *halo inference*
        primitive the paper builds dmp on: "determine the minimal halo shape
        and size ... by scanning the stencil.access offsets"."""
        rank = self.result_bounds.rank
        extents: dict[int, tuple] = {}
        for acc in self.accesses():
            arg = acc.temp
            assert isinstance(arg, type(self.body.args[0])), "access of non-block-arg"
            idx = arg.index
            lo, hi = extents.get(
                idx, (tuple([0] * rank), tuple([0] * rank))
            )
            off = acc.offset
            lo = tuple(min(l, o) for l, o in zip(lo, off))
            hi = tuple(max(h, o) for h, o in zip(hi, off))
            extents[idx] = (lo, hi)
        return extents

    def verify_(self) -> None:
        if len(self.body.args) != len(self.operands):
            raise VerificationError(
                "stencil.apply region arg count != operand count"
            )
        for arg, operand in zip(self.body.args, self.operands):
            if arg.type != operand.type:
                raise VerificationError(
                    f"stencil.apply region arg type {arg.type} != operand type "
                    f"{operand.type}"
                )
        if not self.body.ops or not isinstance(self.body.ops[-1], StencilReturnOp):
            raise VerificationError("stencil.apply must end in stencil.return")
        ret = self.body.ops[-1]
        if len(ret.operands) != len(self.results):
            raise VerificationError(
                "stencil.return arity != stencil.apply result arity"
            )
        # Accessed extents must be available in operand bounds.  When the
        # operand bounds equal the result bounds (a *core* value, no explicit
        # halo), out-of-core accesses are boundary-condition reads — legal at
        # the global level; the decomposition pass materializes them via
        # dmp.swap, after which this check is enforced.
        for idx, (lo, hi) in self.access_extents().items():
            operand_bounds = self.operands[idx].type.bounds
            if operand_bounds == self.result_bounds:
                continue
            needed = Bounds(
                tuple(b + l for b, l in zip(self.result_bounds.lb, lo)),
                tuple(b + h for b, h in zip(self.result_bounds.ub, hi)),
            )
            if not operand_bounds.contains(needed):
                raise VerificationError(
                    f"stencil.apply accesses {needed} of operand {idx} with "
                    f"bounds {operand_bounds} (halo missing?)"
                )


class CombineOp(Operation):
    """``%out = stencil.combine %part…`` — assemble disjoint sub-domain
    temps into one temp covering ``result_bounds``.

    Emitted by ``split_overlapped_applies``: the interior apply and the
    boundary-frame applies each produce a rectangle of the original apply's
    domain; combine reassembles them (MLIR's ``stencil.combine``, N-ary).
    Points not covered by any part are zero.
    """

    name = "stencil.combine"

    def __init__(
        self,
        parts: Sequence[SSAValue],
        result_bounds: Bounds,
        element_type: ScalarType = f32,
    ) -> None:
        assert parts, "stencil.combine needs at least one part"
        for p in parts:
            assert isinstance(p.type, TempType)
        super().__init__(
            operands=list(parts),
            result_types=[TempType(result_bounds, element_type)],
        )

    @property
    def result_bounds(self) -> Bounds:
        return self.results[0].type.bounds

    def verify_(self) -> None:
        rb = self.result_bounds
        for p in self.operands:
            if not rb.contains(p.type.bounds):
                raise VerificationError(
                    f"stencil.combine part {p.type.bounds} outside result "
                    f"bounds {rb}"
                )


class FusedEpochOp(Operation):
    """``%out… = stencil.fused_epoch(%in…) ({ epoch body })`` — one deep-halo
    epoch's apply chain packaged for single-kernel code generation.

    Produced by the ``fuse-epoch-kernel`` pass from the k-times-unrolled
    chain that ``temporal-tile{k}`` emits: the region holds the grown
    ``stencil.apply`` clones (plus any ``comm.boundary_mask`` re-zeroing)
    in program order, with block arguments mirroring the operands (the
    values the chain reads from outside) and a ``stencil.fused_yield``
    terminator carrying the values that escape the chain.  The kernel
    backend lowers the whole region to ONE ``pl.pallas_call`` so the k
    sub-steps stay in fast memory; the interpreter backends evaluate the
    region inline.

    ``k`` records the epoch depth (1 for an untiled program — fusing a
    plain apply chain is legal and still saves dispatches).
    """

    name = "stencil.fused_epoch"

    #: region op names a fused epoch may contain (terminator last).
    FUSABLE_NAMES = ("stencil.apply", "comm.boundary_mask")

    def __init__(
        self,
        args: Sequence[SSAValue],
        result_types: Sequence[TypeAttribute],
        k: int = 1,
    ) -> None:
        from repro.core.ir import IntAttr

        region = Region.empty([a.type for a in args])
        super().__init__(
            operands=list(args),
            result_types=list(result_types),
            regions=[region],
            attributes={"k": IntAttr(int(k))},
        )

    @property
    def body(self):
        return self.regions[0].block

    @property
    def k(self) -> int:
        return self.attributes["k"].value  # type: ignore[attr-defined]

    def verify_(self) -> None:
        if len(self.body.args) != len(self.operands):
            raise VerificationError(
                "stencil.fused_epoch region arg count != operand count"
            )
        for arg, operand in zip(self.body.args, self.operands):
            if arg.type != operand.type:
                raise VerificationError(
                    f"stencil.fused_epoch region arg type {arg.type} != "
                    f"operand type {operand.type}"
                )
        ops = self.body.ops
        if not ops or not isinstance(ops[-1], FusedYieldOp):
            raise VerificationError(
                "stencil.fused_epoch must end in stencil.fused_yield"
            )
        for op in ops[:-1]:
            if op.name not in self.FUSABLE_NAMES:
                raise VerificationError(
                    f"stencil.fused_epoch region holds non-fusable op "
                    f"{op.name!r}"
                )
        yielded = ops[-1].operands
        if len(yielded) != len(self.results):
            raise VerificationError(
                "stencil.fused_yield arity != stencil.fused_epoch result arity"
            )
        for y, r in zip(yielded, self.results):
            if y.type != r.type:
                raise VerificationError(
                    f"stencil.fused_yield type {y.type} != result type {r.type}"
                )


class FusedYieldOp(Operation):
    """Terminates a stencil.fused_epoch region with the escaping values."""

    name = "stencil.fused_yield"

    def __init__(self, values: Sequence[SSAValue]) -> None:
        super().__init__(operands=list(values))


class AccessOp(Operation):
    """``%v = stencil.access %t [offset]`` — read a temp at a relative offset."""

    name = "stencil.access"

    def __init__(self, temp: SSAValue, offset: Sequence[int]) -> None:
        ttype = temp.type
        assert isinstance(ttype, TempType), f"stencil.access needs a temp, got {ttype}"
        from repro.core.ir import TupleAttr, IntAttr

        super().__init__(
            operands=[temp],
            result_types=[ttype.element_type],
            attributes={
                "offset": TupleAttr(tuple(IntAttr(int(o)) for o in offset))
            },
        )

    @property
    def temp(self) -> SSAValue:
        return self.operands[0]

    @property
    def offset(self) -> tuple:
        return tuple(a.value for a in self.attributes["offset"])  # type: ignore


class DynAccessOp(Operation):
    """Access at the current point plus a *runtime* index — used only by the
    frontends for boundary-condition encodings; not decomposable."""

    name = "stencil.dyn_access"

    def __init__(self, temp: SSAValue, indices: Sequence[SSAValue]) -> None:
        ttype = temp.type
        assert isinstance(ttype, TempType)
        super().__init__(
            operands=[temp, *indices], result_types=[ttype.element_type]
        )


class IndexOp(Operation):
    """``%i = stencil.index {dim}`` — the current logical index along dim."""

    name = "stencil.index"

    def __init__(self, dim: int) -> None:
        from repro.core.ir import IntAttr, index

        super().__init__(result_types=[f32], attributes={"dim": IntAttr(dim)})

    @property
    def dim(self) -> int:
        return self.attributes["dim"].value  # type: ignore[attr-defined]


class StencilReturnOp(Operation):
    """Terminates a stencil.apply point function."""

    name = "stencil.return"

    def __init__(self, values: Sequence[SSAValue]) -> None:
        super().__init__(operands=list(values))
