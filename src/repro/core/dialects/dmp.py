"""The ``dmp`` dialect (paper sec. 4.2) — declarative domain decomposition.

``dmp.swap`` expresses halo exchanges as *data declarations*: a cartesian
grid of ranks (``GridAttr``) plus a list of ``ExchangeDecl``s, each marking
a rectangular region to receive into, the matching region to send from, and
the relative offset of the neighbour rank (paper fig. 3).

Adaptation to JAX (DESIGN.md §2): the paper's swap mutates a memref whose
allocation already includes the halo.  JAX is functional and shard_map
wants uniform core shards, so ``dmp.swap`` consumes a *core* temp
(bounds ``[0, n)``) and returns the halo-grown temp (bounds
``[-h_lo, n + h_hi)``) whose halo regions are filled by the declared
exchanges (decomposed dims) and by the boundary condition (physical edges
and undecomposed dims).  The declarative exchange payload — rectangles +
relative neighbour offsets — is exactly the paper's.

Rectangle coordinates are in the local logical frame: core is ``[0, n)``,
halos are negative / ``>= n``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from repro.core.ir import Attribute, Operation, SSAValue, StringAttr, VerificationError
from repro.core.dialects.stencil import Bounds, TempType


@dataclass(frozen=True)
class GridAttr(Attribute):
    """Cartesian topology of ranks over the decomposed dims.

    ``shape[i]`` ranks decompose array dimension ``dims[i]``; ``axis_names[i]``
    is the JAX mesh axis implementing that grid axis — the TPU analogue of an
    MPI cartesian communicator.
    """

    shape: tuple
    axis_names: tuple
    dims: tuple

    def __post_init__(self) -> None:
        assert len(self.shape) == len(self.axis_names) == len(self.dims)

    def __hash__(self) -> int:
        return hash((GridAttr, self.shape, self.axis_names, self.dims))

    @property
    def rank(self) -> int:
        return len(self.shape)

    def axis_of_dim(self, dim: int) -> Optional[int]:
        return self.dims.index(dim) if dim in self.dims else None


@dataclass(frozen=True)
class ExchangeDecl(Attribute):
    """One halo exchange (paper fig. 3).

    ``neighbor`` — relative offset of the peer rank in the *grid* (length =
    grid rank, entries in {-1, 0, +1} for the standard strategy).
    ``recv_offset/size`` — rectangle (array coords) updated with the peer's
    data; ``send_offset/size`` — rectangle sent to the same peer in return.
    """

    neighbor: tuple
    recv_offset: tuple
    recv_size: tuple
    send_offset: tuple
    send_size: tuple

    def __hash__(self) -> int:
        return hash(
            (
                ExchangeDecl,
                self.neighbor,
                self.recv_offset,
                self.recv_size,
                self.send_offset,
                self.send_size,
            )
        )

    def __post_init__(self) -> None:
        assert len(self.recv_offset) == len(self.recv_size)
        assert tuple(self.recv_size) == tuple(self.send_size), (
            "send/recv rectangles must have equal size"
        )

    def numel(self) -> int:
        n = 1
        for s in self.recv_size:
            n *= int(s)
        return n

    def is_axis_aligned(self) -> bool:
        """True when the exchange moves along exactly one grid axis (a face
        exchange); diagonal/corner exchanges (beyond-paper) are not."""
        return sum(1 for c in self.neighbor if c != 0) == 1

    def extract_offset(self, grid: "GridAttr", core_shape: tuple) -> tuple:
        """The rectangle every rank extracts so that, after the uniform-SPMD
        permute toward ``-neighbor``, each receiver's ``recv`` rectangle is
        filled: the recv rect translated into the peer's frame — the peer
        sits ``+neighbor·n`` away, so my coordinate ``c`` is its
        ``c - neighbor·n``.

        (The decl's ``send_offset`` is the *other* half of the pairwise
        exchange — the paper's "in exchange, a region ... will be sent" —
        which equals the extract rect of the opposite-direction decl.)
        """
        off = list(self.recv_offset)
        for gax, step in enumerate(self.neighbor):
            if step == 0:
                continue
            d = grid.dims[gax]
            off[d] = off[d] - step * core_shape[d]
        return tuple(off)


class SwapOp(Operation):
    """``%out = dmp.swap %in {grid, exchanges, boundary, schedule}``.

    ``%in`` holds the local core; ``%out`` is halo-grown with exchanged /
    boundary-filled halos.  ``schedule`` is ``"sequential"`` (exchange
    rounds per grid axis, later rounds forwarding earlier halos — fills
    corners without diagonal messages; the paper's standard strategy) or
    ``"concurrent"`` (all exchanges independent — star stencils, or box
    stencils after the beyond-paper diagonal-exchange rewrite).
    """

    name = "dmp.swap"

    def __init__(
        self,
        temp: SSAValue,
        grid: GridAttr,
        exchanges: Sequence[ExchangeDecl],
        result_bounds: Optional[Bounds] = None,
        boundary: str = "zero",
        schedule: str = "sequential",
    ) -> None:
        assert isinstance(temp.type, TempType)
        assert boundary in ("zero", "periodic")
        assert schedule in ("sequential", "concurrent")
        from repro.core.ir import TupleAttr

        rb = result_bounds or temp.type.bounds
        super().__init__(
            operands=[temp],
            result_types=[TempType(rb, temp.type.element_type)],
            attributes={
                "grid": grid,
                "exchanges": TupleAttr(tuple(exchanges)),
                "boundary": StringAttr(boundary),
                "schedule": StringAttr(schedule),
            },
        )

    @property
    def temp(self) -> SSAValue:
        return self.operands[0]

    @property
    def grid(self) -> GridAttr:
        return self.attributes["grid"]  # type: ignore[return-value]

    @property
    def exchanges(self) -> tuple:
        return tuple(self.attributes["exchanges"])  # type: ignore[arg-type]

    @property
    def boundary(self) -> str:
        return self.attributes["boundary"].value  # type: ignore[attr-defined]

    @property
    def schedule(self) -> str:
        return self.attributes["schedule"].value  # type: ignore[attr-defined]

    @property
    def result_bounds(self) -> Bounds:
        return self.results[0].type.bounds

    def halo_widths(self) -> tuple:
        """(lo_widths, hi_widths) grown by this swap, per array dim."""
        ib: Bounds = self.temp.type.bounds
        ob: Bounds = self.result_bounds
        lo = tuple(i - o for i, o in zip(ib.lb, ob.lb))
        hi = tuple(o - i for o, i in zip(ob.ub, ib.ub))
        return lo, hi

    def total_exchange_elems(self) -> int:
        return sum(e.numel() for e in self.exchanges)

    def rounds(self) -> list:
        """Group exchanges into dependency rounds.

        Sequential: one round per grid axis, in sweep order (later rounds
        read halos written by earlier ones — corner forwarding).
        Concurrent: all exchanges in one independent round.
        """
        if self.schedule == "concurrent":
            return [list(self.exchanges)]
        by_axis: dict[int, list[ExchangeDecl]] = {}
        for e in self.exchanges:
            active = [g for g, s in enumerate(e.neighbor) if s != 0]
            assert len(active) == 1, "sequential schedule expects face exchanges"
            by_axis.setdefault(active[0], []).append(e)
        return [by_axis[g] for g in sorted(by_axis)]

    def verify_(self) -> None:
        ib: Bounds = self.temp.type.bounds
        ob: Bounds = self.result_bounds
        if not ob.contains(ib):
            raise VerificationError(
                f"dmp.swap result bounds {ob} must contain input bounds {ib}"
            )
        for e in self.exchanges:
            if len(e.neighbor) != self.grid.rank:
                raise VerificationError(
                    f"exchange neighbor {e.neighbor} rank != grid rank "
                    f"{self.grid.rank}"
                )
            if len(e.recv_offset) != ob.rank:
                raise VerificationError(
                    f"exchange rectangle rank {len(e.recv_offset)} != temp "
                    f"rank {ob.rank}"
                )
            for off, size, lb, ub in zip(e.recv_offset, e.recv_size, ob.lb, ob.ub):
                if off < lb or off + size > ub:
                    raise VerificationError(
                        f"exchange recv rectangle [{off}, {off + size}) "
                        f"outside result bounds [{lb}, {ub})"
                    )
