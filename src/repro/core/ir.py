"""Minimal SSA+Regions IR infrastructure (an xDSL-in-miniature).

This module provides the foundational compiler-IR concepts the paper builds
on (sec. 3 "Sharing Abstractions through IRs"): *operations* chained by the
SSA *values* they define and use, *attributes* carrying static information,
*types* attached to every value, and *regions* nesting control flow under
operations.  The three dialects of the paper (``stencil``, ``dmp`` and the
message-passing dialect — here ``comm``) are defined on top of this in
``repro.core.dialects``.

Design notes
------------
- Single-block regions only, matching the paper ("the abstractions we
  introduce in this paper only use regions with a single block").
- Attributes are immutable values; types are attributes.
- Operations are mutable (operands can be replaced during rewrites); the
  use-lists on values are maintained eagerly so passes can do SSA dataflow
  without separate analyses — the paper's core argument for SSA IRs.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Iterator, Optional, Sequence

# --------------------------------------------------------------------------
# Attributes & types
# --------------------------------------------------------------------------


class Attribute:
    """Base class for immutable static program information."""

    def __eq__(self, other: object) -> bool:
        return type(self) is type(other) and self.__dict__ == other.__dict__

    def __hash__(self) -> int:
        return hash((type(self), tuple(sorted(self.__dict__.items(), key=lambda kv: kv[0]))))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        inner = ", ".join(f"{k}={v!r}" for k, v in self.__dict__.items())
        return f"{type(self).__name__}({inner})"


class TypeAttribute(Attribute):
    """Base class for value types."""


@dataclass(frozen=True, eq=True)
class IntAttr(Attribute):
    value: int

    def __hash__(self) -> int:
        return hash((IntAttr, self.value))


@dataclass(frozen=True, eq=True)
class FloatAttr(Attribute):
    value: float

    def __hash__(self) -> int:
        return hash((FloatAttr, self.value))


@dataclass(frozen=True, eq=True)
class StringAttr(Attribute):
    value: str

    def __hash__(self) -> int:
        return hash((StringAttr, self.value))


@dataclass(frozen=True, eq=True)
class TupleAttr(Attribute):
    """An ordered tuple of attributes (ArrayAttr in MLIR)."""

    values: tuple

    def __hash__(self) -> int:
        return hash((TupleAttr, self.values))

    def __iter__(self) -> Iterator:
        return iter(self.values)

    def __len__(self) -> int:
        return len(self.values)

    def __getitem__(self, i):
        return self.values[i]


class ScalarType(TypeAttribute):
    """Element types: f32/f64/bf16/i32/i64/i1/index."""

    _interned: dict = {}

    def __new__(cls, name: str):
        if name not in cls._interned:
            obj = super().__new__(cls)
            obj.name = name
            cls._interned[name] = obj
        return cls._interned[name]

    def __init__(self, name: str):
        self.name = name

    def __eq__(self, other: object) -> bool:
        return isinstance(other, ScalarType) and other.name == self.name

    def __hash__(self) -> int:
        return hash(("ScalarType", self.name))

    def __repr__(self) -> str:
        return self.name


f32 = ScalarType("f32")
f64 = ScalarType("f64")
bf16 = ScalarType("bf16")
i1 = ScalarType("i1")
i32 = ScalarType("i32")
i64 = ScalarType("i64")
index = ScalarType("index")


# --------------------------------------------------------------------------
# SSA values
# --------------------------------------------------------------------------


class SSAValue:
    """A value in SSA form: defined once, used by ``uses``."""

    _name_counter = itertools.count()

    def __init__(self, type: TypeAttribute, name_hint: str = "") -> None:
        self.type = type
        self.uses: list[Use] = []
        self.name_hint = name_hint or f"v{next(SSAValue._name_counter)}"

    def replace_all_uses_with(self, new: "SSAValue") -> None:
        for use in list(self.uses):
            use.operation.replace_operand(use.index, new)

    @property
    def num_uses(self) -> int:
        return len(self.uses)

    def __repr__(self) -> str:  # pragma: no cover
        return f"%{self.name_hint}: {self.type!r}"


class OpResult(SSAValue):
    def __init__(self, type: TypeAttribute, op: "Operation", idx: int) -> None:
        super().__init__(type)
        self.op = op
        self.index = idx


class BlockArgument(SSAValue):
    def __init__(self, type: TypeAttribute, block: "Block", idx: int) -> None:
        super().__init__(type)
        self.block = block
        self.index = idx


@dataclass
class Use:
    operation: "Operation"
    index: int


# --------------------------------------------------------------------------
# Operations, blocks, regions
# --------------------------------------------------------------------------


class Operation:
    """An SSA operation: name, operands, results, attributes, regions."""

    name: str = "builtin.unregistered"

    def __init__(
        self,
        operands: Sequence[SSAValue] = (),
        result_types: Sequence[TypeAttribute] = (),
        attributes: Optional[dict[str, Attribute]] = None,
        regions: Sequence["Region"] = (),
    ) -> None:
        self._operands: list[SSAValue] = []
        self.results: list[OpResult] = [
            OpResult(t, self, i) for i, t in enumerate(result_types)
        ]
        self.attributes: dict[str, Attribute] = dict(attributes or {})
        self.regions: list[Region] = list(regions)
        for r in self.regions:
            r.parent_op = self
        self.parent_block: Optional[Block] = None
        for v in operands:
            self._append_operand(v)

    # -- operand management (keeps use-lists consistent) --
    def _append_operand(self, v: SSAValue) -> None:
        idx = len(self._operands)
        self._operands.append(v)
        v.uses.append(Use(self, idx))

    def replace_operand(self, index: int, new: SSAValue) -> None:
        old = self._operands[index]
        old.uses = [u for u in old.uses if not (u.operation is self and u.index == index)]
        self._operands[index] = new
        new.uses.append(Use(self, index))

    def set_operands(self, new_operands: Sequence[SSAValue]) -> None:
        for i, old in enumerate(self._operands):
            old.uses = [u for u in old.uses if u.operation is not self]
        self._operands = []
        for v in new_operands:
            self._append_operand(v)

    @property
    def operands(self) -> tuple[SSAValue, ...]:
        return tuple(self._operands)

    # -- structural helpers --
    def drop_all_references(self) -> None:
        for i, old in enumerate(self._operands):
            old.uses = [u for u in old.uses if u.operation is not self]
        self._operands = []

    def erase(self) -> None:
        assert all(not r.uses for r in self.results), (
            f"erasing {self.name} whose results still have uses"
        )
        self.drop_all_references()
        if self.parent_block is not None:
            self.parent_block.ops.remove(self)
            self.parent_block = None

    def verify(self) -> None:
        """Dialect ops override ``verify_`` for op-specific invariants."""
        for region in self.regions:
            for op in region.block.ops:
                op.verify()
        self.verify_()

    def verify_(self) -> None:  # pragma: no cover - default no-op
        pass

    def walk(self) -> Iterator["Operation"]:
        yield self
        for region in self.regions:
            for op in list(region.block.ops):
                yield from op.walk()

    def clone_into(self, value_map: dict[SSAValue, SSAValue]) -> "Operation":
        """Deep-clone this op, remapping operands through ``value_map``."""
        new_regions = []
        cloned = type(self).__new__(type(self))
        Operation.__init__(
            cloned,
            operands=[value_map.get(o, o) for o in self._operands],
            result_types=[r.type for r in self.results],
            attributes=dict(self.attributes),
        )
        cloned.name = self.name
        for region in self.regions:
            new_region = Region.empty([a.type for a in region.block.args])
            for old_arg, new_arg in zip(region.block.args, new_region.block.args):
                value_map[old_arg] = new_arg
            for op in region.block.ops:
                new_region.block.add_op(op.clone_into(value_map))
            new_regions.append(new_region)
        cloned.regions = new_regions
        for r in cloned.regions:
            r.parent_op = cloned
        for old_res, new_res in zip(self.results, cloned.results):
            value_map[old_res] = new_res
        return cloned

    def __repr__(self) -> str:  # pragma: no cover
        return f"<{self.name} @{id(self):x}>"


class Block:
    def __init__(self, arg_types: Sequence[TypeAttribute] = ()) -> None:
        self.args: list[BlockArgument] = [
            BlockArgument(t, self, i) for i, t in enumerate(arg_types)
        ]
        self.ops: list[Operation] = []
        self.parent_region: Optional[Region] = None

    def add_op(self, op: Operation) -> Operation:
        self.ops.append(op)
        op.parent_block = self
        return op

    def insert_op_before(self, op: Operation, anchor: Operation) -> Operation:
        idx = self.ops.index(anchor)
        self.ops.insert(idx, op)
        op.parent_block = self
        return op

    def insert_op_after(self, op: Operation, anchor: Operation) -> Operation:
        idx = self.ops.index(anchor)
        self.ops.insert(idx + 1, op)
        op.parent_block = self
        return op


class Region:
    def __init__(self, block: Block) -> None:
        self.block = block
        block.parent_region = self
        self.parent_op: Optional[Operation] = None

    @staticmethod
    def empty(arg_types: Sequence[TypeAttribute] = ()) -> "Region":
        return Region(Block(arg_types))


# --------------------------------------------------------------------------
# Builtin container ops
# --------------------------------------------------------------------------


class ModuleOp(Operation):
    name = "builtin.module"

    def __init__(self) -> None:
        super().__init__(regions=[Region.empty()])

    @property
    def body(self) -> Block:
        return self.regions[0].block


class FuncOp(Operation):
    """func.func — the container for a stencil program."""

    name = "func.func"

    def __init__(self, sym_name: str, arg_types: Sequence[TypeAttribute]) -> None:
        super().__init__(
            attributes={"sym_name": StringAttr(sym_name)},
            regions=[Region.empty(arg_types)],
        )

    @property
    def body(self) -> Block:
        return self.regions[0].block

    @property
    def sym_name(self) -> str:
        return self.attributes["sym_name"].value  # type: ignore[attr-defined]


class ReturnOp(Operation):
    name = "func.return"

    def __init__(self, operands: Sequence[SSAValue] = ()) -> None:
        super().__init__(operands=operands)


# --------------------------------------------------------------------------
# Arith dialect (the tiny subset stencil bodies need)
# --------------------------------------------------------------------------


class ConstantOp(Operation):
    name = "arith.constant"

    def __init__(self, value: float, type: TypeAttribute = f32) -> None:
        super().__init__(
            result_types=[type], attributes={"value": FloatAttr(float(value))}
        )

    @property
    def value(self) -> float:
        return self.attributes["value"].value  # type: ignore[attr-defined]


class _BinaryOp(Operation):
    def __init__(self, lhs: SSAValue, rhs: SSAValue) -> None:
        assert lhs.type == rhs.type, (
            f"{self.name}: operand types differ: {lhs.type} vs {rhs.type}"
        )
        super().__init__(operands=[lhs, rhs], result_types=[lhs.type])


class AddOp(_BinaryOp):
    name = "arith.addf"


class SubOp(_BinaryOp):
    name = "arith.subf"


class MulOp(_BinaryOp):
    name = "arith.mulf"


class DivOp(_BinaryOp):
    name = "arith.divf"


class _UnaryOp(Operation):
    def __init__(self, v: SSAValue) -> None:
        super().__init__(operands=[v], result_types=[v.type])


class NegOp(_UnaryOp):
    name = "arith.negf"


class AbsOp(_UnaryOp):
    name = "math.absf"


class SqrtOp(_UnaryOp):
    name = "math.sqrt"


class ExpOp(_UnaryOp):
    name = "math.exp"


class SelectGeZeroOp(Operation):
    """select(pred >= 0, a, b) — enough to encode upwind/boundary conditionals."""

    name = "arith.select_ge_zero"

    def __init__(self, pred: SSAValue, a: SSAValue, b: SSAValue) -> None:
        assert a.type == b.type
        super().__init__(operands=[pred, a, b], result_types=[a.type])


BINOP_REGISTRY: dict[str, Callable] = {}


# --------------------------------------------------------------------------
# Printing (for debugging, golden tests, and fingerprinting)
# --------------------------------------------------------------------------


def print_module(root: Operation) -> str:
    """Render an op tree in generic MLIR-ish syntax.

    The output is *stable*: value numbers are assigned in traversal order,
    attributes print sorted by key, and every attribute is an immutable
    dataclass with a deterministic repr — so two structurally identical op
    trees print identically, and any op/operand/attribute difference shows
    up in the text.  ``fingerprint`` builds content hashes on top of this;
    keep the printer deterministic when extending it.
    """
    lines: list[str] = []
    names: dict[SSAValue, str] = {}
    counter = itertools.count()

    def name_of(v: SSAValue) -> str:
        if v not in names:
            names[v] = f"%{next(counter)}"
        return names[v]

    def fmt_attr(a: Any) -> str:
        if isinstance(a, StringAttr):
            return f'"{a.value}"'
        if isinstance(a, (IntAttr, FloatAttr)):
            return str(a.value)
        if isinstance(a, TupleAttr):
            return "[" + ", ".join(fmt_attr(x) for x in a.values) + "]"
        return repr(a)

    def go(op: Operation, indent: int) -> None:
        pad = "  " * indent
        res = ", ".join(name_of(r) for r in op.results)
        res = res + " = " if res else ""
        operands = ", ".join(name_of(o) for o in op.operands)
        attrs = ""
        if op.attributes:
            attrs = " {" + ", ".join(
                f"{k} = {fmt_attr(v)}" for k, v in sorted(op.attributes.items())
            ) + "}"
        types = ""
        if op.results:
            types = " : " + ", ".join(repr(r.type) for r in op.results)
        lines.append(f"{pad}{res}{op.name}({operands}){attrs}{types}")
        for region in op.regions:
            args = ", ".join(
                f"{name_of(a)}: {a.type!r}" for a in region.block.args
            )
            lines.append(f"{pad}({args}) {{")
            for inner in region.block.ops:
                go(inner, indent + 1)
            lines.append(f"{pad}}}")

    go(root, 0)
    return "\n".join(lines)


def fingerprint(root: Operation, *salt: str) -> str:
    """Stable content hash of an op tree (plus optional salt strings).

    Derived from the stable textual printer, so two structurally identical
    trees hash equal and any op/operand/attribute change produces a
    different hash.  This is the key the process-wide compile cache uses
    (``repro.api``).
    """
    import hashlib

    h = hashlib.sha256(print_module(root).encode())
    for s in salt:
        h.update(b"\x00")
        h.update(s.encode())
    return h.hexdigest()[:16]


def verify_module(root: Operation) -> None:
    root.verify()
    # SSA dominance within single-block regions: uses must come after defs.
    def check_block(block: Block, visible: set[SSAValue]) -> None:
        visible = set(visible) | set(block.args)
        for op in block.ops:
            for operand in op.operands:
                if operand not in visible:
                    raise VerificationError(
                        f"operand {operand!r} of {op.name} used before definition"
                    )
            for region in op.regions:
                check_block(region.block, visible)
            visible.update(op.results)

    for region in root.regions:
        check_block(region.block, set())


class VerificationError(Exception):
    pass
