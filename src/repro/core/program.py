"""DEPRECATED compile surface — thin shim over ``repro.api``.

The user-facing API is now ``repro.api``'s three nouns (DESIGN.md §1):

    prog   = Program(func, boundary="periodic")       # or any frontend
    target = Target(mesh=mesh, strategy=make_strategy_2d((4, 2)))
    step   = repro.api.compile(prog, target)          # CompiledStencil

``StencilComputation`` and ``CompileOptions`` are kept so existing call
sites keep working bitwise-identically; they delegate to the new surface
(and therefore share its process-wide compile cache).  New code should
not use them.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional, Sequence

import jax.numpy as jnp
from jax.sharding import Mesh

from repro import api
from repro.api import time_loop, trivial_strategy  # noqa: F401  (legacy import path)
from repro.core import ir
from repro.core.passes import PassManager, PipelineContext, build_pipeline
from repro.core.passes.decompose import SlicingStrategy


@dataclasses.dataclass
class CompileOptions:
    """DEPRECATED flag bundle — the fields of ``repro.api.Target`` minus
    mesh/strategy.  Kept for source compatibility."""

    backend: str = "jnp"  # "jnp" | "pallas"
    fuse: bool = True
    cse: bool = True
    overlap: bool = False  # beyond-paper: comm/compute overlap
    diagonal: bool = False  # beyond-paper: concurrent corner exchanges
    # DEPRECATED no-op: the dmp→comm lowering is the canonical path and
    # always runs — every distributed compile executes comm ops.
    comm_dialect: bool = False
    pallas_interpret: bool = True  # CPU container: interpret kernels
    pallas_tile: Optional[tuple] = None
    # Buffer donation (whole-state handover).  The old implementation
    # computed donate_argnums but never passed them to jax.jit, so the
    # honored default is False; opt in when the caller rotates buffers.
    donate: bool = False
    # Explicit pipeline spec (DESIGN.md §2 grammar); overrides the
    # fuse/cse/diagonal/overlap flags when set.
    pipeline: Optional[str] = None

    def __post_init__(self) -> None:
        if self.comm_dialect:
            warnings.warn(
                "CompileOptions.comm_dialect is a deprecated no-op: the "
                "dmp→comm lowering is the canonical path and always runs; "
                "use an explicit pipeline spec instead",
                DeprecationWarning,
                stacklevel=3,
            )

    def to_target(
        self,
        mesh: Optional[Mesh] = None,
        strategy: Optional[SlicingStrategy] = None,
        jit: bool = True,
    ) -> api.Target:
        return api.Target(
            mesh=mesh,
            strategy=strategy,
            backend=self.backend,
            pipeline=self.pipeline,
            fuse=self.fuse,
            cse=self.cse,
            overlap=self.overlap,
            diagonal=self.diagonal,
            pallas_interpret=self.pallas_interpret,
            pallas_tile=self.pallas_tile,
            donate=self.donate,
            jit=jit,
        )


def default_pipeline(opts: "CompileOptions") -> str:
    """The canonical pipeline spec the option flags denote (fig. 4)."""
    return opts.to_target().pipeline_spec()


class StencilComputation:
    """DEPRECATED shim: wraps a ``repro.api.Program`` and delegates every
    compile to ``repro.api.compile`` — one compile path, one cache."""

    def __init__(self, func: ir.FuncOp, boundary: str = "zero") -> None:
        warnings.warn(
            "StencilComputation is deprecated; use repro.api.Program / "
            "Target / compile (see DESIGN.md §1 migration table)",
            DeprecationWarning,
            stacklevel=2,
        )
        self.program = api.Program(func, boundary=boundary)
        self.func = self.program.func
        self.boundary = boundary
        self.field_args = list(self.program.field_args)
        self.last_local: Optional[ir.FuncOp] = None  # for inspection/tests
        self.last_pipeline: Optional[str] = None
        self.last_timings: list = []  # (pass name, seconds) per stage

    # ------------------------------------------------------------------
    def prepare_local(
        self,
        strategy: Optional[SlicingStrategy] = None,
        options: Optional[CompileOptions] = None,
    ) -> ir.FuncOp:
        """Run the shared pass pipeline; returns the rank-local,
        comm-lowered function.  (Unlike ``compile``, accepts a decomposed
        strategy without a mesh — IR-only inspection.)"""
        opts = options or CompileOptions()
        strategy = strategy or trivial_strategy(self.program.rank)
        spec = opts.pipeline or default_pipeline(opts)
        ctx = PipelineContext(strategy=strategy, boundary=self.boundary)
        pm = PassManager(build_pipeline(spec, ctx))
        local = pm.run(api._clone_func(self.func))
        self.last_local = local
        self.last_pipeline = spec
        self.last_timings = list(pm.timings)
        return local

    # ------------------------------------------------------------------
    def compile(
        self,
        mesh: Optional[Mesh] = None,
        strategy: Optional[SlicingStrategy] = None,
        options: Optional[CompileOptions] = None,
        jit: bool = True,
    ) -> Callable:
        """Compile to a callable over *global* arrays (a CompiledStencil)."""
        opts = options or CompileOptions()
        artifact = api.compile(
            self.program, opts.to_target(mesh=mesh, strategy=strategy, jit=jit)
        )
        self.last_local = artifact.local_ir
        self.last_pipeline = artifact.pipeline_report.spec
        self.last_timings = list(artifact.pipeline_report.timings)
        return artifact

    # ------------------------------------------------------------------
    def partition_specs(self, strategy: SlicingStrategy) -> list:
        return api.partition_specs(self.program, strategy)

    # ------------------------------------------------------------------
    def lower(
        self,
        mesh: Mesh,
        strategy: SlicingStrategy,
        options: Optional[CompileOptions] = None,
        dtype=jnp.float32,
    ):
        """AOT-lower for the dry-run: ShapeDtypeStruct inputs, no allocation."""
        opts = options or CompileOptions()
        artifact = api.compile(
            self.program, opts.to_target(mesh=mesh, strategy=strategy)
        )
        self.last_local = artifact.local_ir
        self.last_pipeline = artifact.pipeline_report.spec
        self.last_timings = list(artifact.pipeline_report.timings)
        return artifact.lower(dtype=dtype)

    # ------------------------------------------------------------------
    def global_zeros(self, dtype=jnp.float32) -> list:
        return self.program.global_zeros(dtype)


def _stored_fields(func: ir.FuncOp, field_args: Sequence[Any] = ()) -> list:
    # legacy helper signature; field_args was never needed
    return api._stored_fields(func)


def _clone_func(func: ir.FuncOp) -> ir.FuncOp:
    return api._clone_func(func)
