"""User-facing compilation API — the shared entry point all three
frontends lower into (paper fig. 1b).

``StencilComputation`` wraps a global-domain stencil function and compiles
it for a device mesh with a decomposition strategy:

    comp = StencilComputation(func, boundary="periodic")
    step = comp.compile(mesh=mesh, strategy=make_strategy_2d((4, 2)))
    u1 = step(u0)                      # global arrays in, global arrays out

The pipeline is the paper's: [fusion + cse] → decompose (dmp.swap
insertion) → redundant-swap elimination → [overlap / diagonal rewrites] →
lowering to shard_map + ppermute + (jnp | pallas) compute.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import ir
from repro.core.dialects import stencil
from repro.core.lowering import StencilInterpreter
from repro.core.passes import PassManager, PipelineContext, build_pipeline
from repro.core.passes.decompose import SlicingStrategy


@dataclasses.dataclass
class CompileOptions:
    backend: str = "jnp"  # "jnp" | "pallas"
    fuse: bool = True
    cse: bool = True
    overlap: bool = False  # beyond-paper: comm/compute overlap
    diagonal: bool = False  # beyond-paper: concurrent corner exchanges
    # DEPRECATED no-op: the dmp→comm lowering is the canonical path and
    # always runs — every distributed compile executes comm ops.
    comm_dialect: bool = False
    pallas_interpret: bool = True  # CPU container: interpret kernels
    pallas_tile: Optional[tuple] = None
    donate: bool = True
    # Explicit pipeline spec (DESIGN.md §2 grammar); overrides the
    # fuse/cse/diagonal/overlap flags when set.
    pipeline: Optional[str] = None


def default_pipeline(opts: "CompileOptions") -> str:
    """The canonical pipeline spec the option flags denote (fig. 4):
    [fuse,cse] → decompose → swap-elim → [diagonal] → [overlap] →
    lower-comm.  Always ends in the dmp→comm lowering — the interpreter
    executes comm ops only."""
    stages: list[str] = []
    if opts.fuse:
        stages.append("fuse")
    if opts.cse:
        stages += ["cse", "dce"]
    stages += ["decompose", "swap-elim"]
    if opts.diagonal:
        stages.append("diagonal")
    if opts.overlap:
        stages.append("overlap")
    stages.append("lower-comm")
    return ",".join(stages)


def trivial_strategy(rank: int) -> SlicingStrategy:
    names = ("x", "y", "z", "w")[:rank]
    return SlicingStrategy((1,) * rank, names, tuple(range(rank)))


class StencilComputation:
    def __init__(self, func: ir.FuncOp, boundary: str = "zero") -> None:
        ir.verify_module(func)
        self.func = func
        self.boundary = boundary
        self.field_args = [
            a for a in func.body.args if isinstance(a.type, stencil.FieldType)
        ]
        self.last_local: Optional[ir.FuncOp] = None  # for inspection/tests
        self.last_pipeline: Optional[str] = None
        self.last_timings: list = []  # (pass name, seconds) per stage

    # ------------------------------------------------------------------
    def prepare_local(
        self,
        strategy: Optional[SlicingStrategy] = None,
        options: Optional[CompileOptions] = None,
    ) -> ir.FuncOp:
        """Run the shared pass pipeline; returns the rank-local,
        comm-lowered function (no dmp.swap survives — the canonical
        dmp→comm path is the only one)."""
        opts = options or CompileOptions()
        rank = self.field_args[0].type.bounds.rank if self.field_args else 1
        strategy = strategy or trivial_strategy(rank)

        spec = opts.pipeline or default_pipeline(opts)
        ctx = PipelineContext(strategy=strategy, boundary=self.boundary)
        pm = PassManager(build_pipeline(spec, ctx))
        local = pm.run(_clone_func(self.func))
        self.last_local = local
        self.last_pipeline = spec
        self.last_timings = list(pm.timings)
        return local

    # ------------------------------------------------------------------
    def compile(
        self,
        mesh: Optional[Mesh] = None,
        strategy: Optional[SlicingStrategy] = None,
        options: Optional[CompileOptions] = None,
        jit: bool = True,
    ) -> Callable:
        """Compile to a callable over *global* arrays."""
        opts = options or CompileOptions()
        rank = self.field_args[0].type.bounds.rank if self.field_args else 1
        strategy = strategy or trivial_strategy(rank)
        local = self.prepare_local(strategy, opts)

        distributed = mesh is not None and any(s > 1 for s in strategy.grid_shape)
        axis_sizes = (
            {name: mesh.shape[name] for name in mesh.axis_names} if mesh else {}
        )
        interp = StencilInterpreter(
            local,
            axis_sizes=axis_sizes,
            distributed=distributed,
            backend=opts.backend,
            pallas_interpret=opts.pallas_interpret,
            pallas_tile=opts.pallas_tile,
        )
        if not distributed:
            fn = interp
            if jit:
                fn = jax.jit(interp)
            return fn

        specs = self.partition_specs(strategy)
        out_specs = tuple(
            specs[self.field_args.index(f)] for f in _stored_fields(self.func, self.field_args)
        )
        from repro.dist.sharding import shard_map  # version-portable

        sharded = shard_map(
            interp,
            mesh=mesh,
            in_specs=tuple(specs),
            out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
            check_vma=False,  # pallas_call outputs carry no vma info
        )
        if jit:
            donate = tuple(range(len(specs))) if opts.donate else ()
            sharded = jax.jit(sharded)
        return sharded

    # ------------------------------------------------------------------
    def partition_specs(self, strategy: SlicingStrategy) -> list:
        """PartitionSpec per field argument, from the decomposition map."""
        specs = []
        for f in self.field_args:
            rank = f.type.bounds.rank
            entries: list = [None] * rank
            for gax, d in enumerate(strategy.dims):
                if d < rank and strategy.grid_shape[gax] > 1:
                    entries[d] = strategy.axis_names[gax]
            specs.append(P(*entries))
        return specs

    # ------------------------------------------------------------------
    def lower(
        self,
        mesh: Mesh,
        strategy: SlicingStrategy,
        options: Optional[CompileOptions] = None,
        dtype=jnp.float32,
    ):
        """AOT-lower for the dry-run: ShapeDtypeStruct inputs, no allocation."""
        opts = options or CompileOptions()
        fn = self.compile(mesh, strategy, opts, jit=False)
        specs = self.partition_specs(strategy)
        args = [
            jax.ShapeDtypeStruct(
                f.type.bounds.shape,
                dtype,
                sharding=NamedSharding(mesh, spec),
            )
            for f, spec in zip(self.field_args, specs)
        ]
        return jax.jit(fn).lower(*args)

    # ------------------------------------------------------------------
    def global_zeros(self, dtype=jnp.float32) -> list:
        return [jnp.zeros(f.type.bounds.shape, dtype) for f in self.field_args]


def _stored_fields(func: ir.FuncOp, field_args: Sequence[ir.SSAValue]) -> list:
    out = []
    for op in func.body.ops:
        if isinstance(op, stencil.StoreOp) and op.field not in out:
            out.append(op.field)
    return out


def _clone_func(func: ir.FuncOp) -> ir.FuncOp:
    new = ir.FuncOp(func.sym_name, [a.type for a in func.body.args])
    vmap: dict[ir.SSAValue, ir.SSAValue] = {}
    for oa, na in zip(func.body.args, new.body.args):
        vmap[oa] = na
    for op in func.body.ops:
        new.body.add_op(op.clone_into(vmap))
    return new


# --------------------------------------------------------------------------
# Time-loop driver (paper benchmarks iterate stencils over timesteps)
# --------------------------------------------------------------------------


def time_loop(
    step: Callable,
    state: Sequence[Any],
    n_steps: int,
    unroll: int = 1,
) -> tuple:
    """Iterate ``step`` with time-buffer rotation.

    ``state`` is ordered oldest→newest; each call consumes the full state
    and produces the newest buffer(s), which rotate in:
    ``state' = state[k:] + outs``.  Runs under ``lax.fori_loop`` so the
    whole simulation is one XLA computation.
    """
    state = tuple(state)

    def body(_, s):
        outs = step(*s)
        outs = outs if isinstance(outs, tuple) else (outs,)
        return tuple(s[len(outs):]) + outs

    return jax.lax.fori_loop(0, n_steps, body, state, unroll=unroll)
