"""Persistent on-disk tuning cache.

Tuned configurations outlive the process that searched for them: a JSON
entry per cache key under ``$REPRO_TUNE_CACHE`` (or
``~/.cache/repro-tune/``), keyed by

    sha256(schema | program fingerprint | hardware signature |
           rank count | search-options digest)

so a result is only reused when the program, the hardware it was tuned
on, the rank count *and* the search configuration all match.  Entries
carry a ``schema`` version: bumping ``SCHEMA_VERSION`` invalidates every
old entry (they read as misses, never as wrong answers).

``Target`` serialization lives here too (``target_to_dict`` /
``target_from_dict``): a mesh is stored as (axis names, axis sizes) and
re-materialized from the *current* device inventory at load time; the
stored target fingerprint is re-checked after reconstruction, so an
entry written on different devices misses instead of lying.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import time
from typing import Optional, Sequence

# 2: Target grew the fused_epoch axis and pallas_interpret became a
# real-device knob (both are now part of the stored target dict); v1
# entries read as misses rather than resurrecting as unfused winners.
SCHEMA_VERSION = 2


class TuneCacheError(ValueError):
    """A cache entry that cannot be rebuilt on this machine (not enough
    devices, unknown fields) — callers treat it as a miss."""


def cache_dir() -> str:
    """``$REPRO_TUNE_CACHE`` or ``~/.cache/repro-tune``; not created
    until the first ``store``."""
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(
        os.environ.get("XDG_CACHE_HOME", os.path.expanduser("~/.cache")),
        "repro-tune",
    )


@dataclasses.dataclass
class TuneCacheStats:
    hits: int = 0
    misses: int = 0
    stores: int = 0
    # cross-hardware warm starts (``lookup_transfer``) — counted apart
    # from ``hits`` because a transferred winner was tuned on DIFFERENT
    # hardware: it is a good starting point, not a verified local fact
    transfer_hits: int = 0

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "stores": self.stores,
            "transfer_hits": self.transfer_hits,
        }


_STATS = TuneCacheStats()


def cache_stats() -> TuneCacheStats:
    """Process-wide tuning-cache counters (disk hits/misses/stores)."""
    return _STATS


def reset_cache_stats() -> None:
    _STATS.hits = 0
    _STATS.misses = 0
    _STATS.stores = 0
    _STATS.transfer_hits = 0


# --------------------------------------------------------------------------
# keys
# --------------------------------------------------------------------------


def hardware_signature(devices: Optional[Sequence] = None) -> str:
    """Stable description of the device inventory a tuning ran on:
    platform, device kind, and count — the quantities that change the
    winner (not device *ids*, which vary per process)."""
    if devices is None:
        import jax

        devices = jax.devices()
    d = devices[0]
    kind = getattr(d, "device_kind", "") or d.platform
    return f"{d.platform}:{kind}:n{len(devices)}"


def options_digest(**options) -> str:
    """Digest of the search options that change the candidate space (and
    therefore the winner's identity): measurement on/off, backends, epoch
    depths, pruning knobs."""
    text = json.dumps(options, sort_keys=True, default=str)
    return hashlib.sha256(text.encode()).hexdigest()[:12]


def cache_key(
    program_fingerprint: str,
    hardware: str,
    n_ranks: int,
    options: str,
) -> str:
    text = "\n".join(
        [
            f"schema={SCHEMA_VERSION}",
            f"program={program_fingerprint}",
            f"hardware={hardware}",
            f"ranks={int(n_ranks)}",
            f"options={options}",
        ]
    )
    return hashlib.sha256(text.encode()).hexdigest()[:24]


def entry_path(key: str) -> str:
    return os.path.join(cache_dir(), f"{key}.json")


# --------------------------------------------------------------------------
# Target <-> dict
# --------------------------------------------------------------------------


def target_to_dict(target) -> dict:
    """JSON-able description of a ``repro.api.Target`` (devices elided —
    the mesh is stored as axis names + sizes)."""
    d = {
        "backend": target.backend,
        "pipeline": target.pipeline,
        "fuse": target.fuse,
        "cse": target.cse,
        "overlap": target.overlap,
        "diagonal": target.diagonal,
        "exchange_every": target.exchange_every,
        "slot_axis": target.slot_axis,
        "fused_epoch": target.fused_epoch,
        "pallas_interpret": target.pallas_interpret,
        "pallas_tile": list(target.pallas_tile) if target.pallas_tile else None,
        "donate": target.donate,
        "jit": target.jit,
        "mesh": None,
        "strategy": None,
        "fingerprint": target.fingerprint,
    }
    if target.mesh is not None:
        d["mesh"] = {
            "axes": list(target.mesh.axis_names),
            "shape": [int(target.mesh.shape[a]) for a in target.mesh.axis_names],
        }
    if target.strategy is not None:
        s = target.strategy
        d["strategy"] = {
            "grid": list(s.grid_shape),
            "axes": list(s.axis_names),
            "dims": list(s.dims),
        }
    return d


def target_from_dict(d: dict, devices: Optional[Sequence] = None):
    """Rebuild a ``Target`` from ``target_to_dict`` output against the
    current device inventory.  Raises ``TuneCacheError`` when the entry
    needs more devices than exist."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.api import Target
    from repro.core.passes.decompose import SlicingStrategy

    mesh = None
    if d.get("mesh"):
        shape = tuple(int(x) for x in d["mesh"]["shape"])
        n = int(np.prod(shape))
        devs = list(devices) if devices is not None else jax.devices()
        if n > len(devs):
            raise TuneCacheError(
                f"cached mesh needs {n} devices, have {len(devs)}"
            )
        mesh = Mesh(
            np.array(devs[:n]).reshape(shape), tuple(d["mesh"]["axes"])
        )
    strategy = None
    if d.get("strategy"):
        s = d["strategy"]
        strategy = SlicingStrategy(
            tuple(int(g) for g in s["grid"]),
            tuple(s["axes"]),
            tuple(int(x) for x in s["dims"]),
        )
    tile = d.get("pallas_tile")
    return Target(
        mesh=mesh,
        strategy=strategy,
        backend=d["backend"],
        pipeline=d.get("pipeline"),
        fuse=bool(d.get("fuse", True)),
        cse=bool(d.get("cse", True)),
        overlap=bool(d.get("overlap", False)),
        diagonal=bool(d.get("diagonal", False)),
        exchange_every=int(d.get("exchange_every", 1)),
        slot_axis=d.get("slot_axis"),
        fused_epoch=bool(d.get("fused_epoch", False)),
        pallas_interpret=bool(d.get("pallas_interpret", True)),
        pallas_tile=tuple(tile) if tile else None,
        donate=bool(d.get("donate", False)),
        jit=bool(d.get("jit", True)),
    )


# --------------------------------------------------------------------------
# load / store
# --------------------------------------------------------------------------


def load(key: str) -> Optional[dict]:
    """The entry for ``key``, or ``None`` (counted as a miss).  Corrupt
    files and schema mismatches are misses, never errors."""
    path = entry_path(key)
    try:
        with open(path) as f:
            entry = json.load(f)
    except (OSError, ValueError):
        _STATS.misses += 1
        return None
    if not isinstance(entry, dict) or entry.get("schema") != SCHEMA_VERSION:
        _STATS.misses += 1
        return None
    _STATS.hits += 1
    return entry


def demote_hit_to_miss() -> None:
    """An entry that *loaded* but failed semantic validation (device
    inventory drift, stale strategy, program mismatch) is a miss, not a
    hit — callers that reject a loaded entry call this so the counters
    report what actually happened: the search ran."""
    _STATS.hits -= 1
    _STATS.misses += 1


def lookup_transfer(
    program,
    n_ranks: int,
    options: str,
    devices: Optional[Sequence] = None,
) -> Optional[tuple]:
    """Cross-hardware warm start: the newest entry tuned for the SAME
    program and search options under a DIFFERENT hardware signature,
    whose winner still rebuilds and validates here.

    Returns ``(entry, target)`` or ``None``.  A success counts as a
    ``transfer_hit`` — never a ``hit`` — because the winner was ranked
    on other hardware: it is a plausible starting configuration, not a
    verified local fact, and nothing is re-stored under this machine's
    key (a later measured search writes that entry honestly).  The same
    safety gates as a primary hit apply: the winner's Target must
    rebuild against this inventory's first ``n_ranks`` devices with a
    matching stored fingerprint and pass program validation — entries
    that cannot (e.g. a mesh needing more ranks than the new job has)
    are skipped, not errors.
    """
    import jax

    devices = list(devices) if devices is not None else jax.devices()
    local = devices[: int(n_ranks)] or devices
    here = hardware_signature(local)
    d = cache_dir()
    try:
        names = [n for n in os.listdir(d) if n.endswith(".json")]
    except OSError:
        return None
    entries = []
    for name in names:
        try:
            with open(os.path.join(d, name)) as f:
                entry = json.load(f)
        except (OSError, ValueError):
            continue
        if not isinstance(entry, dict) or entry.get("schema") != SCHEMA_VERSION:
            continue
        if entry.get("program") != program.fingerprint:
            continue
        if entry.get("options") != options:
            continue
        if entry.get("hardware") == here:
            # same signature is the primary cache key's territory — a
            # transfer is by definition a signature change (the rank
            # count is part of the signature, so an elastic 2 -> 4 rank
            # move on one machine IS a transfer)
            continue
        entries.append(entry)
    entries.sort(key=lambda e: e.get("created", ""), reverse=True)
    for entry in entries:
        try:
            target = target_from_dict(entry["winner"], devices=local)
        except (TuneCacheError, KeyError, ValueError):
            continue
        if target.fingerprint != entry["winner"].get("fingerprint"):
            continue
        from repro import api

        try:
            api._validate_for_program(program, target)
        except api.TargetError:
            continue
        _STATS.transfer_hits += 1
        return entry, target
    return None


def store(key: str, entry: dict) -> str:
    """Atomically write ``entry`` (tmp file + rename) and return its
    path.  The schema version and key are stamped in."""
    entry = dict(entry)
    entry["schema"] = SCHEMA_VERSION
    entry["key"] = key
    entry.setdefault("created", time.strftime("%Y-%m-%dT%H:%M:%S"))
    d = cache_dir()
    os.makedirs(d, exist_ok=True)
    path = entry_path(key)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(entry, f, indent=1)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):  # pragma: no cover - rename failed
            os.unlink(tmp)
    _STATS.stores += 1
    return path
