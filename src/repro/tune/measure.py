"""On-device measurement harness for candidate ``Target``s.

Protocol (DESIGN.md §8): jit the candidate's ``time_loop`` over a
fixed-seed random state, run ``warmup`` untimed epochs' worth of steps,
then ``trials`` timed runs blocked until ready, and report the *median*
per-step seconds.  The step count is rounded up to a multiple of the
candidate's ``exchange_every`` (a partial epoch has no compiled form),
and the per-step normalization uses the rounded count, so depth-k
candidates are compared per step, not per call.

Distributed-awareness: on a multi-*process* runtime the wall clocks of
different hosts disagree, so ``agree_on_times`` broadcasts process 0's
timing vector to every process before the argmin — all ranks then select
the identical winner.  In a single process (shard_map over local
devices, the test harness) the vector is already shared.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


def measurement_state(compiled, dtype=None, seed: int = 0) -> tuple:
    """Fixed-seed random *input* state for ``compiled.time_loop`` (output
    buffers are allocated inside ``CompiledStencil.step``)."""
    import jax.numpy as jnp

    dtype = dtype or jnp.float32
    rng = np.random.default_rng(seed)
    outs = set(
        compiled.program.field_args.index(f)
        for f in compiled.program.output_fields
    )
    state = []
    for i, f in enumerate(compiled.program.field_args):
        if i in outs:
            continue
        shape = f.type.bounds.shape
        state.append(jnp.asarray(rng.standard_normal(shape), dtype))
    return tuple(state)


def measure_compiled(
    compiled,
    steps: int = 8,
    trials: int = 3,
    warmup: int = 1,
    dtype=None,
    seed: int = 0,
) -> float:
    """Median wall-clock seconds *per time step* of ``compiled`` over
    ``steps`` steps (rounded up to a whole number of epochs)."""
    import jax

    k = compiled.target.exchange_every
    steps = max(int(steps), k)
    steps = ((steps + k - 1) // k) * k
    state = measurement_state(compiled, dtype=dtype, seed=seed)

    loop = jax.jit(lambda *s: compiled.time_loop(s, steps))
    out = None
    for _ in range(max(int(warmup), 1)):
        out = loop(*state)
    jax.block_until_ready(out)
    import time

    times = []
    for _ in range(max(int(trials), 1)):
        t0 = time.perf_counter()
        out = loop(*state)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) / steps


def agree_on_times(times: Sequence[Optional[float]]) -> list:
    """One timing vector every process agrees on: process 0's
    measurements, broadcast.  ``None`` slots (unmeasured candidates) are
    carried through.  A single-process runtime returns the input."""
    import jax

    if jax.process_count() <= 1:
        return list(times)
    try:  # pragma: no cover - requires a multi-process runtime
        from jax.experimental import multihost_utils

        arr = np.array(
            [np.nan if t is None else float(t) for t in times], np.float64
        )
        arr = np.asarray(multihost_utils.broadcast_one_to_all(arr))
        return [None if np.isnan(t) else float(t) for t in arr]
    except Exception:
        return list(times)
