"""Roofline-guided search over the candidate space.

Three stages, each feeding the next:

1. **model** — every candidate is scored with the shared roofline model:
   one representative artifact per (decomposition, overlap) group is
   compiled (jnp backend, k=1 — the cheapest member) and its
   ``CompiledStencil.cost()`` terms extrapolate the whole group via
   ``RooflineTerms.step_time(k)``.  Backend/tile variants share the
   group's modeled score — the roofline cannot tell them apart; only
   measurement can.
2. **prune** — candidates outside the top ``keep_quantile`` by modeled
   score are dropped from measurement (never the baseline: the default
   configuration is always measured so the win is quantified).
3. **measure** (optional) — ``measure.measure_compiled`` on every
   survivor, timing vector agreed across processes, winner = argmin.

With ``measure=False`` the winner is the modeled argmin (ties resolve to
the earliest-enumerated, i.e. least exotic, candidate).  Results persist
through ``tune.cache`` keyed on (program fingerprint, hardware
signature, rank count, options digest).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.tune import cache as tune_cache
from repro.tune import measure as tune_measure
from repro.tune.space import Candidate, enumerate_candidates


@dataclasses.dataclass
class TuneResult:
    """Outcome of one tuning run: the winner, the full ranked candidate
    list (live searches) or the cached summary (cache hits), and
    provenance."""

    program_fingerprint: str
    winner: Candidate
    candidates: list
    measured: bool
    from_cache: bool
    cache_key: str
    cache_path: Optional[str] = None
    hardware: str = ""
    n_ranks: int = 1

    @property
    def target(self):
        return self.winner.target

    def summary(self) -> list:
        if self.candidates:
            return [c.as_dict() for c in self.candidates]
        return []

    def table(self, top: Optional[int] = None) -> str:
        """The ranked candidate table (best first) as printable text."""
        rows = []
        cands = self.candidates[:top] if top else self.candidates
        for i, c in enumerate(cands):
            rows.append(
                (
                    i,
                    c.describe(),
                    _fmt(c.modeled_s),
                    _fmt(c.measured_s),
                    c.origin + (" PRUNED" if c.pruned else ""),
                )
            )
        headers = ("#", "candidate", "modeled/step", "measured/step", "origin")
        widths = [
            max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
            for i, h in enumerate(headers)
        ]
        out = ["  ".join(str(h).ljust(w) for h, w in zip(headers, widths))]
        for r in rows:
            out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
        return "\n".join(out)


def _fmt(t: Optional[float]) -> str:
    if t is None:
        return "-"
    if not math.isfinite(t):
        return "inf"
    if t >= 1.0:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t * 1e3:.2f}ms"
    return f"{t * 1e6:.0f}µs"


# --------------------------------------------------------------------------


def _group_representative(target):
    """The cheapest member of a candidate's cost group: same
    decomposition and overlap, jnp backend, no tile, one exchange per
    step, per-step dispatch — the artifact whose roofline terms
    extrapolate the group (fused_epoch/pallas_interpret are pallas-only
    knobs and must be cleared along with the backend)."""
    return dataclasses.replace(
        target,
        backend="jnp",
        pallas_tile=None,
        exchange_every=1,
        fused_epoch=False,
        pallas_interpret=None,
    )


def score_candidates(program, candidates: Sequence[Candidate]) -> None:
    """Fill ``modeled_s`` in place via the shared roofline model.  A
    group whose representative fails to compile poisons only that group
    (score = inf, note carries the error)."""
    from repro import api

    terms_of: dict = {}
    for cand in candidates:
        rep = _group_representative(cand.target)
        key = rep.fingerprint
        if key not in terms_of:
            try:
                terms_of[key] = api.compile(program, rep).cost()
            except Exception as e:  # noqa: BLE001 - score, don't crash
                terms_of[key] = e
        terms = terms_of[key]
        if isinstance(terms, Exception):
            cand.modeled_s = float("inf")
            cand.pruned = True
            cand.note = f"model failed: {terms}"
            continue
        if not cand.target.distributed:
            # a single-device artifact's exchange ops lower to local
            # rolls/pads — no ICI messages exist, so the latency
            # amortization term must not reward deep epochs for a
            # saving the hardware cannot deliver
            terms = dataclasses.replace(terms, messages_per_epoch=0)
        cand.modeled_s = terms.step_time(cand.target.exchange_every)


def prune_candidates(
    candidates: Sequence[Candidate],
    keep_quantile: float = 0.25,
    min_keep: int = 3,
) -> list:
    """Mark everything outside the top modeled quantile ``pruned`` and
    return the survivors.  The baseline always survives."""
    scored = [
        c
        for c in candidates
        if c.modeled_s is not None and math.isfinite(c.modeled_s)
    ]
    n_keep = max(int(min_keep), math.ceil(keep_quantile * len(scored)))
    ranked = sorted(scored, key=lambda c: c.modeled_s)
    keep = set(id(c) for c in ranked[:n_keep])
    survivors = []
    for c in candidates:
        if id(c) in keep or (
            c.origin == "baseline" and c.modeled_s is not None
            and math.isfinite(c.modeled_s)
        ):
            c.pruned = False
            survivors.append(c)
        else:
            c.pruned = True
    return survivors


# --------------------------------------------------------------------------


def tune(
    program,
    ranks: Optional[int] = None,
    devices: Optional[Sequence] = None,
    measure: bool = True,
    cache: bool = True,
    transfer: bool = False,
    keep_quantile: float = 0.25,
    min_keep: int = 3,
    steps: int = 8,
    trials: int = 3,
    warmup: int = 1,
    backends: Sequence[str] = ("jnp", "pallas"),
    exchange_every: Sequence[int] = (1, 2, 4, 8),
    overlap: Sequence[bool] = (False, True),
    fused_epoch: Sequence[bool] = (False, True),
    verbose: bool = False,
) -> TuneResult:
    """Search the ``Target`` space for ``program`` on this machine.

    ``measure=False`` selects on the cost model alone (no timed runs —
    cheap enough for CI); ``measure=True`` times the unpruned candidates
    and picks the measured argmin, identically on every process.

    ``transfer=True`` adds a cross-hardware warm start: when the primary
    cache key misses, the newest entry for the same program + options
    under a *different* hardware signature (other machine, or another
    rank count — elastic resume) is adopted if its winner rebuilds and
    validates here.  It counts as a ``transfer_hit`` (never a ``hit``),
    the winner's ``origin`` is ``"transfer"``, and nothing is stored
    under this machine's key — run a measured search to earn that entry.
    """
    import jax

    devices = list(devices) if devices is not None else jax.devices()
    n_ranks = len(devices) if ranks is None else int(ranks)
    hardware = tune_cache.hardware_signature(devices[:n_ranks] or devices)
    digest = tune_cache.options_digest(
        measure=bool(measure),
        backends=sorted(backends),
        exchange_every=sorted(int(k) for k in exchange_every),
        overlap=sorted(bool(o) for o in overlap),
        fused_epoch=sorted(bool(f) for f in fused_epoch),
        keep_quantile=float(keep_quantile),
        min_keep=int(min_keep),
        # measurement protocol changes the winner's fidelity: a
        # high-trial search must not read back a noisy low-trial entry
        steps=int(steps),
        trials=int(trials),
        warmup=int(warmup),
    )
    key = tune_cache.cache_key(
        program.fingerprint, hardware, n_ranks, digest
    )

    if cache:
        cached = _load_cached(program, key, devices[:n_ranks])
        if cached is not None:
            cached.hardware = hardware
            cached.n_ranks = n_ranks
            return cached
        if transfer:
            moved = _load_transfer(program, key, n_ranks, digest, devices)
            if moved is not None:
                moved.hardware = hardware
                moved.n_ranks = n_ranks
                return moved

    candidates = enumerate_candidates(
        program,
        devices=devices,
        ranks=n_ranks,
        backends=backends,
        exchange_every=exchange_every,
        overlap=overlap,
        fused_epoch=fused_epoch,
    )
    score_candidates(program, candidates)
    survivors = prune_candidates(
        candidates, keep_quantile=keep_quantile, min_keep=min_keep
    )
    if not survivors:
        notes = "; ".join(sorted({c.note for c in candidates if c.note}))
        raise RuntimeError(
            f"tune: no candidate for program {program.fingerprint} could "
            "be modeled" + (f" ({notes})" if notes else "")
        )

    if measure:
        _measure_survivors(
            program, survivors, steps=steps, trials=trials, warmup=warmup,
            verbose=verbose,
        )
        measured = [c for c in survivors if c.measured_s is not None]
        pool = measured or survivors
        winner = min(
            pool,
            key=lambda c: (
                c.measured_s if c.measured_s is not None else c.modeled_s
            ),
        )
    else:
        winner = min(survivors, key=lambda c: c.modeled_s)

    candidates.sort(key=_rank_key)
    result = TuneResult(
        program_fingerprint=program.fingerprint,
        winner=winner,
        candidates=candidates,
        measured=bool(measure),
        from_cache=False,
        cache_key=key,
        hardware=hardware,
        n_ranks=n_ranks,
    )
    if cache:
        result.cache_path = tune_cache.store(
            key,
            {
                "program": program.fingerprint,
                "hardware": hardware,
                "n_ranks": n_ranks,
                "options": digest,
                "measured": bool(measure),
                "winner": tune_cache.target_to_dict(winner.target),
                "winner_modeled_s": winner.modeled_s,
                "winner_measured_s": winner.measured_s,
                "ranked": [c.as_dict() for c in candidates],
            },
        )
    return result


def _rank_key(c: Candidate):
    # measured candidates first (by measurement), then unmeasured by
    # modeled score, failures last
    measured = c.measured_s is not None
    score = c.measured_s if measured else c.modeled_s
    if score is None or not math.isfinite(score):
        return (2, float("inf"))
    return (0 if measured else 1, score)


def _measure_survivors(
    program, survivors, steps: int, trials: int, warmup: int, verbose: bool
) -> None:
    from repro import api

    times: list = []
    for cand in survivors:
        try:
            compiled = api.compile(program, cand.target)
            times.append(
                tune_measure.measure_compiled(
                    compiled, steps=steps, trials=trials, warmup=warmup
                )
            )
        except Exception as e:  # noqa: BLE001 - rank, don't crash
            cand.note = f"measurement failed: {e}"
            times.append(None)
        if verbose:  # pragma: no cover - CLI chatter
            print(f"  measured {cand.describe()}: {_fmt(times[-1])}/step")
    # all processes adopt process 0's clock before the argmin
    for cand, t in zip(survivors, tune_measure.agree_on_times(times)):
        cand.measured_s = t


def _load_transfer(
    program, key: str, n_ranks: int, digest: str, devices
) -> Optional[TuneResult]:
    """Warm-start from another hardware signature's entry (see
    ``cache.lookup_transfer``).  The result keys under THIS search's
    cache key but points its ``cache_path`` at the donor entry."""
    found = tune_cache.lookup_transfer(
        program, n_ranks, digest, devices=devices
    )
    if found is None:
        return None
    entry, target = found
    winner = Candidate(
        target=target,
        origin="transfer",
        modeled_s=entry.get("winner_modeled_s"),
        measured_s=entry.get("winner_measured_s"),
    )
    return TuneResult(
        program_fingerprint=program.fingerprint,
        winner=winner,
        candidates=[],
        measured=bool(entry.get("measured")),
        from_cache=True,
        cache_key=key,
        cache_path=(
            tune_cache.entry_path(entry["key"]) if entry.get("key") else None
        ),
    )


def _load_cached(program, key: str, devices) -> Optional[TuneResult]:
    entry = tune_cache.load(key)
    if entry is None:
        return None
    try:
        target = tune_cache.target_from_dict(entry["winner"], devices=devices)
    except (tune_cache.TuneCacheError, KeyError, ValueError):
        tune_cache.demote_hit_to_miss()
        return None
    # the rebuilt target must be the one that was tuned — device
    # inventory drift shows up as a fingerprint mismatch → miss
    if target.fingerprint != entry["winner"].get("fingerprint"):
        tune_cache.demote_hit_to_miss()
        return None
    from repro import api

    try:
        api._validate_for_program(program, target)
    except api.TargetError:
        tune_cache.demote_hit_to_miss()
        return None
    winner = Candidate(
        target=target,
        origin="cached",
        modeled_s=entry.get("winner_modeled_s"),
        measured_s=entry.get("winner_measured_s"),
    )
    return TuneResult(
        program_fingerprint=program.fingerprint,
        winner=winner,
        candidates=[],
        measured=bool(entry.get("measured")),
        from_cache=True,
        cache_key=key,
        cache_path=tune_cache.entry_path(key),
    )
