"""CLI: rank candidate ``Target``s for a stencil program.

    PYTHONPATH=src python -m repro.tune                    # fig7 heat, model-only
    PYTHONPATH=src python -m repro.tune --measure          # + timed runs
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.tune --ranks 4      # distributed space

Prints the ranked candidate table (modeled and, with ``--measure``,
measured per-step seconds), the winner, and where it was cached.
"""
from __future__ import annotations

import argparse
import json


def build_program(kind: str, size: int, so: int):
    from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

    shape = (size, size)
    g = Grid(shape=shape, extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=g, space_order=so)
    if kind == "heat":
        dt = 0.1 * g.spacing[0] ** 2 / 0.5
        op = Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="zero")
    elif kind == "wave":
        u = TimeFunction(name="u", grid=g, space_order=so, time_order=2)
        op = Operator(Eq(u.dt2, 1.0 * u.laplace), dt=1e-4, boundary="zero")
    else:
        raise SystemExit(f"unknown --program {kind!r}")
    return op.program


def main() -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="roofline-guided Target autotuning",
    )
    ap.add_argument("--program", default="heat", choices=["heat", "wave"])
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--so", type=int, default=2, help="space order")
    ap.add_argument("--ranks", type=int, default=None)
    ap.add_argument("--measure", action="store_true",
                    help="time the unpruned candidates (default: cost model)")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--top", type=int, default=None, help="rows to print")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--json", action="store_true", help="machine-readable dump")
    args = ap.parse_args()

    from repro.tune import cache_stats, tune

    prog = build_program(args.program, args.size, args.so)
    result = tune(
        prog,
        ranks=args.ranks,
        measure=args.measure,
        cache=not args.no_cache,
        steps=args.steps,
        trials=args.trials,
        verbose=args.measure and not args.json,
    )

    if args.json:
        print(json.dumps(
            {
                "program": result.program_fingerprint,
                "hardware": result.hardware,
                "n_ranks": result.n_ranks,
                "from_cache": result.from_cache,
                "cache_key": result.cache_key,
                "winner": {
                    "describe": result.winner.describe(),
                    "fingerprint": result.winner.fingerprint,
                    "modeled_s": result.winner.modeled_s,
                    "measured_s": result.winner.measured_s,
                },
                "ranked": result.summary(),
            },
            indent=1,
        ))
        return 0

    print(f"program  : {args.program} {args.size}x{args.size} so{args.so} "
          f"fingerprint={result.program_fingerprint}")
    if result.from_cache:
        print(f"cache HIT: {result.cache_path}")
    else:
        print(result.table(top=args.top))
        if result.cache_path:
            print(f"cached to: {result.cache_path}")
    print(f"winner   : {result.winner.describe()} "
          f"(origin={result.winner.origin})")
    print(f"tune cache stats: {cache_stats().as_dict()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
