"""Search-space enumeration: ``Program`` + device inventory → candidate
``Target``s.

The space is the cross product of every knob the compile surface
exposes, filtered down to configurations that can actually compile:

- **mesh factorizations** of the rank count over the program's array
  dims (8 ranks, rank-2 program → 8×1 slabs on dim 0 or 1, 4×2, 2×4,
  2×2×2 is dropped — more mesh dims than array dims), keeping only
  grids that divide every field extent;
- **overlap** on/off (IR-level comm/compute overlap, PR 2);
- **exchange_every** ∈ ``ks`` filtered by
  ``RooflineTerms.feasible_exchange_every`` on the program's per-step
  halo and shard extents (deep halo must fit the neighbour's core);
- **backend** jnp/pallas, with ``pallas_tile`` candidates derived from
  the local shard shape (whole-shard and split-leading-dim tiles that
  divide it).

Every candidate is validated through ``api._validate_for_program`` —
what comes out of ``enumerate_candidates`` either compiles or was never
offered.  The baseline ``Target.auto(ranks)`` configuration is always
candidate #0 and is never pruned, so a tuned result can be compared
against the default it replaces.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

AXIS_NAMES = ("x", "y", "z", "w")


@dataclasses.dataclass
class Candidate:
    """One point of the search space, with its scores as they accrue:
    ``modeled_s`` from the roofline stage, ``measured_s`` from the
    on-device stage (``None`` when pruned before measurement)."""

    target: object  # repro.api.Target
    origin: str = "enumerated"  # "baseline" | "enumerated" | "cached"
    modeled_s: Optional[float] = None
    measured_s: Optional[float] = None
    pruned: bool = False
    note: str = ""

    @property
    def fingerprint(self) -> str:
        return self.target.fingerprint

    def describe(self) -> str:
        t = self.target
        if t.strategy is not None and any(g > 1 for g in t.strategy.grid_shape):
            grid = "x".join(
                f"{g}@d{d}"
                for g, d in zip(t.strategy.grid_shape, t.strategy.dims)
                if g > 1
            )
        else:
            grid = "1"
        parts = [f"grid={grid}", f"backend={t.backend}", f"k={t.exchange_every}"]
        if t.overlap:
            parts.append("overlap")
        if t.fused_epoch:
            parts.append("fused")
        if t.backend == "pallas" and not t.pallas_interpret:
            parts.append("native")
        if t.pallas_tile:
            parts.append("tile=" + "x".join(str(x) for x in t.pallas_tile))
        return " ".join(parts)

    def as_dict(self) -> dict:
        return {
            "describe": self.describe(),
            "fingerprint": self.fingerprint,
            "origin": self.origin,
            "modeled_s": self.modeled_s,
            "measured_s": self.measured_s,
            "pruned": self.pruned,
            "note": self.note,
        }


# --------------------------------------------------------------------------
# mesh factorizations
# --------------------------------------------------------------------------


def factorizations(n: int) -> list:
    """Ordered tuples of factors ≥ 2 with product ``n`` (``8 → (8,),
    (2,4), (4,2), (2,2,2)``); ``(())`` for n=1."""
    if n <= 1:
        return [()]
    out: list[tuple] = []

    def rec(rem: int, cur: list) -> None:
        if rem == 1:
            out.append(tuple(cur))
            return
        for f in range(2, rem + 1):
            if rem % f == 0:
                rec(rem // f, cur + [f])

    rec(n, [])
    return out


def mesh_assignments(n_ranks: int, rank: int) -> list:
    """Every way to decompose ``n_ranks`` over a rank-``rank`` program:
    tuples of (grid size, array dim), deduplicated (a 2×2 grid on dims
    (0,1) equals the same grid on dims (1,0))."""
    seen = set()
    out = []
    for factors in factorizations(n_ranks):
        if len(factors) > rank:
            continue
        for dims in itertools.permutations(range(rank), len(factors)):
            key = frozenset(zip(factors, dims))
            if len(key) != len(factors) or key in seen:
                continue
            seen.add(key)
            out.append(tuple(sorted(zip(factors, dims), key=lambda fd: fd[1])))
    return out


def strategy_candidates(program, n_ranks: int) -> list:
    """``SlicingStrategy`` per feasible mesh assignment (every field
    extent divisible by its dim's grid size); ``[None]`` at 1 rank."""
    from repro.core.passes.decompose import SlicingStrategy

    if n_ranks <= 1:
        return [None]
    out = []
    for assignment in mesh_assignments(n_ranks, program.rank):
        if not assignment:
            continue
        ok = True
        for g, d in assignment:
            for f in program.field_args:
                if f.type.bounds.shape[d] % g != 0:
                    ok = False
        if not ok:
            continue
        grid = tuple(g for g, _ in assignment)
        dims = tuple(d for _, d in assignment)
        axes = tuple(AXIS_NAMES[i] for i in range(len(grid)))
        out.append(SlicingStrategy(grid, axes, dims))
    return out


def mesh_for_strategy(strategy, devices):
    """A JAX mesh matching ``strategy``'s grid over ``devices``."""
    import numpy as np
    from jax.sharding import Mesh

    if strategy is None:
        return None
    n = int(np.prod(strategy.grid_shape))
    return Mesh(
        np.array(list(devices)[:n]).reshape(strategy.grid_shape),
        strategy.axis_names,
    )


# --------------------------------------------------------------------------
# per-strategy knob candidates
# --------------------------------------------------------------------------


def exchange_every_candidates(
    program, strategy, ks: Sequence[int] = (1, 2, 4, 8)
) -> list:
    """Epoch depths from ``ks`` that are feasible for this program +
    decomposition, via ``RooflineTerms.feasible_exchange_every`` on the
    per-step halo and shard extents; non-epochable programs (e.g.
    time_order=2 state that does not rotate closed) keep only k=1."""
    from repro.core.passes.temporal import TemporalTilingError, epoch_halo
    from repro.launch.roofline import RooflineTerms

    ks = sorted(set(int(k) for k in ks))
    if not program.field_args:
        return [k for k in ks if k == 1]
    try:
        lo1, hi1 = epoch_halo(program.func, 1)
    except TemporalTilingError:
        return [k for k in ks if k == 1] or [1]
    step_halo = tuple(max(l, h) for l, h in zip(lo1, hi1))
    local_shape = _local_shape(program, strategy)
    probe = RooflineTerms(
        flops=0.0,
        bytes_accessed=0.0,
        step_halo=step_halo,
        local_shape=local_shape,
    )
    out = [k for k in ks if k == 1 or probe.feasible_exchange_every(k)]
    return out or [1]


def pallas_tile_candidates(program, strategy) -> list:
    """Tiles derived from the local shard shape: ``None`` (auto), the
    whole shard, and the shard with its leading extent halved — each
    kept only when it divides the shard."""
    local = _local_shape(program, strategy)
    out: list = [None]
    if not local or any(n <= 0 for n in local):
        return out
    out.append(tuple(local))
    if local[0] % 2 == 0 and local[0] >= 16:
        out.append((local[0] // 2,) + tuple(local[1:]))
    # dedupe, preserve order
    seen: set = set()
    uniq = []
    for t in out:
        if t not in seen:
            seen.add(t)
            uniq.append(t)
    return uniq


def _local_shape(program, strategy) -> tuple:
    if not program.field_args:
        return ()
    bounds = program.field_args[0].type.bounds
    if strategy is None:
        return tuple(bounds.shape)
    return tuple(strategy.local_bounds(bounds).shape)


# --------------------------------------------------------------------------
# pool widths (slot mesh axis — serving / ensemble batching)
# --------------------------------------------------------------------------


def slot_width_candidates(
    n_devices: int, spatial_ranks: int, capacity: int
) -> list:
    """Feasible slot-axis widths for a pool of ``capacity`` slots over a
    ``spatial_ranks``-device decomposition: every ``s`` that divides the
    pool (shard_map needs ``capacity % s == 0``) and fits the inventory
    (``s * spatial_ranks <= n_devices``), widest first.  Always non-empty
    — width 1 (the whole pool vmapped inside each spatial shard) is
    feasible whenever the spatial mesh itself is."""
    cap = max(1, int(capacity))
    spatial = max(1, int(spatial_ranks))
    hi = max(1, min(cap, int(n_devices) // spatial))
    out = [s for s in range(hi, 0, -1) if cap % s == 0]
    return out or [1]


def enumerate_pool_candidates(
    program,
    capacity: int,
    devices: Optional[Sequence] = None,
    backends: Sequence[str] = ("jnp",),
    exchange_every: Sequence[int] = (1,),
    slot_axis: str = "slot",
) -> list:
    """The ROADMAP's ensemble axis as a search space: every way to trade
    pool (ensemble) batch width against mesh factorization on this
    inventory.  For each slot width ``s`` dividing ``capacity``, the
    remaining ``n_devices // s`` devices enumerate spatial strategies
    (``strategy_candidates``), and each feasible pair becomes a slot-axis
    ``Target`` whose compiled step advances ``capacity`` same-fingerprint
    simulations in ONE ``shard_map`` dispatch over ``(slot, *spatial)``.

    Candidates carry ``origin="pool"``; ``describe()`` shows the slot
    width as ``slots=s``.  Widest slot axis enumerates first — the serve
    engine takes the head as its default factorization."""
    import jax

    from repro import api

    devices = list(devices) if devices is not None else jax.devices()
    cap = max(1, int(capacity))
    out: list = []
    seen: set = set()
    widths = sorted(
        {s for s in range(1, min(cap, len(devices)) + 1) if cap % s == 0},
        reverse=True,
    )
    for s in widths:
        n_spatial = len(devices) // s
        if n_spatial < 1:
            continue
        for strategy in strategy_candidates(program, n_spatial):
            spatial_mesh = (
                mesh_for_strategy(strategy, devices)
                if strategy is not None
                else None
            )
            if spatial_mesh is None:
                # pure-ensemble pool: no spatial decomposition.  The
                # lowered IR still binds spatial axis names for its
                # (trivial) exchanges, so the mesh carries them at size 1
                # alongside the slot axis.
                import numpy as np
                from jax.sharding import Mesh

                strategy = api.trivial_strategy(program.rank)
                shape = (s,) + (1,) * program.rank
                mesh = Mesh(
                    np.array(devices[:s]).reshape(shape),
                    (slot_axis,) + tuple(strategy.axis_names),
                )
                kw = dict(mesh=mesh, strategy=strategy, slot_axis=slot_axis)
            else:
                from repro.dist.sharding import factor_slot_mesh

                mesh = factor_slot_mesh(
                    spatial_mesh, s, axis=slot_axis, devices=devices
                )
                kw = dict(mesh=mesh, strategy=strategy, slot_axis=slot_axis)
            ks = exchange_every_candidates(program, strategy, exchange_every)
            for k in ks:
                for backend in backends:
                    try:
                        t = api.Target(
                            backend=backend, exchange_every=k, **kw
                        )
                        api._validate_for_program(program, t)
                    except api.TargetError:
                        continue
                    if t.fingerprint in seen:
                        continue
                    seen.add(t.fingerprint)
                    out.append(
                        Candidate(target=t, origin="pool", note=f"slots={s}")
                    )
    return out


# --------------------------------------------------------------------------
# the full space
# --------------------------------------------------------------------------


def pallas_interpret_candidates(devices: Sequence) -> list:
    """Interpret-mode values the search varies for pallas candidates:
    only the resolved default on CPU-only inventories (interpret — the
    real-device path would crash), the *native* non-interpret path first
    when the inventory has an accelerator (interpret mode on a GPU/TPU is
    a debugging oracle, never a perf winner, so it is not enumerated)."""
    if any(getattr(d, "platform", "cpu") in ("gpu", "tpu") for d in devices):
        return [False]
    return [None]  # resolves via kernels.default_interpret()


def enumerate_candidates(
    program,
    devices: Optional[Sequence] = None,
    ranks: Optional[int] = None,
    backends: Sequence[str] = ("jnp", "pallas"),
    exchange_every: Sequence[int] = (1, 2, 4, 8),
    overlap: Sequence[bool] = (False, True),
    pallas_tiles: bool = True,
    fused_epoch: Sequence[bool] = (False, True),
) -> list:
    """The candidate list for ``program`` on ``devices`` (default: all),
    baseline first.  Simple configurations enumerate first (no overlap,
    shallow epochs, jnp, no tile, per-step dispatch), so stable
    min-by-score tie-breaks prefer the least exotic winner.  Pallas
    candidates additionally vary ``fused_epoch`` (one megakernel per
    epoch) and — when the device inventory has an accelerator — run the
    native non-interpret path (``pallas_interpret_candidates``)."""
    import jax

    from repro import api

    devices = list(devices) if devices is not None else jax.devices()
    n_ranks = len(devices) if ranks is None else int(ranks)
    if n_ranks > len(devices):
        raise api.TargetError(
            f"requested {n_ranks} ranks, have {len(devices)} devices"
        )
    devices = devices[:n_ranks]

    baseline = Candidate(
        target=api.Target.auto(ranks=n_ranks), origin="baseline"
    )
    try:
        api._validate_for_program(program, baseline.target)
    except api.TargetError as e:
        # e.g. extents not divisible by the device count 1-D: fall back
        # to single-device as the reference configuration
        baseline = Candidate(
            target=api.Target(), origin="baseline", note=f"auto invalid: {e}"
        )

    seen = {baseline.fingerprint}
    out = [baseline]
    interprets = pallas_interpret_candidates(devices)
    for strategy in strategy_candidates(program, n_ranks):
        mesh = mesh_for_strategy(strategy, devices)
        ks = exchange_every_candidates(program, strategy, exchange_every)
        tiles = (
            pallas_tile_candidates(program, strategy)
            if pallas_tiles
            else [None]
        )
        for ov in overlap:
            for k in ks:
                for backend in backends:
                    # fused_epoch / pallas_interpret only vary on the
                    # pallas backend (they are inert — and fused_epoch
                    # invalid — on jnp, and would only duplicate
                    # fingerprint-identical candidates)
                    pallas_axes = (
                        [
                            (fe, pi)
                            for fe in fused_epoch
                            for pi in interprets
                            if not (fe and ov)  # fused ⊥ overlap
                        ]
                        if backend == "pallas"
                        else [(False, None)]
                    )
                    for tile in tiles if backend == "pallas" else [None]:
                        for fe, pi in pallas_axes:
                            try:
                                t = api.Target(
                                    mesh=mesh,
                                    strategy=strategy,
                                    backend=backend,
                                    overlap=bool(ov),
                                    exchange_every=k,
                                    fused_epoch=bool(fe),
                                    pallas_interpret=pi,
                                    pallas_tile=tile,
                                )
                                api._validate_for_program(program, t)
                            except api.TargetError:
                                continue
                            if t.fingerprint in seen:
                                continue
                            seen.add(t.fingerprint)
                            out.append(Candidate(target=t))
    return out
