"""repro.tune — roofline-guided autotuning of ``Target`` configurations.

The compile surface (PR 3) exposes a multi-dimensional ``Target`` space:
mesh factorization, comm/compute overlap, temporal-tiling depth
(``exchange_every``), backend and pallas tile.  This package searches it
automatically:

    from repro.tune import tune
    result = tune(program)                 # enumerate → model → measure
    step = repro.compile(program, result.target)

or through the compile surface itself:

    target = repro.Target.tuned(program)           # same search, cached
    step = repro.api.compile(program, tune=True)   # tune + compile

``tune(measure=False)`` selects on the shared roofline model alone (no
timed runs); results persist on disk (``tune.cache``) keyed by program
fingerprint × hardware signature × rank count, so tuned configurations
survive processes and ship with benchmark results.

    python -m repro.tune            # ranked table for the fig7 heat kernel
"""
from repro.tune.cache import (
    cache_dir,
    cache_stats,
    hardware_signature,
    lookup_transfer,
    reset_cache_stats,
    target_from_dict,
    target_to_dict,
)
from repro.tune.measure import agree_on_times, measure_compiled
from repro.tune.search import TuneResult, prune_candidates, score_candidates, tune
from repro.tune.space import Candidate, enumerate_candidates

__all__ = [
    "Candidate",
    "TuneResult",
    "agree_on_times",
    "cache_dir",
    "cache_stats",
    "enumerate_candidates",
    "hardware_signature",
    "lookup_transfer",
    "measure_compiled",
    "prune_candidates",
    "reset_cache_stats",
    "score_candidates",
    "target_from_dict",
    "target_to_dict",
    "tune",
]
