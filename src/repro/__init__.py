"""repro — a shared compilation stack for distributed-memory stencil DSLs.

The compile surface lives in ``repro.api`` and is re-exported here:

    import repro
    step = repro.compile(program, repro.Target.auto())

Imports are lazy so ``import repro`` stays light (no jax import until the
API is touched).
"""

__all__ = [
    "api",
    "obs",
    "tune",
    "resilience",
    "Program",
    "Target",
    "TargetError",
    "CompiledStencil",
    "compile",
    "cache_stats",
    "clear_cache",
    "resilient_loop",
    "resume",
]


def __getattr__(name: str):
    if name == "obs":
        import repro.obs as obs

        return obs
    if name == "tune":
        import repro.tune as tune

        return tune
    if name == "resilience":
        import repro.resilience as resilience

        return resilience
    if name in __all__:
        import repro.api as api

        return api if name == "api" else getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
