"""Mamba block (jamba's SSM layer), TPU-adapted.

Hardware adaptation (DESIGN.md §2): Mamba-1's per-channel selective scan
is a GPU-kernel-shaped recurrence; on TPU the MXU wants the *chunked SSD
formulation* (Mamba-2): per-head scalar decay, intra-chunk attention-like
L×L matmuls, inter-chunk state carried by ``lax.scan``.  Per-chunk
tensors are transient inside the scan body, so memory is
O(B·L²·heads/chunk) instead of O(B·S²).

Sequence dependency structure (the paper's halo story, DESIGN.md §4):
the causal conv reads ``[t-3, t]`` (halo k-1 = 3) and the scan carries a
[heads, N, P] state across chunk/shard boundaries — both are bounded
one-sided exchanges under sequence parallelism, expressed through the
same dmp/comm machinery as stencil halos (repro.dist.context_parallel).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.layers import dense_init
from repro.models.flags import scan_unroll_arg

HEAD_P = 64  # channels per SSD head


def mamba_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // HEAD_P
    return d_inner, n_heads


def mamba_init(key, cfg):
    d = cfg.d_model
    d_inner, nh = mamba_dims(cfg)
    N = cfg.ssm_state_dim
    k = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(k[0], d, 2 * d_inner),        # x and gate z
        "conv_w": jax.random.normal(k[1], (cfg.ssm_conv_width, d_inner), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "dt_proj": dense_init(k[2], d, nh),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01))),  # softplus⁻¹
        "B_proj": dense_init(k[3], d, N),
        "C_proj": dense_init(k[4], d, N),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": dense_init(k[5], d_inner, d),
    }


def _causal_conv(x, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along seq.  x: [B,S,C]; w: [K,C].

    ``state`` ([B,K-1,C], previous inputs) enables decode/chunk stitching;
    returns (y, new_state).
    """
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)
    y = sum(
        xx[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    new_state = xx[:, -(K - 1) :, :] if K > 1 else state
    return y + b[None, None, :], new_state


def _segsum_decay(a):
    """a: [..., L] per-step log-decays → [..., L, L] lower-tri decay matrix
    exp(cum[t]-cum[s]) for s<=t, 0 above diagonal (in exp space)."""
    L = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # [t, s]
    tri = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(tri, jnp.exp(diff), 0.0)


def mamba_ssd_scan(x, dt, B, C, A, chunk: int, h0=None):
    """Chunked selective scan.

    x:  [Bt, S, nh, P]   inputs per head
    dt: [Bt, S, nh]      positive step sizes
    B:  [Bt, S, N], C: [Bt, S, N]
    A:  [nh]             negative per-head decay rates
    Returns (y [Bt,S,nh,P], h_final [Bt,nh,N,P]).
    """
    Bt, S, nh, P = x.shape
    N = B.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nchunk = S // L

    def resh(t, extra):
        return t.reshape((Bt, nchunk, L) + extra)

    xc = resh(x, (nh, P))
    dtc = resh(dt, (nh,))
    Bc = resh(B, (N,))
    Cc = resh(C, (N,))

    if h0 is None:
        h0 = jnp.zeros((Bt, nh, N, P), jnp.float32)

    def chunk_step(h, inp):
        xk, dtk, Bk, Ck = inp  # [Bt,L,nh,P], [Bt,L,nh], [Bt,L,N], [Bt,L,N]
        a = dtk * A[None, None, :]                       # [Bt,L,nh] (<=0)
        decay = _segsum_decay(a.transpose(0, 2, 1))      # [Bt,nh,L,L]
        cum = jnp.cumsum(a, axis=1)                      # [Bt,L,nh]
        # intra-chunk: scores[t,s] = (C_t·B_s) decay[t,s] dt_s
        cb = jnp.einsum("btn,bsn->bts", Ck, Bk)          # [Bt,L,L]
        scores = cb[:, None] * decay * dtk.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhts,bshp->bthp", scores, xk)
        # contribution of incoming state
        state_decay = jnp.exp(cum)                       # [Bt,L,nh]
        y_state = jnp.einsum("btn,bhnp->bthp", Ck, h)
        y_state = y_state * state_decay[..., None]
        # state update
        chunk_decay = jnp.exp(cum[:, -1])                # [Bt,nh]
        rel = jnp.exp(cum[:, -1][:, None] - cum)         # [Bt,L,nh]
        dB = (dtk * rel)[..., None] * Bk[:, :, None, :]  # [Bt,L,nh,N]
        h_new = h * chunk_decay[..., None, None] + jnp.einsum(
            "blhn,blhp->bhnp", dB, xk
        )
        return h_new, (y_intra + y_state).astype(x.dtype)

    inputs = (
        xc.transpose(1, 0, 2, 3, 4),
        dtc.transpose(1, 0, 2, 3),
        Bc.transpose(1, 0, 2, 3),
        Cc.transpose(1, 0, 2, 3),
    )
    h_final, ys = jax.lax.scan(chunk_step, h0, inputs, unroll=scan_unroll_arg())
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bt, S, nh, P)
    return y, h_final


def mamba_apply(p, x, cfg, dtype, chunk: int = 256, state=None):
    """x: [B,S,D] → (y [B,S,D], new_state) — train/prefill path.

    ``state``: optional (conv_state [B,K-1,d_inner], h [B,nh,N,P]).
    """
    B_, S, D = x.shape
    d_inner, nh = mamba_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x.astype(dtype),
                    shard(p["in_proj"], "embed", "mlp").astype(dtype),
                    preferred_element_type=jnp.float32)
    xr, z = jnp.split(xz, 2, axis=-1)
    conv_state = state[0] if state is not None else None
    xr, new_conv_state = _causal_conv(
        xr.astype(jnp.float32), p["conv_w"], p["conv_b"], conv_state
    )
    xr = jax.nn.silu(xr)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x.astype(dtype), p["dt_proj"].astype(dtype),
                   preferred_element_type=jnp.float32) + p["dt_bias"]
    )
    Bm = jnp.einsum("bsd,dn->bsn", x.astype(dtype), p["B_proj"].astype(dtype),
                    preferred_element_type=jnp.float32)
    Cm = jnp.einsum("bsd,dn->bsn", x.astype(dtype), p["C_proj"].astype(dtype),
                    preferred_element_type=jnp.float32)
    A = -jnp.exp(p["A_log"])
    xh = xr.reshape(B_, S, nh, HEAD_P)
    h0 = state[1] if state is not None else None
    y, h = mamba_ssd_scan(xh, dt, Bm, Cm, A, chunk=chunk, h0=h0)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B_, S, d_inner) * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(dtype),
                     shard(p["out_proj"], "mlp", "embed").astype(dtype),
                     preferred_element_type=jnp.float32)
    return out.astype(dtype), (new_conv_state.astype(dtype), h)


def mamba_decode_step(p, x, cfg, dtype, state):
    """Single-token decode: x [B,1,D], state (conv [B,K-1,di], h [B,nh,N,P])."""
    return mamba_apply(p, x, cfg, dtype, chunk=1, state=state)


def mamba_init_state(cfg, batch: int, dtype):
    d_inner, nh = mamba_dims(cfg)
    return (
        jnp.zeros((batch, cfg.ssm_conv_width - 1, d_inner), dtype),
        jnp.zeros((batch, nh, cfg.ssm_state_dim, HEAD_P), jnp.float32),
    )
