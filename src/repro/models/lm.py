"""Model assembly: embedding → scanned supercells → norm → logits.

Heterogeneous stacks (jamba, gemma2, xlstm) repeat a *supercell* of block
kinds; parameters are stacked per slot over supercells and the stack runs
under ``lax.scan`` — one compiled cell body regardless of depth (flat
compile time, the production pattern).

Three entry points per model:
  forward_train    — full-sequence forward, logits for the loss;
  forward_prefill  — forward + cache construction (inference prefill);
  decode_step      — one token against the cache (decode / long-context).

Encoder-decoder (seamless) adds an encoder stack + cross-attention;
modality stubs (audio frames / ViT patches) enter as precomputed
embeddings per the assignment spec.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, MAMBA, MLSTM, ModelConfig, SLSTM
from repro.dist.sharding import shard
from repro.models import attention as attn
from repro.models.flags import scan_unroll_arg
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    dense_init,
    embed_init,
    embed_lookup,
    rms_norm,
    swiglu_apply,
    swiglu_init,
    unembed_logits,
)


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _slot_init(key, cfg: ModelConfig, slot: int, cross: bool = False) -> dict:
    kind = cfg.block_pattern[slot]
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"norm_mixer": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind in (ATTN, ATTN_LOCAL):
        p["attn"] = attn.attn_init(ks[0], cfg)
    elif kind == MAMBA:
        p["mamba"] = mamba_mod.mamba_init(ks[0], cfg)
    elif kind == MLSTM:
        p["mlstm"] = xlstm_mod.mlstm_init(ks[0], cfg)
    elif kind == SLSTM:
        p["slstm"] = xlstm_mod.slstm_init(ks[0], cfg)
    if cross:
        p["norm_cross"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["cross"] = attn.attn_init(ks[1], cfg)
    if cfg.d_ff > 0:
        p["norm_ffn"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.layer_is_moe(slot):
            p["moe"] = moe_mod.moe_init(ks[2], cfg)
        else:
            p["ffn"] = swiglu_init(ks[2], cfg.d_model, cfg.d_ff)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    n_cells = cfg.n_supercells
    cells = []
    cell_keys = jax.random.split(ks[0], n_cells)
    for c in range(n_cells):
        slot_keys = jax.random.split(cell_keys[c], len(cfg.block_pattern))
        cells.append(
            {
                f"slot{s}": _slot_init(
                    slot_keys[s], cfg, s, cross=cfg.is_encoder_decoder
                )
                for s in range(len(cfg.block_pattern))
            }
        )
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *cells)
    params = {
        "embed": embed_init(ks[1], cfg.vocab_size, cfg.d_model),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "cells": stacked,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[2], cfg.vocab_size, cfg.d_model)
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(ks[3], cfg.n_encoder_layers)
        enc_cfg = dataclasses.replace(cfg, block_pattern=(ATTN,))
        enc_layers = [
            _slot_init(ek, enc_cfg, 0, cross=False) for ek in enc_keys
        ]
        params["encoder"] = {
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
            "norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    if cfg.modality == "vision" and cfg.modality_dim:
        params["projector"] = {
            "w1": dense_init(ks[4], cfg.modality_dim, cfg.d_model),
            "w2": dense_init(ks[5], cfg.d_model, cfg.d_model),
        }
    return params


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------


def _ffn_part(slot_p, x, cfg, dtype, aux):
    if cfg.d_ff <= 0:
        return x, aux
    h = rms_norm(x, slot_p["norm_ffn"], cfg.norm_eps)
    if "moe" in slot_p:
        y, moe_aux = moe_mod.moe_apply(slot_p["moe"], h, cfg, dtype)
        aux = {k: aux.get(k, 0.0) + v for k, v in moe_aux.items()} if aux is not None else None
    else:
        y = swiglu_apply(slot_p["ffn"], h, dtype)
    return x + y, aux


def _run_slot_train(slot_p, x, cfg, slot, dtype, memory, aux, q_chunk):
    kind = cfg.layer_kind(slot)
    h = rms_norm(x, slot_p["norm_mixer"], cfg.norm_eps)
    if kind in (ATTN, ATTN_LOCAL):
        y, _ = attn.self_attention(
            slot_p["attn"], h, cfg, kind=kind, dtype=dtype, q_chunk=q_chunk
        )
    elif kind == MAMBA:
        y, _ = mamba_mod.mamba_apply(slot_p["mamba"], h, cfg, dtype)
    elif kind == MLSTM:
        y, _ = xlstm_mod.mlstm_apply(slot_p["mlstm"], h, cfg, dtype)
    elif kind == SLSTM:
        y, _ = xlstm_mod.slstm_apply(slot_p["slstm"], h, cfg, dtype)
    else:
        raise ValueError(kind)
    x = x + y
    if memory is not None:
        hc = rms_norm(x, slot_p["norm_cross"], cfg.norm_eps)
        x = x + attn.cross_attention(slot_p["cross"], hc, memory, cfg, dtype=dtype)
    return _ffn_part(slot_p, x, cfg, dtype, aux)


# --------------------------------------------------------------------------
# embedding / frontends
# --------------------------------------------------------------------------


def embed_inputs(params, cfg: ModelConfig, tokens, modality=None, dtype=None):
    dtype = dtype or _dtype(cfg)
    x = embed_lookup(params["embed"], tokens, dtype)
    if cfg.modality == "vision" and modality is not None:
        h = jnp.einsum("bmd,de->bme", modality.astype(dtype),
                       params["projector"]["w1"].astype(dtype),
                       preferred_element_type=jnp.float32)
        h = jax.nn.gelu(h).astype(dtype)
        vis = jnp.einsum("bme,ef->bmf", h, params["projector"]["w2"].astype(dtype),
                         preferred_element_type=jnp.float32).astype(dtype)
        x = jnp.concatenate([vis, x], axis=1)
    return shard(x, "batch", "seq", "embed_act")


def encode(params, cfg: ModelConfig, frames, dtype=None):
    """Bidirectional encoder over (stub) modality frame embeddings."""
    dtype = dtype or _dtype(cfg)
    x = frames.astype(dtype)
    enc_cfg = dataclasses.replace(cfg, block_pattern=(ATTN,))

    def layer(x, lp):
        h = rms_norm(x, lp["norm_mixer"], cfg.norm_eps)
        q, k, v = attn._project_qkv(lp["attn"], h, h, enc_cfg, dtype, None, None)
        o = attn.chunked_attention(q, k, v, causal=False, dtype=dtype)
        x = x + attn._out_proj(lp["attn"], o, enc_cfg, dtype)
        x, _ = _ffn_part(lp, x, enc_cfg, dtype, None)
        return x, None

    x, _ = jax.lax.scan(layer, x, params["encoder"]["layers"], unroll=scan_unroll_arg())
    return rms_norm(x, params["encoder"]["norm"], cfg.norm_eps)


# --------------------------------------------------------------------------
# train / prefill forward
# --------------------------------------------------------------------------


def forward_train(
    params,
    cfg: ModelConfig,
    tokens,
    modality=None,
    remat: bool = True,
    q_chunk: int = 1024,
):
    """tokens: [B, S_text] → (logits [B,S,Vpad], aux dict)."""
    dtype = _dtype(cfg)
    memory = None
    if cfg.is_encoder_decoder:
        assert modality is not None, "encoder-decoder needs encoder frames"
        memory = encode(params, cfg, modality, dtype)
        x = embed_inputs(params, cfg, tokens, None, dtype)
    else:
        x = embed_inputs(params, cfg, tokens, modality, dtype)

    def cell(carry, cell_p):
        x, aux = carry
        for s in range(len(cfg.block_pattern)):
            x, aux = _run_slot_train(
                cell_p[f"slot{s}"], x, cfg, s, dtype, memory, aux, q_chunk
            )
            x = shard(x, "batch", "seq", "embed_act")
        return (x, aux), None

    cell_fn = jax.checkpoint(cell) if remat else cell
    aux0 = (
        {"moe_lb_loss": jnp.float32(0.0), "moe_z_loss": jnp.float32(0.0)}
        if cfg.moe is not None and cfg.moe_every > 0
        else {}
    )
    (x, aux), _ = jax.lax.scan(cell_fn, (x, aux0), params["cells"], unroll=scan_unroll_arg())
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = unembed_logits(x, table, cfg.vocab_size, dtype, cfg.logit_softcap)
    return shard(logits, "batch", "seq", "vocab_act"), aux


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------


def _slot_cache_len(cfg: ModelConfig, slot: int, max_len: int) -> int:
    kind = cfg.layer_kind(slot)
    if kind == ATTN_LOCAL and cfg.local_window > 0:
        return min(cfg.local_window, max_len)
    return max_len


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=None, memory_len: int = 0
) -> dict:
    """Cache pytree, stacked over supercells per slot.

    For encoder-decoder models, ``memory_len`` adds cached cross-attention
    K/V per slot (filled at prefill, read-only during decode)."""
    dtype = dtype or _dtype(cfg)
    n_cells = cfg.n_supercells
    kh, hd = cfg.n_kv_heads, cfg.head_dim_
    cache: dict[str, Any] = {}
    for s, kind in enumerate(cfg.block_pattern):
        if kind in (ATTN, ATTN_LOCAL):
            T = _slot_cache_len(cfg, s, max_len)
            cache[f"slot{s}"] = {
                "k": jnp.zeros((n_cells, batch, T, kh, hd), dtype),
                "v": jnp.zeros((n_cells, batch, T, kh, hd), dtype),
            }
            if cfg.is_encoder_decoder and memory_len:
                cache[f"slot{s}"]["ck"] = jnp.zeros(
                    (n_cells, batch, memory_len, kh, hd), dtype
                )
                cache[f"slot{s}"]["cv"] = jnp.zeros(
                    (n_cells, batch, memory_len, kh, hd), dtype
                )
        elif kind == MAMBA:
            conv, h = mamba_mod.mamba_init_state(cfg, batch, dtype)
            cache[f"slot{s}"] = {
                "conv": jnp.broadcast_to(conv, (n_cells,) + conv.shape),
                "h": jnp.broadcast_to(h, (n_cells,) + h.shape),
            }
        elif kind == MLSTM:
            C, n = xlstm_mod.mlstm_init_state(cfg, batch)
            cache[f"slot{s}"] = {
                "C": jnp.broadcast_to(C, (n_cells,) + C.shape),
                "n": jnp.broadcast_to(n, (n_cells,) + n.shape),
            }
        elif kind == SLSTM:
            st = xlstm_mod.slstm_init_state(cfg, batch)
            cache[f"slot{s}"] = {
                f"s{i}": jnp.broadcast_to(t, (n_cells,) + t.shape)
                for i, t in enumerate(st)
            }
    return cache


def grow_cache(cfg: ModelConfig, cache: dict, new_len: int, prefill_len: int) -> dict:
    """Extend attention-cache capacity with a zero tail (serving: prefill
    length < decode budget).  Valid when the existing ring has not wrapped
    (prefill_len ≤ current capacity), so slot == position."""
    out = {}
    for key, sc in cache.items():
        s = int(key[4:])
        kind = cfg.layer_kind(s)
        if kind in (ATTN, ATTN_LOCAL) and "k" in sc:
            T = sc["k"].shape[2]
            target = _slot_cache_len(cfg, s, new_len)
            if target > T:
                assert prefill_len <= T, (
                    "cannot grow a wrapped ring cache (prefill_len > capacity)"
                )
                pad = [(0, 0)] * sc["k"].ndim
                pad[2] = (0, target - T)
                sc = dict(sc, k=jnp.pad(sc["k"], pad), v=jnp.pad(sc["v"], pad))
        out[key] = sc
    return out


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------


def forward_prefill(
    params, cfg: ModelConfig, tokens, modality=None, q_chunk: int = 1024
):
    """Full-sequence forward that also builds the decode cache.

    Returns (logits_last [B,Vpad], cache).  Cache lengths equal the
    prompt length (decode_32k-style serving appends into preallocated
    buffers sized by the driver; here prefill fills exactly S).
    """
    dtype = _dtype(cfg)
    memory = None
    if cfg.is_encoder_decoder:
        memory = encode(params, cfg, modality, dtype)
        x = embed_inputs(params, cfg, tokens, None, dtype)
    else:
        x = embed_inputs(params, cfg, tokens, modality, dtype)
    B, S = x.shape[0], x.shape[1]

    def cell(carry, cell_p):
        x = carry
        caches = {}
        for s in range(len(cfg.block_pattern)):
            slot_p = cell_p[f"slot{s}"]
            kind = cfg.layer_kind(s)
            h = rms_norm(x, slot_p["norm_mixer"], cfg.norm_eps)
            if kind in (ATTN, ATTN_LOCAL):
                y, (k, v) = attn.self_attention(
                    slot_p["attn"], h, cfg, kind=kind, dtype=dtype, q_chunk=q_chunk
                )
                T = _slot_cache_len(cfg, s, S)
                kc, vc = k[:, -T:], v[:, -T:]
                if S % T:
                    # ring layout: slot = position % T (what decode's
                    # rolling-cache reconstruction expects)
                    kc = jnp.roll(kc, S % T, axis=1)
                    vc = jnp.roll(vc, S % T, axis=1)
                caches[f"slot{s}"] = {"k": kc, "v": vc}
                if memory is not None:
                    ck, cv = attn.project_cross_kv(
                        slot_p["cross"], memory, cfg, dtype
                    )
                    caches[f"slot{s}"]["ck"] = ck
                    caches[f"slot{s}"]["cv"] = cv
            elif kind == MAMBA:
                y, (conv, hst) = mamba_mod.mamba_apply(slot_p["mamba"], h, cfg, dtype)
                caches[f"slot{s}"] = {"conv": conv, "h": hst}
            elif kind == MLSTM:
                y, (C, n) = xlstm_mod.mlstm_apply(slot_p["mlstm"], h, cfg, dtype)
                caches[f"slot{s}"] = {"C": C, "n": n}
            elif kind == SLSTM:
                y, st = xlstm_mod.slstm_apply(slot_p["slstm"], h, cfg, dtype)
                caches[f"slot{s}"] = {f"s{i}": t for i, t in enumerate(st)}
            x = x + y
            if memory is not None:
                hc = rms_norm(x, slot_p["norm_cross"], cfg.norm_eps)
                x = x + attn.cross_attention(slot_p["cross"], hc, memory, cfg, dtype=dtype)
            x, _ = _ffn_part(slot_p, x, cfg, dtype, None)
            x = shard(x, "batch", "seq", "embed_act")
        return x, caches

    x, cache = jax.lax.scan(cell, x, params["cells"], unroll=scan_unroll_arg())
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = unembed_logits(x[:, -1], table, cfg.vocab_size, dtype, cfg.logit_softcap)
    return logits, cache


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def decode_step(params, cfg: ModelConfig, token, pos, cache, memory=None):
    """token: [B] ids; pos: scalar position; cache from init_cache/prefill.

    Returns (logits [B,Vpad], new_cache).
    """
    dtype = _dtype(cfg)
    x = embed_lookup(params["embed"], token[:, None], dtype)  # [B,1,D]

    def cell(x, inp):
        cell_p, cell_cache = inp
        new_cache = {}
        for s in range(len(cfg.block_pattern)):
            slot_p = cell_p[f"slot{s}"]
            sc = cell_cache[f"slot{s}"]
            kind = cfg.layer_kind(s)
            h = rms_norm(x, slot_p["norm_mixer"], cfg.norm_eps)
            if kind in (ATTN, ATTN_LOCAL):
                y, nk, nv = attn.decode_self_attention(
                    slot_p["attn"], h, sc["k"], sc["v"], pos, cfg,
                    kind=kind, dtype=dtype,
                )
                new_cache[f"slot{s}"] = {"k": nk, "v": nv}
                if "ck" in sc:  # enc-dec: cached cross K/V (read-only)
                    new_cache[f"slot{s}"]["ck"] = sc["ck"]
                    new_cache[f"slot{s}"]["cv"] = sc["cv"]
            elif kind == MAMBA:
                y, (conv, hst) = mamba_mod.mamba_decode_step(
                    slot_p["mamba"], h, cfg, dtype, (sc["conv"], sc["h"])
                )
                new_cache[f"slot{s}"] = {"conv": conv, "h": hst}
            elif kind == MLSTM:
                y, (C, n) = xlstm_mod.mlstm_apply(
                    slot_p["mlstm"], h, cfg, dtype, chunk=1, state=(sc["C"], sc["n"])
                )
                new_cache[f"slot{s}"] = {"C": C, "n": n}
            elif kind == SLSTM:
                st = tuple(sc[f"s{i}"] for i in range(4))
                y, st = xlstm_mod.slstm_apply(slot_p["slstm"], h, cfg, dtype, state=st)
                new_cache[f"slot{s}"] = {f"s{i}": t for i, t in enumerate(st)}
            x = x + y
            if "ck" in sc:  # cached cross-attention K/V from prefill
                hc = rms_norm(x, slot_p["norm_cross"], cfg.norm_eps)
                x = x + attn.cross_decode_attention(
                    slot_p["cross"], hc, sc["ck"], sc["cv"], cfg, dtype=dtype
                )
            elif memory is not None:
                hc = rms_norm(x, slot_p["norm_cross"], cfg.norm_eps)
                x = x + attn.cross_attention(slot_p["cross"], hc, memory, cfg, dtype=dtype)
            x, _ = _ffn_part(slot_p, x, cfg, dtype, None)
        return x, new_cache

    x, new_cache = jax.lax.scan(cell, x, (params["cells"], cache), unroll=scan_unroll_arg())
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = unembed_logits(x[:, 0], table, cfg.vocab_size, dtype, cfg.logit_softcap)
    return logits, new_cache
