"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM
[arXiv:2405.04517], TPU-adapted.

- **mLSTM** (matrix memory, fully parallelizable): C_t = f_t C_{t-1} +
  i_t v_t k_tᵀ, h_t = (q_t·C_t) / max(|q_t·n_t|, 1).  Computed chunkwise
  like the SSD scan (decay matrices from cumulative log-f gates, state
  carried across chunks) — the MXU-friendly form; gates are
  log-sigmoid-stabilized.
- **sLSTM** (scalar memory, inherently sequential): per-timestep
  ``lax.scan`` with block-diagonal (per-head) recurrent weights and the
  paper's m-state exponential stabilization.  The xLSTM paper itself
  resorts to a fused recurrent GPU kernel here; on TPU this stays a
  sequential scan (documented in DESIGN.md §Arch-applicability).

The xLSTM-1.3b config uses d_ff = 0: mLSTM blocks pre-up-project 2×,
sLSTM blocks carry a 4/3 gated MLP, matching the paper's block designs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.layers import dense_init, rms_norm
from repro.models.flags import scan_unroll_arg


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def mlstm_init(key, cfg):
    """TP layout (EXPERIMENTS.md §Perf B): the mLSTM state is an OUTER
    PRODUCT C = Σ k⊗v, so the only shardable inner dim is hd_v — q, k and
    the gates stay model-replicated (their projections are local given a
    replicated xi), v/z/h are hd_v-sharded, and the block pays exactly
    ONE activation all-reduce, at down_proj (row-parallel).  The previous
    layout (xi TP-sharded, q/k/v row-parallel) paid THREE f32 [B,S,nh,hd]
    all-reduces per layer — 21.5 GiB per supercell at prefill_32k."""
    d = cfg.d_model
    nh = cfg.n_heads
    d_inner = 2 * d
    hd = d_inner // nh
    k = jax.random.split(key, 8)
    return {
        "up_x": dense_init(k[0], d, d_inner),      # replicated branch
        "up_z": dense_init(k[7], d, (nh, hd)),     # gate branch, hd_v-sharded
        "wq": dense_init(k[1], d_inner, (nh, hd)),
        "wk": dense_init(k[2], d_inner, (nh, hd)),
        "wv": dense_init(k[3], d_inner, (nh, hd)),
        "wi": dense_init(k[4], d_inner, nh, scale=0.01),
        "wf": dense_init(k[5], d_inner, nh, scale=0.01),
        "bf": jnp.full((nh,), 3.0),  # forget-gate bias → long memory at init
        "out_norm": jnp.zeros((nh, hd), jnp.float32),  # per-head norm
        "down_proj": jax.random.normal(k[6], (nh, hd, d), jnp.float32)
        / (d_inner ** 0.5),
    }


def mlstm_chunk_scan(q, k, v, logf, logi, chunk: int, state=None):
    """Chunkwise mLSTM.

    q,k,v: [B,S,nh,hd]; logf,logi: [B,S,nh] (log-sigmoid forget, log input).
    Returns (h [B,S,nh,hd], (C [B,nh,hd,hd], n [B,nh,hd])).
    """
    B, S, nh, hd = q.shape
    L = min(chunk, S)
    assert S % L == 0
    nchunk = S // L
    scale = hd ** -0.5

    def resh(t, extra):
        return t.reshape((B, nchunk, L) + extra).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(extra)))
        )

    qc, kc, vc = (resh(t, (nh, hd)) for t in (q, k, v))
    fc = resh(logf, (nh,))
    ic = resh(logi, (nh,))

    if state is None:
        C0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, nh, hd), jnp.float32)
    else:
        C0, n0 = state

    def step(carry, inp):
        C, n = carry
        qk, kk, vk, fk, ik = inp
        cum = jnp.cumsum(fk, axis=1)                       # [B,L,nh]
        # stabilized intra-chunk weights: w[t,s] = exp(cum_t - cum_s + i_s - m_t)
        logw = (
            cum[:, :, None, :] - cum[:, None, :, :] + ik[:, None, :, :]
        )  # [B,t,s,nh]
        tri = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        logw = jnp.where(tri, logw, -jnp.inf)
        m_intra = jnp.max(logw, axis=2)                    # [B,t,nh]
        m_state = cum                                      # state weight log-scale
        m = jnp.maximum(m_intra, m_state)
        m = jnp.maximum(m, 0.0)
        w = jnp.exp(logw - m[:, :, None, :])               # [B,t,s,nh]
        scores = jnp.einsum("bthd,bshd->btsh", qk, kk) * scale
        num_intra = jnp.einsum("btsh,btsh,bshd->bthd", scores, w, vk)
        n_intra = jnp.einsum("btsh,bshd->bthd", w, kk)     # running key sum
        den_intra = jnp.einsum("bthd,bthd->bth", qk, n_intra) * scale
        state_w = jnp.exp(cum - m)                         # [B,L,nh]
        num_state = jnp.einsum("bthd,bhde->bthe", qk * state_w[..., None], C) * scale
        den_state = jnp.einsum("bthd,bhd->bth", qk * state_w[..., None], n) * scale
        h = (num_intra + num_state) / jnp.maximum(
            jnp.abs(den_intra + den_state), jnp.exp(-m) + 1e-6
        )[..., None]
        # state update (unnormalized, log-stabilized at chunk granularity)
        tot = cum[:, -1]                                   # [B,nh]
        rel = jnp.exp(tot[:, None] - cum + ik)             # [B,L,nh]
        C_new = C * jnp.exp(tot)[:, :, None, None] + jnp.einsum(
            "blhd,blhe->bhde", kk * rel[..., None], vk
        )
        n_new = n * jnp.exp(tot)[:, :, None] + jnp.einsum(
            "blhd,blh->bhd", kk, rel
        )
        return (C_new, n_new), h.astype(q.dtype)

    # note: num_intra already includes scores×w; rescale with q in einsum
    (Cf, nf), hs = jax.lax.scan(step, (C0, n0), (qc, kc, vc, fc, ic), unroll=scan_unroll_arg())
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, hd)
    return h, (Cf, nf)


def mlstm_apply(p, x, cfg, dtype, chunk: int = 256, state=None):
    B, S, D = x.shape
    nh = cfg.n_heads
    d_inner = 2 * D
    xi = jnp.einsum("bsd,de->bse", x.astype(dtype),
                    shard(p["up_x"], "embed", None).astype(dtype),
                    preferred_element_type=jnp.float32).astype(dtype)
    z = jnp.einsum("bsd,dhk->bshk", x.astype(dtype),
                   shard(p["up_z"], "embed", None, "mlp").astype(dtype),
                   preferred_element_type=jnp.float32).astype(dtype)
    # xi is model-replicated; q/k projections are therefore local …
    q = jnp.einsum("bse,ehd->bshd", xi, p["wq"].astype(dtype),
                   preferred_element_type=jnp.float32)
    k = jnp.einsum("bse,ehd->bshd", xi, p["wk"].astype(dtype),
                   preferred_element_type=jnp.float32)
    # … and v is hd_v-sharded (column-parallel) — the one inner dim the
    # outer-product state C = Σ k⊗v can shard without cross-talk.
    v = jnp.einsum("bse,ehd->bshd", xi, p["wv"].astype(dtype),
                   preferred_element_type=jnp.float32)
    v = shard(v, "batch", "seq", None, "mlp_act")
    logi = jnp.einsum("bse,eh->bsh", xi, p["wi"].astype(dtype),
                      preferred_element_type=jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", xi, p["wf"].astype(dtype),
                   preferred_element_type=jnp.float32) + p["bf"]
    )
    h, new_state = mlstm_chunk_scan(q, k, v, logf, logi, chunk, state)
    # per-head norm (xLSTM's MultiHeadLayerNorm) keeps everything in the
    # hd_v-sharded [B,S,nh,hd] form — no strided reshape/regather
    h = rms_norm(h, p["out_norm"]) * jax.nn.silu(z.astype(jnp.float32)).astype(dtype)
    out = jnp.einsum("bshk,hkd->bsd", h.astype(dtype),
                     shard(p["down_proj"], None, "mlp", "embed").astype(dtype),
                     preferred_element_type=jnp.float32)
    return out.astype(dtype), new_state


def mlstm_init_state(cfg, batch: int):
    nh = cfg.n_heads
    hd = 2 * cfg.d_model // nh
    return (
        jnp.zeros((batch, nh, hd, hd), jnp.float32),
        jnp.zeros((batch, nh, hd), jnp.float32),
    )


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def slstm_init(key, cfg):
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    k = jax.random.split(key, 6)
    return {
        "w_gates": dense_init(k[0], d, (4, nh, hd)),       # i f z o from x
        "r_gates": jax.random.normal(k[1], (4, nh, hd, hd), jnp.float32)
        / (hd**0.5),                                        # block-diag recurrents
        "b_gates": jnp.zeros((4, nh, hd), jnp.float32),
        "up1": dense_init(k[2], d, (4 * d) // 3),
        "up2": dense_init(k[3], d, (4 * d) // 3),
        "down": dense_init(k[4], (4 * d) // 3, d),
    }


def slstm_apply(p, x, cfg, dtype, state=None):
    """x: [B,S,D] → (y, state).  state = (c, n, h, m) each [B,nh,hd]."""
    B, S, D = x.shape
    nh = cfg.n_heads
    hd = D // nh
    gates_x = jnp.einsum("bsd,dghe->bsghe", x.astype(dtype),
                         p["w_gates"].astype(dtype),
                         preferred_element_type=jnp.float32)  # [B,S,4,nh,hd]

    if state is None:
        zeros = jnp.zeros((B, nh, hd), jnp.float32)
        state = (zeros, zeros, zeros, zeros - 10.0)

    R = p["r_gates"]

    def step(carry, gx):
        c, n, h, m = carry
        rec = jnp.einsum("bhe,ghef->bghf", h, R)          # [B,4,nh,hd]
        it, ft, zt, ot = [gx[:, g] + rec[:, g] + p["b_gates"][g] for g in range(4)]
        # exponential-gate stabilization (xLSTM eq. 15-17)
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        c_new = f_s * c + i_s * jnp.tanh(zt)
        n_new = f_s * n + i_s
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new.astype(x.dtype)

    state, hs = jax.lax.scan(step, state, gates_x.transpose(1, 0, 2, 3, 4))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, D)
    # post-up gated MLP (4/3 factor)
    g = jnp.einsum("bsd,de->bse", y.astype(dtype), p["up1"].astype(dtype),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("bsd,de->bse", y.astype(dtype), p["up2"].astype(dtype),
                   preferred_element_type=jnp.float32)
    h = (jax.nn.gelu(g) * u).astype(dtype)
    out = jnp.einsum("bse,ed->bsd", h, p["down"].astype(dtype),
                     preferred_element_type=jnp.float32)
    return out.astype(dtype), state


def slstm_init_state(cfg, batch: int):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return (z, z, z, z - 10.0)
