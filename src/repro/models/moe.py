"""Top-k token-choice MoE with capacity-based dispatch and **explicit
expert parallelism** (all-to-all under shard_map).

Why not GShard one-hot dispatch einsums: with few experts and long
sequences (olmoe: E=64, 1M tokens/batch) the [tokens, E, capacity]
dispatch tensor is astronomically large — the dispatch-matrix formulation
only works when capacity is tiny.  The production formulation is
scatter-based:

  1. each (data, model) rank takes its 1/|model| slice of the local
     tokens (activations are model-replicated),
  2. routes them into a [E, C, D] send buffer (scatter, capacity C per
     (source-rank, expert) — overflow drops to the residual),
  3. ``all_to_all`` over the *model* axis re-buckets by expert owner
     (E/|model| experts per rank),
  4. dense per-expert SwiGLU on [E_loc, |model|·C, D] (MXU-friendly),
  5. reverse all_to_all, gather+gate-combine, psum over the model axis
     (each rank contributed a disjoint token slice).

Without a mesh the same code runs the P=1 path (no collectives) — used
by the CPU smoke tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import active_mesh, active_rules, shard, shard_map
from repro.models.layers import dense_init


def moe_init(key, cfg):
    assert cfg.moe is not None
    E = cfg.moe.num_experts
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, E, scale=0.02),
        "wi": jax.random.normal(ks[1], (E, d, dff), jnp.float32) / (d**0.5),
        "wu": jax.random.normal(ks[2], (E, d, dff), jnp.float32) / (d**0.5),
        "wo": jax.random.normal(ks[3], (E, dff, d), jnp.float32) / (dff**0.5),
    }


# -- core (runs per-rank inside shard_map, or whole-array without a mesh) ----


def _route(p, xt, cfg, dtype):
    """xt: [n, D] → (gate_vals [n,K], gate_idx [n,K], aux)."""
    mcfg = cfg.moe
    logits = jnp.einsum(
        "nd,de->ne", xt.astype(dtype), p["router"].astype(dtype),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, mcfg.top_k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    density = jnp.zeros(mcfg.num_experts).at[gate_idx.reshape(-1)].add(1.0)
    density = density / gate_idx.size
    lb_loss = mcfg.num_experts * jnp.sum(density * probs.mean(0))
    z_loss = mcfg.router_z_loss * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1))
    )
    return gate_vals, gate_idx, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}


def _dispatch_scatter(xt, gate_idx, E: int, C: int):
    """Scatter tokens into [E, C, D]; returns (buffer, slot_of [n,K], kept)."""
    n, K = gate_idx.shape
    flat_e = gate_idx.reshape(-1)                       # [n*K]
    # rank of each assignment within its expert bucket
    onehot_pos = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot_pos, axis=0) - 1            # [n*K, E]
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    kept = slot < C
    dest = jnp.where(kept, flat_e * C + slot, E * C)    # overflow → dropped row
    buf = jnp.zeros((E * C + 1, xt.shape[1]), xt.dtype)
    buf = buf.at[dest].add(jnp.repeat(xt, K, axis=0) * kept[:, None].astype(xt.dtype))
    return buf[: E * C].reshape(E, C, xt.shape[1]), dest, kept


def _expert_ffn(p, h_in, dtype):
    """h_in: [E_loc, T, D] → [E_loc, T, D] through each expert's SwiGLU."""
    wi, wu, wo = p["wi"], p["wu"], p["wo"]
    g = jnp.einsum("etd,edf->etf", h_in.astype(dtype), wi.astype(dtype),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("etd,edf->etf", h_in.astype(dtype), wu.astype(dtype),
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(dtype)
    return jnp.einsum("etf,efd->etd", h, wo.astype(dtype),
                      preferred_element_type=jnp.float32).astype(dtype)


def _combine(buf_out, dest, kept, gate_vals, n: int, K: int, D: int, dtype):
    flat = buf_out.reshape(-1, D)
    flat = jnp.concatenate([flat, jnp.zeros((1, D), flat.dtype)], axis=0)
    per_assignment = flat[dest]                          # [n*K, D]
    w = (gate_vals.reshape(-1) * kept).astype(dtype)
    return (per_assignment * w[:, None]).reshape(n, K, D).sum(axis=1)


def moe_apply(p, x, cfg, dtype, ep_axis: str = "model"):
    """x: [B,S,D] → ([B,S,D], aux).  Uses EP over ``ep_axis`` when a mesh
    with that axis is active and E % axis_size == 0."""
    mesh = active_mesh()
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    cf = cfg.moe.capacity_factor

    if mesh is not None and ep_axis in mesh.shape and E % mesh.shape[ep_axis] == 0 and mesh.shape[ep_axis] > 1:
        rules = active_rules()
        batch_spec = rules.physical("batch") if rules else ("data",)
        n_ep = mesh.shape[ep_axis]
        # batch too small for the batch axes (decode / long-context)?
        # replicate it instead of sharding.
        from repro.dist.sharding import _valid_spec

        x_spec = _valid_spec(mesh, P(batch_spec, None, None), x.shape)
        b_axes = x_spec[0]
        n_b = 1
        for a in (b_axes if isinstance(b_axes, tuple) else (b_axes,)) or ():
            n_b *= mesh.shape.get(a, 1) if a else 1
        tokens_per_shard = (B // max(n_b, 1)) * S
        small = tokens_per_shard % n_ep != 0

        def ep_block_small(params, xl):
            """Decode-friendly EP: routing is model-replicated; each rank
            runs only its resident experts and psums the combined output.
            No all_to_all — the token count is tiny (one step per request),
            so the [n,D] psum is cheaper than re-bucketing."""
            b, s, d = xl.shape
            xt = xl.reshape(b * s, d)
            gate_vals, gate_idx, aux = _route(params, xt, cfg, dtype)
            C = max(1, -(-(b * s * K) // E))  # ceil; no drops at decode
            buf, dest, kept = _dispatch_scatter(xt.astype(dtype), gate_idx, E, C)
            e_loc = E // n_ep
            ridx = jax.lax.axis_index(ep_axis)
            buf_loc = jax.lax.dynamic_slice_in_dim(buf, ridx * e_loc, e_loc, 0)
            out_loc = _expert_ffn(params, buf_loc, dtype)
            out = jnp.zeros((E, C, d), out_loc.dtype)
            out = jax.lax.dynamic_update_slice_in_dim(out, out_loc, ridx * e_loc, 0)
            yt = _combine(out, dest, kept, gate_vals, b * s, K, d, dtype)
            yt = jax.lax.psum(yt, ep_axis)
            return yt.reshape(b, s, d), aux

        def ep_block(params, xl):
            # xl: [b_loc, S, D] (model-replicated); take this rank's slice
            b, s, d = xl.shape
            xt = xl.reshape(b * s, d)
            n_total = b * s
            assert n_total % n_ep == 0, (n_total, n_ep)
            n_loc = n_total // n_ep
            ridx = jax.lax.axis_index(ep_axis)
            xt_slice = jax.lax.dynamic_slice_in_dim(xt, ridx * n_loc, n_loc, 0)
            gate_vals, gate_idx, aux = _route(params, xt_slice, cfg, dtype)
            C = max(1, int(n_loc * K * cf) // E)
            buf, dest, kept = _dispatch_scatter(
                xt_slice.astype(dtype), gate_idx, E, C
            )
            # all_to_all: expert dim split across ranks, contributions concat
            buf = jax.lax.all_to_all(
                buf, ep_axis, split_axis=0, concat_axis=1, tiled=True
            )  # [E/n_ep, n_ep*C, D]
            out = _expert_ffn(params, buf, dtype)  # params carry local experts
            out = jax.lax.all_to_all(
                out, ep_axis, split_axis=1, concat_axis=0, tiled=True
            )  # back to [E, C, D]
            yt = _combine(out, dest, kept, gate_vals, n_loc, K, d, dtype)
            # reassemble full token set over the model axis
            full = jnp.zeros((n_total, d), dtype)
            full = jax.lax.dynamic_update_slice_in_dim(full, yt, ridx * n_loc, 0)
            full = jax.lax.psum(full, ep_axis)
            aux = {k: jax.lax.pmean(v, ep_axis) for k, v in aux.items()}
            return full.reshape(b, s, d), aux

        # expert weights enter sharded over their expert dim (EP-resident);
        # the router is replicated.
        param_specs = {
            "router": P(None, None),
            "wi": P(ep_axis, None, None),
            "wu": P(ep_axis, None, None),
            "wo": P(ep_axis, None, None),
        }
        y, aux = shard_map(
            ep_block_small if small else ep_block,
            mesh=mesh,
            in_specs=(param_specs, x_spec),
            out_specs=(x_spec, P()),
            check_vma=False,
        )(p, x)
        return y.astype(dtype), aux

    # ---- single-rank path (no mesh / EP not possible) ----
    xt = x.reshape(B * S, D)
    gate_vals, gate_idx, aux = _route(p, xt, cfg, dtype)
    C = max(1, int(B * S * K * cf) // E)
    C = min(C, B * S)
    buf, dest, kept = _dispatch_scatter(xt.astype(dtype), gate_idx, E, C)
    out = _expert_ffn(p, buf, dtype)
    yt = _combine(out, dest, kept, gate_vals, B * S, K, D, dtype)
    return yt.reshape(B, S, D).astype(dtype), aux
