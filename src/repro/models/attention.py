"""GQA attention: chunked-flash training/prefill, cached decode.

Covers the per-arch variants: RoPE, QKV bias (qwen2), attention-logit
softcap (gemma2), sliding-window local attention (gemma2 local layers —
*a stencil on the sequence axis*, see DESIGN.md §4), and cross-attention
(seamless decoder).

The training/prefill path is chunked over queries (lax.scan) so the
S×S score matrix never materializes — the pure-JAX flash formulation the
Pallas kernel (repro.kernels.sliding_attention) replaces on real TPUs.

Decode supports two cache shardings (picked by the framework per config):
heads-sharded (kv_heads % model_axis == 0) or sequence-sharded (the
paper's domain-decomposition idea applied to the KV domain; XLA turns the
softmax/PV reductions over the sharded axis into small all-reduces).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import active_mesh, kv_cache_layout, shard, shard_map
from repro.models.layers import apply_rope, dense_init, matmul, softcap
from repro.models.flags import scan_unroll_arg

NEG_INF = -1e30


def attn_init(key, cfg):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (h, hd)),
        "wk": dense_init(ks[1], d, (kh, hd)),
        "wv": dense_init(ks[2], d, (kh, hd)),
        "wo": dense_init(ks[3], h * hd, d) .reshape(h, hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kh, hd), jnp.float32)
        p["bv"] = jnp.zeros((kh, hd), jnp.float32)
    return p


def _project_qkv(p, x, xkv, cfg, dtype, q_positions, kv_positions):
    """x: [B,S,D] queries source; xkv: [B,T,D] key/value source."""
    wq = shard(p["wq"], "embed", "q_heads_p", None)
    wk = shard(p["wk"], "embed", "kv_heads_p", None)
    wv = shard(p["wv"], "embed", "kv_heads_p", None)
    q = jnp.einsum("bsd,dhk->bshk", x.astype(dtype), wq.astype(dtype),
                   preferred_element_type=jnp.float32)
    k = jnp.einsum("btd,dhk->bthk", xkv.astype(dtype), wk.astype(dtype),
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("btd,dhk->bthk", xkv.astype(dtype), wv.astype(dtype),
                   preferred_element_type=jnp.float32)
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if q_positions is not None:  # rope (self-attention only)
        q = apply_rope(q.astype(dtype), q_positions, cfg.rope_theta)
        k = apply_rope(k.astype(dtype), kv_positions, cfg.rope_theta)
    q = shard(q.astype(dtype), "batch", "seq", "heads", None)
    k = shard(k.astype(dtype), "batch", "seq", "kv_heads", None)
    v = shard(v.astype(dtype), "batch", "seq", "kv_heads", None)
    return q, k, v


def _out_proj(p, o, cfg, dtype):
    wo = shard(p["wo"], "q_heads_p", None, "embed")
    out = jnp.einsum("bshk,hkd->bsd", o.astype(dtype), wo.astype(dtype),
                     preferred_element_type=jnp.float32)
    return shard(out.astype(dtype), "batch", "seq", "embed_act")


def chunked_attention(
    q, k, v, *,
    causal: bool,
    window: int = 0,
    attn_softcap: float = 0.0,
    q_chunk: int = 1024,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,
    dtype=jnp.bfloat16,
):
    """q: [B,S,H,D], k/v: [B,T,Kh,D] → [B,S,H,D].

    Scans over query chunks; scores per step are [B, C, H, T] so peak
    memory is C/S of the naive product.  ``window > 0`` restricts to a
    causal sliding window (local attention).  ``kv_len`` masks a partially
    filled cache.
    """
    B, S, H, D = q.shape
    T, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, S)
    n_chunks = S // q_chunk if S % q_chunk == 0 else 1
    if S % q_chunk != 0:
        q_chunk = S

    qg = q.reshape(B, S, Kh, G, D)
    kv_pos = jnp.arange(T)

    def one_chunk(ci, qc):
        # qc: [B,C,Kh,G,D]
        s = jnp.einsum("bckgd,btkd->bckgt", qc.astype(dtype), k.astype(dtype),
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, attn_softcap)
        qpos = q_offset + ci * q_chunk + jnp.arange(q_chunk)
        mask = jnp.ones((q_chunk, T), bool)
        if causal:
            mask &= kv_pos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kv_pos[None, :] > qpos[:, None] - window
        if kv_len is not None:
            mask &= kv_pos[None, :] < kv_len
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bckgt,btkd->bckgd", p.astype(dtype), v.astype(dtype),
                       preferred_element_type=jnp.float32)
        return o.astype(dtype)

    if n_chunks == 1:
        out = one_chunk(0, qg)
    else:
        qs = qg.reshape(B, n_chunks, q_chunk, Kh, G, D).transpose(1, 0, 2, 3, 4, 5)

        def body(_, x):
            ci, qc = x
            return None, one_chunk(ci, qc)

        _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), qs), unroll=scan_unroll_arg())
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Kh, G, D)
    return out.reshape(B, S, H, D)


def self_attention(
    p, x, cfg, *, kind: str, dtype, positions=None, q_chunk: int = 1024
):
    """Training/prefill self-attention; returns [B,S,D] plus (k, v) for
    cache writes."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, x, x, cfg, dtype, positions, positions)
    window = cfg.local_window if kind == "attn_local" else 0
    o = chunked_attention(
        q, k, v,
        causal=True,
        window=window,
        attn_softcap=cfg.attn_softcap,
        q_chunk=q_chunk,
        dtype=dtype,
    )
    return _out_proj(p, o, cfg, dtype), (k, v)


def cross_attention(p, x, memory, cfg, *, dtype):
    """Decoder cross-attention over encoder output (no rope, no mask)."""
    q, k, v = _project_qkv(p, x, memory, cfg, dtype, None, None)
    o = chunked_attention(q, k, v, causal=False, dtype=dtype)
    return _out_proj(p, o, cfg, dtype)


def project_cross_kv(p, memory, cfg, dtype):
    """Cross-attention K/V of the encoder memory (cached at prefill)."""
    k = jnp.einsum("btd,dhk->bthk", memory.astype(dtype), p["wk"].astype(dtype),
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("btd,dhk->bthk", memory.astype(dtype), p["wv"].astype(dtype),
                   preferred_element_type=jnp.float32)
    if cfg.qkv_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    return k.astype(dtype), v.astype(dtype)


def cross_decode_attention(p, x, ck, cv, cfg, *, dtype):
    """One-token cross-attention against cached encoder K/V."""
    B = x.shape[0]
    wq = shard(p["wq"], "embed", "q_heads_p", None)
    q = jnp.einsum("bsd,dhk->bshk", x.astype(dtype), wq.astype(dtype),
                   preferred_element_type=jnp.float32)
    if cfg.qkv_bias:
        q = q + p["bq"]
    Kh = ck.shape[2]
    H = q.shape[2]
    G = H // Kh
    qg = q.reshape(B, 1, Kh, G, q.shape[-1]).astype(dtype)
    s = jnp.einsum("bckgd,btkd->bckgt", qg, ck.astype(dtype),
                   preferred_element_type=jnp.float32) / math.sqrt(q.shape[-1])
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bckgt,btkd->bckgd", pattn.astype(dtype), cv.astype(dtype),
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, H, q.shape[-1]).astype(dtype)
    return _out_proj(p, o, cfg, dtype)


def decode_self_attention(
    p, x, cache_k, cache_v, pos, cfg, *, kind: str, dtype
):
    """One-token decode.  x: [B,1,D]; cache_k/v: [B,T,Kh,D]; pos: scalar
    current position.  Returns (out [B,1,D], new_k, new_v).

    Local layers use a *rolling* cache of size window (position mod W) —
    the sequence-stencil footprint bounds the state, exactly the halo
    argument from DESIGN.md §4.
    """
    B = x.shape[0]
    T = cache_k.shape[1]
    mesh = active_mesh()
    layout = (
        kv_cache_layout(B, T, cache_k.shape[2], mesh)
        if mesh is not None and mesh.shape.get("model", 1) > 1 else "flat"
    )
    pos = jnp.asarray(pos)
    per_seq = pos.ndim == 1  # continuous batching: one position per slot
    positions = pos[:, None] if per_seq else jnp.full((B, 1), pos)
    q, k, v = _project_qkv(p, x, x, cfg, dtype, positions, positions)
    slot = jnp.where(jnp.asarray(T > 0), positions[:, 0] % T, 0)  # [B]
    if per_seq:
        upd = jax.vmap(
            lambda c, kv, s: jax.lax.dynamic_update_slice(c, kv, (s, 0, 0))
        )
        cache_k = upd(cache_k, k.astype(cache_k.dtype), slot)
        cache_v = upd(cache_v, v.astype(cache_v.dtype), slot)
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, slot[0], 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, slot[0], 0, 0))
    # constrain the updated cache to the SAME layout the spec builder
    # chose (kv_cache_layout) — a mismatched constraint here (e.g. a
    # blanket "replicated along T") makes GSPMD all-gather the whole
    # cache every layer (measured: +4.8 GiB/layer/device for yi-9b
    # decode_32k; EXPERIMENTS.md §Perf A3)
    cache_k = _constrain_cache(cache_k, layout, mesh)
    cache_v = _constrain_cache(cache_v, layout, mesh)

    window = cfg.local_window if kind == "attn_local" else 0
    # valid entries: rolling cache holds [max(0,pos-T+1), pos]
    kv_pos = jnp.arange(T)[None, :]                               # [1,T]
    posb = positions                                              # [B,1]
    slotb = slot[:, None]                                         # [B,1]
    # reconstruct absolute position of each slot in the rolling cache
    abs_pos = jnp.where(
        kv_pos <= slotb, posb - (slotb - kv_pos), posb - (slotb + T - kv_pos)
    )                                                             # [B,T]
    valid = (abs_pos >= 0) & (abs_pos <= posb)
    if window > 0:
        valid &= abs_pos > posb - window

    Kh = cache_k.shape[2]
    H = q.shape[2]
    G = H // Kh
    hd = q.shape[-1]
    qg = q.reshape(B, Kh, G, hd)

    if layout in ("seq", "seq_all"):
        # distributed flash-decode: the cache is *sequence-sharded* over
        # the model axis (dmp-style domain decomposition of the KV
        # domain).  Each shard reduces its local slice with an online
        # softmax; shards combine via an LSE-weighted psum of (denom,
        # accum) — O(B·H·hd) bytes on the wire instead of gathering the
        # O(B·T·Kh·hd) cache.
        o = _flash_decode_sharded(
            qg, cache_k, cache_v, valid, cfg, dtype, mesh, layout
        )
    elif T > DECODE_KV_CHUNK and T % DECODE_KV_CHUNK == 0:
        # flash-style decode: online softmax over KV chunks, so the f32
        # score tensor is [B,Kh,G,chunk] instead of [...,T] — bounds peak
        # memory for 32k+ caches (yi-9b decode_32k: 25.7 → <16 GiB/dev)
        o = _online_softmax_decode(qg, cache_k, cache_v, valid, cfg, dtype)
    else:
        s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(dtype),
                       cache_k.astype(dtype),
                       preferred_element_type=jnp.float32)
        s = s / math.sqrt(hd)
        s = softcap(s, cfg.attn_softcap)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        pattn = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgt,btkd->bkgd", pattn.astype(dtype),
                       cache_v.astype(dtype),
                       preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, H, hd).astype(dtype)
    return _out_proj(p, o, cfg, dtype), cache_k, cache_v


DECODE_KV_CHUNK = 4096


def _constrain_cache(c, layout: str, mesh):
    """Pin a [B,T,Kh,hd] cache to the layout from ``kv_cache_layout``."""
    if mesh is None or layout == "flat":
        return c
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist.sharding import _valid_spec, active_rules, default_rules

    rules = active_rules() or default_rules("pod" in mesh.axis_names)
    batch_ax = rules.physical("batch")
    if layout == "heads":
        spec = P(batch_ax, None, "model", None)
    elif layout == "seq":
        spec = P(batch_ax, "model", None, None)
    elif layout == "seq_all":
        axes = batch_ax if isinstance(batch_ax, tuple) else (batch_ax,)
        spec = P(None, tuple(a for a in axes if a) + ("model",), None, None)
    else:  # "batch"
        spec = P(batch_ax, None, None, None)
    return jax.lax.with_sharding_constraint(
        c, NamedSharding(mesh, _valid_spec(mesh, spec, tuple(c.shape)))
    )


def _flash_decode_sharded(qg, cache_k, cache_v, valid, cfg, dtype, mesh, layout):
    """qg: [B,Kh,G,hd] (seq-replicated); cache_k/v: [B,T,Kh,hd] with T
    sharded — over "model" (layout "seq") or over every axis (layout
    "seq_all", tiny-batch long context); valid: [B,T].  Returns o
    [B,Kh,G,hd].  Per-shard online softmax + cross-shard LSE combine
    (flash-decoding / tree attention).  The in_specs mirror
    ``launch.steps.kv_cache_spec`` exactly (same ``kv_cache_layout``)."""
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import _valid_spec, active_rules, default_rules

    rules = active_rules() or default_rules("pod" in mesh.axis_names)
    batch_ax = rules.physical("batch")
    B, T = valid.shape
    hd = qg.shape[-1]
    scale = 1.0 / math.sqrt(hd)

    if layout == "seq":
        seq_axes: tuple = ("model",)
        kv_spec = _valid_spec(mesh, P(batch_ax, "model", None, None),
                              tuple(cache_k.shape))
        q_spec = _valid_spec(mesh, P(batch_ax, None, None, None),
                             tuple(qg.shape))
    else:  # "seq_all": batch too small to shard — everything on T
        axes = batch_ax if isinstance(batch_ax, tuple) else (batch_ax,)
        seq_axes = tuple(a for a in axes if a) + ("model",)
        kv_spec = _valid_spec(mesh, P(None, seq_axes, None, None),
                              tuple(cache_k.shape))
        q_spec = P(None, None, None, None)
    v_spec = _valid_spec(mesh, P(q_spec[0], kv_spec[1]), (B, T))

    def block(qg_l, k_l, v_l, ok_l):
        s = jnp.einsum("bkgd,btkd->bkgt", qg_l.astype(dtype), k_l.astype(dtype),
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cfg.attn_softcap)
        s = jnp.where(ok_l[:, None, None, :], s, NEG_INF)
        m = s.max(-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(-1)
        acc = jnp.einsum("bkgt,btkd->bkgd", p.astype(dtype), v_l.astype(dtype),
                         preferred_element_type=jnp.float32)
        # LSE combine across sequence shards
        ax = kv_spec[1]
        ax = ax if isinstance(ax, tuple) else (ax,)
        g = jax.lax.pmax(m, ax)
        r = jnp.exp(m - g)
        l = jax.lax.psum(l * r, ax)
        acc = jax.lax.psum(acc * r[..., None], ax)
        return acc / jnp.maximum(l, 1e-30)[..., None]

    return shard_map(
        block,
        mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, v_spec),
        out_specs=q_spec,
        check_vma=False,
    )(qg, cache_k, cache_v, valid)


def _online_softmax_decode(qg, cache_k, cache_v, valid, cfg, dtype):
    """qg: [B,Kh,G,hd]; cache_k/v: [B,T,Kh,hd]; valid: [B,T] →
    o [B,Kh,G,hd].  Running (max, denom, acc) over KV chunks."""
    B, Kh, G, hd = qg.shape
    T = cache_k.shape[1]
    C = DECODE_KV_CHUNK
    n = T // C
    scale = 1.0 / math.sqrt(hd)

    kc = cache_k.reshape(B, n, C, Kh, hd).transpose(1, 0, 2, 3, 4)
    vc = cache_v.reshape(B, n, C, Kh, hd).transpose(1, 0, 2, 3, 4)
    vm = valid.reshape(B, n, C).transpose(1, 0, 2)

    def body(carry, inp):
        m, l, acc = carry
        k, v, ok = inp
        s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(dtype), k.astype(dtype),
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cfg.attn_softcap)
        s = jnp.where(ok[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        r = jnp.exp(m - m_new)
        l_new = l * r + p.sum(-1)
        acc_new = acc * r[..., None] + jnp.einsum(
            "bkgt,btkd->bkgd", p.astype(dtype), v.astype(dtype),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Kh, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kh, G), jnp.float32)
    a0 = jnp.zeros((B, Kh, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, vm), unroll=scan_unroll_arg()
    )
    return acc / jnp.maximum(l, 1e-30)[..., None]
