"""Shared neural primitives (pure functions over param pytrees).

Conventions:
- params are fp32 pytrees; compute casts to the config dtype (bf16) and
  matmuls accumulate in fp32 (``preferred_element_type``);
- every weight/activation is annotated with logical axes via
  ``repro.dist.sharding.shard`` — a no-op without an active mesh.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard

VOCAB_PAD = 512  # embedding tables padded for clean TP sharding


def padded_vocab(v: int) -> int:
    return ((v + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def dense_init(key, in_dim: int, out_dims, scale: Optional[float] = None):
    out_dims = (out_dims,) if isinstance(out_dims, int) else tuple(out_dims)
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return jax.random.normal(key, (in_dim, *out_dims), jnp.float32) * scale


def matmul(x, w, dtype):
    return jax.lax.dot_general(
        x.astype(dtype),
        w.astype(dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def rms_norm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * (1.0 + gamma)).astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * (1.0 + gamma) + beta).astype(
        x.dtype
    )


def softcap(x, cap: float):
    if cap <= 0.0:
        return x
    return jnp.tanh(x / cap) * cap


# -- rotary embeddings ------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    freqs = rope_freqs(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- embedding / unembedding -------------------------------------------------


def embed_init(key, vocab: int, d_model: int):
    return (
        jax.random.normal(key, (padded_vocab(vocab), d_model), jnp.float32) * 0.02
    )


def embed_lookup(table, tokens, dtype):
    out = jnp.take(table.astype(dtype), tokens, axis=0)
    return out * jnp.asarray(math.sqrt(table.shape[1]), dtype)


def unembed_logits(x, table, vocab: int, dtype, final_softcap: float = 0.0):
    """x @ table^T with padded-column masking."""
    logits = jax.lax.dot_general(
        x.astype(dtype),
        table.astype(dtype),
        (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    logits = softcap(logits, final_softcap)
    pad = table.shape[0] - vocab
    if pad:
        mask = jnp.concatenate(
            [jnp.zeros((vocab,), jnp.float32), jnp.full((pad,), -1e9, jnp.float32)]
        )
        logits = logits + mask
    return logits


# -- MLPs ---------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, d_ff),       # gate
        "wu": dense_init(k2, d_model, d_ff),       # up
        "wo": dense_init(k3, d_ff, d_model),
    }


def swiglu_apply(p, x, dtype):
    x = shard(x, "batch", "seq", "embed_act")
    g = matmul(x, shard(p["wi"], "embed", "mlp"), dtype)
    u = matmul(x, shard(p["wu"], "embed", "mlp"), dtype)
    h = jax.nn.silu(g) * u
    h = shard(h.astype(dtype), "batch", "seq", "mlp_act")
    out = matmul(h, shard(p["wo"], "mlp", "embed"), dtype)
    return shard(out.astype(dtype), "batch", "seq", "embed_act")
