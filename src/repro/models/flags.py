"""Trace-time flags threaded through the model code.

``unroll_scans`` — XLA's ``cost_analysis`` counts a while-loop body once
(measured in EXPERIMENTS.md §Dry-run), so the roofline pass unrolls the
supercell scan and the inner chunk scans (attention q-chunks, mamba/mLSTM
chunk scans) to make HLO_FLOPs/bytes/collectives exact.  Functional runs
keep scans (flat compile time).  The sLSTM time scan is never unrolled
(4k steps); the roofline module applies its analytic correction instead.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar

_UNROLL: ContextVar[bool] = ContextVar("unroll_scans", default=False)


def unroll_scans() -> bool:
    return _UNROLL.get()


@contextlib.contextmanager
def set_unroll_scans(value: bool):
    token = _UNROLL.set(value)
    try:
        yield
    finally:
        _UNROLL.reset(token)


def scan_unroll_arg() -> int | bool:
    """Pass as lax.scan's ``unroll=``."""
    return True if _UNROLL.get() else 1
