"""Frontend tests: three DSL inputs, one shared stack (paper fig. 1b).

Validates each frontend against independent numpy oracles, and the
*cross-frontend* property that the same mathematical stencil expressed in
all three DSLs produces identical results through the shared pipeline.
"""
import numpy as np
import pytest

from repro.api import compile as api_compile, time_loop
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction
from repro.frontends.oec_like import ProgramBuilder
from repro.frontends.psyclone_like import RecognitionError, recognize


# -------------------------------------------------------------------------
# numpy oracles
# -------------------------------------------------------------------------


def np_jacobi(u, boundary="zero"):
    if boundary == "periodic":
        return 0.25 * (
            np.roll(u, 1, 0) + np.roll(u, -1, 0) + np.roll(u, 1, 1) + np.roll(u, -1, 1)
        )
    p = np.pad(u, 1)
    return 0.25 * (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:])


def np_heat(u, alpha, dt, h, order=2, boundary="zero"):
    from repro.core.fd import laplacian_star

    star = laplacian_star(2, order, spacing=h)
    out = np.zeros_like(u)
    for off, c in star.items():
        if boundary == "periodic":
            out += c * np.roll(np.roll(u, -off[0], 0), -off[1], 1)
        else:
            r = max(abs(o) for offs in star for o in offs)
            p = np.pad(u, r)
            out += c * p[
                r + off[0] : r + off[0] + u.shape[0],
                r + off[1] : r + off[1] + u.shape[1],
            ]
    return u + dt * alpha * out


# -------------------------------------------------------------------------
# Devito-like (paper listing 5)
# -------------------------------------------------------------------------


@pytest.mark.parametrize("order", [2, 4, 8])
@pytest.mark.parametrize("boundary", ["zero", "periodic"])
def test_devito_heat_matches_numpy(order, boundary):
    shape = (32, 32)
    g = Grid(shape=shape, extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=g, space_order=order)
    dt = 1e-5
    op = Operator(Eq(u.dt, 0.7 * u.laplace), dt=dt, boundary=boundary)

    rng = np.random.default_rng(0)
    u0 = rng.standard_normal(shape).astype(np.float32)
    (got,) = op.apply([u0], timesteps=3)

    want = u0.copy().astype(np.float64)
    for _ in range(3):
        want = np_heat(want, 0.7, dt, g.spacing[0], order=order, boundary=boundary)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=1e-6)


def test_devito_wave_equation_second_order_time():
    """u.dt2 = c²∇²u — the paper's acoustic benchmark shape (3 time slots)."""
    shape = (24, 24)
    g = Grid(shape=shape, extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=g, space_order=4, time_order=2)
    dt = 1e-4
    op = Operator(Eq(u.dt2, 1.5 * u.laplace), dt=dt, boundary="zero")

    rng = np.random.default_rng(1)
    um1 = rng.standard_normal(shape).astype(np.float32)
    u0 = rng.standard_normal(shape).astype(np.float32)
    state = op.zero_state()
    assert len(state) == 2  # needs t-1 and t
    got = op.apply([um1, u0], timesteps=1)[-1]  # newest buffer

    from repro.core.fd import laplacian_star

    star = laplacian_star(2, 4, spacing=g.spacing[0])
    lap = np.zeros(shape)
    r = 2
    p = np.pad(u0.astype(np.float64), r)
    for off, c in star.items():
        lap += c * p[r + off[0]: r + off[0] + 24, r + off[1]: r + off[1] + 24]
    want = 2 * u0 - um1 + dt**2 * 1.5 * lap
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=1e-6)


def test_devito_3d():
    g = Grid(shape=(12, 12, 12), extent=(1.0, 1.0, 1.0))
    u = TimeFunction(name="u", grid=g, space_order=2)
    op = Operator(Eq(u.dt, u.laplace), dt=1e-6)
    u0 = np.random.default_rng(2).standard_normal((12, 12, 12)).astype(np.float32)
    (got,) = op.apply([u0], timesteps=2)
    assert np.asarray(got).shape == (12, 12, 12)
    assert np.isfinite(np.asarray(got)).all()


def test_devito_coupled_fields():
    """Two coupled equations (v reads u) — multiple updates per step."""
    g = Grid(shape=(16, 16))
    u = TimeFunction(name="u", grid=g, space_order=2)
    v = TimeFunction(name="v", grid=g, space_order=2)
    op = Operator(
        [Eq(u.forward, u + 0.1 * v), Eq(v.forward, v.laplace)],
        boundary="periodic",
    )
    rng = np.random.default_rng(3)
    u0 = rng.standard_normal((16, 16)).astype(np.float32)
    v0 = rng.standard_normal((16, 16)).astype(np.float32)
    state = op.apply([u0, v0], timesteps=1)
    got_u, got_v = [np.asarray(s) for s in state]
    np.testing.assert_allclose(got_u, u0 + 0.1 * v0, rtol=1e-5)


# -------------------------------------------------------------------------
# PSyclone-like (stencil recognition from loop code, paper §5.2)
# -------------------------------------------------------------------------


def test_psyclone_recognizes_jacobi():
    def kern(u, out):
        out[i, j] = 0.25 * (u[i - 1, j] + u[i + 1, j] + u[i, j - 1] + u[i, j + 1])

    prog = recognize(kern, shape=(20, 20), boundary="periodic")
    rng = np.random.default_rng(4)
    u0 = rng.standard_normal((20, 20)).astype(np.float32)
    (got,) = api_compile(prog)(u0, np.zeros_like(u0))
    np.testing.assert_allclose(np.asarray(got), np_jacobi(u0, "periodic"), rtol=1e-5)


def test_psyclone_multi_statement_dependency():
    """Intermediate arrays create apply chains (tracer-advection shape)."""
    def kern(u, flux, out):
        flux[i, j] = 0.5 * (u[i + 1, j] - u[i - 1, j])
        out[i, j] = u[i, j] - 0.1 * (flux[i + 1, j] - flux[i, j])

    prog = recognize(kern, shape=(16, 16), boundary="periodic")
    rng = np.random.default_rng(5)
    u0 = rng.standard_normal((16, 16)).astype(np.float32)
    flux0 = np.zeros_like(u0)
    out0 = np.zeros_like(u0)
    results = api_compile(prog)(u0, flux0, out0)
    got_flux, got_out = [np.asarray(r) for r in results]

    want_flux = 0.5 * (np.roll(u0, -1, 0) - np.roll(u0, 1, 0))
    want_out = u0 - 0.1 * (np.roll(want_flux, -1, 0) - want_flux)
    np.testing.assert_allclose(got_flux, want_flux, rtol=1e-5)
    np.testing.assert_allclose(got_out, want_out, rtol=1e-5, atol=1e-6)


def test_psyclone_rejects_non_stencil():
    def bad(u, out):
        out[i + 1, j] = u[i, j]  # store at an offset — not recognizable

    with pytest.raises(RecognitionError):
        recognize(bad, shape=(8, 8))


def test_psyclone_3d_kernel():
    def kern(u, out):
        out[i, j, k] = (u[i, j, k - 1] + u[i, j, k + 1]) * 0.5

    prog = recognize(kern, shape=(8, 8, 8), boundary="periodic")
    u0 = np.random.default_rng(6).standard_normal((8, 8, 8)).astype(np.float32)
    (got,) = api_compile(prog)(u0, np.zeros_like(u0))
    got = np.asarray(got)
    want = 0.5 * (np.roll(u0, 1, 2) + np.roll(u0, -1, 2))
    np.testing.assert_allclose(got, want, rtol=1e-5)


# -------------------------------------------------------------------------
# OEC-like (direct stencil IR)
# -------------------------------------------------------------------------


def test_oec_builder_jacobi():
    p = ProgramBuilder("jacobi", shape=(20, 20))
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.25,
    )
    p.store(r, out)
    prog = p.finish(boundary="zero")
    rng = np.random.default_rng(7)
    u0 = rng.standard_normal((20, 20)).astype(np.float32)
    (got,) = api_compile(prog)(u0, np.zeros_like(u0))
    np.testing.assert_allclose(np.asarray(got), np_jacobi(u0, "zero"), rtol=1e-5)


# -------------------------------------------------------------------------
# cross-frontend equivalence: one math, three DSLs, one result
# -------------------------------------------------------------------------


def test_three_frontends_agree():
    shape = (24, 24)
    rng = np.random.default_rng(8)
    u0 = rng.standard_normal(shape).astype(np.float32)

    # 1. OEC
    p = ProgramBuilder("j", shape=shape)
    uf = p.input("u")
    of = p.output("out")
    t = p.load(uf)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.25,
    )
    p.store(r, of)
    r_oec = np.asarray(api_compile(p.finish(boundary="periodic"))(u0, np.zeros_like(u0))[0])

    # 2. PSyclone-like
    def kern(u, out):
        out[i, j] = 0.25 * (u[i - 1, j] + u[i + 1, j] + u[i, j - 1] + u[i, j + 1])

    r_psy = np.asarray(
        api_compile(recognize(kern, shape=shape, boundary="periodic"))(
            u0, np.zeros_like(u0)
        )[0]
    )

    # 3. Devito-like: u.forward = jacobi average — express directly via taps
    g = Grid(shape=shape, extent=shape)  # spacing 1
    u = TimeFunction(name="u", grid=g, space_order=2)
    expr = (
        u.shifted(0, -1) + u.shifted(0, 1) + u.shifted(1, -1) + u.shifted(1, 1)
    ) * 0.25
    op = Operator(Eq(u.forward, expr), boundary="periodic")
    (r_dev,) = op.apply([u0], timesteps=1)
    r_dev = np.asarray(r_dev)

    np.testing.assert_allclose(r_oec, r_psy, rtol=1e-6)
    np.testing.assert_allclose(r_oec, r_dev, rtol=1e-6)


def test_time_loop_rotation():
    """time_loop rotates buffers oldest→newest (paper's time-buffering)."""
    import jax.numpy as jnp

    def step(a, b):
        return (a + b,)

    out = time_loop(step, (jnp.array(1.0), jnp.array(1.0)), 5)
    # fibonacci: after 5 steps state = (f5, f6) = (8, 13)
    assert float(out[0]) == 8.0 and float(out[1]) == 13.0
