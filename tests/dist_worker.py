"""Multi-device distribution correctness worker.

Run in a SUBPROCESS (tests/test_distributed.py) so the 8-device flag
never leaks into the main pytest process:

    python tests/dist_worker.py <scenario>

Exit 0 = all assertions passed.  Each scenario compares an N-rank
decomposed run (shard_map + dmp halo exchanges over virtual CPU devices)
against the single-device run of the same program — the decomposition +
swap machinery is correct by test, not by construction.
"""
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.api import Target, compile as api_compile  # noqa: E402
from repro.core.passes.decompose import (  # noqa: E402
    make_strategy_1d,
    make_strategy_2d,
    make_strategy_3d,
)
from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction  # noqa: E402
from repro.frontends.oec_like import ProgramBuilder  # noqa: E402


def _jacobi(shape):
    p = ProgramBuilder("jacobi", shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    nd = len(shape)
    if nd == 2:
        r = p.apply(
            [t],
            lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.25,
        )
    else:
        r = p.apply(
            [t],
            lambda b, u: (
                u.at(-1, 0, 0) + u.at(1, 0, 0) + u.at(0, -1, 0)
                + u.at(0, 1, 0) + u.at(0, 0, -1) + u.at(0, 0, 1)
            ) * (1.0 / 6.0),
        )
    p.store(r, out)
    return p


def _box(shape):
    """Corner-reading stencil — exercises multi-round / diagonal paths."""
    p = ProgramBuilder("box", shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: u.at(-1, -1) + u.at(1, 1) * 0.5 + u.at(-1, 1) * 0.25
        + u.at(0, 0),
    )
    p.store(r, out)
    return p


def _mesh(axes_shape, names):
    devs = np.array(jax.devices()[: int(np.prod(axes_shape))]).reshape(axes_shape)
    return Mesh(devs, names)


def check(name, got, want, tol=0.0):
    got, want = np.asarray(got), np.asarray(want)
    if tol == 0.0:
        ok = np.array_equal(got, want)
    else:
        ok = np.allclose(got, want, rtol=tol, atol=tol)
    if not ok:
        print(f"MISMATCH in {name}: max abs diff "
              f"{np.abs(got - want).max()}")
        sys.exit(1)
    print(f"ok: {name}")


def run_single(builder_fn, shape, boundary):
    prog = builder_fn(shape).finish(boundary=boundary)
    rng = np.random.default_rng(42)
    u0 = rng.standard_normal(shape).astype(np.float32)
    ref = api_compile(prog)(u0, np.zeros_like(u0))
    return u0, np.asarray(ref[0])


def scenario_1d(boundary):
    shape = (64, 32)
    u0, want = run_single(_jacobi, shape, boundary)
    mesh = _mesh((8,), ("x",))
    prog = _jacobi(shape).finish(boundary=boundary)
    step = api_compile(prog, Target(mesh=mesh, strategy=make_strategy_1d(8)))
    got = step(u0, np.zeros(shape, np.float32))
    # fp32 stencil: distribution must be bitwise-identical
    check(f"1d-{boundary}", got[0], want)


def scenario_2d(boundary):
    shape = (32, 64)
    u0, want = run_single(_jacobi, shape, boundary)
    mesh = _mesh((4, 2), ("x", "y"))
    prog = _jacobi(shape).finish(boundary=boundary)
    step = api_compile(prog, Target(mesh=mesh, strategy=make_strategy_2d((4, 2))))
    got = step(u0, np.zeros(shape, np.float32))
    check(f"2d-{boundary}", got[0], want)


def scenario_3d():
    shape = (16, 16, 32)
    u0, want = run_single(_jacobi, shape, "periodic")
    mesh = _mesh((2, 2, 2), ("x", "y", "z"))
    prog = _jacobi(shape).finish(boundary="periodic")
    step = api_compile(prog, Target(mesh=mesh, strategy=make_strategy_3d((2, 2, 2))))
    got = step(u0, np.zeros(shape, np.float32))
    check("3d-periodic", got[0], want)


def scenario_box(diagonal):
    """Corner-reading stencil under 2D decomposition; with/without the
    beyond-paper diagonal-exchange rewrite."""
    shape = (32, 32)
    u0, want = run_single(_box, shape, "periodic")
    mesh = _mesh((2, 2), ("x", "y"))
    prog = _box(shape).finish(boundary="periodic")
    step = api_compile(
        prog,
        Target(mesh=mesh, strategy=make_strategy_2d((2, 2)), diagonal=diagonal),
    )
    got = step(u0, np.zeros(shape, np.float32))
    check(f"box-diagonal={diagonal}", got[0], want)


def scenario_options(opt):
    """overlap / explicit pipeline spec / pallas backend under distribution."""
    shape = (32, 64)
    u0, want = run_single(_jacobi, shape, "periodic")
    mesh = _mesh((4, 2), ("x", "y"))
    prog = _jacobi(shape).finish(boundary="periodic")
    kw = {}
    tol = 0.0
    if opt == "pallas":
        kw["backend"] = "pallas"
        tol = 1e-6
    elif opt == "pipeline-spec":
        # the canonical spec written out explicitly (replaces the removed
        # comm_dialect flag): must equal the flag-denoted default pipeline
        kw["pipeline"] = "fuse,cse,dce,decompose,swap-elim,lower-comm"
    else:
        kw[opt] = True
    step = api_compile(
        prog, Target(mesh=mesh, strategy=make_strategy_2d((4, 2)), **kw)
    )
    got = step(u0, np.zeros(shape, np.float32))
    check(f"options-{opt}", got[0], want, tol=tol)


def scenario_overlap_matrix(boundary, builder="jacobi", diagonal=False,
                            backend="jnp"):
    """split_overlapped_applies equivalence: overlap=True crossed with
    boundary × schedule (star=concurrent, box=sequential/diagonal) ×
    backend on a 2-D grid — distributed must stay bitwise-equal."""
    shape = (32, 32)
    builder_fn = _jacobi if builder == "jacobi" else _box
    u0, want = run_single(builder_fn, shape, boundary)
    mesh = _mesh((2, 2), ("x", "y"))
    prog = builder_fn(shape).finish(boundary=boundary)
    step = api_compile(
        prog,
        Target(mesh=mesh, strategy=make_strategy_2d((2, 2)),
               overlap=True, diagonal=diagonal, backend=backend),
    )
    got = step(u0, np.zeros(shape, np.float32))
    tol = 1e-6 if backend == "pallas" else 0.0
    check(
        f"overlap-{builder}-{boundary}-diag={diagonal}-{backend}",
        got[0], want, tol=tol,
    )
    # the overlap structure must be visible in the lowered IR
    from repro.core.dialects import comm, stencil

    names = [op.name for op in step.local_ir.body.ops]
    assert "comm.exchange_start" in names and "stencil.combine" in names, names
    first_apply = names.index("stencil.apply")
    assert names.index("comm.exchange_start") < first_apply < names.index(
        "comm.wait"
    ), f"interior apply not between starts and wait: {names}"


def scenario_wide_halo():
    """SDO-8 stencil (radius 4): halo wider than 1, both directions."""
    shape = (64, 64)
    g = Grid(shape=shape, extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=g, space_order=8)
    op = Operator(Eq(u.dt, 0.3 * u.laplace), dt=1e-6, boundary="periodic")
    rng = np.random.default_rng(3)
    u0 = rng.standard_normal(shape).astype(np.float32)
    want = np.asarray(op.apply([u0], timesteps=2)[0])

    mesh = _mesh((4, 2), ("x", "y"))
    got = np.asarray(
        op.apply(
            [u0], timesteps=2, mesh=mesh, strategy=make_strategy_2d((4, 2))
        )[0]
    )
    check("wide-halo-sdo8", got, want)


def _step_n(step, u0, shape, n):
    """n single steps with explicit rotation (p == q == 1 programs)."""
    u = u0
    for _ in range(n):
        u = np.asarray(step(u, np.zeros(shape, np.float32))[0])
    return u


def scenario_exchange_every(k, boundary, overlap=False, backend="jnp",
                            builder="jacobi", steps=8):
    """Deep-halo temporal tiling under a real mesh: a depth-k epoch
    (exchange once, step k times, redundant boundary compute) must stay
    bitwise-equal to k single-exchange steps — crossed with overlap
    (interior of step 1 rides the deep exchange) and backend."""
    shape = (32, 32)
    builder_fn = _jacobi if builder == "jacobi" else _box
    prog = builder_fn(shape).finish(boundary=boundary)
    rng = np.random.default_rng(42)
    u0 = rng.standard_normal(shape).astype(np.float32)
    want = _step_n(api_compile(prog), u0, shape, steps)

    mesh = _mesh((2, 2), ("x", "y"))
    base = api_compile(
        prog, Target(mesh=mesh, strategy=make_strategy_2d((2, 2)),
                     overlap=overlap, backend=backend)
    )
    tiled = api_compile(
        prog, Target(mesh=mesh, strategy=make_strategy_2d((2, 2)),
                     overlap=overlap, backend=backend, exchange_every=k)
    )
    got = u0
    for _ in range(steps // k):
        got = np.asarray(tiled(got, np.zeros(shape, np.float32))[0])
    tol = 1e-6 if backend == "pallas" else 0.0
    check(
        f"exchange-every-{builder}-{boundary}-k{k}-overlap={overlap}-{backend}",
        got, want, tol=tol,
    )
    # one exchange volley per k-step epoch: the tiled IR must not carry
    # more exchange_start ops than the single-step IR (let alone k×)
    from repro.core.dialects import comm

    def starts(s):
        return sum(
            1 for op in s.local_ir.body.ops
            if isinstance(op, comm.ExchangeStartOp)
        )

    assert starts(tiled) <= starts(base), (starts(tiled), starts(base))
    if overlap:
        names = [op.name for op in tiled.local_ir.body.ops]
        first_apply = names.index("stencil.apply")
        assert names.index("comm.exchange_start") < first_apply < names.index(
            "comm.wait"
        ), f"step-1 interior does not overlap the deep exchange: {names}"


def scenario_heat_epoch():
    """ISSUE 4 acceptance: the fig7 heat kernel on a 4-shard mesh with
    exchange_every=4 emits exactly ONE exchange pair per 4-step epoch
    (asserted on .local_ir) and is bitwise-equal to exchange_every=1
    over 32 steps."""
    shape = (64, 32)
    g = Grid(shape=shape, extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=g, space_order=2)
    dt = 0.1 * (g.spacing[0] ** 2) / 0.5
    op = Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="periodic")
    rng = np.random.default_rng(8)
    u0 = rng.standard_normal(shape).astype(np.float32)
    want = np.asarray(op.apply([u0], timesteps=32)[0])

    import jax.numpy as jnp

    mesh = _mesh((4,), ("x",))
    tiled = api_compile(
        op.program,
        Target(mesh=mesh, strategy=make_strategy_1d(4), exchange_every=4),
    )
    got = np.asarray(tiled.time_loop((jnp.asarray(u0),), 32)[0])
    from repro.core.dialects import comm

    starts = [
        o for o in tiled.local_ir.body.ops
        if isinstance(o, comm.ExchangeStartOp)
    ]
    waits = [
        o for o in tiled.local_ir.body.ops if isinstance(o, comm.WaitOp)
    ]
    # 1-D decomposition: one send/recv pair (low + high face) per epoch
    assert len(starts) == 2 and len(waits) == 1, (len(starts), len(waits))
    check("heat-epoch-k4-32steps", got, want)


def scenario_tune_4rank():
    """ISSUE 5 acceptance: measured autotuning on a 4-shard mesh — every
    rank selects the identical winner (deterministic search + one shared
    timing vector), the winner's measured per-step time is ≤ the default
    ``Target.auto()`` config's, and a second tune() is a persistent
    disk-cache hit that reproduces the winner."""
    import tempfile

    os.environ["REPRO_TUNE_CACHE"] = tempfile.mkdtemp(prefix="repro-tune-dist-")
    from repro.tune import cache_stats, tune

    shape = (64, 32)
    prog = _jacobi(shape).finish(boundary="periodic")
    kwargs = dict(
        ranks=4, measure=True, steps=4, trials=2, warmup=1,
        backends=("jnp",), exchange_every=(1, 2, 4), overlap=(False, True),
    )
    res = tune(prog, **kwargs)
    assert not res.from_cache and cache_stats().stores == 1

    measured = [c for c in res.candidates if c.measured_s is not None]
    assert res.winner in measured, "winner must come from the measured set"
    assert all(res.winner.measured_s <= c.measured_s for c in measured)
    baseline = [c for c in measured if c.origin == "baseline"]
    assert baseline, "the Target.auto() default must always be measured"
    assert res.winner.measured_s <= baseline[0].measured_s, (
        res.winner.measured_s, baseline[0].measured_s,
    )

    # all ranks agree: the search is deterministic given the agreed
    # timing vector, and the second call reads the identical winner back
    # from the on-disk cache
    res2 = tune(prog, **kwargs)
    assert res2.from_cache and cache_stats().hits == 1
    assert res2.target.fingerprint == res.winner.fingerprint

    # the tuned winner is still *correct*: bitwise vs single-device
    u0, want = run_single(_jacobi, shape, "periodic")
    k = res.target.exchange_every
    steps = 4  # every candidate k ∈ {1,2,4} divides 4
    assert steps % k == 0
    got = u0
    tuned = api_compile(prog, res.target)
    for _ in range(steps // k):
        got = np.asarray(tuned(got, np.zeros(shape, np.float32))[0])
    ref = _step_n(api_compile(prog), u0, shape, steps)
    check(f"tune-4rank-winner-k{k}", got, ref)
    print(f"ok: tune-4rank (winner {res.winner.describe()}, "
          f"{len(measured)} measured)")


def scenario_pallas_tile_shard_error():
    """Satellite: a pallas_tile that does not divide the *local shard*
    is rejected at compile() with an error naming the tile, the shard
    shape, and the mesh axis — not by the assert in core/lowering."""
    from repro.api import TargetError

    shape = (64, 32)
    prog = _jacobi(shape).finish(boundary="periodic")
    mesh = _mesh((4,), ("x",))
    # global 64 over 4 ranks → shard (16, 32); tile 7 does not divide 16
    bad = Target(
        mesh=mesh, strategy=make_strategy_1d(4),
        backend="pallas", pallas_tile=(7, 32),
    )
    try:
        api_compile(prog, bad)
    except TargetError as e:
        msg = str(e)
        for needle in ("(7, 32)", "(16, 32)", "mesh axis 'x'"):
            assert needle in msg, f"{needle!r} missing from: {msg}"
        print("ok: pallas-tile-shard-error")
    else:
        print("MISSING TargetError for shard-nondividing pallas_tile")
        sys.exit(1)
    # the same global tile on a single device divides (64, 32): valid —
    # proof the check is shard-aware, not global-shape-aware
    ok = Target(backend="pallas", pallas_tile=(16, 32))
    api_compile(prog, ok)
    print("ok: pallas-tile-shard-aware")


def scenario_time_loop():
    """Many timesteps under fori_loop + distribution (the fig. 8 path)."""
    shape = (64, 32)
    g = Grid(shape=shape, extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=g, space_order=4)
    op = Operator(Eq(u.dt, 0.5 * u.laplace), dt=1e-6, boundary="zero")
    rng = np.random.default_rng(4)
    u0 = rng.standard_normal(shape).astype(np.float32)
    want = np.asarray(op.apply([u0], timesteps=20)[0])
    mesh = _mesh((8,), ("x",))
    got = np.asarray(
        op.apply([u0], timesteps=20, mesh=mesh, strategy=make_strategy_1d(8))[0]
    )
    check("time-loop-20", got, want)


def _wave(shape):
    """p=2 inputs > q=1 output — carried-state rotation under resume."""
    p = ProgramBuilder("wave_res", shape)
    um = p.input("u_prev")
    u0 = p.input("u_now")
    out = p.output("u_next")
    tm, t0 = p.load(um), p.load(u0)
    r = p.apply(
        [tm, t0],
        lambda b, um, u0: 2.0 * u0.at(0, 0) - um.at(0, 0)
        + 0.1 * (
            u0.at(-1, 0) + u0.at(1, 0) + u0.at(0, -1) + u0.at(0, 1)
            - 4.0 * u0.at(0, 0)
        ),
    )
    p.store(r, out)
    return p


def scenario_resilience_reshape(builder="jacobi", k=4, steps=32):
    """ISSUE 8 acceptance: a FaultPlan-killed 4-rank run resumed onto a
    2-rank mesh (different factorization AND rank count) finishes
    bitwise-identical to both the uninterrupted 4-rank resilient run and
    the single-device time_loop reference — for k ∈ {1, 4}, heat + wave."""
    import shutil
    import tempfile

    from repro.resilience import FaultPlan, ResilientLoop, SimulatedFault, resume

    shape = (64, 32)
    builder_fn = _jacobi if builder == "jacobi" else _wave
    prog = builder_fn(shape).finish(
        boundary="periodic" if builder == "jacobi" else "zero"
    )
    rng = np.random.default_rng(13)
    n_in = 1 if builder == "jacobi" else 2
    state0 = tuple(
        rng.standard_normal(shape).astype(np.float32) for _ in range(n_in)
    )

    # single-device reference over the full horizon
    ref = api_compile(prog, Target(exchange_every=k)).time_loop(state0, steps)
    ref = tuple(np.asarray(a) for a in (ref if isinstance(ref, tuple) else (ref,)))

    big = Target(
        mesh=_mesh((4,), ("x",)), strategy=make_strategy_1d(4),
        exchange_every=k,
    )
    small = Target(
        mesh=_mesh((2,), ("x",)), strategy=make_strategy_1d(2),
        exchange_every=k,
    )

    d = tempfile.mkdtemp(prefix="repro-res-")
    try:
        # uninterrupted resilient run on the big mesh
        full = ResilientLoop(
            prog, big, state0, steps, directory=os.path.join(d, "full"),
            checkpoint_every=1,
        ).run()
        for i, (g, w) in enumerate(zip(full, ref)):
            check(f"res-{builder}-k{k}-uninterrupted-b{i}", g, w)

        # killed mid-run on 4 ranks, resumed onto 2 ranks
        kill = (steps // k) // 2
        loop = ResilientLoop(
            prog, big, state0, steps, directory=os.path.join(d, "killed"),
            checkpoint_every=1, fault_plan=FaultPlan(kill_at_epoch=kill),
        )
        try:
            loop.run()
            print(f"MISSING SimulatedFault at epoch {kill}")
            sys.exit(1)
        except SimulatedFault:
            pass
        resumed = resume(prog, os.path.join(d, "killed"), small)
        assert resumed.step_count == kill * k, (resumed.step_count, kill, k)
        got = resumed.run()
        for i, (g, w) in enumerate(zip(got, ref)):
            check(f"res-{builder}-k{k}-4to2ranks-b{i}", g, w)
    finally:
        shutil.rmtree(d, ignore_errors=True)


def scenario_tune_transfer():
    """Cross-hardware-signature warm start: a winner tuned at 2 ranks
    transfers to a 4-rank job (the rank count is part of the hardware
    signature, so an elastic resize IS a transfer), counts as a
    transfer_hit (never a hit), and reuses the stored winner verbatim."""
    import tempfile

    os.environ["REPRO_TUNE_CACHE"] = tempfile.mkdtemp(prefix="repro-tune-xfer-")
    from repro.tune import cache_stats, reset_cache_stats, tune

    shape = (64, 32)
    prog = _jacobi(shape).finish(boundary="periodic")
    kwargs = dict(
        measure=False, backends=("jnp",), exchange_every=(1, 2),
        overlap=(False,), fused_epoch=(False,),
    )
    reset_cache_stats()  # counters are process-wide; earlier scenarios tune
    res2 = tune(prog, ranks=2, **kwargs)
    assert not res2.from_cache and cache_stats().stores == 1

    # 4-rank primary key misses; with transfer=True the 2-rank winner is
    # adopted (its mesh rebuilds on this inventory's device prefix)
    reset_cache_stats()
    moved = tune(prog, ranks=4, transfer=True, **kwargs)
    s = cache_stats().as_dict()
    assert moved.from_cache and moved.winner.origin == "transfer", (
        moved.from_cache, moved.winner.origin,
    )
    assert s["transfer_hits"] == 1 and s["hits"] == 0 and s["stores"] == 0, s
    assert moved.target.fingerprint == res2.target.fingerprint

    # without transfer the same miss falls through to a fresh search
    reset_cache_stats()
    fresh = tune(prog, ranks=4, **kwargs)
    s = cache_stats().as_dict()
    assert not fresh.from_cache and s["transfer_hits"] == 0, s
    print("ok: tune-transfer")


def scenario_slot_axis():
    """ISSUE 9 tentpole oracle: a slot-axis pooled Target (shard_map over
    ``(slot, *spatial)``, vmap inside) advances a ``[B, *shape]`` batch
    bitwise-identically to B per-slot solo dispatches of the spatial-only
    sibling — for k ∈ {1, 2} and both boundaries, and across slot widths
    that do (4) and do not (2 with B=4) equal the batch size."""
    from repro.api import TargetError, pooled_target

    shape = (32, 32)
    B = 4
    for boundary, k, slots in (("zero", 1, 4), ("periodic", 2, 2)):
        prog = _jacobi(shape).finish(boundary=boundary)
        solo_t = Target(
            mesh=_mesh((2,), ("x",)), strategy=make_strategy_1d(2),
            exchange_every=k,
        )
        pooled_t = pooled_target(solo_t, slots=slots)
        assert pooled_t.fingerprint != solo_t.fingerprint
        assert pooled_t.mesh.shape["slot"] == slots
        solo = api_compile(prog, solo_t)
        pooled = api_compile(prog, pooled_t)
        rng = np.random.default_rng(7)
        u = rng.standard_normal((B,) + shape).astype(np.float32)
        got = pooled.time_loop((u,), 8)
        got = np.asarray(got[0] if isinstance(got, tuple) else got)
        want = np.stack([
            np.asarray(
                (lambda r: r[0] if isinstance(r, tuple) else r)(
                    solo.time_loop((u[i],), 8)
                )
            )
            for i in range(B)
        ])
        check(f"slot-axis-{boundary}-k{k}-s{slots}", got, want)
    # validation: a slot axis colliding with a spatial axis is rejected
    try:
        Target(
            mesh=_mesh((2,), ("x",)), strategy=make_strategy_1d(2),
            slot_axis="x",
        )
        print("MISSING TargetError for colliding slot_axis")
        sys.exit(1)
    except TargetError:
        print("ok: slot-axis collision rejected")


def scenario_serve_pooled():
    """ISSUE 9 acceptance: a 2-rank distributed bucket with 4 live slots
    executes as ONE pooled dispatch per engine step (per-bucket counters:
    batched > 0, solo == 0) and every request's final state is
    bitwise-equal to its solo ``time_loop``."""
    from repro.serve.stencil import StencilEngine, StencilEngineConfig

    shape = (32, 32)
    prog = _jacobi(shape).finish(boundary="periodic")
    target = Target(mesh=_mesh((2,), ("x",)), strategy=make_strategy_1d(2))
    rng = np.random.default_rng(3)
    states = [rng.standard_normal(shape).astype(np.float32) for _ in range(4)]
    eng = StencilEngine(StencilEngineConfig(slots_per_group=4))
    # equal n_steps: the bucket stays at 4 live slots every dispatch
    hs = [eng.submit(prog, (s,), 8, target=target) for s in states]
    done = eng.run()
    assert len(done) == 4, len(done)
    bd = eng.metrics.bucket_dispatches[
        f"{prog.fingerprint}/{target.fingerprint}"
    ]
    assert bd["batched"] > 0 and bd["solo"] == 0, bd
    solo = api_compile(prog, target)
    for h, s in zip(hs, states):
        want = solo.time_loop((s,), 8)
        want = np.asarray(want[0] if isinstance(want, tuple) else want)
        check(f"serve-pooled-rid{h.rid}", np.asarray(h.result()[0]), want)
    print(f"ok: serve-pooled counters {bd}")


def scenario_serve_autoscale():
    """ISSUE 9 acceptance: a queue burst against a small distributed
    bucket forces ≥1 autoscale grow, the long tail forces ≥1 shrink,
    every event carries queue-depth/utilization provenance, and every
    request's final state stays bitwise-equal across the resizes."""
    from repro.serve.stencil import (
        PoolSizerConfig,
        StencilEngine,
        StencilEngineConfig,
    )

    shape = (32, 32)
    prog = _jacobi(shape).finish(boundary="periodic")
    target = Target(mesh=_mesh((2,), ("x",)), strategy=make_strategy_1d(2))
    rng = np.random.default_rng(5)
    states = [rng.standard_normal(shape).astype(np.float32) for _ in range(8)]
    steps = [8] * 7 + [48]
    eng = StencilEngine(
        StencilEngineConfig(
            slots_per_group=2,
            autoscale=PoolSizerConfig(
                min_capacity=1, max_capacity=8, cooldown_steps=1,
                ewma_alpha=1.0,
            ),
        )
    )
    hs = [eng.submit(prog, (s,), n, target=target)
          for s, n in zip(states, steps)]
    eng.run()
    auto = eng.metrics.snapshot()["autoscale"]
    assert auto["grows"] >= 1 and auto["shrinks"] >= 1, auto
    for e in auto["events"]:
        missing = {
            "queue_ewma", "utilization_ewma", "queue_depth", "live",
            "from_capacity", "to_capacity",
        } - set(e)
        assert not missing, f"provenance missing {missing}"
    solo = api_compile(prog, target)
    for h, s, n in zip(hs, states, steps):
        want = solo.time_loop((s,), n)
        want = np.asarray(want[0] if isinstance(want, tuple) else want)
        check(f"serve-autoscale-rid{h.rid}", np.asarray(h.result()[0]), want)
    print(f"ok: serve-autoscale grows={auto['grows']} "
          f"shrinks={auto['shrinks']}")


def scenario_obs_trace():
    """ISSUE 10 acceptance: a traced 2-rank ``exchange_every=4`` heat run
    exports a merged Chrome trace with exactly ONE exchange span pair per
    epoch on each rank's track, and the exchange window overlaps the
    interior apply that hides it (comm/compute overlap, measured)."""
    import json
    import shutil
    import tempfile

    from repro import obs
    from repro.core.dialects import comm as comm_dialect

    shape = (64, 32)
    k, steps = 4, 8  # two epochs
    boundary = "periodic"
    u0, _ = run_single(_jacobi, shape, boundary)
    prog = _jacobi(shape).finish(boundary=boundary)

    # untraced 2-rank run: the fori_loop reference the traced path must match
    mesh = _mesh((2,), ("x",))
    target = Target(mesh=mesh, strategy=make_strategy_1d(2),
                    exchange_every=k, overlap=True)
    step = api_compile(prog, target)
    want = step.time_loop((u0,), steps)
    want = np.asarray(want[0] if isinstance(want, tuple) else want)

    # one deep exchange VOLLEY per epoch: a pair of directional
    # exchange_starts (up + down the 1-D mesh) closed by a single wait —
    # so each epoch's track shows exactly one exchange span pair
    n_starts = sum(
        1 for op in step.local_ir.body.ops
        if isinstance(op, comm_dialect.ExchangeStartOp)
    )
    assert n_starts == 2, f"expected one exchange pair per epoch, IR has {n_starts} starts"

    obs.enable()
    obs.clear()
    got = step.time_loop((u0,), steps)
    got = np.asarray(got[0] if isinstance(got, tuple) else got)
    obs.disable()
    check("obs-trace-2rank-bitwise", got, want)

    spans = obs.spans()
    epochs = sorted((s for s in spans if s.name == "epoch"),
                    key=lambda s: s.ts)
    assert len(epochs) == steps // k, f"{len(epochs)} epoch spans"
    comm_spans = [s for s in spans if s.cat == "comm"]
    assert len(comm_spans) == len(epochs) * n_starts, (
        f"{len(comm_spans)} exchange windows for {len(epochs)} epochs"
    )
    interior = [s for s in spans if s.name == "apply:interior"]
    assert interior, "overlap target produced no interior apply spans"
    for e in epochs:
        inside = [c for c in comm_spans if e.ts <= c.ts and c.end <= e.end]
        assert len(inside) == n_starts, (
            f"epoch {e.args.get('epoch')}: {len(inside)} exchange windows"
        )
        # the exchange window must overlap an interior apply span
        c = inside[0]
        hidden = [a for a in interior if a.ts < c.end and c.ts < a.end]
        assert hidden, "exchange window overlaps no interior apply"

    rep = obs.drift_report(exchange_every=k)
    assert rep.epochs == len(epochs) and rep.achieved_overlap > 0.0, (
        rep.as_dict()
    )

    # per-rank trace files -> merged Chrome trace, one track per rank
    tmp = tempfile.mkdtemp(prefix="repro-obs-trace-")
    try:
        paths = obs.write_rank_traces(tmp, spans)
        assert len(paths) == 2, paths
        merged = obs.merge_traces(tmp, out=os.path.join(tmp, "merged.json"))
        with open(os.path.join(tmp, "merged.json")) as f:
            merged = json.load(f)
        events = [e for e in merged["traceEvents"] if e.get("ph") == "X"]
        for r in (0, 1):
            track_comm = [e for e in events
                          if e["pid"] == r and e["cat"] == "comm"]
            assert len(track_comm) == len(epochs) * n_starts, (
                f"rank {r}: {len(track_comm)} exchange events"
            )
            track_interior = [e for e in events if e["pid"] == r
                              and e["name"] == "apply:interior"]
            for c in track_comm:
                c0, c1 = c["ts"], c["ts"] + c["dur"]
                assert any(a["ts"] < c1 and c0 < a["ts"] + a["dur"]
                           for a in track_interior), (
                    f"rank {r}: exchange window hides no interior apply"
                )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    obs.clear()
    print(f"ok: obs-trace-2rank ({len(epochs)} epochs, "
          f"one exchange pair ({n_starts} spans)/epoch, overlap "
          f"{rep.achieved_overlap:.0%})")


SCENARIOS = {
    "1d-zero": lambda: scenario_1d("zero"),
    "1d-periodic": lambda: scenario_1d("periodic"),
    "2d-zero": lambda: scenario_2d("zero"),
    "2d-periodic": lambda: scenario_2d("periodic"),
    "3d": scenario_3d,
    "box": lambda: scenario_box(False),
    "box-diagonal": lambda: scenario_box(True),
    "overlap": lambda: scenario_options("overlap"),
    "overlap-zero": lambda: scenario_overlap_matrix("zero"),
    "overlap-periodic": lambda: scenario_overlap_matrix("periodic"),
    "overlap-box-seq": lambda: scenario_overlap_matrix("periodic", "box"),
    "overlap-diagonal": lambda: scenario_overlap_matrix(
        "periodic", "box", diagonal=True
    ),
    "overlap-pallas": lambda: scenario_overlap_matrix(
        "periodic", backend="pallas"
    ),
    "pipeline-spec": lambda: scenario_options("pipeline-spec"),
    "pallas": lambda: scenario_options("pallas"),
    "wide-halo": scenario_wide_halo,
    "time-loop": scenario_time_loop,
    # deep-halo temporal tiling: exchange_every × overlap × backend
    "ee2-periodic": lambda: scenario_exchange_every(2, "periodic"),
    "ee4-zero": lambda: scenario_exchange_every(4, "zero"),
    "ee4-overlap": lambda: scenario_exchange_every(4, "periodic", overlap=True),
    "ee4-overlap-zero": lambda: scenario_exchange_every(4, "zero", overlap=True),
    "ee2-box-overlap": lambda: scenario_exchange_every(
        2, "periodic", overlap=True, builder="box"
    ),
    "ee4-pallas": lambda: scenario_exchange_every(
        4, "periodic", backend="pallas"
    ),
    "ee-heat-epoch": scenario_heat_epoch,
    # repro.tune: measured autotuning under a real mesh + shard-aware
    # pallas_tile validation
    "tune-4rank": scenario_tune_4rank,
    "pallas-tile-shard-error": scenario_pallas_tile_shard_error,
    # repro.resilience: killed on 4 ranks, resumed onto 2 (elastic) —
    # bitwise vs the uninterrupted run and the single-device reference
    "resilience-heat-k1": lambda: scenario_resilience_reshape("jacobi", k=1),
    "resilience-heat-k4": lambda: scenario_resilience_reshape("jacobi", k=4),
    "resilience-wave-k4": lambda: scenario_resilience_reshape("wave", k=4),
    "tune-transfer": scenario_tune_transfer,
    # ISSUE 9 — elastic slot pools: slot-axis compile oracle, pooled
    # distributed serving, queue-depth autoscaling (all bitwise vs solo)
    "slot-axis": scenario_slot_axis,
    "serve-pooled": scenario_serve_pooled,
    "serve-autoscale": scenario_serve_autoscale,
    # ISSUE 10 — repro.obs: merged 2-rank trace with one exchange span
    # pair per epoch and measured comm/compute overlap
    "obs-trace-2rank": scenario_obs_trace,
}


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    names = list(SCENARIOS) if which == "all" else [which]
    for n in names:
        SCENARIOS[n]()
    print("ALL OK")
