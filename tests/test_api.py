"""The one compile surface: Program / Target / CompiledStencil.

Covers Target validation (construction-time rejection), IR fingerprint
stability, the process-wide fingerprint-keyed compile cache (hit/miss
counters + pass pipeline not re-running), buffer donation, and the
acceptance property that all three frontends compile through
``repro.api.compile`` with one shared Target — with the deprecated
``StencilComputation`` shim staying bitwise-equivalent.
"""
import numpy as np
import pytest

import repro
from repro import api
from repro.api import CompiledStencil, Program, Target, TargetError
from repro.core import ir
from repro.core.passes import PassManager
from repro.core.passes.decompose import SlicingStrategy, make_strategy_1d
from repro.frontends.oec_like import ProgramBuilder


def _jacobi_prog(shape=(16, 16), boundary="periodic", name="jacobi"):
    p = ProgramBuilder(name, shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.25,
    )
    p.store(r, out)
    return p.finish(boundary=boundary)


def _one_device_mesh():
    import jax
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:1]), ("x",))


# -------------------------------------------------------------------------
# Program: metadata + fingerprint stability
# -------------------------------------------------------------------------


def test_program_metadata():
    prog = _jacobi_prog()
    assert prog.rank == 2
    assert prog.field_names == ("u", "out")
    assert len(prog.output_fields) == 1
    assert "stencil.apply" in prog.ir_text()


def test_fingerprint_stable_across_rebuilds():
    # structurally identical programs built twice hash identically
    assert _jacobi_prog().fingerprint == _jacobi_prog().fingerprint


def test_fingerprint_changes_on_op_change():
    base = _jacobi_prog().fingerprint
    # different constant in the apply body
    p = ProgramBuilder("jacobi", (16, 16))
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.5,
    )
    p.store(r, out)
    assert p.finish(boundary="periodic").fingerprint != base


def test_fingerprint_changes_on_attr_change():
    # same ops, different boundary attribute → different fingerprint
    assert (
        _jacobi_prog(boundary="zero").fingerprint
        != _jacobi_prog(boundary="periodic").fingerprint
    )
    # op-attribute change (store bounds shape via program shape)
    assert (
        _jacobi_prog(shape=(16, 32)).fingerprint
        != _jacobi_prog(shape=(16, 16)).fingerprint
    )


def test_fingerprint_covers_metadata():
    # same IR, different field names / program name → different identity,
    # so a cache hit always hands back matching metadata
    p1 = _jacobi_prog()
    p2 = Program(_jacobi_prog().func, boundary="periodic",
                 field_names=("in0", "out0"), name="jacobi")
    assert p1.fingerprint != p2.fingerprint


def test_compile_rejects_program_mutated_after_construction():
    prog = _jacobi_prog(name="mutation_probe")
    const = next(
        op for op in prog.func.walk() if isinstance(op, ir.ConstantOp)
    )
    const.attributes["value"] = ir.FloatAttr(0.5)  # rewrite AFTER wrapping
    with pytest.raises(ValueError, match="mutated"):
        api.compile(prog, Target())


def test_ir_fingerprint_ignores_name_hints():
    # name hints are debugging sugar, not structure
    f1 = _jacobi_prog().func
    f2 = _jacobi_prog().func
    f2.body.args[0].name_hint = "renamed"
    assert ir.fingerprint(f1) == ir.fingerprint(f2)


# -------------------------------------------------------------------------
# Target validation: rejected at construction / compile, not inside lowering
# -------------------------------------------------------------------------


def test_target_rejects_unknown_backend():
    with pytest.raises(TargetError, match="backend"):
        Target(backend="cuda")


def test_target_rejects_decomposed_strategy_without_mesh():
    with pytest.raises(TargetError, match="no mesh"):
        Target(strategy=make_strategy_1d(2))


def test_target_rejects_mesh_grid_mismatch():
    mesh = _one_device_mesh()  # axis "x" has size 1
    with pytest.raises(TargetError, match="mesh size"):
        Target(mesh=mesh, strategy=make_strategy_1d(2))
    with pytest.raises(TargetError, match="not in mesh axes"):
        Target(mesh=mesh, strategy=make_strategy_1d(2, axis="q"))


def test_target_rejects_malformed_pipeline_at_construction():
    from repro.core.passes import PipelineError

    with pytest.raises(PipelineError):
        Target(pipeline="decompose{grid=2x2")


def test_compile_rejects_bad_strategy_rank():
    # strategy decomposes dim 4 of a rank-2 program
    prog = _jacobi_prog()
    bad = Target(strategy=SlicingStrategy((1,), ("x",), (4,)))
    with pytest.raises(TargetError, match="rank-2"):
        api.compile(prog, bad)


def test_compile_rejects_indivisible_extent():
    import jax
    from jax.sharding import Mesh

    prog = _jacobi_prog(shape=(15, 16))
    # a validation-only mesh (never executed) of logical size 2
    mesh = Mesh(np.array(jax.devices() * 2), ("x",))
    target = Target(mesh=mesh, strategy=make_strategy_1d(2))
    with pytest.raises(TargetError, match="divisible"):
        api.compile(prog, target)


def test_target_auto_single_device():
    t = Target.auto()
    # the test process sees one CPU device
    assert not t.distributed
    with pytest.raises(TargetError, match="devices"):
        Target.auto(ranks=64)


def test_target_fingerprint_distinguishes_knobs():
    assert Target().fingerprint == Target().fingerprint
    assert Target(backend="pallas").fingerprint != Target().fingerprint
    assert Target(overlap=True).fingerprint != Target().fingerprint
    assert (
        Target(pipeline="decompose,swap-elim,lower-comm").fingerprint
        != Target().fingerprint
    )


# -------------------------------------------------------------------------
# the process-wide compile cache
# -------------------------------------------------------------------------


def test_compile_cache_hit_returns_same_artifact_and_skips_passes():
    prog = _jacobi_prog(name="cache_probe")
    target = Target()
    first = api.compile(prog, target)
    assert isinstance(first, CompiledStencil)

    stats0 = api.cache_stats().as_dict()
    runs0 = PassManager.runs_completed
    second = api.compile(_jacobi_prog(name="cache_probe"), Target())
    assert second is first  # same artifact object
    assert PassManager.runs_completed == runs0  # pass pipeline did not re-run
    stats1 = api.cache_stats().as_dict()
    assert stats1["hits"] == stats0["hits"] + 1
    assert stats1["misses"] == stats0["misses"]


def test_compile_cache_misses_on_different_target():
    prog = _jacobi_prog(name="cache_probe2")
    a = api.compile(prog, Target())
    b = api.compile(prog, Target(fuse=False))
    assert a is not b
    assert a.pipeline_report.spec != b.pipeline_report.spec


def test_top_level_reexport():
    assert repro.compile is api.compile
    assert repro.Target is Target
    assert repro.Program is Program


# -------------------------------------------------------------------------
# donation
# -------------------------------------------------------------------------


def test_buffers_are_donated():
    """The old StencilComputation computed donate_argnums but never passed
    them to jax.jit; a donate=True Target must actually donate."""
    import jax
    import jax.numpy as jnp

    prog = _jacobi_prog(name="donate_probe")
    step = api.compile(prog, Target(donate=True))
    assert step.donate_argnums == (0, 1)  # whole-state handover

    # the input→output aliasing must be visible in the lowering…
    u = jnp.ones((16, 16), jnp.float32)
    out = jnp.zeros((16, 16), jnp.float32)
    txt = jax.jit(step._raw_fn, donate_argnums=step.donate_argnums).lower(
        u, out
    ).as_text()
    assert "tf.aliasing_output" in txt or "jax.buffer_donor" in txt

    # …and actually happen at execution: the donated input buffer is
    # consumed (its storage rotated into the result)
    step(u, out)
    assert u.is_deleted()


def test_donation_can_be_disabled():
    import jax.numpy as jnp

    prog = _jacobi_prog(name="donate_probe2")
    step = api.compile(prog, Target(donate=False))
    assert step.donate_argnums == ()
    out = jnp.zeros((16, 16), jnp.float32)
    step(jnp.ones((16, 16), jnp.float32), out)
    assert not out.is_deleted()


# -------------------------------------------------------------------------
# acceptance: three frontends, one Target, one compile — shim equivalent
# -------------------------------------------------------------------------


def test_three_frontends_share_one_target():
    from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction
    from repro.frontends.psyclone_like import recognize

    shape = (24, 24)
    target = Target()  # ONE target for all three frontends

    oec = _jacobi_prog(shape=shape, name="j")

    def kern(u, out):
        out[i, j] = 0.25 * (u[i - 1, j] + u[i + 1, j] + u[i, j - 1] + u[i, j + 1])

    psy = recognize(kern, shape=shape, boundary="periodic")

    g = Grid(shape=shape, extent=shape)  # spacing 1
    u = TimeFunction(name="u", grid=g, space_order=2)
    expr = (
        u.shifted(0, -1) + u.shifted(0, 1) + u.shifted(1, -1) + u.shifted(1, 1)
    ) * 0.25
    dev = Operator(Eq(u.forward, expr), boundary="periodic").program

    for prog in (oec, psy, dev):
        assert isinstance(prog, Program)

    rng = np.random.default_rng(8)
    u0 = rng.standard_normal(shape).astype(np.float32)
    r_oec = np.asarray(api.compile(oec, target)(u0, np.zeros_like(u0))[0])
    r_psy = np.asarray(api.compile(psy, target)(u0, np.zeros_like(u0))[0])
    r_dev = np.asarray(api.compile(dev, target)(u0, np.zeros_like(u0))[0])
    np.testing.assert_array_equal(r_oec, r_psy)
    np.testing.assert_array_equal(r_oec, r_dev)


def test_stencil_computation_shim_is_bitwise_equivalent():
    from repro.core.program import CompileOptions, StencilComputation

    prog = _jacobi_prog(name="shim_probe")
    rng = np.random.default_rng(9)
    u0 = rng.standard_normal((16, 16)).astype(np.float32)

    new = api.compile(prog, Target())(u0, np.zeros_like(u0))
    with pytest.deprecated_call(match="StencilComputation"):
        comp = StencilComputation(_jacobi_prog(name="shim_probe").func,
                                  boundary="periodic")
    old = comp.compile(options=CompileOptions())(u0, np.zeros_like(u0))
    np.testing.assert_array_equal(np.asarray(new[0]), np.asarray(old[0]))
    # the shim went through the same cache + pipeline
    assert comp.last_pipeline == Target().pipeline_spec()
    assert [n for n, _ in comp.last_timings] == comp.last_pipeline.split(",")


# -------------------------------------------------------------------------
# artifact surface: local_ir / pipeline_report / specs / lower / cost
# -------------------------------------------------------------------------


def test_artifact_inspection_surface():
    from repro.core.dialects import comm, dmp

    step = api.compile(_jacobi_prog(name="inspect_probe"), Target())
    # comm-lowered local IR, no dmp.swap survives
    assert not any(isinstance(op, dmp.SwapOp) for op in step.local_ir.body.ops)
    assert any(isinstance(op, comm.HaloPadOp) for op in step.local_ir.body.ops)
    # pipeline report matches the spec stage-by-stage
    names = [n for n, _ in step.pipeline_report.timings]
    assert names == step.pipeline_report.spec.split(",")
    assert "pipeline:" in str(step.pipeline_report)
    # partition specs: one per field arg (trivial strategy → all None)
    assert len(step.partition_specs) == 2
    # AOT lower + roofline cost
    cost = step.cost()
    assert cost.flops > 0
    assert cost.dominant in ("compute", "memory", "collective")
    assert cost.t_serial >= cost.t_overlapped


def test_time_loop_on_artifact():
    step = api.compile(_jacobi_prog(name="loop_probe"), Target())
    rng = np.random.default_rng(10)
    u0 = rng.standard_normal((16, 16)).astype(np.float32)
    # 2 steps via time_loop == 2 manual calls
    (via_loop,) = step.time_loop([u0], 2)
    once = step(u0, np.zeros_like(u0))[0]
    twice = step(np.asarray(once), np.zeros_like(u0))[0]
    np.testing.assert_allclose(
        np.asarray(via_loop), np.asarray(twice), rtol=1e-6
    )


def test_lower_ir_cache_for_generated_exchanges():
    """dist/context_parallel's entry point: same exchange shape → cached
    (lru memo on top, fingerprint-keyed api cache underneath)."""
    from repro.dist.context_parallel import SeqHaloSpec, _comm_func

    spec = SeqHaloSpec(axis="x", n_shards=4, halo_lo=3)
    f1 = _comm_func((2, 8, 4), spec)
    # the thin lru memo short-circuits repeat calls entirely
    assert _comm_func((2, 8, 4), spec) is f1
    # the process-wide api cache underneath hits when the memo is bypassed
    # (fresh IR build, same fingerprint)
    stats0 = api.cache_stats().as_dict()
    f2 = _comm_func.__wrapped__((2, 8, 4), spec)
    assert f2 is f1
    assert api.cache_stats().hits == stats0["hits"] + 1
