"""Hypothesis strategies for random stencil programs.

Built on the ``_hypothesis_compat`` shim (real hypothesis when installed,
a seeded deterministic fallback otherwise), so the strategies stick to the
shim's primitive set: draw a compact *descriptor* tuple and expand it into
a `repro.api.Program` deterministically with a seeded numpy RNG.  Two
descriptor draws with the same values always yield the same program —
shrinkability and reproducibility come for free.

Programs are generated rotation-closed (one input, one output buffer) so
temporal tiling applies: rank 1 or 2, a chain/DAG of 1–3 applies, access
offsets within radius 2, and either boundary condition.
"""
from __future__ import annotations

import numpy as np

from _hypothesis_compat import strategies as st

# (seed, rank, n_applies, boundary) — the whole program derives from this
program_descriptors = st.tuples(
    st.integers(0, 10**6),
    st.sampled_from([1, 2]),
    st.sampled_from([1, 2, 3]),
    st.sampled_from(["zero", "periodic"]),
)

exchange_everys = st.sampled_from([1, 2, 4])

SHAPES = {1: (24,), 2: (16, 12)}


def build_program(seed: int, rank: int, n_applies: int, boundary: str):
    """Expand a descriptor into a verified Program.

    The apply chain is a DAG: each apply reads 1–2 of the values produced
    so far (the loaded field or earlier results) at random offsets within
    radius 2, with random fp32 coefficients; the last result is stored.
    """
    from repro.frontends.oec_like import ProgramBuilder

    rng = np.random.default_rng(seed)
    shape = SHAPES[rank]
    p = ProgramBuilder(f"hyp_{seed}_{rank}_{n_applies}", shape)
    u = p.input("u")
    out = p.output("out")
    values = [p.load(u)]

    def point_fn(offsets, coeffs):
        def fn(b, *handles):
            acc = None
            for (arg_idx, off), c in zip(offsets, coeffs):
                term = handles[arg_idx].at(*off) * float(c)
                acc = term if acc is None else acc + term
            return acc

        return fn

    for _ in range(n_applies):
        n_args = int(rng.integers(1, min(2, len(values)) + 1))
        arg_ids = rng.choice(len(values), size=n_args, replace=False)
        args = [values[i] for i in arg_ids]
        taps = []
        for arg_idx in range(n_args):
            for _ in range(int(rng.integers(1, 4))):
                off = tuple(int(o) for o in rng.integers(-2, 3, size=rank))
                taps.append((arg_idx, off))
        # small, exactly-representable coefficients keep chained epochs
        # from overflowing while staying bitwise-comparable
        coeffs = rng.integers(1, 8, size=len(taps)) / 16.0
        values.append(p.apply(args, point_fn(taps, coeffs)))
    p.store(values[-1], out)
    return p.finish(boundary=boundary)
