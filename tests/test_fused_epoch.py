"""Fused-epoch Pallas megakernel: one kernel dispatch per deep-halo epoch.

The acceptance harness for the fuse-epoch-kernel lowering: random
programs (rank, chained applies, either boundary) at exchange_every ∈
{1, 2, 4} must be *bitwise-identical* between ``fused_epoch=True`` (one
``pl.pallas_call`` per epoch) and the unfused interpreted per-step
oracle — plus dispatch-counter proofs that the epoch really is one
kernel, Target-surface validation, and the interpret-flag plumbing.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings
from _strategies import build_program, exchange_everys, program_descriptors

from repro import api, kernels
from repro.api import Target, TargetError
from repro.core.dialects import stencil
from repro.core.passes.temporal import epoch_halo


def _fused(k: int, **kw) -> Target:
    return Target(
        backend="pallas",
        exchange_every=k,
        fused_epoch=True,
        pallas_interpret=True,
        **kw,
    )


def _unfused(k: int, **kw) -> Target:
    return Target(
        backend="pallas",
        exchange_every=k,
        pallas_interpret=True,
        **kw,
    )


def _heat(shape=(16, 16), boundary="periodic", name="heat_fe"):
    from repro.frontends.oec_like import ProgramBuilder

    p = ProgramBuilder(name, shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: u.at(0, 0) * 0.5
        + (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.125,
    )
    p.store(r, out)
    return p.finish(boundary=boundary)


# -------------------------------------------------------------------------
# the property: fused epoch == unfused interpreted steps, bitwise
# -------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(descriptor=program_descriptors, k=exchange_everys)
def test_fused_epoch_equals_unfused_bitwise(descriptor, k):
    """One megakernel per epoch is bitwise-equal to the unfused
    interpreted path (one pallas dispatch per time step, same k) for
    random programs (≥50 per run).  Both targets are jitted: the unfused
    epoch then traces its k per-step kernels into one XLA module — the
    very module the fused kernel emits — so equality is exact.  (Eagerly
    the unfused path is one XLA module *per step* and XLA CPU's
    per-module FMA contraction drifts ~1ulp; see epoch_kernel.py.)"""
    seed, rank, n_applies, boundary = descriptor
    prog = build_program(seed, rank, n_applies, boundary)
    shape = prog.field_args[0].type.bounds.shape
    lo, hi = epoch_halo(prog.func, k)
    if any(max(l, h) > n for l, h, n in zip(lo, hi, shape)):
        with pytest.raises(TargetError, match="deep halo"):
            api.compile(prog, _fused(k))
        return
    oracle = api.compile(prog, _unfused(k))
    fused = api.compile(prog, _fused(k))
    rng = np.random.default_rng(seed + 1)
    u0 = rng.standard_normal(shape).astype(np.float32)
    want = got = u0
    for _ in range(2):  # two epochs: exercises epoch-to-epoch rotation too
        want = np.asarray(oracle(want, np.zeros_like(u0))[0])
        got = np.asarray(fused(got, np.zeros_like(u0))[0])
    np.testing.assert_array_equal(want, got)


# -------------------------------------------------------------------------
# one dispatch per epoch, counter-asserted
# -------------------------------------------------------------------------


def test_fused_epoch_is_one_dispatch():
    """Target(exchange_every=4, fused_epoch=True): the compiled epoch
    step issues exactly ONE pallas_call — the trace counter says so, and
    the static IR census (kernel_dispatches) agrees."""
    prog = _heat()
    fused = api.compile(prog, _fused(4))
    assert fused.kernel_dispatches == {"fused_epoch": 1, "apply": 0, "total": 1}
    u0 = np.random.default_rng(0).standard_normal((16, 16)).astype(np.float32)
    kernels.reset_dispatch_stats()
    fused(u0, np.zeros_like(u0))
    stats = kernels.dispatch_stats()
    assert stats.fused_epoch_calls == 1
    assert stats.apply_calls == 0
    assert stats.pallas_calls == 1


def test_unfused_epoch_is_k_dispatches():
    prog = _heat()
    unfused = api.compile(prog, _unfused(4))
    assert unfused.kernel_dispatches == {"fused_epoch": 0, "apply": 4, "total": 4}
    u0 = np.random.default_rng(0).standard_normal((16, 16)).astype(np.float32)
    kernels.reset_dispatch_stats()
    unfused(u0, np.zeros_like(u0))
    assert kernels.dispatch_stats().pallas_calls == 4


def test_fused_epoch_ir_has_single_fused_op():
    """The lowered local IR holds ONE FusedEpochOp wrapping the k cloned
    applies (and the zero-BC masks); no top-level applies survive."""
    prog = _heat(boundary="zero")
    fused = api.compile(prog, _fused(4))
    ops = list(fused.local_ir.body.ops)
    fused_ops = [op for op in ops if isinstance(op, stencil.FusedEpochOp)]
    assert len(fused_ops) == 1
    assert not any(isinstance(op, stencil.ApplyOp) for op in ops)
    inner = [op.name for op in fused_ops[0].body.ops]
    assert inner.count("stencil.apply") == 4
    assert fused_ops[0].k == 4
    assert inner[-1] == "stencil.fused_yield"


def test_fused_epoch_with_explicit_tile_matches():
    """An explicit dividing pallas_tile routes through the tiled (grid)
    kernel mode and stays bitwise-equal to the whole-shard mode."""
    prog = _heat((32, 32))
    u0 = np.random.default_rng(2).standard_normal((32, 32)).astype(np.float32)
    whole = api.compile(prog, _fused(2))
    tiled = api.compile(prog, _fused(2, pallas_tile=(16, 32)))
    a = np.asarray(whole(u0, np.zeros_like(u0))[0])
    b = np.asarray(tiled(u0, np.zeros_like(u0))[0])
    np.testing.assert_array_equal(a, b)


# -------------------------------------------------------------------------
# Target surface
# -------------------------------------------------------------------------


def test_fused_epoch_requires_pallas_backend():
    with pytest.raises(TargetError, match="backend='pallas'"):
        Target(backend="jnp", fused_epoch=True)


def test_fused_epoch_incompatible_with_overlap():
    with pytest.raises(TargetError, match="overlap"):
        Target(backend="pallas", fused_epoch=True, overlap=True)


def test_fused_epoch_explicit_pipeline_must_match():
    spec = Target(backend="pallas", fused_epoch=True).pipeline_spec()
    assert spec.endswith("fuse-epoch-kernel")
    # spec says fused but the flag does not (and vice versa) → reject
    with pytest.raises(TargetError, match="fuse-epoch-kernel"):
        Target(backend="pallas", pipeline=spec, fused_epoch=False)
    no_fuse = Target(backend="pallas").pipeline_spec()
    with pytest.raises(TargetError, match="fuse-epoch-kernel"):
        Target(backend="pallas", pipeline=no_fuse, fused_epoch=True)


def test_fused_epoch_changes_fingerprint():
    a = Target(backend="pallas", exchange_every=2)
    b = Target(backend="pallas", exchange_every=2, fused_epoch=True)
    assert a.fingerprint != b.fingerprint


def test_pallas_interpret_resolves_at_construction():
    t = Target(backend="pallas")
    assert t.pallas_interpret == kernels.default_interpret()
    assert isinstance(t.pallas_interpret, bool)
    forced = Target(backend="pallas", pallas_interpret=True)
    assert forced.pallas_interpret is True
    assert forced.fingerprint != Target(
        backend="pallas", pallas_interpret=False
    ).fingerprint


def test_ops_default_interpret_follows_env(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert kernels.default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert kernels.default_interpret() is False
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert kernels.default_interpret() == (not kernels.has_accelerator())


def test_kernel_ops_single_flag_source():
    """kernels.ops entry points no longer hardcode interpret=True: the
    default resolves through kernels.default_interpret (env-overridable),
    and an explicit value is honored."""
    import inspect

    from repro.kernels import ops

    for fn in (ops.star_stencil, ops.laplacian, ops.heat_step, ops.wave_step):
        assert inspect.signature(fn).parameters["interpret"].default is None
    u = np.random.default_rng(3).standard_normal((12, 12)).astype(np.float32)
    a = np.asarray(ops.laplacian(u, interpret=True))
    b = np.asarray(ops.laplacian(u))  # CPU default resolves to interpret
    np.testing.assert_array_equal(a, b)
