"""Context-parallelism correctness (subprocess, 8 virtual devices):
the sequence-halo exchange of ``repro.dist.context_parallel`` — routed
through the shared ``dmp``/``comm`` stencil machinery — must equal the
single-device reference bitwise (and the comm-dialect route must be the
one actually taken)."""
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "cp_worker.py")

SCENARIOS = [
    "exchange-zero",
    "exchange-periodic",
    "conv",
    "window-attention",
    "window-vs-dense",
    "comm-ir",
]


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_context_parallel_equivalence(scenario):
    proc = subprocess.run(
        [sys.executable, WORKER, scenario],
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, (
        f"scenario {scenario} failed:\nSTDOUT:\n{proc.stdout}\n"
        f"STDERR:\n{proc.stderr[-3000:]}"
    )
