"""``hypothesis`` compatibility shim.

The container this repo targets does not ship hypothesis, and the PR
rules forbid installing it.  Property tests import ``given/settings/
strategies`` from here: the real library is used when present; otherwise
a minimal deterministic fallback runs each property over a fixed number
of seeded samples (enough to keep the sweeps meaningful, not a full
shrinking engine).
"""
try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda rng: tuple(s.example(rng) for s in strats))

    strategies = _Strategies()

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        def deco(inner):
            # no functools.wraps: pytest must see the zero-arg signature,
            # not the property's drawn parameters
            def runner():
                n = getattr(runner, "_max_examples", 10)
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {
                        k: s.example(rng) for k, s in strategy_kwargs.items()
                    }
                    inner(**drawn)

            runner.__name__ = inner.__name__
            runner.__doc__ = inner.__doc__
            runner._max_examples = getattr(inner, "_max_examples", 10)
            return runner

        return deco
