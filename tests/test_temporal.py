"""Deep-halo temporal tiling: exchange once, step k times.

The property-based equivalence harness (ISSUE 4 acceptance): random
stencil programs — rank, offsets, chained applies, either boundary — must
produce *bitwise-identical* results for ``exchange_every ∈ {1, 2, 4}``
vs the one-exchange-per-step baseline, plus unit coverage of the pass
mechanics, Target validation, epoch time_loop arithmetic, cache identity
and the roofline tradeoff terms.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings
from _strategies import build_program, exchange_everys, program_descriptors

from repro import api
from repro.api import Target, TargetError
from repro.core.dialects import comm, dmp
from repro.core.passes.temporal import TemporalTilingError, epoch_halo, temporal_tile
from repro.frontends.oec_like import ProgramBuilder


def _jacobi(shape=(16, 16), boundary="periodic", name="jacobi_t"):
    p = ProgramBuilder(name, shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.25,
    )
    p.store(r, out)
    return p.finish(boundary=boundary)


def _run_steps(step, u0, n):
    u = u0
    for _ in range(n):
        u = np.asarray(step(u, np.zeros_like(u0))[0])
    return u


# -------------------------------------------------------------------------
# the property: epochs == steps, bitwise, for generated programs
# -------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(descriptor=program_descriptors, k=exchange_everys)
def test_epoch_equals_steps_bitwise(descriptor, k):
    """exchange_every=k over lcm(k, 2·k) steps is bitwise-equal to the
    k=1 baseline for a random program (≥50 generated programs per run)."""
    seed, rank, n_applies, boundary = descriptor
    prog = build_program(seed, rank, n_applies, boundary)
    shape = prog.field_args[0].type.bounds.shape
    lo, hi = epoch_halo(prog.func, k)
    if any(max(l, h) > n for l, h, n in zip(lo, hi, shape)):
        # the accumulated halo outgrew the domain: the validator must
        # reject the depth instead of computing garbage
        with pytest.raises(TargetError, match="deep halo"):
            api.compile(prog, Target(exchange_every=k, jit=False))
        return
    # jit=False: the eager interpreter path — identical arithmetic,
    # no per-program XLA compile, so the sweep stays fast
    base = api.compile(prog, Target(jit=False))
    tiled = api.compile(prog, Target(exchange_every=k, jit=False))
    rng = np.random.default_rng(seed + 1)
    u0 = rng.standard_normal(shape).astype(np.float32)
    steps = 2 * k  # two epochs: exercises epoch-to-epoch rotation too
    want = _run_steps(base, u0, steps)
    got = u0
    for _ in range(steps // k):
        got = np.asarray(tiled(got, np.zeros_like(u0))[0])
    np.testing.assert_array_equal(want, got)


# -------------------------------------------------------------------------
# pass mechanics
# -------------------------------------------------------------------------


def test_temporal_tile_k1_is_identity():
    from repro.core.passes import decompose_stencil
    from repro.core.passes.decompose import make_strategy_2d

    local = decompose_stencil(_jacobi().func, make_strategy_2d((2, 2)))
    assert temporal_tile(local, 1) is local


def test_epoch_halo_accumulates_with_depth():
    func = _jacobi().func
    lo1, hi1 = epoch_halo(func, 1)
    lo4, hi4 = epoch_halo(func, 4)
    assert lo1 == hi1 == (1, 1)
    assert lo4 == hi4 == (4, 4)


def test_epoch_halo_accumulates_through_chains():
    p = ProgramBuilder("chain_t", (24, 24))
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    a = p.apply([t], lambda b, u: (u.at(-1, 0) + u.at(1, 0)) * 0.5)
    r = p.apply([a], lambda b, a: (a.at(0, -1) + a.at(0, 1)) * 0.5)
    p.store(r, out)
    func = p.finish().func
    # one step reads (1, 1); two chained steps read (2, 2) per step
    assert epoch_halo(func, 1) == ((1, 1), (1, 1))
    assert epoch_halo(func, 2) == ((2, 2), (2, 2))


def test_single_deep_swap_per_epoch_even_for_chains():
    """A chain with an intermediate per-step exchange collapses to ONE
    deep exchange per epoch: the intermediate halo becomes redundant
    boundary compute."""
    p = ProgramBuilder("chain_one", (24, 24))
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    a = p.apply([t], lambda b, u: (u.at(-1, 0) + u.at(1, 0)) * 0.5)
    r = p.apply([a], lambda b, a: (a.at(0, -1) + a.at(0, 1)) * 0.5)
    p.store(r, out)
    prog = p.finish(boundary="periodic")

    base = api.compile(prog, Target())
    tiled = api.compile(prog, Target(exchange_every=2))
    waits = lambda s: sum(
        1 for op in s.local_ir.body.ops if isinstance(op, comm.WaitOp)
    )
    # baseline: one exchange per apply per step; epoch: one deep exchange
    assert waits(base) == 2
    assert waits(tiled) <= waits(base)
    starts = sum(
        1
        for op in tiled.local_ir.body.ops
        if isinstance(op, comm.ExchangeStartOp)
    )
    assert starts == 4  # one deep volley (4 faces on the trivial 2-d grid)


def test_boundary_mask_only_for_zero_bc():
    def masks(boundary, k):
        prog = _jacobi(boundary=boundary, name=f"mask_probe_{boundary}_{k}")
        step = api.compile(prog, Target(exchange_every=k))
        return sum(
            1
            for op in step.local_ir.body.ops
            if isinstance(op, comm.BoundaryMaskOp)
        )

    assert masks("periodic", 4) == 0
    # k-1 grown intermediates each get re-masked to the physical domain
    assert masks("zero", 4) == 3
    assert masks("zero", 1) == 0


@pytest.mark.parametrize("k", [1, 2])
@pytest.mark.parametrize("boundary", ["zero", "periodic"])
def test_wave_rotates_closed_bitwise(k, boundary):
    """time_order-2 (wave-style) programs carry p=2 buffers through a
    q=1 output: the epoch now emits the carried state into the dead
    oldest buffer, so a k-step epoch returns the FULL rotated state and
    exchange_every>1 is bitwise-equal to the per-step baseline."""
    from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

    g = Grid(shape=(32, 32), extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=g, space_order=2, time_order=2)
    op = Operator(Eq(u.dt2, u.laplace), dt=1e-3, boundary=boundary)
    rng = np.random.default_rng(7)
    state = tuple(
        rng.standard_normal((32, 32)).astype(np.float32) for _ in range(2)
    )
    base = api.compile(op.program, Target())
    tiled = api.compile(op.program, Target(exchange_every=k))
    want = base.time_loop(state, 4)
    got = tiled.time_loop(state, 4)
    assert len(got) == 2  # full rotated state: (u@t+3, u@t+4)
    for w, o in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(o))


def test_rejects_more_outputs_than_inputs():
    """q > p state can never rotate closed — must still fail loudly at
    validation (no input buffer exists to carry the extra output)."""
    p = ProgramBuilder("two_out", (16, 16))
    u = p.input("u")
    a = p.output("a")
    b = p.output("b")
    t = p.load(u)
    r = p.apply([t], lambda bb, uu: uu.at(0, 0) * 0.5)
    p.store(r, a)
    p.store(r, b)
    with pytest.raises(TargetError, match="rotate"):
        api.compile(p.finish(), Target(exchange_every=2))


def test_rejects_position_dependent_bodies():
    from repro.core.builder import Expr
    from repro.core.dialects import stencil

    p = ProgramBuilder("idx_probe", (16, 16))
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: u.at(0, 0)
        + Expr(b, b.insert(stencil.IndexOp(0)).results[0]),
    )
    p.store(r, out)
    with pytest.raises(TemporalTilingError, match="position-dependent"):
        epoch_halo(p.finish().func, 2)


# -------------------------------------------------------------------------
# Target validation + fingerprints + time_loop epochs
# -------------------------------------------------------------------------


def test_target_rejects_bad_exchange_every():
    with pytest.raises(TargetError, match="positive integer"):
        Target(exchange_every=0)
    with pytest.raises(TargetError, match="positive integer"):
        Target(exchange_every=-2)


def test_target_rejects_pipeline_epoch_mismatch():
    with pytest.raises(TargetError, match="temporal-tile"):
        Target(
            pipeline="decompose,swap-elim,temporal-tile{k=4},lower-comm",
            exchange_every=2,
        )
    with pytest.raises(TargetError, match="temporal-tile"):
        # exchange_every>1 with a pipeline that never tiles
        Target(pipeline="decompose,swap-elim,lower-comm", exchange_every=2)


def test_deep_halo_validation_names_axis_and_depth():
    """Satellite fix: exceeding the shard capacity must name the offending
    axis and the inferred per-step depth, mirroring the strategy-grid
    error style."""
    import jax
    from jax.sharding import Mesh

    from repro.core.passes.decompose import make_strategy_1d

    prog = _jacobi(shape=(16, 16), name="deep_probe")
    mesh = Mesh(np.array(jax.devices() * 8), ("x",))
    target = Target(
        mesh=mesh, strategy=make_strategy_1d(8), exchange_every=4
    )
    # shard extent 16/8 = 2 < deep halo 4
    with pytest.raises(TargetError) as ei:
        api.compile(prog, target)
    msg = str(ei.value)
    assert "mesh axis 'x'" in msg
    assert "per-step depth 1" in msg
    assert "deep halo 4" in msg
    assert "exchange_every <= 2" in msg


def test_fingerprints_distinct_per_epoch_depth():
    assert (
        Target(exchange_every=4).fingerprint != Target().fingerprint
    )
    assert (
        Target(exchange_every=4).fingerprint
        != Target(exchange_every=2).fingerprint
    )
    prog = _jacobi(name="fp_probe")
    a = api.compile(prog, Target())
    b = api.compile(prog, Target(exchange_every=4))
    assert a is not b
    assert "temporal-tile{k=4}" in b.pipeline_report.spec


def test_time_loop_iterates_in_epochs():
    import jax.numpy as jnp

    prog = _jacobi(name="epoch_loop_probe")
    base = api.compile(prog, Target())
    tiled = api.compile(prog, Target(exchange_every=4))
    rng = np.random.default_rng(7)
    u0 = jnp.asarray(rng.standard_normal((16, 16)).astype(np.float32))
    (want,) = base.time_loop([u0], 8)
    (got,) = tiled.time_loop([u0], 8)  # 2 epochs of 4
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    with pytest.raises(ValueError, match="multiple of the epoch depth"):
        tiled.time_loop([u0], 6)


# -------------------------------------------------------------------------
# roofline tradeoff terms
# -------------------------------------------------------------------------


def test_cost_carries_tiling_terms_and_recommends():
    from repro.launch.roofline import RooflineTerms

    prog = _jacobi(name="cost_probe")
    terms = api.compile(prog, Target()).cost()
    assert terms.exchange_every == 1
    assert terms.messages_per_epoch == 4  # 4 faces on the trivial 2-d grid
    assert terms.step_halo == (1, 1)
    assert terms.local_shape == (16, 16)
    assert terms.redundant_compute_factor(1) == 1.0
    assert terms.redundant_compute_factor(4) > 1.0
    d = terms.as_dict()
    assert "recommended_exchange_every" in d and "t_latency" in d

    # latency-dominated regime (tiny shard, many messages): deep epochs win
    lat = RooflineTerms(
        flops=1e6, bytes_accessed=1e5, collectives={},
        exchange_every=1, messages_per_epoch=8,
        step_halo=(1, 1), local_shape=(32, 32),
    )
    assert lat.recommend_exchange_every(max_k=8) > 1
    # compute-dominated regime (huge shard FLOPs): stay at k=1
    comp = RooflineTerms(
        flops=1e13, bytes_accessed=1e5, collectives={},
        exchange_every=1, messages_per_epoch=2,
        step_halo=(4, 4), local_shape=(8, 8),
    )
    assert comp.recommend_exchange_every(max_k=8) == 1
    # infeasible depths (deep halo > shard) are never recommended
    assert not lat.feasible_exchange_every(64)


def test_epoch_emits_scaled_swap_extents():
    """The deep swap's halo extents are the per-step extents scaled by k
    (golden structural property of the rewrite)."""
    from repro.core.passes import (
        decompose_stencil,
        eliminate_redundant_swaps,
    )
    from repro.core.passes.decompose import make_strategy_2d

    local = decompose_stencil(
        _jacobi((32, 32)).func, make_strategy_2d((2, 2)), boundary="periodic"
    )
    eliminate_redundant_swaps(local)
    tiled = temporal_tile(local, 4)
    (swap,) = [op for op in tiled.body.ops if isinstance(op, dmp.SwapOp)]
    assert swap.halo_widths() == ((4, 4), (4, 4))
    # step j computes core grown by (k-j): 22, 20, 18, 16
    from repro.core.dialects import stencil

    shapes = [
        op.result_bounds.shape
        for op in tiled.body.ops
        if isinstance(op, stencil.ApplyOp)
    ]
    assert shapes == [(22, 22), (20, 20), (18, 18), (16, 16)]
