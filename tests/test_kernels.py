"""Pallas kernel allclose sweeps vs the pure-jnp oracles (ref.py).

Kernels run in interpret=True mode (CPU container; TPU is the target).
Hypothesis drives shape/radius/coefficient sweeps; fixed parametrized
cases cover the paper's benchmark configurations (SDO 2/4/8 × 2D/3D).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.fd import laplacian_star, radius
from repro.kernels import ops, ref
from repro.kernels.stencil_apply import choose_tile


def _rand(shape, dtype=np.float32, seed=0):
    return np.random.default_rng(seed).standard_normal(shape).astype(dtype)


# -------------------------------------------------------------------------
# fixed paper-configuration sweeps: SDO × rank
# -------------------------------------------------------------------------


@pytest.mark.parametrize("order", [2, 4, 8])
@pytest.mark.parametrize("rank", [1, 2, 3])
def test_laplacian_matches_ref(order, rank):
    h = radius(order)
    core = {1: (128,), 2: (32, 64), 3: (8, 16, 32)}[rank]
    x = _rand(tuple(c + 2 * h for c in core), seed=order * 10 + rank)
    got = ops.laplacian(jnp.asarray(x), order=order)
    want = ref.star_stencil_ref(jnp.asarray(x), laplacian_star(rank, order), (h,) * rank)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("order", [2, 4, 8])
def test_heat_step_matches_ref(order):
    h = radius(order)
    x = _rand((24 + 2 * h, 48 + 2 * h), seed=order)
    got = ops.heat_step(jnp.asarray(x), 0.1, order=order)
    want = ref.heat_step_ref(jnp.asarray(x), 0.1, order, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("order", [2, 4, 8])
def test_wave_step_matches_ref(order):
    h = radius(order)
    u_t = _rand((16 + 2 * h, 16 + 2 * h), seed=order + 1)
    u_tm1 = _rand((16 + 2 * h, 16 + 2 * h), seed=order + 2)
    core = tuple(slice(h, s - h) for s in u_t.shape)
    got = ops.wave_step(jnp.asarray(u_t), jnp.asarray(u_tm1[core]), 0.25, order=order)
    want = ref.wave_step_ref(jnp.asarray(u_t), jnp.asarray(u_tm1), 0.25, order, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------------------
# hypothesis property sweeps
# -------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    nx=st.integers(4, 40),
    ny=st.integers(4, 40),
    halo=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_star_stencil_random_shapes(nx, ny, halo, seed):
    """Arbitrary core shapes/halos: kernel == oracle."""
    rng = np.random.default_rng(seed)
    coeffs = {}
    for d in range(2):
        for o in (-halo, halo):
            off = tuple(o if k == d else 0 for k in range(2))
            coeffs[off] = float(rng.standard_normal())
    coeffs[(0, 0)] = float(rng.standard_normal())
    x = rng.standard_normal((nx + 2 * halo, ny + 2 * halo)).astype(np.float32)
    got = ops.star_stencil(jnp.asarray(x), coeffs, (halo, halo))
    want = ref.star_stencil_ref(jnp.asarray(x), coeffs, (halo, halo))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(8, 64),
    order=st.sampled_from([2, 4, 8]),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**16),
)
def test_laplacian_dtype_sweep_1d(n, order, dtype, seed):
    h = radius(order)
    x = np.random.default_rng(seed).standard_normal(n + 2 * h).astype(dtype)
    got = ops.laplacian(jnp.asarray(x), order=order)
    want = ref.star_stencil_ref(jnp.asarray(x), laplacian_star(1, order), (h,))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    shape=st.tuples(st.integers(2, 12), st.integers(2, 12), st.integers(2, 12)),
    seed=st.integers(0, 2**16),
)
def test_box_stencil_3d(shape, seed):
    """Box (corner-reading) stencils — the diagonal-exchange case."""
    rng = np.random.default_rng(seed)
    coeffs = {
        (1, 1, 0): 0.5,
        (-1, -1, 0): -0.25,
        (0, 1, -1): 1.5,
        (0, 0, 0): 1.0,
    }
    halo = (1, 1, 1)
    x = rng.standard_normal(tuple(s + 2 for s in shape)).astype(np.float32)
    got = ops.star_stencil(jnp.asarray(x), coeffs, halo)
    want = ref.star_stencil_ref(jnp.asarray(x), coeffs, halo)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------------------
# explicit tiling: the BlockSpec grid path (tile ≠ full array)
# -------------------------------------------------------------------------


@pytest.mark.parametrize("tile", [(8, 64), (16, 32), (32, 16)])
def test_explicit_tiles_agree(tile):
    """Different VMEM tilings must not change results (overlap windows)."""
    x = _rand((64 + 2, 64 + 2), seed=11)
    star = laplacian_star(2, 2)
    from repro.kernels.stencil_apply import run_apply_pallas
    from repro.kernels.ops import _star_apply_ir

    apply_op, ob = _star_apply_ir(star, (64, 64), (1, 1))
    from repro.core.dialects import stencil

    rb = stencil.Bounds.from_shape((64, 64))
    (got,) = run_apply_pallas(
        apply_op, [jnp.asarray(x)], [ob.lb], rb, tile=tile, interpret=True
    )
    want = ref.star_stencil_ref(jnp.asarray(x), star, (1, 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_choose_tile_respects_budget_and_divisibility():
    shape = (512, 1024)
    spans = [((-4, -4), (4, 4))]
    tile = choose_tile(shape, spans, budget=256 * 1024)
    assert all(s % t == 0 for s, t in zip(shape, tile))
    numel = (tile[0] + 8) * (tile[1] + 8)
    assert numel * 4 <= 256 * 1024
    # minor dim kept whole (lane alignment) when possible
    assert tile[1] == 1024 or tile[1] % 128 == 0


def test_kernel_backend_equals_jnp_backend_end_to_end():
    """Same stencil program through lowering w/ jnp vs pallas backends."""
    from repro.api import Target, compile as api_compile
    from repro.frontends.oec_like import ProgramBuilder

    def build():
        p = ProgramBuilder("j", shape=(32, 32))
        u = p.input("u")
        out = p.output("out")
        t = p.load(u)
        r = p.apply(
            [t],
            lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.25
            - u.at(0, 0) * 0.1,
        )
        p.store(r, out)
        return p.finish(boundary="periodic")

    u0 = _rand((32, 32), seed=13)
    out0 = np.zeros_like(u0)
    r_jnp = api_compile(build(), Target(backend="jnp"))(u0, out0)
    r_pal = api_compile(build(), Target(backend="pallas"))(u0, out0)
    np.testing.assert_allclose(
        np.asarray(r_jnp[0]), np.asarray(r_pal[0]), rtol=1e-5, atol=1e-6
    )
