"""Context-parallelism correctness worker (run in a SUBPROCESS with 8
virtual devices, tests/test_context_parallel.py):

    python tests/cp_worker.py <scenario>

Asserts that ``repro.dist.context_parallel`` — the sequence-dimension
halo exchange routed through the shared ``dmp``/``comm`` stencil
machinery — produces results **bitwise identical** to the single-device
reference, the same guarantee tests/dist_worker.py asserts for stencil
programs.  Exit 0 = all assertions passed.
"""
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.dist.context_parallel import (  # noqa: E402
    SeqHaloSpec,
    causal_conv_cp,
    comm_ir_text,
    seq_halo_exchange,
    sliding_window_attention_cp,
)
from repro.dist.sharding import shard_map  # noqa: E402


def _mesh(n, axis="seq"):
    return Mesh(np.array(jax.devices()[:n]), (axis,))


def check(name, got, want):
    got, want = np.asarray(got), np.asarray(want)
    if not np.array_equal(got, want):
        print(
            f"MISMATCH in {name}: max abs diff {np.abs(got - want).max():.3e}"
        )
        sys.exit(1)
    print(f"ok: {name}")


def scenario_exchange(boundary):
    """The raw exchange: distributed halos == numpy slicing of the global
    array (bitwise — the exchange only moves data)."""
    from jax.sharding import PartitionSpec as P

    B, S, C = 2, 64, 6
    n, lo, hi = 8, 3, 2
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((B, S, C)), jnp.float32)
    spec = SeqHaloSpec(axis="seq", n_shards=n, halo_lo=lo, halo_hi=hi,
                       seq_dim=1, boundary=boundary)
    mesh = _mesh(n)

    def local(x_loc):
        return seq_halo_exchange(x_loc, spec, distributed=True)

    got = jax.jit(
        shard_map(
            local, mesh=mesh, in_specs=P(None, "seq"),
            out_specs=P(None, "seq"), check_vma=False,
        )
    )(x)  # [B, n*(lo + S/n + hi), C] concatenated per-shard halo blocks
    S_loc = S // n
    got = np.asarray(got).reshape(B, n, lo + S_loc + hi, C)

    xp = np.asarray(x)
    if boundary == "periodic":
        pad = np.concatenate([xp[:, -lo:], xp, xp[:, :hi]], axis=1)
    else:
        pad = np.pad(xp, ((0, 0), (lo, hi), (0, 0)))
    for r in range(n):
        want = pad[:, r * S_loc : r * S_loc + lo + S_loc + hi]
        check(f"exchange-{boundary}-shard{r}", got[:, r], want)


def scenario_conv():
    """Distributed Mamba causal conv == single-device _causal_conv,
    bitwise (fp32; the halo is the conv's stitching state)."""
    from repro.models.mamba import _causal_conv

    B, S, C, K = 2, 64, 16, 4
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((B, S, C)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, C)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((C,)), jnp.float32)

    want = jax.jit(lambda x, w, b: _causal_conv(x, w, b)[0])(x, w, b)
    got = jax.jit(
        lambda x, w, b: causal_conv_cp(x, w, b, _mesh(8), "seq")
    )(x, w, b)
    check("causal-conv-8-ranks", got, want)


def scenario_window_attention():
    """Sequence-parallel sliding-window attention == the same window
    kernel on one device (bitwise: per-query arithmetic is independent of
    the decomposition)."""
    B, S, H, D, W = 2, 64, 2, 8, 8
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)

    want = jax.jit(
        lambda q, k, v: sliding_window_attention_cp(q, k, v, W, _mesh(1), "x")
    )(q, k, v)
    got = jax.jit(
        lambda q, k, v: sliding_window_attention_cp(q, k, v, W, _mesh(8), "seq")
    )(q, k, v)
    check("window-attention-8-ranks", got, want)


def scenario_window_vs_dense():
    """The window kernel agrees with the dense masked reference (tight
    tolerance — different reduction shapes, so not bitwise)."""
    B, S, H, D, W = 2, 64, 2, 8, 8
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)

    s = np.einsum("bthd,bshd->bhts", np.asarray(q), np.asarray(k)) / np.sqrt(D)
    pos = np.arange(S)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - W)
    s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = np.einsum("bhts,bshd->bthd", p, np.asarray(v))

    got = jax.jit(
        lambda q, k, v: sliding_window_attention_cp(q, k, v, W, _mesh(8), "seq")
    )(q, k, v)
    if not np.allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5):
        print(f"MISMATCH vs dense: {np.abs(np.asarray(got) - want).max():.3e}")
        sys.exit(1)
    print("ok: window-vs-dense-reference")


def scenario_comm_ir():
    """The exchange really lowers through the comm dialect (halo_pad +
    exchange_start/wait), not a bespoke path."""
    spec = SeqHaloSpec(axis="seq", n_shards=8, halo_lo=3, halo_hi=0)
    ops = comm_ir_text((2, 8, 6), spec)
    assert "comm.halo_pad" in ops, ops
    assert "comm.exchange_start" in ops, ops
    assert "comm.wait" in ops, ops
    print("ok: comm-dialect-ir")


SCENARIOS = {
    "exchange-zero": lambda: scenario_exchange("zero"),
    "exchange-periodic": lambda: scenario_exchange("periodic"),
    "conv": scenario_conv,
    "window-attention": scenario_window_attention,
    "window-vs-dense": scenario_window_vs_dense,
    "comm-ir": scenario_comm_ir,
}


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    for n in list(SCENARIOS) if which == "all" else [which]:
        SCENARIOS[n]()
    print("ALL OK")
