"""Flash-style chunked decode attention == dense decode attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as attn
from repro.configs import get_config
from repro.configs.base import reduced_config


@pytest.mark.parametrize("window", [0, 24])
def test_online_softmax_matches_dense(monkeypatch, window):
    cfg = dataclasses.replace(
        reduced_config(get_config("qwen2-7b")),
        dtype="float32",
        local_window=window,
    )
    kind = "attn_local" if window else "attn"
    p = attn.attn_init(jax.random.PRNGKey(0), cfg)
    B, T = 3, 64
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((B, 1, cfg.d_model)), jnp.float32)
    kh, hd = cfg.n_kv_heads, cfg.head_dim_
    ck = jnp.asarray(rng.standard_normal((B, T, kh, hd)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((B, T, kh, hd)), jnp.float32)
    pos = jnp.asarray([40, 55, 63], jnp.int32)  # per-slot positions

    def run():
        return attn.decode_self_attention(
            p, x, ck, cv, pos, cfg, kind=kind, dtype=jnp.float32
        )

    # dense path (chunking disabled)
    monkeypatch.setattr(attn, "DECODE_KV_CHUNK", 10**9)
    o_dense, k1, v1 = run()
    # chunked path (T=64 -> 8 chunks of 8)
    monkeypatch.setattr(attn, "DECODE_KV_CHUNK", 8)
    o_chunk, k2, v2 = run()

    np.testing.assert_allclose(
        np.asarray(o_dense), np.asarray(o_chunk), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))


def test_chunked_path_used_for_long_caches(monkeypatch):
    """Sanity: with a tiny threshold the scan body appears in the jaxpr."""
    cfg = dataclasses.replace(reduced_config(get_config("yi-9b")), dtype="float32")
    p = attn.attn_init(jax.random.PRNGKey(0), cfg)
    monkeypatch.setattr(attn, "DECODE_KV_CHUNK", 16)
    B, T = 2, 128
    x = jnp.zeros((B, 1, cfg.d_model))
    ck = jnp.zeros((B, T, cfg.n_kv_heads, cfg.head_dim_))
    jaxpr = jax.make_jaxpr(
        lambda x, ck: attn.decode_self_attention(
            p, x, ck, ck, jnp.int32(100), cfg, kind="attn", dtype=jnp.float32
        )
    )(x, ck)
    assert "scan" in str(jaxpr)
