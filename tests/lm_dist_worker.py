"""LM distribution correctness worker (run in a subprocess with 8
virtual devices).  Checks that sharded execution through the production
specs equals single-device execution.

    python tests/lm_dist_worker.py decode_seq_sharded
"""
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import reduced_config  # noqa: E402
from repro.dist.sharding import default_rules, kv_cache_layout, use_mesh  # noqa: E402
from repro.models import lm  # noqa: E402


def _mesh(data, model):
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def check(name, got, want, tol):
    got, want = np.asarray(got, np.float32), np.asarray(want, np.float32)
    if not np.allclose(got, want, rtol=tol, atol=tol):
        print(f"MISMATCH {name}: max abs {np.abs(got-want).max():.3e}")
        sys.exit(1)
    print(f"ok: {name}")


def decode_seq_sharded():
    """KH=2 on model=4 forces the seq-sharded cache layout; the
    distributed flash-decode (shard_map + LSE psum) must equal the
    single-device dense path."""
    cfg = dataclasses.replace(
        reduced_config(get_config("yi-9b")), dtype="float32", n_kv_heads=2,
        n_layers=2,
    )
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, T = 4, 32
    cache = lm.init_cache(cfg, B, T)
    rng = np.random.default_rng(0)
    # warm the cache with random (valid) content
    cache = jax.tree.map(
        lambda c: jnp.asarray(rng.standard_normal(c.shape), c.dtype), cache
    )
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, B), jnp.int32)
    pos = jnp.int32(20)

    # single-device reference
    ref_logits, ref_cache = lm.decode_step(params, cfg, tok, pos, cache)

    mesh = _mesh(2, 4)
    rules = default_rules()
    assert kv_cache_layout(B, T, cfg.n_kv_heads, mesh, rules) == "seq"

    def step(params, tok, pos, cache):
        with use_mesh(mesh, rules):
            return lm.decode_step(params, cfg, tok, pos, cache)

    cache_sh = jax.tree.map(
        lambda c: jax.device_put(
            c,
            NamedSharding(mesh, P(None, "data", "model", None, None))
            if c.ndim == 5 else NamedSharding(mesh, P()),
        ),
        cache,
    )
    got_logits, got_cache = jax.jit(step)(params, tok, pos, cache_sh)
    check("decode-seq-sharded logits", got_logits, ref_logits, 2e-5)
    for a, b in zip(jax.tree.leaves(got_cache), jax.tree.leaves(ref_cache)):
        check("cache leaf", a, b, 2e-5)


def decode_seq_all_sharded():
    """B=1 long-context: cache spread over (data, model)."""
    cfg = dataclasses.replace(
        reduced_config(get_config("yi-9b")), dtype="float32", n_kv_heads=2,
        n_layers=2,
    )
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    B, T = 1, 64
    cache = lm.init_cache(cfg, B, T)
    rng = np.random.default_rng(1)
    cache = jax.tree.map(
        lambda c: jnp.asarray(rng.standard_normal(c.shape), c.dtype), cache
    )
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, B), jnp.int32)
    pos = jnp.int32(50)
    ref_logits, _ = lm.decode_step(params, cfg, tok, pos, cache)

    mesh = _mesh(2, 4)
    rules = default_rules()
    assert kv_cache_layout(B, T, cfg.n_kv_heads, mesh, rules) == "seq_all"

    def step(params, tok, pos, cache):
        with use_mesh(mesh, rules):
            return lm.decode_step(params, cfg, tok, pos, cache)

    cache_sh = jax.tree.map(
        lambda c: jax.device_put(
            c,
            NamedSharding(mesh, P(None, None, ("data", "model"), None, None))
            if c.ndim == 5 else NamedSharding(mesh, P()),
        ),
        cache,
    )
    got_logits, _ = jax.jit(step)(params, tok, pos, cache_sh)
    check("decode-seq-all logits", got_logits, ref_logits, 2e-5)


SCENARIOS = {
    "decode_seq_sharded": decode_seq_sharded,
    "decode_seq_all_sharded": decode_seq_all_sharded,
}

if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    for n in list(SCENARIOS) if which == "all" else [which]:
        SCENARIOS[n]()
    print("ALL OK")
