"""repro.obs: span tracer, Chrome export, unified registry, drift.

The tracer itself is tested synthetically (hand-built spans, no jax);
the end-to-end acceptance — a traced 2-rank ``exchange_every=4`` heat
run whose merged Chrome trace shows one exchange span pair per epoch
overlapping the interior apply — runs in a subprocess through
``tests/dist_worker.py obs-trace-2rank`` so the 8-device XLA flag never
leaks into this process.
"""
import json
import os
import subprocess
import sys

import pytest

from repro import obs
from repro.obs.trace import LANE_COMM, LANE_EXECUTE, Span, Tracer

WORKER = os.path.join(os.path.dirname(__file__), "dist_worker.py")


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Every test starts and ends with the singleton disabled + empty."""
    obs.disable()
    obs.clear()
    yield
    obs.disable()
    obs.clear()


# --------------------------------------------------------------------------
# tracer core
# --------------------------------------------------------------------------


def test_disabled_tracing_is_a_shared_noop():
    assert not obs.enabled()
    h1 = obs.span("work", cat="compute", big="payload")
    h2 = obs.span("other")
    # one shared null object — nothing allocated per disabled call site
    assert h1 is h2
    with h1:
        h1.args["ignored"] = True  # writes to a disabled span go nowhere
    assert obs.spans() == []
    obs.instant("event")
    assert obs.end_window(obs.begin_window("w")) is None
    assert obs.spans() == []


def test_span_records_nesting_and_args():
    obs.enable()
    with obs.span("outer", cat="compile", phase="a"):
        with obs.span("inner", cat="compile"):
            pass
        with obs.span("inner2", cat="compute"):
            pass
    got = obs.spans()
    assert [s.name for s in got] == ["inner", "inner2", "outer"]
    by = {s.name: s for s in got}
    assert by["outer"].depth == 0
    assert by["inner"].depth == by["inner2"].depth == 1
    assert by["outer"].args == {"phase": "a"}
    # children are contained in the parent's window
    assert by["outer"].ts <= by["inner"].ts
    assert by["inner"].end <= by["outer"].end + 1e-6
    assert by["inner"].end <= by["inner2"].ts + by["inner2"].dur + 1e-6


def test_traced_decorator_bare_and_named():
    @obs.traced
    def f(x):
        return x + 1

    @obs.traced("custom.name", cat="serve")
    def g(x):
        return x * 2

    assert f(1) == 2 and g(2) == 4  # disabled: plain passthrough
    assert obs.spans() == []
    obs.enable()
    assert f(1) == 2 and g(2) == 4
    names = [s.name for s in obs.spans()]
    assert any("f" in n for n in names) and "custom.name" in names
    assert {s.cat for s in obs.spans() if s.name == "custom.name"} == {"serve"}


def test_async_windows_live_on_the_comm_lane():
    obs.enable()
    tok = obs.begin_window("comm.exchange", size=[1, 4])
    with obs.span("apply:interior", cat="compute"):
        pass
    obs.end_window(tok, rounds=1)
    comm = [s for s in obs.spans() if s.cat == "comm"]
    assert len(comm) == 1
    assert comm[0].tid == LANE_COMM
    assert comm[0].args == {"size": [1, 4], "rounds": 1}
    # the window opened before the apply and closed after it: overlap
    apply = next(s for s in obs.spans() if s.name == "apply:interior")
    assert apply.tid == LANE_EXECUTE
    assert comm[0].ts <= apply.ts and apply.end <= comm[0].end + 1e-6


def test_ring_buffer_bounds_and_counts_drops():
    t = Tracer(capacity=4)
    t.enable()
    for i in range(7):
        with t.span(f"s{i}"):
            pass
    kept = [s.name for s in t.spans()]
    assert kept == ["s3", "s4", "s5", "s6"]
    assert t.dropped == 3
    assert t.counters()["dropped"] == 3
    t.clear()
    assert t.spans() == [] and t.dropped == 0


def test_span_dict_roundtrip():
    s = Span(name="epoch", cat="dispatch", ts=10.0, dur=0.5, rank=1,
             tid=LANE_EXECUTE, depth=2, args={"k": 4})
    assert Span.from_dict(s.as_dict()) == s


# --------------------------------------------------------------------------
# export
# --------------------------------------------------------------------------


def _synthetic_spans():
    """Two ranks, one SPMD span, one comm window overlapping an apply."""
    return [
        Span("epoch", "dispatch", ts=1.0, dur=1.0, rank=None,
             args={"ranks": 2, "k": 4}),
        Span("comm.exchange", "comm", ts=1.1, dur=0.5, rank=None,
             tid=LANE_COMM, args={"ranks": 2}),
        Span("apply:interior", "compute", ts=1.2, dur=0.3, rank=None,
             args={"ranks": 2}),
        Span("engine.step", "serve", ts=2.0, dur=0.1, rank=0),
    ]


def test_chrome_export_schema(tmp_path):
    path = obs.write_chrome(str(tmp_path / "t.json"), _synthetic_spans())
    with open(path) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    xs = [e for e in events if e["ph"] == "X"]
    # two ranks discovered from args.ranks -> two process-name records
    assert {e["args"]["name"] for e in meta if e["name"] == "process_name"} \
        == {"rank 0", "rank 1"}
    # SPMD spans replicate onto both pids; rank-0 span stays on pid 0
    epochs = [e for e in xs if e["name"] == "epoch"]
    assert sorted(e["pid"] for e in epochs) == [0, 1]
    assert all(e["args"]["spmd"] for e in epochs)
    steps = [e for e in xs if e["name"] == "engine.step"]
    assert [e["pid"] for e in steps] == [0]
    # microseconds, comm lane separated
    ep = epochs[0]
    assert ep["ts"] == pytest.approx(1.0 * 1e6) and \
        ep["dur"] == pytest.approx(1.0 * 1e6)
    assert {e["tid"] for e in xs if e["cat"] == "comm"} == {LANE_COMM}


def test_rank_traces_merge_and_reload(tmp_path):
    spans = _synthetic_spans()
    paths = obs.write_rank_traces(str(tmp_path), spans)
    assert len(paths) == 2
    merged_path = str(tmp_path / "merged.json")
    merged = obs.merge_traces(str(tmp_path), out=merged_path)
    xs = [e for e in merged["traceEvents"] if e["ph"] == "X"]
    # 3 SPMD spans x 2 ranks + 1 rank-0 span
    assert len(xs) == 7
    meta = [e for e in merged["traceEvents"] if e["ph"] == "M"]
    names = [(e["name"], e["pid"], e["tid"]) for e in meta]
    assert len(names) == len(set(names)), "merge must dedupe metadata"
    # a merged chrome file loads back into Span objects (rank = pid)
    loaded = obs.load_spans(merged_path)
    assert len(loaded) == 7
    assert {s.rank for s in loaded} == {0, 1}


def test_jsonl_roundtrip(tmp_path):
    spans = _synthetic_spans()
    path = obs.write_jsonl(str(tmp_path / "t.jsonl"), spans)
    loaded = obs.load_spans(path)
    assert loaded == spans


# --------------------------------------------------------------------------
# unified registry
# --------------------------------------------------------------------------


def test_snapshot_unifies_five_counter_islands():
    snap = obs.snapshot()
    for ns in ("compile", "kernel", "serve", "checkpoint", "tune"):
        assert ns in snap, f"missing namespace {ns}"
        assert isinstance(snap[ns], dict) and snap[ns], snap[ns]
    assert {"hits", "misses", "pipeline_runs"} <= set(snap["compile"])
    assert {"apply_calls", "pallas_calls"} <= set(snap["kernel"])
    assert "engines" in snap["serve"]
    assert {"saves", "restores"} <= set(snap["checkpoint"])
    assert "hits" in snap["tune"]
    assert snap["trace"]["enabled"] is False
    flat = obs.snapshot(flat=True)
    assert "compile.hits" in flat and "checkpoint.saves" in flat


def test_snapshot_sees_live_traffic():
    import numpy as np

    from repro.api import Target, cache_stats, compile as api_compile
    from repro.frontends.oec_like import ProgramBuilder

    p = ProgramBuilder("obs_snap", (8, 8))
    u = p.input("u")
    out = p.output("out")
    r = p.apply([p.load(u)], lambda b, u: u.at(0, 0) * 2.0)
    p.store(r, out)
    prog = p.finish(boundary="zero")
    before = obs.snapshot()
    step = api_compile(prog, Target())
    step(np.zeros((8, 8), np.float32), np.zeros((8, 8), np.float32))
    after = obs.snapshot()
    assert after["compile"]["pipeline_runs"] > before["compile"]["pipeline_runs"]
    total = after["compile"]["hits"] + after["compile"]["misses"]
    assert total > before["compile"]["hits"] + before["compile"]["misses"]


# --------------------------------------------------------------------------
# drift
# --------------------------------------------------------------------------


class _FixedTerms:
    """RooflineTerms stand-in with a known modeled step time."""

    def __init__(self, step_s):
        self._s = step_s

    def step_time(self, k):
        return self._s


def _drift_spans(epoch_dur=0.8, k=4):
    spans = []
    for e in range(2):
        t0 = float(e)
        spans.append(Span("epoch", "dispatch", ts=t0, dur=epoch_dur,
                          args={"k": k, "epoch": e}))
        # exchange window 0.2 wide; interior apply covers half of it
        spans.append(Span("comm.exchange", "comm", ts=t0 + 0.1, dur=0.2,
                          tid=LANE_COMM))
        spans.append(Span("apply:interior", "compute", ts=t0 + 0.2, dur=0.3))
    return spans


def test_drift_report_synthetic():
    rep = obs.drift_report(spans=_drift_spans(), terms=_FixedTerms(0.1))
    assert rep.epochs == 2
    assert rep.exchange_every == 4  # inferred from the epoch span's k tag
    assert rep.measured_step_s == pytest.approx(0.8 / 4)
    assert rep.modeled_step_s == pytest.approx(0.1)
    assert rep.drift_ratio == pytest.approx(2.0)
    assert rep.error_pct == pytest.approx(100.0)
    # window [0.1, 0.3], apply covers [0.2, 0.3] -> half hidden
    assert rep.overlap_windows == 2
    assert rep.achieved_overlap == pytest.approx(0.5)
    assert rep.per_phase_s["comm"] == pytest.approx(0.4)
    text = str(rep)
    assert "drift ratio" in text and "achieved overlap" in text
    d = rep.as_dict()
    assert d["drift_ratio"] == pytest.approx(2.0)


def test_drift_report_without_model_or_epochs():
    rep = obs.drift_report(spans=[])
    assert rep.epochs == 0 and rep.measured_step_s is None
    assert rep.drift_ratio is None and rep.achieved_overlap is None
    rep = obs.drift_report(spans=_drift_spans())  # measured-only
    assert rep.modeled_step_s is None and rep.drift_ratio is None
    assert rep.achieved_overlap == pytest.approx(0.5)


def test_obs_cli_summarizes_a_trace(tmp_path):
    path = obs.write_chrome(str(tmp_path / "t.json"), _drift_spans())
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs", path, "--modeled-step", "0.1"],
        capture_output=True, text=True, timeout=120,
        env={**os.environ,
             "PYTHONPATH": os.path.join(os.path.dirname(WORKER), "..", "src")},
    )
    assert proc.returncode == 0, proc.stderr
    assert "epoch" in proc.stdout and "drift" in proc.stdout


# --------------------------------------------------------------------------
# acceptance: traced 2-rank deep-halo run (subprocess, 8 virtual devices)
# --------------------------------------------------------------------------


def test_traced_two_rank_exchange_windows():
    proc = subprocess.run(
        [sys.executable, WORKER, "obs-trace-2rank"],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, (
        f"obs-trace-2rank failed:\nSTDOUT:\n{proc.stdout}\n"
        f"STDERR:\n{proc.stderr[-3000:]}"
    )
    assert "ok: obs-trace-2rank" in proc.stdout
