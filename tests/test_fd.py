"""FD coefficient tables: consistency + convergence properties."""
import numpy as np
import pytest

from repro.core import fd


@pytest.mark.parametrize("order", [2, 4, 6, 8])
def test_d2_coeffs_annihilate_constants_and_linears(order):
    offs, coeffs = fd.second_derivative(order)
    assert abs(sum(coeffs)) < 1e-12                       # f=1 → f''=0
    assert abs(sum(o * c for o, c in zip(offs, coeffs))) < 1e-12  # f=x → 0


@pytest.mark.parametrize("order", [2, 4, 6, 8])
def test_d2_coeffs_exact_on_quadratic(order):
    offs, coeffs = fd.second_derivative(order)
    # f = x² → f'' = 2 exactly for any central scheme of order ≥ 2
    assert abs(sum((o**2) * c for o, c in zip(offs, coeffs)) - 2.0) < 1e-10


@pytest.mark.parametrize("order", [2, 4])
def test_d1_coeffs(order):
    offs, coeffs = fd.first_derivative(order)
    assert abs(sum(coeffs)) < 1e-12
    assert abs(sum(o * c for o, c in zip(offs, coeffs)) - 1.0) < 1e-12


@pytest.mark.parametrize("order", [2, 4, 8])
def test_convergence_order(order):
    """Error of d²/dx² sin(x) scales like h^order."""
    errs = []
    # keep h large enough that the error stays above the f64 noise floor
    for h in (0.4, 0.2):
        offs, coeffs = fd.second_derivative(order, spacing=h)
        x = 0.7
        approx = sum(c * np.sin(x + o * h) for o, c in zip(offs, coeffs))
        errs.append(abs(approx - (-np.sin(x))))
    rate = np.log2(errs[0] / errs[1])
    assert rate > order - 0.5, f"observed rate {rate} for order {order}"


@pytest.mark.parametrize("ndim", [1, 2, 3])
@pytest.mark.parametrize("order", [2, 4, 8])
def test_laplacian_star_shape(ndim, order):
    star = fd.laplacian_star(ndim, order)
    r = fd.radius(order)
    # star points: center + 2r per dim
    assert len(star) == 1 + 2 * r * ndim
    assert abs(sum(star.values())) < 1e-10
    for off in star:
        assert len(off) == ndim
        assert sum(1 for o in off if o != 0) <= 1  # star, not box
        assert all(abs(o) <= r for o in off)


def test_unsupported_order_raises():
    with pytest.raises(ValueError):
        fd.second_derivative(3)
    with pytest.raises(ValueError):
        fd.first_derivative(8)
