"""Unit tests for the SSA+regions IR infrastructure (repro.core.ir) and
the stencil dialect invariants — the paper's §3 foundations."""
import pytest

from repro.core import ir
from repro.core.builder import build_apply
from repro.core.dialects import stencil


def _jacobi_func(shape=(8, 8)):
    core = stencil.Bounds.from_shape(shape)
    func = ir.FuncOp("jacobi", [stencil.FieldType(core), stencil.FieldType(core)])
    load = func.body.add_op(stencil.LoadOp(func.body.args[0]))

    def body(b, u):
        return (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.25

    apply_op = build_apply(func.body, [load.results[0]], core, body)
    func.body.add_op(stencil.StoreOp(apply_op.results[0], func.body.args[1], core))
    func.body.add_op(ir.ReturnOp([]))
    return func, apply_op


def test_ssa_def_use_chains():
    func, apply_op = _jacobi_func()
    load = next(op for op in func.body.ops if isinstance(op, stencil.LoadOp))
    # the load result is used exactly once (by the apply)
    assert load.results[0].num_uses == 1
    assert apply_op.operands[0] is load.results[0]
    # apply result used by the store
    assert apply_op.results[0].num_uses == 1


def test_verifier_accepts_wellformed():
    func, _ = _jacobi_func()
    ir.verify_module(func)  # must not raise


def test_verifier_rejects_store_out_of_bounds():
    core = stencil.Bounds.from_shape((8, 8))
    big = stencil.Bounds.from_shape((16, 16))
    func = ir.FuncOp("bad", [stencil.FieldType(core), stencil.FieldType(core)])
    load = func.body.add_op(stencil.LoadOp(func.body.args[0]))

    def body(b, u):
        return u.at(0, 0)

    apply_op = build_apply(func.body, [load.results[0]], core, body)
    # store with bounds exceeding the field
    func.body.add_op(stencil.StoreOp(apply_op.results[0], func.body.args[1], big))
    func.body.add_op(ir.ReturnOp([]))
    with pytest.raises(Exception):
        ir.verify_module(func)


def test_access_extents_reflect_offsets():
    _, apply_op = _jacobi_func()
    exts = apply_op.access_extents()
    lo, hi = exts[0]
    assert lo == (-1, -1)
    assert hi == (1, 1)


def test_bounds_algebra():
    b = stencil.Bounds.from_shape((10, 20))
    assert b.shape == (10, 20)
    assert b.rank == 2
    g = b.grow((2, 1), (2, 1))
    assert g.lb == (-2, -1) and g.ub == (12, 21)
    assert g.contains(b)
    assert not b.contains(g)


def test_value_replacement_updates_uses():
    func, apply_op = _jacobi_func()
    core = stencil.Bounds.from_shape((8, 8))
    # splice a second load and redirect the apply to it
    load2 = stencil.LoadOp(func.body.args[0])
    func.body.insert_op_before(load2, apply_op)
    old = apply_op.operands[0]
    old.replace_all_uses_with(load2.results[0])
    assert apply_op.operands[0] is load2.results[0]
    assert old.num_uses == 0
    ir.verify_module(func)


def test_clone_is_deep_and_disconnected():
    func, _ = _jacobi_func()
    new = ir.FuncOp(func.sym_name, [a.type for a in func.body.args])
    vmap = dict(zip(func.body.args, new.body.args))
    for op in func.body.ops:
        new.body.add_op(op.clone_into(vmap))
    ir.verify_module(new)
    assert len(new.body.ops) == len(func.body.ops)
    # mutating the clone leaves the original intact
    n_before = len(func.body.ops)
    new.body.ops[-1].erase()
    assert len(func.body.ops) == n_before


def test_printer_emits_mlir_like_text():
    func, _ = _jacobi_func()
    text = ir.print_module(func)
    for needle in ("stencil.load", "stencil.apply", "stencil.store", "stencil.access"):
        assert needle in text, text


def test_multi_result_apply():
    core = stencil.Bounds.from_shape((6, 6))
    func = ir.FuncOp(
        "multi",
        [stencil.FieldType(core), stencil.FieldType(core), stencil.FieldType(core)],
    )
    load = func.body.add_op(stencil.LoadOp(func.body.args[0]))

    def body(b, u):
        return u.at(0, 0) * 2.0, u.at(0, 0) + 1.0

    apply_op = build_apply(func.body, [load.results[0]], core, body, n_results=2)
    assert len(apply_op.results) == 2
    func.body.add_op(stencil.StoreOp(apply_op.results[0], func.body.args[1], core))
    func.body.add_op(stencil.StoreOp(apply_op.results[1], func.body.args[2], core))
    func.body.add_op(ir.ReturnOp([]))
    ir.verify_module(func)
