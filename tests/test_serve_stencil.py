"""The stencil-serving engine: fingerprint-batched slot pools.

Covers the ISSUE 6 acceptance surface: admission/reclaim ordering over a
full pool, same-fingerprint coalescing (asserted through the engine's
batched-vs-solo dispatch counters), bitwise equality of every request's
final state against a solo ``compile(...).time_loop(...)`` run (heat and
the newly-rotating wave under ``exchange_every=2``), streaming-frame
cadence, utilization math — plus the LRU bound and truthful eviction
counters of the process-wide compile cache.
"""
import numpy as np
import pytest

from repro import api
from repro.api import Target
from repro.frontends.oec_like import ProgramBuilder
from repro.serve.stencil import (
    DONE,
    QUEUED,
    RUNNING,
    Scheduler,
    StencilEngine,
    StencilEngineConfig,
    StepMetrics,
)


def _heat(shape=(16, 16), alpha=0.25, boundary="periodic", name="heat_serve"):
    p = ProgramBuilder(name, shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1))
        * alpha,
    )
    p.store(r, out)
    return p.finish(boundary=boundary)


def _wave(shape=(16, 16), boundary="zero", name="wave_serve"):
    # p=2 inputs (u@t-1, u@t), q=1 output — exercises carried-state
    # rotation inside the slot pool
    p = ProgramBuilder(name, shape)
    um = p.input("u_prev")
    u0 = p.input("u_now")
    out = p.output("u_next")
    tm, t0 = p.load(um), p.load(u0)
    r = p.apply(
        [tm, t0],
        lambda b, um, u0: 2.0 * u0.at(0, 0)
        - um.at(0, 0)
        + 0.1
        * (
            u0.at(-1, 0)
            + u0.at(1, 0)
            + u0.at(0, -1)
            + u0.at(0, 1)
            - 4.0 * u0.at(0, 0)
        ),
    )
    p.store(r, out)
    return p.finish(boundary=boundary)


def _rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(
        np.float32
    )


# -------------------------------------------------------------------------
# scheduler: admission / reclaim ordering
# -------------------------------------------------------------------------


def test_admission_is_fifo_and_bounded_by_pool():
    prog = _heat(name="heat_admit")
    compiled = api.compile(prog, Target())
    sched = Scheduler(slots_per_group=2)
    group = sched.group_for(compiled)
    from repro.serve.stencil.request import StencilRequest

    reqs = [
        StencilRequest(
            rid=i,
            program=prog,
            target=compiled.target,
            state=(_rand((16, 16), i),),
            n_steps=2,
        )
        for i in range(4)
    ]
    for r in reqs:
        sched.enqueue(group, r)
    admitted = sched.admit(group)
    # FIFO: the first two submitted run first; the rest wait queued
    assert [r.rid for r in admitted] == [0, 1]
    assert [r.status for r in reqs] == [RUNNING, RUNNING, QUEUED, QUEUED]
    assert group.free == [] and len(group.queue) == 2
    # reclaim frees the exact slot and the next FIFO request takes it
    slot = reqs[0].slot
    sched.reclaim(group, slot)
    assert sched.admit(group)[0].rid == 2
    assert reqs[2].slot == slot


def test_group_for_reuses_bucket_per_fingerprint():
    sched = Scheduler(slots_per_group=2)
    a = api.compile(_heat(name="heat_fp_a"), Target())
    g1 = sched.group_for(a)
    g2 = sched.group_for(api.compile(_heat(name="heat_fp_a"), Target()))
    assert g1 is g2  # same (program fp, target fp) → same slot pool
    g3 = sched.group_for(api.compile(_heat(name="heat_fp_a"), Target(exchange_every=2)))
    assert g3 is not g1  # different target fingerprint → new bucket


# -------------------------------------------------------------------------
# engine: coalescing, bitwise correctness, continuous admission
# -------------------------------------------------------------------------


def test_same_fingerprint_requests_coalesce_into_batched_dispatch():
    prog = _heat(name="heat_coalesce")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=4))
    for i in range(3):
        eng.submit(prog, (_rand((16, 16), i),), n_steps=4)
    m = eng.step()
    # three live same-fingerprint requests advanced by ONE dispatch
    assert m.live_slots == 3
    assert m.batched_dispatches == 1 and m.solo_dispatches == 0
    assert m.steps_advanced == 3
    eng.run()
    assert eng.metrics.solo_dispatches == 0  # never fell back to solo


def test_final_state_bitwise_equals_solo_time_loop():
    heat = _heat(name="heat_bitwise")
    wave = _wave(name="wave_bitwise")
    t1 = Target()
    t2 = Target(exchange_every=2)
    eng = StencilEngine(StencilEngineConfig(slots_per_group=3))
    jobs = []
    for i in range(3):
        s = (_rand((16, 16), 10 + i),)
        jobs.append((eng.submit(heat, s, n_steps=4 + 2 * i), heat, t1, s))
    for i in range(2):
        s = (_rand((16, 16), 20 + i), _rand((16, 16), 30 + i))
        jobs.append((eng.submit(wave, s, n_steps=4, target=t2), wave, t2, s))
    eng.run()
    for handle, prog, target, state in jobs:
        want = api.compile(prog, target).time_loop(state, handle._req.n_steps)
        got = handle.result()
        assert len(got) == len(want)
        for w, o in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(o))


def test_mixed_fingerprints_dispatch_independently():
    heat = _heat(name="heat_mixed")
    wave = _wave(name="wave_mixed")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=4))
    for i in range(2):
        eng.submit(heat, (_rand((16, 16), i),), n_steps=2)
    eng.submit(
        wave,
        (_rand((16, 16), 5), _rand((16, 16), 6)),
        n_steps=2,
        target=Target(exchange_every=2),
    )
    m = eng.step()
    # heat bucket (2 live) batched; wave bucket (1 live) went solo
    assert m.batched_dispatches == 1 and m.solo_dispatches == 1
    # wave advanced a whole epoch (2 steps), heat 1 step each
    assert m.steps_advanced == 2 * 1 + 2


def test_continuous_admission_refills_freed_slots_same_step():
    prog = _heat(name="heat_refill")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=2))
    handles = [
        eng.submit(prog, (_rand((16, 16), i),), n_steps=1) for i in range(4)
    ]
    m = eng.step()
    # both pool requests finished and both queued ones were admitted
    # before the step returned — the pool never idles
    assert handles[0].done and handles[1].done
    assert handles[2].status == RUNNING and handles[3].status == RUNNING
    assert m.queued == 0
    eng.run()
    assert all(h.done for h in handles)
    assert eng.metrics.requests_completed == 4


def test_submit_validates_epoch_alignment_and_shapes():
    prog = _heat(name="heat_validate")
    eng = StencilEngine()
    with pytest.raises(ValueError, match="multiple"):
        eng.submit(
            prog, (_rand((16, 16), 0),), n_steps=3, target=Target(exchange_every=2)
        )
    with pytest.raises(ValueError, match="n_steps"):
        eng.submit(prog, (_rand((16, 16), 0),), n_steps=0)
    with pytest.raises(ValueError, match="shape"):
        eng.submit(prog, (_rand((8, 8), 0),), n_steps=2)
    with pytest.raises(ValueError, match="input buffer"):
        eng.submit(prog, (_rand((16, 16), 0), _rand((16, 16), 1)), n_steps=2)


def test_result_raises_until_done():
    prog = _heat(name="heat_notdone")
    eng = StencilEngine()
    h = eng.submit(prog, (_rand((16, 16), 0),), n_steps=4)
    with pytest.raises(RuntimeError, match="queued"):
        h.result()
    eng.step()
    with pytest.raises(RuntimeError, match="running"):
        h.result()
    eng.run()
    assert h.status == DONE
    assert h.result() is not None


# -------------------------------------------------------------------------
# streaming frames
# -------------------------------------------------------------------------


def test_frame_cadence_callback_and_iterator():
    prog = _heat(name="heat_frames")
    eng = StencilEngine()
    seen = []
    h_cb = eng.submit(
        prog,
        (_rand((16, 16), 0),),
        n_steps=6,
        frame_every=2,
        on_frame=seen.append,
    )
    h_pull = eng.submit(
        prog, (_rand((16, 16), 1),), n_steps=6, frame_every=3
    )
    eng.run()
    assert [f.step for f in seen] == [2, 4, 6]
    assert all(f.rid == h_cb.rid for f in seen)
    pulled = list(h_pull.frames())
    assert [f.step for f in pulled] == [3, 6]
    assert list(h_pull.frames()) == []  # iterator drains
    # the cadence-final frame equals the result, and callback frames
    # never double-buffer on the handle
    np.testing.assert_array_equal(
        pulled[-1].arrays[0], np.asarray(h_pull.result()[0])
    )
    assert list(h_cb.frames()) == []


def test_epoch_target_frames_land_on_epoch_boundaries():
    wave = _wave(name="wave_frames")
    eng = StencilEngine()
    h = eng.submit(
        wave,
        (_rand((16, 16), 0), _rand((16, 16), 1)),
        n_steps=8,
        target=Target(exchange_every=2),
        frame_every=3,  # marks at 3 and 6 → snapshots at epochs 4 and 6
    )
    eng.run()
    assert [f.step for f in h.frames()] == [4, 6]


# -------------------------------------------------------------------------
# metrics: utilization math
# -------------------------------------------------------------------------


def test_step_metrics_utilization_math():
    m = StepMetrics(
        engine_step=1,
        live_slots=3,
        pool_slots=4,
        queued=2,
        batched_dispatches=1,
        solo_dispatches=0,
        steps_advanced=3,
        queue_depth={},
    )
    assert m.utilization == pytest.approx(0.75)
    empty = StepMetrics(0, 0, 0, 0, 0, 0, 0, {})
    assert empty.utilization == 0.0


def test_engine_metrics_aggregate_and_cache_deltas():
    prog = _heat(name="heat_metrics")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=2))
    for i in range(2):
        eng.submit(prog, (_rand((16, 16), i),), n_steps=2)
    eng.run()
    snap = eng.metrics.snapshot()
    assert snap["requests_submitted"] == 2
    assert snap["requests_completed"] == 2
    assert snap["batched_dispatches"] == eng.metrics.batched_dispatches >= 1
    assert snap["steps_advanced"] == 4
    # full pool both steps → mean utilization 1.0
    assert snap["mean_utilization"] == pytest.approx(1.0)
    # cache counters are deltas since engine construction, never negative
    assert all(v >= 0 for v in snap["compile_cache"].values())
    # a second identical engine re-uses every compile artifact
    eng2 = StencilEngine(StencilEngineConfig(slots_per_group=2))
    eng2.submit(prog, (_rand((16, 16), 9),), n_steps=2)
    eng2.run()
    cache2 = eng2.metrics.compile_cache()
    assert cache2["misses"] == 0 and cache2["hits"] >= 1


def test_step_latency_reports_per_fingerprint_quantiles():
    """Every dispatch is timed under its bucket's "program_fp/target_fp"
    key: a fused-epoch target and its unfused sibling land in separate
    buckets, each with p50/p99/mean over the recorded window — the
    fused-vs-unfused win is visible straight from the snapshot."""
    prog = _heat(name="heat_latency")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=2))
    t_unfused = Target(backend="pallas", exchange_every=2, pallas_interpret=True)
    t_fused = Target(
        backend="pallas", exchange_every=2, fused_epoch=True,
        pallas_interpret=True,
    )
    eng.submit(prog, (_rand((16, 16), 0),), n_steps=4, target=t_unfused)
    eng.submit(prog, (_rand((16, 16), 1),), n_steps=4, target=t_fused)
    eng.run()
    lat = eng.metrics.snapshot()["step_latency"]
    assert len(lat) == 2
    for t in (t_unfused, t_fused):
        key = f"{prog.fingerprint}/{t.fingerprint}"
        stats = lat[key]
        assert stats["count"] == 2  # 4 steps at k=2 → 2 epoch dispatches
        assert 0.0 < stats["p50_s"] <= stats["p99_s"]
        assert stats["mean_s"] > 0.0


def test_queue_depth_reports_per_fingerprint():
    prog = _heat(name="heat_depth")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=1))
    for i in range(3):
        eng.submit(prog, (_rand((16, 16), i),), n_steps=2)
    m = eng.step()
    compiled = api.compile(prog, Target())
    key = f"{compiled.program.fingerprint}/{compiled.target.fingerprint}"
    assert m.queue_depth[key] == 2  # 1 running (pool=1), 2 still waiting
    eng.run()
    assert eng.scheduler.queue_depths()[key] == 0


# -------------------------------------------------------------------------
# LRU compile cache bound (satellite: api.py)
# -------------------------------------------------------------------------


def test_cache_capacity_bounds_entries_and_counts_evictions():
    prev = api.set_cache_capacity(2)
    try:
        api.clear_cache()
        progs = [_heat(alpha=0.1 * (i + 1), name=f"heat_lru{i}") for i in range(3)]
        for p in progs:
            api.compile(p, Target())
        stats = api.cache_stats()
        assert stats.misses == 3
        assert stats.evictions == 1  # capacity 2, third insert evicts oldest
        assert len(api._CACHE) == 2
        # the evicted (oldest) program recompiles: miss, and evicts again
        api.compile(progs[0], Target())
        stats = api.cache_stats()
        assert stats.misses == 4 and stats.evictions == 2
        # the most-recent entry is still cached: a true hit
        api.compile(progs[0], Target())
        assert api.cache_stats().hits >= 1
    finally:
        api.set_cache_capacity(prev)
        api.clear_cache()


def test_cache_hit_refreshes_lru_order():
    prev = api.set_cache_capacity(2)
    try:
        api.clear_cache()
        a = _heat(alpha=0.11, name="heat_lru_a")
        b = _heat(alpha=0.12, name="heat_lru_b")
        c = _heat(alpha=0.13, name="heat_lru_c")
        api.compile(a, Target())
        api.compile(b, Target())
        api.compile(a, Target())  # refresh a → b is now oldest
        api.compile(c, Target())  # evicts b, not a
        misses = api.cache_stats().misses
        api.compile(a, Target())  # still cached
        assert api.cache_stats().misses == misses
        api.compile(b, Target())  # was evicted → recompiles
        assert api.cache_stats().misses == misses + 1
    finally:
        api.set_cache_capacity(prev)
        api.clear_cache()


def test_set_cache_capacity_validates():
    with pytest.raises(ValueError, match=">= 1"):
        api.set_cache_capacity(0)
