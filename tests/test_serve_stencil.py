"""The stencil-serving engine: fingerprint-batched slot pools.

Covers the ISSUE 6 acceptance surface: admission/reclaim ordering over a
full pool, same-fingerprint coalescing (asserted through the engine's
batched-vs-solo dispatch counters), bitwise equality of every request's
final state against a solo ``compile(...).time_loop(...)`` run (heat and
the newly-rotating wave under ``exchange_every=2``), streaming-frame
cadence, utilization math — plus the LRU bound and truthful eviction
counters of the process-wide compile cache.
"""
import numpy as np
import pytest

from repro import api
from repro.api import Target
from repro.frontends.oec_like import ProgramBuilder
from repro.serve.stencil import (
    DONE,
    QUEUED,
    RUNNING,
    Scheduler,
    StencilEngine,
    StencilEngineConfig,
    StepMetrics,
)


def _heat(shape=(16, 16), alpha=0.25, boundary="periodic", name="heat_serve"):
    p = ProgramBuilder(name, shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1))
        * alpha,
    )
    p.store(r, out)
    return p.finish(boundary=boundary)


def _wave(shape=(16, 16), boundary="zero", name="wave_serve"):
    # p=2 inputs (u@t-1, u@t), q=1 output — exercises carried-state
    # rotation inside the slot pool
    p = ProgramBuilder(name, shape)
    um = p.input("u_prev")
    u0 = p.input("u_now")
    out = p.output("u_next")
    tm, t0 = p.load(um), p.load(u0)
    r = p.apply(
        [tm, t0],
        lambda b, um, u0: 2.0 * u0.at(0, 0)
        - um.at(0, 0)
        + 0.1
        * (
            u0.at(-1, 0)
            + u0.at(1, 0)
            + u0.at(0, -1)
            + u0.at(0, 1)
            - 4.0 * u0.at(0, 0)
        ),
    )
    p.store(r, out)
    return p.finish(boundary=boundary)


def _rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(
        np.float32
    )


# -------------------------------------------------------------------------
# scheduler: admission / reclaim ordering
# -------------------------------------------------------------------------


def test_admission_is_fifo_and_bounded_by_pool():
    prog = _heat(name="heat_admit")
    compiled = api.compile(prog, Target())
    sched = Scheduler(slots_per_group=2)
    group = sched.group_for(compiled)
    from repro.serve.stencil.request import StencilRequest

    reqs = [
        StencilRequest(
            rid=i,
            program=prog,
            target=compiled.target,
            state=(_rand((16, 16), i),),
            n_steps=2,
        )
        for i in range(4)
    ]
    for r in reqs:
        sched.enqueue(group, r)
    admitted = sched.admit(group)
    # FIFO: the first two submitted run first; the rest wait queued
    assert [r.rid for r in admitted] == [0, 1]
    assert [r.status for r in reqs] == [RUNNING, RUNNING, QUEUED, QUEUED]
    assert group.free == [] and len(group.queue) == 2
    # reclaim frees the exact slot and the next FIFO request takes it
    slot = reqs[0].slot
    sched.reclaim(group, slot)
    assert sched.admit(group)[0].rid == 2
    assert reqs[2].slot == slot


def test_group_for_reuses_bucket_per_fingerprint():
    sched = Scheduler(slots_per_group=2)
    a = api.compile(_heat(name="heat_fp_a"), Target())
    g1 = sched.group_for(a)
    g2 = sched.group_for(api.compile(_heat(name="heat_fp_a"), Target()))
    assert g1 is g2  # same (program fp, target fp) → same slot pool
    g3 = sched.group_for(api.compile(_heat(name="heat_fp_a"), Target(exchange_every=2)))
    assert g3 is not g1  # different target fingerprint → new bucket


# -------------------------------------------------------------------------
# engine: coalescing, bitwise correctness, continuous admission
# -------------------------------------------------------------------------


def test_same_fingerprint_requests_coalesce_into_batched_dispatch():
    prog = _heat(name="heat_coalesce")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=4))
    for i in range(3):
        eng.submit(prog, (_rand((16, 16), i),), n_steps=4)
    m = eng.step()
    # three live same-fingerprint requests advanced by ONE dispatch
    assert m.live_slots == 3
    assert m.batched_dispatches == 1 and m.solo_dispatches == 0
    assert m.steps_advanced == 3
    eng.run()
    assert eng.metrics.solo_dispatches == 0  # never fell back to solo


def test_final_state_bitwise_equals_solo_time_loop():
    heat = _heat(name="heat_bitwise")
    wave = _wave(name="wave_bitwise")
    t1 = Target()
    t2 = Target(exchange_every=2)
    eng = StencilEngine(StencilEngineConfig(slots_per_group=3))
    jobs = []
    for i in range(3):
        s = (_rand((16, 16), 10 + i),)
        jobs.append((eng.submit(heat, s, n_steps=4 + 2 * i), heat, t1, s))
    for i in range(2):
        s = (_rand((16, 16), 20 + i), _rand((16, 16), 30 + i))
        jobs.append((eng.submit(wave, s, n_steps=4, target=t2), wave, t2, s))
    eng.run()
    for handle, prog, target, state in jobs:
        want = api.compile(prog, target).time_loop(state, handle._req.n_steps)
        got = handle.result()
        assert len(got) == len(want)
        for w, o in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(o))


def test_mixed_fingerprints_dispatch_independently():
    heat = _heat(name="heat_mixed")
    wave = _wave(name="wave_mixed")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=4))
    for i in range(2):
        eng.submit(heat, (_rand((16, 16), i),), n_steps=2)
    eng.submit(
        wave,
        (_rand((16, 16), 5), _rand((16, 16), 6)),
        n_steps=2,
        target=Target(exchange_every=2),
    )
    m = eng.step()
    # heat bucket (2 live) batched; wave bucket (1 live) went solo
    assert m.batched_dispatches == 1 and m.solo_dispatches == 1
    # wave advanced a whole epoch (2 steps), heat 1 step each
    assert m.steps_advanced == 2 * 1 + 2


def test_continuous_admission_refills_freed_slots_same_step():
    prog = _heat(name="heat_refill")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=2))
    handles = [
        eng.submit(prog, (_rand((16, 16), i),), n_steps=1) for i in range(4)
    ]
    m = eng.step()
    # both pool requests finished and both queued ones were admitted
    # before the step returned — the pool never idles
    assert handles[0].done and handles[1].done
    assert handles[2].status == RUNNING and handles[3].status == RUNNING
    assert m.queued == 0
    eng.run()
    assert all(h.done for h in handles)
    assert eng.metrics.requests_completed == 4


def test_submit_validates_epoch_alignment_and_shapes():
    prog = _heat(name="heat_validate")
    eng = StencilEngine()
    with pytest.raises(ValueError, match="multiple"):
        eng.submit(
            prog, (_rand((16, 16), 0),), n_steps=3, target=Target(exchange_every=2)
        )
    with pytest.raises(ValueError, match="n_steps"):
        eng.submit(prog, (_rand((16, 16), 0),), n_steps=0)
    with pytest.raises(ValueError, match="shape"):
        eng.submit(prog, (_rand((8, 8), 0),), n_steps=2)
    with pytest.raises(ValueError, match="input buffer"):
        eng.submit(prog, (_rand((16, 16), 0), _rand((16, 16), 1)), n_steps=2)


def test_result_raises_until_done():
    prog = _heat(name="heat_notdone")
    eng = StencilEngine()
    h = eng.submit(prog, (_rand((16, 16), 0),), n_steps=4)
    with pytest.raises(RuntimeError, match="queued"):
        h.result()
    eng.step()
    with pytest.raises(RuntimeError, match="running"):
        h.result()
    eng.run()
    assert h.status == DONE
    assert h.result() is not None


# -------------------------------------------------------------------------
# streaming frames
# -------------------------------------------------------------------------


def test_frame_cadence_callback_and_iterator():
    prog = _heat(name="heat_frames")
    eng = StencilEngine()
    seen = []
    h_cb = eng.submit(
        prog,
        (_rand((16, 16), 0),),
        n_steps=6,
        frame_every=2,
        on_frame=seen.append,
    )
    h_pull = eng.submit(
        prog, (_rand((16, 16), 1),), n_steps=6, frame_every=3
    )
    eng.run()
    assert [f.step for f in seen] == [2, 4, 6]
    assert all(f.rid == h_cb.rid for f in seen)
    pulled = list(h_pull.frames())
    assert [f.step for f in pulled] == [3, 6]
    assert list(h_pull.frames()) == []  # iterator drains
    # the cadence-final frame equals the result, and callback frames
    # never double-buffer on the handle
    np.testing.assert_array_equal(
        pulled[-1].arrays[0], np.asarray(h_pull.result()[0])
    )
    assert list(h_cb.frames()) == []


def test_epoch_target_frames_land_on_epoch_boundaries():
    wave = _wave(name="wave_frames")
    eng = StencilEngine()
    h = eng.submit(
        wave,
        (_rand((16, 16), 0), _rand((16, 16), 1)),
        n_steps=8,
        target=Target(exchange_every=2),
        frame_every=3,  # marks at 3 and 6 → snapshots at epochs 4 and 6
    )
    eng.run()
    assert [f.step for f in h.frames()] == [4, 6]


# -------------------------------------------------------------------------
# metrics: utilization math
# -------------------------------------------------------------------------


def test_step_metrics_utilization_math():
    m = StepMetrics(
        engine_step=1,
        live_slots=3,
        pool_slots=4,
        queued=2,
        batched_dispatches=1,
        solo_dispatches=0,
        steps_advanced=3,
        queue_depth={},
    )
    assert m.utilization == pytest.approx(0.75)
    empty = StepMetrics(0, 0, 0, 0, 0, 0, 0, {})
    assert empty.utilization == 0.0


def test_engine_metrics_aggregate_and_cache_deltas():
    prog = _heat(name="heat_metrics")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=2))
    for i in range(2):
        eng.submit(prog, (_rand((16, 16), i),), n_steps=2)
    eng.run()
    snap = eng.metrics.snapshot()
    assert snap["requests_submitted"] == 2
    assert snap["requests_completed"] == 2
    assert snap["batched_dispatches"] == eng.metrics.batched_dispatches >= 1
    assert snap["steps_advanced"] == 4
    # full pool both steps → mean utilization 1.0
    assert snap["mean_utilization"] == pytest.approx(1.0)
    # cache counters are deltas since engine construction, never negative
    assert all(v >= 0 for v in snap["compile_cache"].values())
    # a second identical engine re-uses every compile artifact
    eng2 = StencilEngine(StencilEngineConfig(slots_per_group=2))
    eng2.submit(prog, (_rand((16, 16), 9),), n_steps=2)
    eng2.run()
    cache2 = eng2.metrics.compile_cache()
    assert cache2["misses"] == 0 and cache2["hits"] >= 1


def test_step_latency_reports_per_fingerprint_quantiles():
    """Every dispatch is timed under its bucket's "program_fp/target_fp"
    key: a fused-epoch target and its unfused sibling land in separate
    buckets, each with p50/p99/mean over the recorded window — the
    fused-vs-unfused win is visible straight from the snapshot."""
    prog = _heat(name="heat_latency")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=2))
    t_unfused = Target(backend="pallas", exchange_every=2, pallas_interpret=True)
    t_fused = Target(
        backend="pallas", exchange_every=2, fused_epoch=True,
        pallas_interpret=True,
    )
    eng.submit(prog, (_rand((16, 16), 0),), n_steps=4, target=t_unfused)
    eng.submit(prog, (_rand((16, 16), 1),), n_steps=4, target=t_fused)
    eng.run()
    lat = eng.metrics.snapshot()["step_latency"]
    assert len(lat) == 2
    for t in (t_unfused, t_fused):
        key = f"{prog.fingerprint}/{t.fingerprint}"
        stats = lat[key]
        assert stats["count"] == 2  # 4 steps at k=2 → 2 epoch dispatches
        assert 0.0 < stats["p50_s"] <= stats["p99_s"]
        assert stats["mean_s"] > 0.0


def test_step_latency_degenerate_windows():
    """0- and 1-sample latency windows are well-defined: an empty window
    reports count=0 with all-zero quantiles (it must not vanish from the
    snapshot or raise), and a single sample is its own p50/p99/max."""
    from repro.serve.stencil.metrics import EngineMetrics

    m = EngineMetrics()
    m.step_seconds["empty/window"] = []
    m.record_dispatch("one/sample", 0.25)
    lat = m.step_latency()
    assert lat["empty/window"] == {
        "count": 0, "mean_s": 0.0, "p50_s": 0.0, "p99_s": 0.0, "max_s": 0.0,
    }
    one = lat["one/sample"]
    assert one["count"] == 1
    assert one["p50_s"] == one["p99_s"] == one["max_s"] == one["mean_s"] == 0.25
    # two samples: max is the larger, p50 interpolates between them
    m.record_dispatch("one/sample", 0.75)
    two = m.step_latency()["one/sample"]
    assert two["max_s"] == 0.75
    assert two["p50_s"] == pytest.approx(0.5)
    assert two["p99_s"] <= two["max_s"]


def test_queue_depth_reports_per_fingerprint():
    prog = _heat(name="heat_depth")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=1))
    for i in range(3):
        eng.submit(prog, (_rand((16, 16), i),), n_steps=2)
    m = eng.step()
    compiled = api.compile(prog, Target())
    key = f"{compiled.program.fingerprint}/{compiled.target.fingerprint}"
    assert m.queue_depth[key] == 2  # 1 running (pool=1), 2 still waiting
    eng.run()
    assert eng.scheduler.queue_depths()[key] == 0


# -------------------------------------------------------------------------
# LRU compile cache bound (satellite: api.py)
# -------------------------------------------------------------------------


def test_cache_capacity_bounds_entries_and_counts_evictions():
    prev = api.set_cache_capacity(2)
    try:
        api.clear_cache()
        progs = [_heat(alpha=0.1 * (i + 1), name=f"heat_lru{i}") for i in range(3)]
        for p in progs:
            api.compile(p, Target())
        stats = api.cache_stats()
        assert stats.misses == 3
        assert stats.evictions == 1  # capacity 2, third insert evicts oldest
        assert len(api._CACHE) == 2
        # the evicted (oldest) program recompiles: miss, and evicts again
        api.compile(progs[0], Target())
        stats = api.cache_stats()
        assert stats.misses == 4 and stats.evictions == 2
        # the most-recent entry is still cached: a true hit
        api.compile(progs[0], Target())
        assert api.cache_stats().hits >= 1
    finally:
        api.set_cache_capacity(prev)
        api.clear_cache()


def test_cache_hit_refreshes_lru_order():
    prev = api.set_cache_capacity(2)
    try:
        api.clear_cache()
        a = _heat(alpha=0.11, name="heat_lru_a")
        b = _heat(alpha=0.12, name="heat_lru_b")
        c = _heat(alpha=0.13, name="heat_lru_c")
        api.compile(a, Target())
        api.compile(b, Target())
        api.compile(a, Target())  # refresh a → b is now oldest
        api.compile(c, Target())  # evicts b, not a
        misses = api.cache_stats().misses
        api.compile(a, Target())  # still cached
        assert api.cache_stats().misses == misses
        api.compile(b, Target())  # was evicted → recompiles
        assert api.cache_stats().misses == misses + 1
    finally:
        api.set_cache_capacity(prev)
        api.clear_cache()


def test_set_cache_capacity_validates():
    with pytest.raises(ValueError, match=">= 1"):
        api.set_cache_capacity(0)


# -------------------------------------------------------------------------
# ISSUE 9 — run() result, idle retirement, batched row commit
# -------------------------------------------------------------------------


def test_run_returns_only_this_calls_finishes():
    """Regression: ``run()`` used to return the cumulative
    ``self.finished``, re-reporting earlier calls' requests."""
    prog = _heat(name="heat_run_twice")
    eng = StencilEngine(StencilEngineConfig(slots_per_group=2))
    h1 = eng.submit(prog, (_rand((16, 16), 0),), n_steps=2)
    first = eng.run()
    assert [r.rid for r in first] == [h1.rid]
    h2 = eng.submit(prog, (_rand((16, 16), 1),), n_steps=2)
    second = eng.run()
    assert [r.rid for r in second] == [h2.rid]  # NOT [h1, h2]
    # the engine-lifetime history still accumulates
    assert [r.rid for r in eng.finished] == [h1.rid, h2.rid]
    # an empty run reports nothing
    assert eng.run() == []


def test_idle_buckets_retire_and_free_pooled_state():
    """Bucket-leak fix: after serving N distinct fingerprints and
    draining them, idle retirement leaves 0 live pooled arrays and
    ``buckets_retired == N``; ``total_slots``/``utilization`` stop
    counting the retired pools."""
    progs = [_heat(name=f"heat_retire{i}") for i in range(3)]
    eng = StencilEngine(
        StencilEngineConfig(slots_per_group=2, bucket_idle_steps=2)
    )
    for i, p in enumerate(progs):
        eng.submit(p, (_rand((16, 16), i),), n_steps=2)
    eng.run()
    assert len(eng.scheduler.groups) == 3  # drained but not yet retired
    eng.step()  # idle step 1
    assert eng.metrics.buckets_retired == 0
    eng.step()  # idle step 2 → all three retire
    assert eng.metrics.buckets_retired == 3
    assert eng.scheduler.groups == {}
    assert eng.scheduler.total_slots == 0
    assert eng.utilization == 0.0
    assert eng.metrics.snapshot()["buckets_retired"] == 3
    # a retired fingerprint that returns gets a fresh bucket and works
    h = eng.submit(progs[0], (_rand((16, 16), 9),), n_steps=2)
    eng.run()
    assert h.done


def test_bucket_activity_resets_idle_counter():
    prog = _heat(name="heat_idle_reset")
    eng = StencilEngine(
        StencilEngineConfig(slots_per_group=2, bucket_idle_steps=3)
    )
    eng.submit(prog, (_rand((16, 16), 0),), n_steps=2)
    eng.run()
    eng.step()
    eng.step()  # 2 idle steps of 3 — still alive
    assert len(eng.scheduler.groups) == 1
    eng.submit(prog, (_rand((16, 16), 1),), n_steps=2)  # traffic returns
    eng.run()
    assert len(eng.scheduler.groups) == 1  # counter reset, not retired
    assert eng.metrics.buckets_retired == 0


def test_commit_rows_matches_per_slot_write_loop():
    """The batched row commit (one ``.at[idx].set`` per buffer) lands
    the same pool state as the old per-slot ``rotate_slot`` loop."""
    prog = _wave(name="wave_commit_rows")
    compiled = api.compile(prog, Target())
    sched_a, sched_b = Scheduler(4), Scheduler(4)
    ga = sched_a.group_for(compiled)
    gb = sched_b.group_for(compiled)
    for slot in range(4):
        row = (_rand((16, 16), slot), _rand((16, 16), 40 + slot))
        ga.write_slot(slot, row)
        gb.write_slot(slot, row)
    outs = {slot: (_rand((16, 16), 80 + slot),) for slot in (0, 2, 3)}
    rows = {}
    for slot, o in outs.items():
        row = ga.read_slot(slot)
        rows[slot] = tuple(row[len(o):]) + o
        gb.rotate_slot(slot, o)  # the old O(capacity) path
    ga.commit_rows(rows)
    for pa, pb in zip(ga.state, gb.state):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


# -------------------------------------------------------------------------
# ISSUE 9 — frame cadence across migration
# -------------------------------------------------------------------------


def test_migrated_request_frame_cadence_with_non_dividing_start_step():
    """A request admitted mid-run at ``start_step=2`` with
    ``frame_every=4`` (not dividing the start step) streams at the next
    cadence marks — 4, 8 — and the landing final frame at 12."""
    prog = _heat(name="heat_cadence_midrun")
    eng = StencilEngine()
    h = eng.submit(
        prog, (_rand((16, 16), 0),), n_steps=12, frame_every=4,
        start_step=2,
    )
    eng.run()
    assert [f.step for f in h.frames()] == [4, 8, 12]


def test_final_frame_emitted_exactly_once_when_cadence_lands_on_n_steps():
    prog = _heat(name="heat_final_frame")
    eng = StencilEngine()
    seen = []
    eng.submit(
        prog, (_rand((16, 16), 0),), n_steps=4, frame_every=2,
        on_frame=seen.append,
    )
    eng.run()
    assert [f.step for f in seen] == [2, 4]
    assert sum(1 for f in seen if f.step == 4) == 1
    assert eng.metrics.frames_emitted == 2


def test_frame_steps_strictly_increase_across_evacuate_admit_hop(tmp_path):
    """Stream cadence survives migration: frames before the hop and
    frames after readmission (``start_step`` at the evacuated step)
    form one strictly increasing ``step`` sequence with no repeats."""
    prog = _heat(name="heat_hop_frames")
    first = StencilEngine(StencilEngineConfig(slots_per_group=1))
    h1 = first.submit(
        prog, (_rand((16, 16), 0),), n_steps=12, frame_every=3
    )
    for _ in range(4):  # advance to step 4; frame mark 3 crossed
        first.step()
    before = [f.step for f in h1.frames()]
    assert before == [3]
    d = str(tmp_path / "hop")
    first.evacuate(prog.fingerprint, d)

    second = StencilEngine(StencilEngineConfig(slots_per_group=1))
    (h2,) = second.admit_evacuated(d, prog)
    assert h2.steps_done == 4
    second.run()
    after = [f.step for f in h2.frames()]
    assert after == [6, 9, 12]  # resumes the schedule, no replay of 3
    combined = before + after
    assert combined == sorted(set(combined))  # strictly increasing


# -------------------------------------------------------------------------
# ISSUE 9 — PoolSizer policy
# -------------------------------------------------------------------------


def _sizer_group(name, capacity, live=0, queued=0):
    from repro.serve.stencil.request import StencilRequest

    compiled = api.compile(_heat(name=name), Target())
    sched = Scheduler(capacity)
    group = sched.group_for(compiled)
    for i in range(live):
        group.active[i] = StencilRequest(
            rid=i, program=compiled.program, target=compiled.target,
            state=(), n_steps=4,
        )
    for i in range(queued):
        group.queue.append(
            StencilRequest(
                rid=100 + i, program=compiled.program,
                target=compiled.target, state=(), n_steps=4,
            )
        )
    return group


def test_pool_sizer_grows_on_queue_depth_with_provenance():
    from repro.serve.stencil import PoolSizer, PoolSizerConfig

    sizer = PoolSizer(PoolSizerConfig(max_capacity=16, ewma_alpha=1.0))
    group = _sizer_group("heat_sizer_grow", capacity=2, live=2, queued=4)
    new, prov = sizer.observe(group)
    assert new == 4 and prov["action"] == "grow"
    assert prov["queue_depth"] == 4 and prov["live"] == 2
    assert prov["queue_ewma"] == pytest.approx(2.0)
    assert prov["from_capacity"] == 2 and prov["to_capacity"] == 4


def test_pool_sizer_shrinks_on_low_utilization_never_below_live():
    from repro.serve.stencil import PoolSizer, PoolSizerConfig

    sizer = PoolSizer(
        PoolSizerConfig(min_capacity=1, ewma_alpha=1.0, cooldown_steps=0)
    )
    group = _sizer_group("heat_sizer_shrink", capacity=8, live=1, queued=0)
    new, prov = sizer.observe(group)
    assert prov["action"] == "shrink"
    assert new == 4  # 8 * 0.5, still >= live
    assert prov["utilization_ewma"] == pytest.approx(0.125)
    group2 = _sizer_group("heat_sizer_floor", capacity=8, live=3, queued=0)
    sizer2 = PoolSizer(
        PoolSizerConfig(
            min_capacity=1, ewma_alpha=1.0, cooldown_steps=0,
            shrink_factor=0.25, shrink_utilization=0.5,
        )
    )
    new2, _ = sizer2.observe(group2)
    assert new2 == 3  # 8 * 0.25 = 2 would strand a live request


def test_pool_sizer_cooldown_hysteresis_blocks_back_to_back_resizes():
    from repro.serve.stencil import PoolSizer, PoolSizerConfig

    sizer = PoolSizer(
        PoolSizerConfig(max_capacity=64, ewma_alpha=1.0, cooldown_steps=2)
    )
    group = _sizer_group("heat_sizer_cool", capacity=2, live=2, queued=8)
    assert sizer.observe(group) is not None  # resize fires
    # pressure persists, but the cooldown holds the width for 2 steps
    assert sizer.observe(group) is None
    assert sizer.observe(group) is None
    assert sizer.observe(group) is not None  # cooldown expired


def test_pool_sizer_holds_idle_and_steady_buckets():
    from repro.serve.stencil import PoolSizer, PoolSizerConfig

    sizer = PoolSizer(PoolSizerConfig(ewma_alpha=1.0, cooldown_steps=0))
    # idle bucket: retirement's job, not the sizer's
    idle = _sizer_group("heat_sizer_idle", capacity=4, live=0, queued=0)
    assert sizer.observe(idle) is None
    # healthy utilization, empty queue: hold
    steady = _sizer_group("heat_sizer_steady", capacity=4, live=3, queued=0)
    assert sizer.observe(steady) is None


def test_autoscaled_engine_results_stay_bitwise_across_resizes():
    """Single-device autoscaling end-to-end: a burst grows the bucket,
    the tail shrinks it, and every result matches solo time_loop
    bitwise (the distributed variant runs in dist_worker)."""
    from repro.serve.stencil import PoolSizerConfig

    prog = _heat(name="heat_autoscale_e2e")
    eng = StencilEngine(
        StencilEngineConfig(
            slots_per_group=2,
            autoscale=PoolSizerConfig(
                min_capacity=1, max_capacity=8, ewma_alpha=1.0,
                cooldown_steps=1,
            ),
        )
    )
    states = [_rand((16, 16), 60 + i) for i in range(8)]
    steps = [4] * 7 + [40]
    handles = [
        eng.submit(prog, (s,), n) for s, n in zip(states, steps)
    ]
    eng.run()
    auto = eng.metrics.snapshot()["autoscale"]
    assert auto["grows"] >= 1 and auto["shrinks"] >= 1, auto
    solo = api.compile(prog, Target())
    for h, s, n in zip(handles, states, steps):
        want = solo.time_loop((s,), n)
        want = want if isinstance(want, tuple) else (want,)
        for w, o in zip(want, h.result()):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(o))
