"""repro.resilience: epoch-aligned checkpointing, elastic resume, fault
injection, serve-layer migration, and the cross-hardware tune transfer.

The ISSUE 8 acceptance surface on a single device (the multi-rank
4 → 2 elastic resume lives in tests/dist_worker.py): a FaultPlan-killed
run resumed from its last committed snapshot is bitwise-identical to
both the uninterrupted resilient run and ``time_loop`` — including the
p>q wave whose time-buffer rotation *phase* must survive the resume —
plus Checkpointer retention/GC truthfulness and torn-write fallback.
"""
import os

import numpy as np
import pytest

from repro import api
from repro.api import Target
from repro.checkpoint.checkpointer import Checkpointer
from repro.frontends.oec_like import ProgramBuilder
from repro.resilience import (
    FaultPlan,
    ResilientLoop,
    ResumeError,
    SimulatedFault,
    resume,
    truncate_snapshot,
)


def _heat(shape=(16, 16), alpha=0.25, name="heat_res"):
    p = ProgramBuilder(name, shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1))
        * alpha,
    )
    p.store(r, out)
    return p.finish(boundary="periodic")


def _wave(shape=(16, 16), name="wave_res"):
    # p=2 inputs > q=1 output: the rotation phase advances by 1 per
    # epoch-step and must be restored exactly on resume
    p = ProgramBuilder(name, shape)
    um = p.input("u_prev")
    u0 = p.input("u_now")
    out = p.output("u_next")
    tm, t0 = p.load(um), p.load(u0)
    r = p.apply(
        [tm, t0],
        lambda b, um, u0: 2.0 * u0.at(0, 0)
        - um.at(0, 0)
        + 0.1
        * (
            u0.at(-1, 0)
            + u0.at(1, 0)
            + u0.at(0, -1)
            + u0.at(0, 1)
            - 4.0 * u0.at(0, 0)
        ),
    )
    p.store(r, out)
    return p.finish(boundary="zero")


def _rand(shape, seed):
    return (
        np.random.default_rng(seed).standard_normal(shape).astype(np.float32)
    )


def _assert_bitwise(got, want, what):
    got = got if isinstance(got, tuple) else (got,)
    want = want if isinstance(want, tuple) else (want,)
    assert len(got) == len(want), (what, len(got), len(want))
    for i, (g, w) in enumerate(zip(got, want)):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (
            f"{what}: buffer {i} differs "
            f"(max |d| = {np.abs(np.asarray(g) - np.asarray(w)).max()})"
        )


# -------------------------------------------------------------------------
# driver: uninterrupted / kill-and-resume bitwise equality
# -------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 4])
def test_kill_and_resume_is_bitwise_heat(k, tmp_path):
    prog = _heat(name=f"heat_res_k{k}")
    u0 = _rand((16, 16), 0)
    tgt = Target(exchange_every=k)
    steps = 24
    ref = api.compile(prog, tgt).time_loop((u0,), steps)

    d = str(tmp_path / "ckpt")
    loop = ResilientLoop(
        prog, tgt, (u0,), steps, directory=d, checkpoint_every=1,
        fault_plan=FaultPlan(kill_at_epoch=(steps // k) // 2),
    )
    with pytest.raises(SimulatedFault):
        loop.run()
    assert ("fault", (steps // k) // 2, steps // 2) in loop.events

    resumed = resume(prog, d, tgt)
    assert resumed.step_count == steps // 2
    assert resumed.resumed_from == steps // 2
    final = resumed.run()
    _assert_bitwise(final, ref, f"heat k={k} kill+resume vs time_loop")


def test_uninterrupted_resilient_run_matches_time_loop(tmp_path):
    prog = _heat(name="heat_res_full")
    u0 = _rand((16, 16), 1)
    tgt = Target(exchange_every=2)
    ref = api.compile(prog, tgt).time_loop((u0,), 16)
    final = ResilientLoop(
        prog, tgt, (u0,), 16, directory=str(tmp_path / "c"),
        checkpoint_every=2,
    ).run()
    _assert_bitwise(final, ref, "uninterrupted resilient run")


@pytest.mark.parametrize("k,kill_epoch", [(1, 5), (2, 3)])
def test_wave_rotation_phase_survives_resume(k, kill_epoch, tmp_path):
    """p=2 > q=1: resuming mid-run must continue the SAME buffer
    rotation — a kill at an odd step (k=1, epoch 5) leaves phase 1."""
    prog = _wave(name=f"wave_res_k{k}")
    s0 = tuple(_rand((16, 16), 10 + i) for i in range(2))
    tgt = Target(exchange_every=k)
    steps = 16
    ref = api.compile(prog, tgt).time_loop(s0, steps)

    d = str(tmp_path / "ckpt")
    loop = ResilientLoop(
        prog, tgt, s0, steps, directory=d, checkpoint_every=1,
        fault_plan=FaultPlan(kill_at_epoch=kill_epoch),
    )
    with pytest.raises(SimulatedFault):
        loop.run()

    resumed = resume(prog, d, tgt)
    assert resumed.step_count == kill_epoch * k
    # k=1 advances one buffer per epoch: odd kill epoch → odd phase
    want_phase = (kill_epoch * (1 if k == 1 else 2)) % 2
    assert resumed._phase == want_phase
    final = resumed.run()
    _assert_bitwise(final, ref, f"wave k={k} rotation-phase resume")


def test_resume_onto_different_exchange_every(tmp_path):
    """The snapshot is global state at an epoch-aligned step — a resumer
    may pick a different temporal-tiling depth and stay bitwise."""
    prog = _heat(name="heat_res_kchange")
    u0 = _rand((16, 16), 2)
    steps = 32
    ref = api.compile(prog, Target(exchange_every=4)).time_loop((u0,), steps)

    d = str(tmp_path / "ckpt")
    loop = ResilientLoop(
        prog, Target(exchange_every=4), (u0,), steps, directory=d,
        checkpoint_every=1, fault_plan=FaultPlan(kill_at_epoch=4),
    )
    with pytest.raises(SimulatedFault):
        loop.run()
    final = resume(prog, d, Target(exchange_every=2)).run()
    _assert_bitwise(final, ref, "resume k=4 -> k=2")


# -------------------------------------------------------------------------
# resume validation
# -------------------------------------------------------------------------


def test_resume_rejects_wrong_program(tmp_path):
    prog = _heat(name="heat_res_owner")
    other = _heat(alpha=0.2, name="heat_res_other")
    d = str(tmp_path / "ckpt")
    ResilientLoop(
        prog, Target(), (_rand((16, 16), 3),), 4, directory=d,
        checkpoint_every=1,
    ).run()
    with pytest.raises(ResumeError, match="fingerprint"):
        resume(other, d, Target())


def test_resume_rejects_epoch_misaligned_target(tmp_path):
    # killed at step 3 under k=1; k=3 divides step 3 but not the
    # remaining 5 of 8 steps — both alignment legs must hold
    prog = _heat(name="heat_res_align")
    d = str(tmp_path / "ckpt")
    loop = ResilientLoop(
        prog, Target(), (_rand((16, 16), 4),), 8, directory=d,
        checkpoint_every=1, fault_plan=FaultPlan(kill_at_epoch=3),
    )
    with pytest.raises(SimulatedFault):
        loop.run()
    with pytest.raises(ResumeError, match="whole epochs"):
        resume(prog, d, Target(exchange_every=3))
    with pytest.raises(ResumeError, match="epoch"):
        ResilientLoop(
            prog, Target(exchange_every=2), (_rand((16, 16), 4),), 8,
            start_step=3,
        )


def test_resume_without_metadata_is_rejected(tmp_path):
    d = str(tmp_path / "ckpt")
    Checkpointer(d).save(0, {"state": {"b0": np.zeros((4, 4))}},
                         blocking=True)
    with pytest.raises(ResumeError, match="metadata"):
        resume(_heat(name="heat_res_meta"), d, Target())


# -------------------------------------------------------------------------
# torn writes: truncation falls back, startup GC reclaims
# -------------------------------------------------------------------------


def test_truncated_checkpoint_is_ignored_and_gcd(tmp_path):
    prog = _heat(name="heat_res_torn")
    u0 = _rand((16, 16), 5)
    tgt = Target(exchange_every=2)
    steps = 16
    ref = api.compile(prog, tgt).time_loop((u0,), steps)

    d = str(tmp_path / "ckpt")
    # checkpoint every epoch; the snapshot at step 10 commits and is then
    # torn, and the process dies before epoch 5 — the freshest COMMITTED
    # snapshot is step 8
    loop = ResilientLoop(
        prog, tgt, (u0,), steps, directory=d, checkpoint_every=1,
        keep_last=8,
        fault_plan=FaultPlan(kill_at_epoch=5, truncate_step=10),
    )
    with pytest.raises(SimulatedFault):
        loop.run()
    assert not os.path.exists(os.path.join(d, "step_00000010", "COMMITTED"))

    # any fresh Checkpointer's startup GC reclaims the wreck (resume()
    # constructs one first thing, so the count is observable here)
    probe = Checkpointer(d, keep_last=8)
    assert probe.stats.gcs == 1
    assert not os.path.exists(os.path.join(d, "step_00000010"))

    resumed = resume(prog, d, tgt)
    # the torn step-10 snapshot is invisible: resume restarts from step 8
    assert resumed.step_count == 8
    final = resumed.run()
    _assert_bitwise(final, ref, "torn-checkpoint fallback resume")


def test_truncate_snapshot_helper(tmp_path):
    d = str(tmp_path / "ckpt")
    ckpt = Checkpointer(d)
    ckpt.save(4, {"u": np.arange(16.0).reshape(4, 4)}, blocking=True)
    assert ckpt.available_steps() == [4]
    truncate_snapshot(d, 4)
    assert ckpt.available_steps() == []


# -------------------------------------------------------------------------
# Checkpointer hardening: retention, GC, truthful counters, manifest
# -------------------------------------------------------------------------


def test_keep_last_retention_and_counters(tmp_path):
    d = str(tmp_path / "ckpt")
    ckpt = Checkpointer(d, keep_last=2)
    for s in range(5):
        ckpt.save(s, {"u": np.full((2, 2), float(s))}, blocking=True)
    assert ckpt.available_steps() == [3, 4]
    assert ckpt.stats.as_dict() == {
        "saves": 5, "prunes": 3, "gcs": 0, "restores": 0,
    }


def test_startup_gc_counts_partials(tmp_path):
    d = str(tmp_path / "ckpt")
    Checkpointer(d).save(2, {"u": np.zeros((2, 2))}, blocking=True)
    # a torn dir (no COMMITTED) and an abandoned staging dir
    os.makedirs(os.path.join(d, "step_00000009"))
    os.makedirs(os.path.join(d, "step_00000011.tmp"))
    ckpt = Checkpointer(d)
    assert ckpt.stats.gcs == 2
    assert sorted(os.listdir(d)) == ["step_00000002"]


def test_manifest_extra_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    ckpt = Checkpointer(d)
    extra = {"program_fingerprint": "abc", "step": 6, "rotation_phase": 1}
    ckpt.save(6, {"state": {"b0": np.ones((3, 3))}}, blocking=True,
              extra=extra)
    m = ckpt.manifest()
    assert m["step"] == 6 and m["extra"] == extra
    assert list(m["leaves"]) == ["state/b0"]
    with pytest.raises(FileNotFoundError):
        Checkpointer(str(tmp_path / "empty")).manifest()


def test_keep_last_must_be_positive(tmp_path):
    with pytest.raises(ValueError, match="keep_last"):
        Checkpointer(str(tmp_path / "c"), keep_last=0)


# -------------------------------------------------------------------------
# serve migration: evacuate -> admit across engines
# -------------------------------------------------------------------------


def test_engine_evacuate_admit_is_bitwise(tmp_path):
    from repro.serve.stencil import StencilEngine, StencilEngineConfig
    from repro.serve.stencil.request import EVACUATED

    prog = _heat(name="heat_res_migrate")
    tgt = Target(exchange_every=2)
    states = [_rand((16, 16), 20 + i) for i in range(3)]
    refs = [
        api.compile(prog, tgt).time_loop((s,), 12) for s in states
    ]

    first = StencilEngine(StencilEngineConfig(slots_per_group=2))
    for s in states:
        first.submit(prog, (s,), 12, target=tgt)
    for _ in range(2):  # two slots advance to step 4; one stays queued
        first.step()
    d = str(tmp_path / "evac")
    evacuated = first.evacuate(prog.fingerprint, d)
    assert [r.steps_done for r in evacuated] == [4, 4, 0]
    assert all(r.status == EVACUATED for r in evacuated)
    assert first.pending == 0
    assert first.metrics.requests_evacuated == 3
    assert first.metrics.snapshot()["requests_evacuated"] == 3

    second = StencilEngine(StencilEngineConfig(slots_per_group=2))
    handles = second.admit_evacuated(d, prog)
    assert [h.steps_done for h in handles] == [4, 4, 0]
    second.run()
    assert second.metrics.requests_resumed == 3
    assert second.metrics.snapshot()["requests_resumed"] == 3
    for h, ref in zip(handles, refs):
        _assert_bitwise(h.result(), ref, f"migrated request {h.rid}")


def test_admit_requires_matching_program(tmp_path):
    from repro.serve.stencil import StencilEngine

    prog = _heat(name="heat_res_mig_owner")
    other = _heat(alpha=0.2, name="heat_res_mig_other")
    first = StencilEngine()
    first.submit(prog, (_rand((16, 16), 30),), 4)
    d = str(tmp_path / "evac")
    first.evacuate(prog.fingerprint, d)
    with pytest.raises(ResumeError, match="no matching Program"):
        StencilEngine().admit_evacuated(d, other)
    with pytest.raises(ResumeError, match="no evacuated requests"):
        StencilEngine().admit_evacuated(str(tmp_path / "nothing_here"), prog)


def test_submit_start_step_is_validated():
    from repro.serve.stencil import StencilEngine

    prog = _heat(name="heat_res_startstep")
    engine = StencilEngine()
    with pytest.raises(ValueError, match="start_step"):
        engine.submit(prog, (_rand((16, 16), 31),), 8,
                      target=Target(exchange_every=2), start_step=3)
    with pytest.raises(ValueError, match="start_step"):
        engine.submit(prog, (_rand((16, 16), 31),), 8, start_step=8)


# -------------------------------------------------------------------------
# tune transfer: cross-hardware warm start
# -------------------------------------------------------------------------


def _tune_kwargs():
    return dict(
        measure=False, backends=("jnp",), exchange_every=(1, 2),
        overlap=(False,), fused_epoch=(False,),
    )


def test_tune_transfer_adopts_foreign_entry(tmp_path, monkeypatch):
    from repro.tune import cache as tc
    from repro.tune import cache_stats, reset_cache_stats, tune

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tc"))
    prog = _heat(name="heat_res_xfer")
    res = tune(prog, ranks=1, **_tune_kwargs())
    assert not res.from_cache

    # re-home the stored entry under a fake foreign hardware signature
    # (the mesh=None winner is device-independent, so it rebuilds here)
    entry = tc.load(res.cache_key)
    donor = dict(entry)
    donor["hardware"] = "tpu:TPU v5e:n8"
    donor["n_ranks"] = 8
    tc.store(
        tc.cache_key(prog.fingerprint, donor["hardware"], 8,
                     donor["options"]),
        donor,
    )
    os.unlink(tc.entry_path(res.cache_key))

    reset_cache_stats()
    moved = tune(prog, ranks=1, transfer=True, **_tune_kwargs())
    stats = cache_stats().as_dict()
    assert moved.from_cache and moved.winner.origin == "transfer"
    assert stats["transfer_hits"] == 1 and stats["hits"] == 0
    # a transfer is a warm start, not a local fact: nothing re-stored
    assert stats["stores"] == 0
    assert moved.target.fingerprint == entry["winner"]["fingerprint"]

    # transfer=False (the default): the very same miss searches fresh
    reset_cache_stats()
    fresh = tune(prog, ranks=1, **_tune_kwargs())
    stats = cache_stats().as_dict()
    assert not fresh.from_cache
    assert stats["transfer_hits"] == 0 and stats["stores"] == 1


def test_tune_transfer_ignores_mismatched_entries(tmp_path, monkeypatch):
    """Different options digest or different program never transfers;
    an empty cache dir is a plain None."""
    from repro.tune import cache as tc
    from repro.tune import cache_stats, reset_cache_stats, tune

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tc"))
    prog = _heat(name="heat_res_noxfer")
    reset_cache_stats()
    assert tc.lookup_transfer(prog, 1, "deadbeef") is None

    res = tune(prog, ranks=1, **_tune_kwargs())
    entry = tc.load(res.cache_key)
    donor = dict(entry)
    donor["hardware"] = "tpu:TPU v5e:n8"
    tc.store(tc.cache_key(prog.fingerprint, donor["hardware"], 8,
                          donor["options"]), donor)
    os.unlink(tc.entry_path(res.cache_key))

    # wrong options digest -> no transfer
    assert tc.lookup_transfer(prog, 1, "0000aaaa0000") is None
    # wrong program -> no transfer
    other = _heat(alpha=0.2, name="heat_res_noxfer2")
    assert tc.lookup_transfer(other, 1, donor["options"]) is None
    assert cache_stats().transfer_hits == 0


# -------------------------------------------------------------------------
# api surface
# -------------------------------------------------------------------------


def test_api_entry_points(tmp_path):
    import repro

    prog = _heat(name="heat_res_api")
    u0 = _rand((16, 16), 40)
    ref = api.compile(prog, Target()).time_loop((u0,), 4)
    d = str(tmp_path / "ckpt")
    loop = repro.resilient_loop(prog, Target(), (u0,), 4, directory=d)
    final = loop.run()
    _assert_bitwise(final, ref, "repro.resilient_loop")
    resumed = repro.resume(prog, d)
    assert resumed.done  # final snapshot is at n_steps
    compiled = api.compile(prog, Target())
    assert compiled.epochs(8) == 8
    assert isinstance(compiled.ret_indices, tuple)
    with pytest.raises(ValueError, match="exchange_every"):
        api.compile(prog, Target(exchange_every=4)).epochs(6)
