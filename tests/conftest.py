"""Shared test config.

NOTE: no --xla_force_host_platform_device_count here — unit/smoke tests
run on the 1 real CPU device.  Multi-device distribution tests spawn
subprocesses (tests/dist_worker.py) that set the flag before importing
jax, mirroring launch/dryrun.py.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
