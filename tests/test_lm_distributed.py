"""Distributed LM correctness (subprocess, 8 virtual devices):
seq-sharded KV caches decode through the shard_map flash-decode path and
must equal the single-device reference."""
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "lm_dist_worker.py")


@pytest.mark.parametrize(
    "scenario", ["decode_seq_sharded", "decode_seq_all_sharded"]
)
def test_lm_distributed(scenario):
    proc = subprocess.run(
        [sys.executable, WORKER, scenario],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, (
        f"{scenario} failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    )
