"""Compiler-pass tests: halo inference, decomposition (dmp.swap
insertion), redundant-swap elimination, fusion, CSE — the paper's §4.2
pass pipeline, validated structurally AND semantically."""
import numpy as np
import pytest

from repro.core import ir
from repro.core.builder import build_apply
from repro.core.dialects import dmp, stencil
from repro.core.passes import (
    cse_apply_bodies,
    dce,
    decompose_stencil,
    eliminate_redundant_swaps,
    fuse_applies,
    infer_apply_halo,
)
from repro.core.passes.decompose import (
    make_strategy_1d,
    make_strategy_2d,
    make_strategy_3d,
)
from repro.core.program import StencilComputation
from repro.frontends.oec_like import ProgramBuilder


def _count(func, kind):
    return sum(1 for op in func.body.ops if isinstance(op, kind))


def _jacobi_prog(shape=(32, 32)):
    p = ProgramBuilder("jacobi", shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.25,
    )
    p.store(r, out)
    return p.build_func()


# -------------------------------------------------------------------------
# halo inference (paper: "minimal halo derived from stencil.access offsets")
# -------------------------------------------------------------------------


@pytest.mark.parametrize(
    "offsets,expect_lo,expect_hi",
    [
        ([(-1, 0), (1, 0), (0, -1), (0, 1)], (-1, -1), (1, 1)),
        ([(-4, 0), (0, 2)], (-4, 0), (0, 2)),
        ([(0, 0)], (0, 0), (0, 0)),
    ],
)
def test_halo_inference_minimal(offsets, expect_lo, expect_hi):
    core = stencil.Bounds.from_shape((16, 16))
    func = ir.FuncOp("h", [stencil.FieldType(core), stencil.FieldType(core)])
    load = func.body.add_op(stencil.LoadOp(func.body.args[0]))

    def body(b, u):
        acc = None
        for off in offsets:
            t = u.at(*off)
            acc = t if acc is None else acc + t
        return acc

    apply_op = build_apply(func.body, [load.results[0]], core, body)
    func.body.add_op(stencil.StoreOp(apply_op.results[0], func.body.args[1], core))
    func.body.add_op(ir.ReturnOp([]))
    lo, hi = infer_apply_halo(apply_op)[0]
    assert lo == expect_lo and hi == expect_hi


# -------------------------------------------------------------------------
# decomposition (dmp.swap insertion)
# -------------------------------------------------------------------------


def test_decompose_inserts_swap_with_correct_halo():
    func = _jacobi_prog((32, 32))
    local = decompose_stencil(func, make_strategy_2d((4, 2)))
    swaps = [op for op in local.body.ops if isinstance(op, dmp.SwapOp)]
    assert len(swaps) == 1
    sw = swaps[0]
    assert sw.halo_widths() == ((1, 1), (1, 1))
    # local domain is the global domain divided by the rank grid
    assert sw.temp.type.bounds.shape == (8, 16)
    # 4 axis-aligned exchanges for a star stencil (no corners)
    assert len(sw.exchanges) == 4
    ir.verify_module(local)


def test_decompose_local_shapes_3d():
    p = ProgramBuilder("j3", (32, 32, 64))
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0, 0) + u.at(1, 0, 0) + u.at(0, 0, -2)) * 0.5,
    )
    p.store(r, out)
    func = p.build_func()
    local = decompose_stencil(func, make_strategy_3d((2, 2, 4)))
    (sw,) = [op for op in local.body.ops if isinstance(op, dmp.SwapOp)]
    assert sw.temp.type.bounds.shape == (16, 16, 16)
    assert sw.halo_widths() == ((1, 0, 2), (1, 0, 0))


def test_exchange_decls_match_paper_model():
    """Each exchange declares send/recv rectangles + neighbor offset
    (paper fig. 3)."""
    func = _jacobi_prog((32, 32))
    local = decompose_stencil(func, make_strategy_1d(4, dim=0))
    (sw,) = [op for op in local.body.ops if isinstance(op, dmp.SwapOp)]
    exs = sw.exchanges
    assert len(exs) == 2  # up + down neighbors in 1-D
    for ex in exs:
        # full-width slabs of thickness 1; width spans the undecomposed
        # dim's locally-filled halo (32 + 2·1) so corners need no 2nd round
        assert ex.numel() == 1 * 34
        assert ex.is_axis_aligned()


def test_decompose_1d_strategy_on_dim1():
    func = _jacobi_prog((32, 64))
    local = decompose_stencil(func, make_strategy_1d(4, dim=1))
    (sw,) = [op for op in local.body.ops if isinstance(op, dmp.SwapOp)]
    assert sw.temp.type.bounds.shape == (32, 16)
    # full stencil halo on both dims (undecomposed dim 0 is filled
    # locally by boundary handling) — but exchanges run only along dim 1
    assert sw.halo_widths() == ((1, 1), (1, 1))
    assert all(ex.neighbor[0] != 0 for ex in sw.exchanges)
    assert len(sw.exchanges) == 2


# -------------------------------------------------------------------------
# redundant swap elimination (paper: SSA dataflow pass removes dup swaps)
# -------------------------------------------------------------------------


def _two_apply_prog(shape=(32, 32)):
    """load → apply(center only) → apply(star): first apply's swap is
    redundant since its result is only read at offset 0... but the second
    needs one.  Construct the redundant case directly: two swaps of the
    same temp."""
    p = ProgramBuilder("two", shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    a = p.apply([t], lambda b, u: u.at(0, 0) * 2.0)
    r = p.apply(
        [a],
        lambda b, v: (v.at(-1, 0) + v.at(1, 0) + v.at(0, -1) + v.at(0, 1)) * 0.25,
    )
    p.store(r, out)
    return p.build_func()


def test_swap_count_after_elimination():
    func = _two_apply_prog()
    local = decompose_stencil(func, make_strategy_2d((2, 2)))
    n_before = _count(local, dmp.SwapOp)
    eliminate_redundant_swaps(local)
    n_after = _count(local, dmp.SwapOp)
    assert n_after <= n_before
    # the center-only apply's input swap must be gone; the star apply's stays
    assert n_after == 1
    ir.verify_module(local)


def test_elimination_preserves_results():
    func = _two_apply_prog((16, 16))
    comp_raw = StencilComputation(_two_apply_prog((16, 16)), boundary="periodic")

    rng = np.random.default_rng(3)
    u0 = rng.standard_normal((16, 16)).astype(np.float32)
    out0 = np.zeros((16, 16), np.float32)

    from repro.core.program import CompileOptions

    # single-rank periodic reference
    ref = comp_raw.compile(options=CompileOptions(fuse=False, cse=False))(u0, out0)
    got = StencilComputation(func, boundary="periodic").compile(
        options=CompileOptions(fuse=True, cse=True)
    )(u0, out0)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), rtol=1e-6)


# -------------------------------------------------------------------------
# fusion (paper §6.2: PW advection fuses 3 stencils → 1 region)
# -------------------------------------------------------------------------


def _three_stencil_prog(shape=(24, 24)):
    """Three chained applies, fusable into one (PW-advection shape)."""
    p = ProgramBuilder("pw", shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    a = p.apply([t], lambda b, u: (u.at(-1, 0) + u.at(1, 0)) * 0.5)
    c = p.apply([t, a], lambda b, u, a: u.at(0, 0) + a.at(0, 0) * 0.1)
    p.store(c, out)
    return p.build_func()


def test_fusion_reduces_apply_count():
    func = _three_stencil_prog()
    n0 = _count(func, stencil.ApplyOp)
    fuse_applies(func)
    dce(func)
    n1 = _count(func, stencil.ApplyOp)
    assert n1 < n0
    assert n1 == 1
    ir.verify_module(func)


def test_fusion_preserves_semantics():
    rng = np.random.default_rng(1)
    u0 = rng.standard_normal((24, 24)).astype(np.float32)
    out0 = np.zeros_like(u0)
    from repro.core.program import CompileOptions

    r_unfused = StencilComputation(_three_stencil_prog(), boundary="periodic").compile(
        options=CompileOptions(fuse=False, cse=False)
    )(u0, out0)
    r_fused = StencilComputation(_three_stencil_prog(), boundary="periodic").compile(
        options=CompileOptions(fuse=True, cse=False)
    )(u0, out0)
    np.testing.assert_allclose(np.asarray(r_unfused), np.asarray(r_fused), rtol=1e-6)


def test_fusion_grows_halo_of_consumer():
    """Fusing apply(shift) into apply(star) widens the fused access set."""
    func = _three_stencil_prog()
    fuse_applies(func)
    dce(func)
    local = decompose_stencil(func, make_strategy_2d((2, 2)))
    (sw,) = [op for op in local.body.ops if isinstance(op, dmp.SwapOp)]
    # fused stencil reads u at (-1,0),(1,0),(0,0) through `a` = halo 1 on dim 0
    lo, hi = sw.halo_widths()
    assert lo[0] >= 1 and hi[0] >= 1


# -------------------------------------------------------------------------
# CSE
# -------------------------------------------------------------------------


def test_cse_dedupes_accesses():
    core = stencil.Bounds.from_shape((8, 8))
    func = ir.FuncOp("c", [stencil.FieldType(core), stencil.FieldType(core)])
    load = func.body.add_op(stencil.LoadOp(func.body.args[0]))

    def body(b, u):
        # u.at(1,0) appears twice; constant 2.0 appears twice
        return u.at(1, 0) * 2.0 + u.at(1, 0) * 2.0

    apply_op = build_apply(func.body, [load.results[0]], core, body)
    func.body.add_op(stencil.StoreOp(apply_op.results[0], func.body.args[1], core))
    func.body.add_op(ir.ReturnOp([]))

    n_access_before = sum(
        1 for op in apply_op.body.ops if isinstance(op, stencil.AccessOp)
    )
    cse_apply_bodies(func)
    dce(func)
    n_access_after = sum(
        1 for op in apply_op.body.ops if isinstance(op, stencil.AccessOp)
    )
    assert n_access_before == 2
    assert n_access_after == 1
    ir.verify_module(func)


# -------------------------------------------------------------------------
# beyond-paper rewrites keep semantics (overlap / diagonal)
# -------------------------------------------------------------------------


# "pipeline" replaces the removed comm_dialect flag: the canonical spec
# written out explicitly must match the flag-denoted default pipeline.
@pytest.mark.parametrize(
    "kw",
    [
        {"overlap": True},
        {"diagonal": True},
        {"pipeline": "fuse,cse,dce,decompose,swap-elim,lower-comm"},
    ],
    ids=["overlap", "diagonal", "pipeline"],
)
def test_beyond_paper_rewrites_preserve_semantics(kw):
    from repro.core.program import CompileOptions

    rng = np.random.default_rng(7)
    u0 = rng.standard_normal((16, 16)).astype(np.float32)
    out0 = np.zeros_like(u0)

    base = StencilComputation(_jacobi_prog((16, 16)), boundary="periodic").compile(
        options=CompileOptions()
    )(u0, out0)
    opt_result = StencilComputation(_jacobi_prog((16, 16)), boundary="periodic").compile(
        options=CompileOptions(**kw)
    )(u0, out0)
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt_result), rtol=1e-6)
