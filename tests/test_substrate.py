"""Substrate tests: optimizer, checkpoint (incl. crash-consistency and
elastic restore), data pipeline, sharding rules, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, PrefetchLoader, make_source
from repro.dist.compression import int8_roundtrip, topk_sparsify
from repro.train import optimizer as opt_mod


# -------------------------------------------------------------------------
# optimizer
# -------------------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    cfg = opt_mod.OptimizerConfig(peak_lr=0.1, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt_mod.init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw |w|²
        params, state, _ = opt_mod.adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_bounds_update():
    cfg = opt_mod.OptimizerConfig(peak_lr=1.0, warmup_steps=0, grad_clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt_mod.init_opt_state(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = opt_mod.adamw_update(cfg, huge, state, params)
    assert float(metrics["grad_norm"]) > 1.0  # pre-clip norm reported


def test_schedule_warmup_and_decay():
    cfg = opt_mod.OptimizerConfig(peak_lr=1e-3, warmup_steps=100, decay_steps=1000)
    lr0 = float(opt_mod.schedule(cfg, jnp.int32(0)))
    lr_peak = float(opt_mod.schedule(cfg, jnp.int32(100)))
    lr_end = float(opt_mod.schedule(cfg, jnp.int32(999)))
    assert lr0 < lr_peak
    assert abs(lr_peak - 1e-3) / 1e-3 < 0.05
    assert lr_end < lr_peak
    assert lr_end >= cfg.peak_lr * cfg.min_lr_ratio * 0.9


def test_weight_decay_skips_norms_and_biases():
    assert opt_mod._decay_mask(("cells", "slot0", "attn", "wq")) is True
    assert opt_mod._decay_mask(("cells", "slot0", "norm_mixer")) is False


# -------------------------------------------------------------------------
# checkpoint
# -------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(8), jnp.float32)},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(3, tree, blocking=True)
    got = ck.restore(jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1))           # async
    ck.wait()
    assert ck.available_steps() == [1]


def test_checkpoint_keeps_latest_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), blocking=True)
    assert ck.available_steps() == [3, 4]


def test_checkpoint_uncommitted_is_invisible(tmp_path):
    """A partially-written checkpoint (no COMMITTED marker) is skipped —
    crash consistency for preempted writers."""
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), blocking=True)
    # simulate a torn write at a later step
    torn = os.path.join(str(tmp_path), "step_00000009")
    os.makedirs(torn)
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        f.write("{}")
    assert ck.latest_step() == 5
    got = ck.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert int(got["step"]) == 7


def test_checkpoint_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, _tree(1), blocking=True)
    ck.save(2, _tree(2), blocking=True)
    got1 = ck.restore(jax.tree.map(jnp.zeros_like, _tree()), step=1)
    want1 = _tree(1)
    np.testing.assert_array_equal(
        np.asarray(got1["params"]["w"]), np.asarray(want1["params"]["w"])
    )


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore with explicit shardings — the elastic-scale path (write on
    mesh A, restore to mesh B = here, 1-device mesh with new layout)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(1, tree, blocking=True)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = {
        "params": {
            "w": NamedSharding(mesh, P("data", None)),
            "b": NamedSharding(mesh, P()),
        },
        "step": NamedSharding(mesh, P()),
    }
    got = ck.restore(jax.tree.map(jnp.zeros_like, tree), shardings=sh)
    assert got["params"]["w"].sharding == sh["params"]["w"]
    np.testing.assert_array_equal(
        np.asarray(got["params"]["w"]), np.asarray(tree["params"]["w"])
    )


# -------------------------------------------------------------------------
# data pipeline
# -------------------------------------------------------------------------


def test_synthetic_batches_deterministic():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=100, seed=1)
    src = make_source(cfg)
    b1, b2 = src.batch_at(3), src.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 100


def test_file_tokens_windows(tmp_path):
    path = str(tmp_path / "toks.bin")
    data = np.arange(160, dtype=np.uint32)
    data.tofile(path)
    cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=1 << 20, path=path)
    src = make_source(cfg)
    b = src.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][0], np.arange(16))
    np.testing.assert_array_equal(b["tokens"][1], np.arange(16, 32))
    # wraps around at the end of the file
    b_last = src.batch_at(5)
    assert b_last["tokens"].shape == (2, 16)


def test_prefetch_loader_orders_steps():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab_size=50)
    loader = PrefetchLoader(make_source(cfg), start_step=10, depth=2)
    it = iter(loader)
    steps = [next(it)[0] for _ in range(4)]
    loader.stop()
    assert steps == [10, 11, 12, 13]


def test_modality_batches():
    cfg = DataConfig(
        seq_len=16, global_batch=2, vocab_size=50, modality_tokens=4, modality_dim=8
    )
    b = make_source(cfg).batch_at(0)
    assert b["tokens"].shape == (2, 12)  # text shortened by vision tokens
    assert b["modality"].shape == (2, 4, 8)


# -------------------------------------------------------------------------
# gradient compression (beyond-paper distributed-optimization hook)
# -------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
def test_int8_roundtrip_error_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((32, 16)) * scale, jnp.float32)
    y = int8_roundtrip({"g": x})["g"]
    err = float(jnp.abs(y - x).max())
    assert err <= float(jnp.abs(x).max()) / 127 * 1.01 + 1e-9


def test_topk_sparsify_keeps_largest():
    x = jnp.asarray(np.arange(100, dtype=np.float32))
    y = topk_sparsify({"g": x}, keep_fraction=0.1)["g"]
    assert int((y != 0).sum()) == 10
    assert float(y[-1]) == 99.0 and float(y[0]) == 0.0


# -------------------------------------------------------------------------
# sharding rules
# -------------------------------------------------------------------------


def test_valid_spec_drops_indivisible_axes():
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.dist.sharding import _valid_spec

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # shape 3 not divisible by axis size 1? (1 divides everything) — use a
    # pure logic check: indivisible entries are dropped
    spec = _valid_spec(mesh, P("data", "model"), (4, 4))
    assert spec == P("data", "model")


def test_param_specs_cover_tree():
    """Every parameter leaf of a real model gets a valid PartitionSpec."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.configs import get_config
    from repro.configs.base import reduced_config
    from repro.dist import param_specs as pspecs
    from repro.dist.sharding import default_rules
    from repro.models import lm

    cfg = reduced_config(get_config("olmoe-1b-7b"))
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    specs = pspecs.param_pspecs(shapes, default_rules(), mesh)
    n = 0
    for leaf, spec in zip(jax.tree.leaves(shapes), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))):
        assert isinstance(spec, P)
        n += 1
    assert n > 10
