"""Declarative pass pipelines + canonical dmp→comm lowering + IR-level
overlap: spec grammar, golden op sequences from split_overlapped_applies,
sym_name preservation, and the interpreter's comm-only contract."""
import numpy as np
import pytest

from repro.core import ir
from repro.core.dialects import comm, dmp, stencil
from repro.core.lowering import StencilInterpreter
from repro.core.passes import (
    PipelineContext,
    PipelineError,
    build_pipeline,
    decompose_stencil,
    eliminate_redundant_swaps,
    enable_comm_compute_overlap,
    lower_dmp_to_comm,
    parse_pipeline,
    run_pipeline,
    split_overlapped_applies,
    use_diagonal_exchanges,
)
from repro.core.passes.decompose import make_strategy_2d
from repro.core.program import CompileOptions, StencilComputation, default_pipeline
from repro.frontends.oec_like import ProgramBuilder


def _jacobi_prog(shape=(32, 32)):
    p = ProgramBuilder("jacobi", shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)) * 0.25,
    )
    p.store(r, out)
    return p.build_func()


def _box_prog(shape=(32, 32)):
    p = ProgramBuilder("box", shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: u.at(-1, -1) + u.at(1, 1) * 0.5 + u.at(-1, 1) * 0.25
        + u.at(0, 0),
    )
    p.store(r, out)
    return p.build_func()


# -------------------------------------------------------------------------
# pipeline spec grammar
# -------------------------------------------------------------------------


def test_parse_pipeline_roundtrip():
    spec = "fuse,cse,dce,decompose{grid=2x2xy,boundary=periodic},swap-elim,lower-comm"
    stages = parse_pipeline(spec)
    assert [s[0] for s in stages] == [
        "fuse", "cse", "dce", "decompose", "swap-elim", "lower-comm",
    ]
    assert stages[3][1] == {"grid": "2x2xy", "boundary": "periodic"}


def test_parse_pipeline_rejects_garbage():
    with pytest.raises(PipelineError):
        parse_pipeline("fuse,decompose{grid=2x2")
    with pytest.raises(PipelineError):
        parse_pipeline("decompose{gridnovalue}")
    with pytest.raises(PipelineError):
        build_pipeline("no-such-pass")


def test_pipeline_rejects_unknown_options():
    # misspelled/inapplicable options must not be silently ignored
    with pytest.raises(PipelineError, match="grd"):
        build_pipeline("decompose{grd=4x2}", PipelineContext())
    with pytest.raises(PipelineError, match="swap-elim"):
        build_pipeline("swap-elim{aggressive=1}")
    with pytest.raises(PipelineError, match="dims"):
        build_pipeline("decompose{dims=0x1}", PipelineContext())
    with pytest.raises(PipelineError, match="boundary"):
        build_pipeline("decompose{grid=2x2,boundary=mirror}")


def test_grid_spec_with_axis_names():
    stages = build_pipeline("decompose{grid=2x2xy}", PipelineContext())
    func = _jacobi_prog()
    local = stages[0](func)
    (sw,) = [op for op in local.body.ops if isinstance(op, dmp.SwapOp)]
    assert sw.grid.shape == (2, 2)
    assert sw.grid.axis_names == ("x", "y")


def test_default_pipeline_always_lowers_comm():
    assert default_pipeline(CompileOptions()).endswith("lower-comm")
    spec = default_pipeline(CompileOptions(overlap=True, diagonal=True))
    assert "diagonal" in spec and "overlap" in spec
    assert spec.index("diagonal") < spec.index("overlap")


def test_pipeline_timings_recorded():
    comp = StencilComputation(_jacobi_prog(), boundary="periodic")
    comp.prepare_local(make_strategy_2d((2, 2)), CompileOptions(overlap=True))
    names = [n for n, _ in comp.last_timings]
    assert names == comp.last_pipeline.split(",")
    assert all(sec >= 0 for _, sec in comp.last_timings)


# -------------------------------------------------------------------------
# canonical lowering invariants
# -------------------------------------------------------------------------


def test_lower_dmp_to_comm_preserves_sym_name():
    local = decompose_stencil(_jacobi_prog(), make_strategy_2d((2, 2)))
    lowered = lower_dmp_to_comm(local)
    assert lowered.sym_name == local.sym_name
    assert not any(isinstance(op, dmp.SwapOp) for op in lowered.body.ops)


def test_prepare_local_emits_comm_only():
    comp = StencilComputation(_jacobi_prog(), boundary="periodic")
    for opts in (CompileOptions(), CompileOptions(overlap=True),
                 CompileOptions(diagonal=True, overlap=True)):
        local = comp.prepare_local(make_strategy_2d((2, 2)), opts)
        assert not any(isinstance(op, dmp.SwapOp) for op in local.body.ops)
        assert any(isinstance(op, comm.ExchangeStartOp) for op in local.body.ops)


def test_interpreter_rejects_dmp_swap():
    local = decompose_stencil(_jacobi_prog(), make_strategy_2d((2, 2)))
    interp = StencilInterpreter(local, axis_sizes={}, distributed=False)
    with pytest.raises(NotImplementedError, match="dmp.swap"):
        interp(np.zeros((16, 16), np.float32), np.zeros((16, 16), np.float32))


def test_comm_dialect_option_is_deprecated_noop():
    comp = StencilComputation(_jacobi_prog(), boundary="periodic")
    a = comp.prepare_local(make_strategy_2d((2, 2)), CompileOptions())
    with pytest.deprecated_call(match="comm_dialect"):
        opts = CompileOptions(comm_dialect=True)
    b = comp.prepare_local(make_strategy_2d((2, 2)), opts)
    assert [op.name for op in a.body.ops] == [op.name for op in b.body.ops]


def test_permute_pairs_shared_helper():
    # 1-axis periodic shift over 4 ranks: full cycle
    axis, pairs = comm.permute_pairs((("x", 1),), {"x": 4}, periodic=True)
    assert axis == "x"
    assert sorted(pairs) == [(0, 3), (1, 0), (2, 1), (3, 2)]
    # zero-BC drops out-of-grid destinations
    _, open_pairs = comm.permute_pairs((("x", 1),), {"x": 4}, periodic=False)
    assert (0, 3) not in open_pairs and len(open_pairs) == 3
    # diagonal: two axes linearized row-major
    axes, dpairs = comm.permute_pairs(
        (("x", 1), ("y", 1)), {"x": 2, "y": 2}, periodic=True
    )
    assert axes == ("x", "y")
    assert len(dpairs) == 4


# -------------------------------------------------------------------------
# split_overlapped_applies: golden op sequences
# -------------------------------------------------------------------------


def _overlap_split(func, grid=(2, 2), diagonal=False):
    local = decompose_stencil(func, make_strategy_2d(grid), boundary="periodic")
    eliminate_redundant_swaps(local)
    if diagonal:
        use_diagonal_exchanges(local)
    assert enable_comm_compute_overlap(local) == 1
    split = split_overlapped_applies(local)
    ir.verify_module(split)
    return split


def test_split_golden_sequence_star_concurrent():
    split = _overlap_split(_jacobi_prog())
    names = [op.name for op in split.body.ops]
    assert names == (
        ["stencil.load", "comm.halo_pad"]
        + ["comm.exchange_start"] * 4   # 4 face exchanges, one round
        + ["stencil.apply"]             # interior, between starts and wait
        + ["comm.wait"]
        + ["stencil.apply"] * 4         # onion-peel boundary frames
        + ["stencil.combine", "stencil.store", "func.return"]
    ), names


def test_split_golden_sequence_box_sequential():
    split = _overlap_split(_box_prog())
    names = [op.name for op in split.body.ops]
    # sequential corner-forwarding: round 1 (axis 0) overlaps the interior,
    # round 2 (axis 1) chains off round 1's wait
    assert names == (
        ["stencil.load", "comm.halo_pad"]
        + ["comm.exchange_start"] * 2   # round 1: axis-0 faces
        + ["stencil.apply"]             # interior
        + ["comm.wait"]
        + ["comm.exchange_start"] * 2   # round 2: axis-1 faces (forwarded)
        + ["comm.wait"]
        + ["stencil.apply"] * 4
        + ["stencil.combine", "stencil.store", "func.return"]
    ), names


def test_split_golden_sequence_box_diagonal():
    split = _overlap_split(_box_prog(), diagonal=True)
    names = [op.name for op in split.body.ops]
    # diagonal rewrite: concurrent faces + corners, all in one round
    n_starts = names.count("comm.exchange_start")
    assert n_starts == 8  # 4 faces + 4 corners on a 2x2 grid
    assert names.index("stencil.apply") > names.index("comm.exchange_start")
    assert names.index("stencil.apply") < names.index("comm.wait")
    assert names.count("comm.wait") == 1


def test_split_part_attributes_and_bounds():
    split = _overlap_split(_jacobi_prog())
    applies = [op for op in split.body.ops if isinstance(op, stencil.ApplyOp)]
    parts = [op.attributes["part"].value for op in applies]
    assert parts == ["interior"] + ["frame"] * 4
    interior = applies[0]
    # jacobi halo 1: interior = local core (16x16) shrunk by 1 per side
    assert interior.result_bounds.shape == (14, 14)
    (combine,) = [op for op in split.body.ops if isinstance(op, stencil.CombineOp)]
    assert combine.result_bounds.shape == (16, 16)
    # parts tile the result exactly
    covered = sum(
        int(np.prod(p.type.bounds.shape)) for p in combine.operands
    )
    assert covered == 16 * 16


def test_split_skips_ineligible_swaps():
    # a swap whose result is consumed by two applies must not be split
    p = ProgramBuilder("two", (16, 16))
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    a = p.apply([t], lambda b, u: (u.at(-1, 0) + u.at(1, 0)) * 0.5)
    c = p.apply([t], lambda b, u: (u.at(0, -1) + u.at(0, 1)) * 0.5)
    s = p.apply([a, c], lambda b, x, y: x.at(0, 0) + y.at(0, 0))
    p.store(s, out)
    func = p.build_func()
    local = decompose_stencil(func, make_strategy_2d((2, 2)))
    eliminate_redundant_swaps(local)
    n_swaps = sum(1 for op in local.body.ops if isinstance(op, dmp.SwapOp))
    enable_comm_compute_overlap(local)
    split = split_overlapped_applies(local)
    remaining = sum(1 for op in split.body.ops if isinstance(op, dmp.SwapOp))
    # declined swaps are untagged, so lower-comm handles them silently
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        lowered = lower_dmp_to_comm(split)
    assert not any(isinstance(op, dmp.SwapOp) for op in lowered.body.ops)
    assert remaining <= n_swaps
    ir.verify_module(lowered)


def test_split_is_identity_when_nothing_tagged():
    local = decompose_stencil(_jacobi_prog(), make_strategy_2d((2, 2)))
    assert split_overlapped_applies(local) is local


def test_lower_comm_warns_on_unsplit_overlap_tag():
    # overlap-tag without split-overlap: the tag must not vanish silently
    local = decompose_stencil(_jacobi_prog(), make_strategy_2d((2, 2)))
    eliminate_redundant_swaps(local)
    enable_comm_compute_overlap(local)
    with pytest.warns(UserWarning, match="overlap-tagged"):
        lower_dmp_to_comm(local)


# -------------------------------------------------------------------------
# temporal_tile: golden op sequences (one exchange per epoch)
# -------------------------------------------------------------------------


def _tiled(func, spec, boundary="periodic"):
    ctx = PipelineContext(
        strategy=make_strategy_2d((2, 2)), boundary=boundary
    )
    out, _ = run_pipeline(func, spec, ctx)
    return out


def test_temporal_tile_golden_sequence():
    """k=2 epoch of the star stencil: ONE deep exchange (sequential —
    S∘S has a diamond footprint, so corners must be forwarded), then the
    two cloned applies, then the store."""
    split = _tiled(
        _jacobi_prog(),
        "decompose,swap-elim,temporal-tile{k=2},lower-comm",
    )
    names = [op.name for op in split.body.ops]
    assert names == (
        ["stencil.load", "comm.halo_pad"]
        + ["comm.exchange_start"] * 2 + ["comm.wait"]   # axis-0 round
        + ["comm.exchange_start"] * 2 + ["comm.wait"]   # axis-1 (forwarded)
        + ["stencil.apply"] * 2                         # step 1 grown, step 2 core
        + ["stencil.store", "func.return"]
    ), names


def test_temporal_tile_scales_halo_extents():
    local = decompose_stencil(
        _jacobi_prog(), make_strategy_2d((2, 2)), boundary="periodic"
    )
    eliminate_redundant_swaps(local)
    from repro.core.passes import temporal_tile

    tiled = temporal_tile(local, 4)
    ir.verify_module(tiled)
    (swap,) = [op for op in tiled.body.ops if isinstance(op, dmp.SwapOp)]
    assert swap.halo_widths() == ((4, 4), (4, 4))  # per-step 1 × k=4
    applies = [op for op in tiled.body.ops if isinstance(op, stencil.ApplyOp)]
    assert [a.attributes["epoch_step"].value for a in applies] == [1, 2, 3, 4]
    # local core 16×16; step j computes core + (k-j) redundant frame
    assert [a.result_bounds.shape for a in applies] == [
        (22, 22), (20, 20), (18, 18), (16, 16)
    ]


def test_temporal_tile_overlap_split_still_applied():
    """temporal-tile composes with the overlap split: step 1's interior
    (clipped to the pre-exchange core minus its reads) overlaps the deep
    exchange; frames + later steps run after the waits."""
    split = _tiled(
        _jacobi_prog(),
        "decompose,swap-elim,temporal-tile{k=2},overlap,lower-comm",
    )
    names = [op.name for op in split.body.ops]
    first_apply = names.index("stencil.apply")
    assert names.index("comm.exchange_start") < first_apply
    assert first_apply < names.index("comm.wait"), names
    assert "stencil.combine" in names
    applies = [op for op in split.body.ops if isinstance(op, stencil.ApplyOp)]
    interior = applies[0]
    assert interior.attributes["part"].value == "interior"
    # the interior may not read exchanged halo points: core 16² shrunk by
    # the step-1 access extent, NOT the grown 18² result shrunk by 1
    assert interior.result_bounds.shape == (14, 14)
    (combine,) = [op for op in split.body.ops if isinstance(op, stencil.CombineOp)]
    assert combine.result_bounds.shape == (18, 18)  # step 1 output, grown
    covered = sum(int(np.prod(p.type.bounds.shape)) for p in combine.operands)
    assert covered == 18 * 18  # interior + frames tile the grown domain
    # the final (core) step runs on the combined value, after every wait
    assert applies[-1].result_bounds.shape == (16, 16)


def test_temporal_tile_zero_bc_masks_in_sequence():
    split = _tiled(
        _jacobi_prog(),
        "decompose,swap-elim,temporal-tile{k=2},lower-comm",
        boundary="zero",
    )
    names = [op.name for op in split.body.ops]
    # exactly one mask: the grown step-1 result, re-clamped to the
    # physical domain before step 2 reads it
    assert names.count("comm.boundary_mask") == 1
    assert names.index("comm.boundary_mask") > names.index("stencil.apply")
    assert names.index("comm.boundary_mask") < len(names) - 1 - names[::-1].index(
        "stencil.apply"
    )


def test_temporal_tile_via_spec_matches_flag_surface():
    from repro.api import Target

    spec = Target(exchange_every=4).pipeline_spec()
    assert "temporal-tile{k=4}" in spec
    assert spec.index("swap-elim") < spec.index("temporal-tile")
    assert spec.index("temporal-tile") < spec.index("lower-comm")
    parsed = parse_pipeline(spec)
    assert ("temporal-tile", {"k": "4"}) in parsed


def test_fuse_epoch_golden_sequence():
    """fuse-epoch-kernel after lower-comm: the k=2 epoch's two applies
    (and the zero-BC re-masking between them) collapse into exactly ONE
    region-bearing stencil.fused_epoch op — the op the pallas backend
    turns into a single kernel dispatch."""
    fused = _tiled(
        _jacobi_prog(),
        "decompose,swap-elim,temporal-tile{k=2},lower-comm,fuse-epoch-kernel",
        boundary="zero",
    )
    ir.verify_module(fused)
    names = [op.name for op in fused.body.ops]
    assert names.count("stencil.fused_epoch") == 1
    assert "stencil.apply" not in names
    assert "comm.boundary_mask" not in names
    # comm stays outside the kernel: exchange before, store after
    assert names.index("comm.wait") < names.index("stencil.fused_epoch")
    assert names.index("stencil.fused_epoch") < names.index("stencil.store")
    (fop,) = [
        op for op in fused.body.ops
        if isinstance(op, stencil.FusedEpochOp)
    ]
    inner = [op.name for op in fop.body.ops]
    assert inner == [
        "stencil.apply",
        "comm.boundary_mask",
        "stencil.apply",
        "stencil.fused_yield",
    ], inner
    assert fop.k == 2
    # the epoch's escape is the core-bounds step-2 result the store reads
    (res,) = fop.results
    assert res.type.bounds.shape == (16, 16)


def test_pipeline_overlap_semantics_single_device():
    rng = np.random.default_rng(11)
    u0 = rng.standard_normal((24, 24)).astype(np.float32)
    out0 = np.zeros_like(u0)
    base = StencilComputation(_box_prog((24, 24)), boundary="periodic").compile(
        options=CompileOptions()
    )(u0, out0)
    via_spec = StencilComputation(_box_prog((24, 24)), boundary="periodic").compile(
        options=CompileOptions(
            pipeline="fuse,cse,dce,decompose,swap-elim,overlap,lower-comm"
        )
    )(u0, out0)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(via_spec))
