"""repro.tune — search space, roofline scoring, measurement, and the
persistent on-disk cache; plus the RooflineTerms edge cases the tuner
leans on and the compile-time pallas_tile validation.

Unit scale: single device (mesh candidates under real multi-device
meshes are exercised by tests/dist_worker.py scenarios ``tune-4rank``
and ``pallas-tile-shard-error``).
"""
import json
import math
import os

import numpy as np
import pytest

from repro import api
from repro.api import Target, TargetError
from repro.frontends.oec_like import ProgramBuilder
from repro.launch.roofline import RooflineTerms
from repro.tune import (
    Candidate,
    cache_stats,
    enumerate_candidates,
    measure_compiled,
    reset_cache_stats,
    target_from_dict,
    target_to_dict,
    tune,
)
from repro.tune import cache as tune_cache
from repro.tune.space import (
    exchange_every_candidates,
    factorizations,
    mesh_assignments,
    pallas_tile_candidates,
    strategy_candidates,
)


def _jacobi_prog(shape=(32, 32), boundary="periodic", name="tune_jacobi"):
    p = ProgramBuilder(name, shape)
    u = p.input("u")
    out = p.output("out")
    t = p.load(u)
    r = p.apply(
        [t],
        lambda b, u: (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1))
        * 0.25,
    )
    p.store(r, out)
    return p.finish(boundary=boundary)


@pytest.fixture
def tune_dir(tmp_path, monkeypatch):
    d = tmp_path / "tune-cache"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(d))
    reset_cache_stats()
    yield str(d)
    reset_cache_stats()


# -------------------------------------------------------------------------
# search space
# -------------------------------------------------------------------------


def test_factorizations():
    assert factorizations(1) == [()]
    assert set(factorizations(8)) == {(8,), (2, 4), (4, 2), (2, 2, 2)}
    assert set(factorizations(6)) == {(6,), (2, 3), (3, 2)}


def test_mesh_assignments_dedup_and_rank_bound():
    # rank-2 program: (2,2,2) factorization needs 3 dims → dropped;
    # 2×2 over dims (0,1) and (1,0) are the same assignment
    assigns = mesh_assignments(8, rank=2)
    assert ((2, 0), (4, 1)) in assigns and ((4, 0), (2, 1)) in assigns
    assert ((8, 0),) in assigns and ((8, 1),) in assigns
    assert not any(len(a) > 2 for a in assigns)
    four = mesh_assignments(4, rank=2)
    assert four.count(((2, 0), (2, 1))) == 1


def test_strategy_candidates_respect_divisibility():
    # 6 does not divide 32: no factor-6 grids on either dim
    prog = _jacobi_prog((32, 32))
    strategies = strategy_candidates(prog, 6)
    for s in strategies:
        for g, d in zip(s.grid_shape, s.dims):
            assert 32 % g == 0
    assert strategy_candidates(prog, 1) == [None]


def test_exchange_every_candidates_filter_deep_halo():
    prog = _jacobi_prog((8, 8))
    # single device, shard 8×8, halo 1/step: k=8 fills the shard, fine;
    # k beyond the shard is filtered
    ks = exchange_every_candidates(prog, None, ks=(1, 2, 4, 8, 16))
    assert 1 in ks and 16 not in ks
    # non-epochable inputs keep k=1 only (wave-like: guarded upstream)
    assert exchange_every_candidates(prog, None, ks=(1,)) == [1]


def test_pallas_tile_candidates_divide_shard():
    prog = _jacobi_prog((64, 32))
    tiles = pallas_tile_candidates(prog, None)
    assert None in tiles and (64, 32) in tiles and (32, 32) in tiles
    for t in tiles:
        if t is not None:
            assert all(n % x == 0 for n, x in zip((64, 32), t))


def test_enumerate_baseline_first_and_valid():
    prog = _jacobi_prog()
    cands = enumerate_candidates(prog)
    assert cands[0].origin == "baseline"
    fps = [c.fingerprint for c in cands]
    assert len(fps) == len(set(fps)), "duplicate candidates"
    for c in cands[:6]:  # spot-check: every offered candidate validates
        api._validate_for_program(prog, c.target)


def test_enumerate_emits_fused_epoch_candidates():
    prog = _jacobi_prog()
    cands = enumerate_candidates(prog)
    fused = [c for c in cands if c.target.fused_epoch]
    assert fused, "no fused_epoch candidates offered"
    for c in fused:
        assert c.target.backend == "pallas"
        assert not c.target.overlap  # fused ⊥ overlap
        assert "fused" in c.describe()
    # the axis can be switched off
    none_fused = enumerate_candidates(prog, fused_epoch=(False,))
    assert not any(c.target.fused_epoch for c in none_fused)


def test_enumerate_interpret_follows_inventory():
    import jax

    from repro.tune.space import pallas_interpret_candidates

    # CPU-only inventory (the CI machine): interpret resolves to the
    # default; an accelerator inventory would enumerate the native path
    devs = jax.devices()
    if any(d.platform in ("gpu", "tpu") for d in devs):
        assert pallas_interpret_candidates(devs) == [False]
    else:
        assert pallas_interpret_candidates(devs) == [None]

    class _FakeGPU:
        platform = "gpu"

    assert pallas_interpret_candidates([_FakeGPU()]) == [False]


# -------------------------------------------------------------------------
# cost-model-only tuning + the persistent cache (acceptance)
# -------------------------------------------------------------------------


def test_tuned_cost_model_only_winner_and_cache(tune_dir):
    prog = _jacobi_prog(name="tune_cost_only")
    res = tune(prog, measure=False)
    assert not res.from_cache
    assert cache_stats().misses == 1 and cache_stats().stores == 1

    # the winner is a *validated* Target: it compiles
    compiled = api.compile(prog, res.target)
    assert compiled.target.fingerprint == res.target.fingerprint

    # winner's modeled step_time ≤ every unpruned candidate's
    unpruned = [c for c in res.candidates if not c.pruned]
    assert unpruned and res.winner in unpruned
    assert all(
        res.winner.modeled_s <= c.modeled_s for c in unpruned
    ), [(c.describe(), c.modeled_s) for c in unpruned]

    # second call: persistent-cache hit with the identical winner
    res2 = tune(prog, measure=False)
    assert res2.from_cache
    assert cache_stats().hits == 1
    assert res2.target.fingerprint == res.target.fingerprint
    assert os.path.exists(res2.cache_path)

    # Target.tuned surfaces the same winner (third call, second hit)
    t = Target.tuned(prog, measure=False)
    assert t.fingerprint == res.target.fingerprint
    assert cache_stats().hits == 2


def test_compile_tune_kwarg(tune_dir):
    prog = _jacobi_prog(name="tune_compile_kwarg")
    step = api.compile(prog, tune={"measure": False})
    assert isinstance(step, api.CompiledStencil)
    with pytest.raises(ValueError, match="not both"):
        api.compile(prog, Target(), tune={"measure": False})
    # tuned target round-trips through the compile cache
    again = api.compile(prog, tune={"measure": False})
    assert again is step


def test_tune_measure_single_device(tune_dir):
    prog = _jacobi_prog((16, 16), name="tune_measured")
    res = tune(
        prog, measure=True, steps=4, trials=2, warmup=1,
        backends=("jnp",), exchange_every=(1, 2),
    )
    measured = [c for c in res.candidates if c.measured_s is not None]
    assert measured and res.winner in measured
    assert all(res.winner.measured_s <= c.measured_s for c in measured)
    # pruned candidates were never measured
    assert all(c.measured_s is None for c in res.candidates if c.pruned)
    # measurement protocol: per-step normalization keeps epochs comparable
    compiled = api.compile(prog, res.target)
    t = measure_compiled(compiled, steps=2, trials=1, warmup=1)
    assert t > 0.0 and math.isfinite(t)


def test_single_device_model_has_no_phantom_latency(tune_dir):
    # a non-distributed artifact's exchanges are local rolls — no ICI
    # messages, so the modeled score must not reward deep epochs with
    # latency amortization that cannot happen; the modeled winner on one
    # device keeps one exchange per step
    prog = _jacobi_prog(name="tune_no_phantom")
    res = tune(prog, ranks=1, measure=False)
    assert res.target.exchange_every == 1, res.winner.describe()


def test_tune_raises_informatively_when_nothing_models(tune_dir, monkeypatch):
    prog = _jacobi_prog(name="tune_all_fail")

    def boom(*a, **k):
        raise RuntimeError("backend exploded")

    monkeypatch.setattr(api, "compile", boom)
    with pytest.raises(RuntimeError, match="no candidate .* could be modeled"):
        tune(prog, measure=False, cache=False)


def test_measurement_protocol_changes_cache_key(tune_dir):
    # steps/trials/warmup are part of the options digest: a
    # higher-fidelity search must not read back a low-fidelity entry
    prog = _jacobi_prog((16, 16), name="tune_protocol")
    kw = dict(measure=True, backends=("jnp",), exchange_every=(1,))
    r1 = tune(prog, steps=2, trials=1, warmup=1, **kw)
    r2 = tune(prog, steps=4, trials=2, warmup=1, **kw)
    assert r1.cache_key != r2.cache_key
    assert not r2.from_cache


def test_tune_result_table_prints(tune_dir):
    prog = _jacobi_prog(name="tune_table")
    res = tune(prog, measure=False)
    text = res.table(top=5)
    assert "candidate" in text and "modeled/step" in text
    assert "baseline" in res.table()


# -------------------------------------------------------------------------
# cache internals
# -------------------------------------------------------------------------


def test_target_dict_roundtrip_fingerprint():
    t = Target(backend="pallas", pallas_tile=(8, 16), exchange_every=2,
               overlap=True)
    d = target_to_dict(t)
    back = target_from_dict(d)
    assert back.fingerprint == t.fingerprint == d["fingerprint"]
    assert back.pallas_tile == (8, 16) and back.exchange_every == 2


def test_target_dict_roundtrips_fused_epoch():
    t = Target(backend="pallas", exchange_every=4, fused_epoch=True,
               pallas_interpret=True)
    d = target_to_dict(t)
    assert d["fused_epoch"] is True and d["pallas_interpret"] is True
    back = target_from_dict(d)
    assert back.fused_epoch and back.fingerprint == t.fingerprint
    # a pre-fused_epoch (schema v1) winner dict rebuilt under v2 defaults
    # to unfused rather than erroring
    legacy = {k: v for k, v in d.items()
              if k not in ("fused_epoch", "pallas_interpret")}
    old = target_from_dict(legacy)
    assert not old.fused_epoch
    assert old.fingerprint != t.fingerprint


def test_cache_schema_and_corruption_are_misses(tune_dir):
    key = tune_cache.cache_key("fp", "hw", 1, "opts")
    assert tune_cache.load(key) is None  # cold
    tune_cache.store(key, {"winner": {}})
    assert tune_cache.load(key) is not None
    # corrupt file → miss, not an exception
    with open(tune_cache.entry_path(key), "w") as f:
        f.write("{not json")
    assert tune_cache.load(key) is None
    # schema drift → miss
    with open(tune_cache.entry_path(key), "w") as f:
        json.dump({"schema": tune_cache.SCHEMA_VERSION + 1}, f)
    assert tune_cache.load(key) is None


def test_cache_key_separates_programs_hardware_ranks():
    k = tune_cache.cache_key
    assert k("a", "hw", 1, "o") != k("b", "hw", 1, "o")
    assert k("a", "hw", 1, "o") != k("a", "hw2", 1, "o")
    assert k("a", "hw", 1, "o") != k("a", "hw", 2, "o")
    assert k("a", "hw", 1, "o") != k("a", "hw", 1, "o2")


def test_stale_cache_entry_for_other_program_misses(tune_dir):
    # an entry whose winner no longer validates for the program reads as
    # a miss (fresh search), never as a wrong answer
    prog = _jacobi_prog(name="tune_stale")
    res = tune(prog, measure=False)
    with open(res.cache_path) as f:
        entry = json.load(f)
    entry["winner"]["strategy"] = {"grid": [5], "axes": ["x"], "dims": [0]}
    entry["winner"]["mesh"] = None
    with open(res.cache_path, "w") as f:
        json.dump(entry, f)
    reset_cache_stats()
    res2 = tune(prog, measure=False)
    assert not res2.from_cache  # fingerprint/validation rejected the entry
    # the rejected load is counted as a miss, not a hit: the search ran
    assert cache_stats().hits == 0 and cache_stats().misses == 1, (
        cache_stats().as_dict()
    )


# -------------------------------------------------------------------------
# RooflineTerms edge cases (satellite)
# -------------------------------------------------------------------------


def _terms(**kw):
    base = dict(
        flops=1e6, bytes_accessed=1e5, collectives={},
        exchange_every=1, messages_per_epoch=8,
        step_halo=(1, 1), local_shape=(64, 64),
    )
    base.update(kw)
    return RooflineTerms(**base)


def test_recommend_clamps_to_max_k():
    lat = _terms(local_shape=(256, 256))  # latency-dominated: deeper is better
    assert lat.recommend_exchange_every(max_k=8) > 2
    assert lat.recommend_exchange_every(max_k=2) <= 2
    assert lat.recommend_exchange_every(max_k=1) == 1


def test_recommend_returns_1_when_no_latency():
    # t_latency == 0 (no messages): amortization buys nothing, redundant
    # compute only costs — k=1 must win
    quiet = _terms(messages_per_epoch=0)
    assert quiet.t_latency == 0.0
    assert quiet.recommend_exchange_every(max_k=8) == 1
    # no halo at all: terms unavailable → 1
    assert _terms(step_halo=(0, 0)).recommend_exchange_every() == 1
    assert _terms(step_halo=(), local_shape=()).recommend_exchange_every() == 1


def test_recommend_skips_infeasible_k():
    tiny = _terms(local_shape=(4, 4), step_halo=(1, 1))
    assert not tiny.feasible_exchange_every(8)  # deep halo 8 > shard 4
    ranked = tiny.ranked_exchange_every(max_k=8)
    assert all(k <= 4 for k, _ in ranked)
    assert tiny.recommend_exchange_every(max_k=8) <= 4


def test_step_time_monotone_pieces():
    t = _terms()
    # redundant-compute factor: 1.0 at k=1, nondecreasing in k
    rcf = [t.redundant_compute_factor(k) for k in (1, 2, 4, 8)]
    assert rcf[0] == 1.0
    assert all(a <= b for a, b in zip(rcf, rcf[1:]))
    assert rcf[-1] > 1.0
    # latency piece: with a huge shard (rcf ≈ 1) step_time strictly
    # decreases with k — pure 1/k amortization
    lat = _terms(local_shape=(10_000, 10_000))
    times = [lat.step_time(k) for k in (1, 2, 4, 8)]
    assert all(a > b for a, b in zip(times, times[1:]))
    # with no messages, step_time is nondecreasing in k (redundant
    # compute only)
    quiet = _terms(messages_per_epoch=0)
    times = [quiet.step_time(k) for k in (1, 2, 4, 8)]
    assert all(a <= b for a, b in zip(times, times[1:]))


def test_ranked_exchange_every_best_first():
    t = _terms(local_shape=(256, 256))
    ranked = t.ranked_exchange_every(max_k=8)
    assert ranked[0][0] == t.recommend_exchange_every(max_k=8)
    times = [s for _, s in ranked]
    assert times == sorted(times)
    assert 1 in [k for k, _ in ranked]


# -------------------------------------------------------------------------
# pallas_tile compile-time validation (satellite)
# -------------------------------------------------------------------------


def test_pallas_tile_good_compiles():
    prog = _jacobi_prog((32, 32), name="tile_ok")
    step = api.compile(prog, Target(backend="pallas", pallas_tile=(16, 32)))
    u0 = np.random.default_rng(0).standard_normal((32, 32)).astype(np.float32)
    out = step(u0, np.zeros_like(u0))
    assert np.isfinite(np.asarray(out[0])).all()


def test_pallas_tile_wrong_rank_rejected():
    prog = _jacobi_prog((32, 32), name="tile_rank")
    with pytest.raises(TargetError, match=r"pallas_tile .* rank-2"):
        api.compile(prog, Target(backend="pallas", pallas_tile=(16,)))


def test_pallas_tile_nondividing_rejected_with_names():
    prog = _jacobi_prog((32, 32), name="tile_bad")
    with pytest.raises(TargetError) as e:
        api.compile(prog, Target(backend="pallas", pallas_tile=(7, 32)))
    msg = str(e.value)
    assert "(7, 32)" in msg            # the tile
    assert "(32, 32)" in msg           # the local shard shape
    assert "undecomposed" in msg       # the (non-)mesh axis
    assert "tile_bad" in msg


def test_pallas_tile_nonpositive_rejected():
    prog = _jacobi_prog((32, 32), name="tile_zero")
    with pytest.raises(TargetError, match="positive"):
        api.compile(prog, Target(backend="pallas", pallas_tile=(0, 32)))


def test_pallas_tile_auto_retiled_paths_accepted():
    # overlap and temporal-tile split applies re-tile automatically: a
    # shard-nondividing tile must stay accepted there (lowering falls
    # back), while the rank check still applies
    prog = _jacobi_prog((32, 32), name="tile_auto")
    t = Target(backend="pallas", pallas_tile=(7, 32), overlap=True)
    api._validate_for_program(prog, t)  # no raise
    t2 = Target(backend="pallas", pallas_tile=(7, 32), exchange_every=2)
    api._validate_for_program(prog, t2)  # no raise
    with pytest.raises(TargetError, match="rank-2"):
        api._validate_for_program(
            prog, Target(backend="pallas", pallas_tile=(7,), overlap=True)
        )


def test_jnp_backend_ignores_tile_shape():
    # pallas_tile is a pallas knob; the jnp backend never reads it and
    # validation must not reject it there
    prog = _jacobi_prog((32, 32), name="tile_jnp")
    api._validate_for_program(
        prog, Target(backend="jnp", pallas_tile=(7, 5))
    )


# -------------------------------------------------------------------------
# ISSUE 9 — slot-pool width enumeration (ensemble axis)
# -------------------------------------------------------------------------


def test_slot_width_candidates_divide_capacity_and_fit_inventory():
    from repro.tune.space import slot_width_candidates

    assert slot_width_candidates(8, 2, 4) == [4, 2, 1]
    assert slot_width_candidates(8, 4, 6) == [2, 1]  # 6 devices short of 3×4
    assert slot_width_candidates(8, 2, 6) == [3, 2, 1]  # 4 ∤ 6 dropped
    assert slot_width_candidates(1, 1, 4) == [1]  # single device still pools
    for s in slot_width_candidates(16, 2, 12):
        assert 12 % s == 0 and s * 2 <= 16


def test_enumerate_pool_candidates_single_device():
    """On a 1-device inventory the pool space degenerates to the
    pure-ensemble slot-axis candidate (trivial spatial grid at width 1)
    — still a valid, compilable slot-axis Target."""
    from repro.tune.space import enumerate_pool_candidates

    prog = _jacobi_prog(name="tune_pool_1dev")
    cands = enumerate_pool_candidates(prog, capacity=4)
    assert cands, "always at least the width-1 pool"
    for c in cands:
        assert c.origin == "pool"
        assert c.target.slot_axis == "slot"
        assert "slot" in c.target.mesh.axis_names
        assert c.note.startswith("slots=")
    # fingerprints are unique and differ from the solo target's
    fps = [c.fingerprint for c in cands]
    assert len(fps) == len(set(fps))
    assert Target().fingerprint not in fps
