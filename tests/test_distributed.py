"""Distribution correctness: N-rank shard_map + dmp halo exchange ==
single-device, bitwise for fp32 stencils.

Each scenario runs in a subprocess with
``--xla_force_host_platform_device_count=8`` so the virtual-device flag
never leaks into this pytest process (unit tests see 1 device).
"""
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "dist_worker.py")

SCENARIOS = [
    "1d-zero",
    "1d-periodic",
    "2d-zero",
    "2d-periodic",
    "3d",
    "box",
    "box-diagonal",
    "overlap",
    "overlap-zero",
    "overlap-periodic",
    "overlap-box-seq",
    "overlap-diagonal",
    "overlap-pallas",
    "pipeline-spec",
    "pallas",
    "wide-halo",
    "time-loop",
    "ee2-periodic",
    "ee4-zero",
    "ee4-overlap",
    "ee4-overlap-zero",
    "ee2-box-overlap",
    "ee4-pallas",
    "ee-heat-epoch",
    "tune-4rank",
    "pallas-tile-shard-error",
    "resilience-heat-k1",
    "resilience-heat-k4",
    "resilience-wave-k4",
    "tune-transfer",
    "slot-axis",
    "serve-pooled",
    "serve-autoscale",
]


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_distributed_equivalence(scenario):
    proc = subprocess.run(
        [sys.executable, WORKER, scenario],
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, (
        f"scenario {scenario} failed:\nSTDOUT:\n{proc.stdout}\n"
        f"STDERR:\n{proc.stderr[-3000:]}"
    )
