"""Per-architecture smoke tests: reduced same-family configs, one
forward + one train step on CPU — output shapes and finiteness.

Plus prefill↔decode consistency (the cache path equals the full-sequence
path) for representative families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_shape  # noqa: F401
from repro.configs.base import ModelConfig, reduced_config
from repro.configs.registry import ARCHS
from repro.models import lm
from repro.train import optimizer as opt_mod
from repro.train.train_step import (
    TrainOptions,
    init_train_state,
    make_train_step,
)

B, S = 2, 16


def _batch(cfg: ModelConfig, seed=0):
    rng = np.random.default_rng(seed)
    n_text = S - (cfg.num_modality_tokens if cfg.modality == "vision" else 0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, n_text)), jnp.int32
        )
    }
    if cfg.modality == "vision":
        batch["modality"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_modality_tokens, cfg.modality_dim)),
            jnp.float32,
        )
    elif cfg.modality == "audio":
        batch["modality"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.modality_dim)), jnp.float32
        )
    return batch


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    arch = request.param
    cfg = reduced_config(get_config(arch))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return arch, cfg, params


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, params = arch_setup
    batch = _batch(cfg)
    logits, aux = lm.forward_train(
        params, cfg, batch["tokens"], batch.get("modality"), q_chunk=8
    )
    n_text = batch["tokens"].shape[1]
    S_total = n_text + (cfg.num_modality_tokens if cfg.modality == "vision" else 0)
    assert logits.shape[0] == B and logits.shape[1] == S_total
    assert logits.shape[2] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


def test_train_step_runs_and_is_finite(arch_setup):
    arch, cfg, params = arch_setup
    state = init_train_state(jax.random.PRNGKey(1), cfg)
    step = make_train_step(
        cfg, opt_mod.OptimizerConfig(), TrainOptions(q_chunk=8)
    )
    state2, metrics = jax.jit(step)(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: loss not finite"
    assert int(state2["step"]) == 1
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), state["params"], state2["params"]
    )
    assert max(jax.tree.leaves(moved)) > 0.0


def test_loss_decreases_over_steps():
    """Few steps on a fixed batch: loss must trend down (overfit sanity)."""
    cfg = reduced_config(get_config("qwen2-7b"))
    state = init_train_state(jax.random.PRNGKey(2), cfg)
    step = jax.jit(
        make_train_step(cfg, opt_mod.OptimizerConfig(peak_lr=1e-2),
                        TrainOptions(q_chunk=8))
    )
    batch = _batch(cfg, seed=3)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize(
    "arch", ["qwen2-7b", "gemma2-27b", "jamba-v0.1-52b", "xlstm-1.3b",
             "olmoe-1b-7b", "seamless-m4t-large-v2"]
)
def test_prefill_decode_consistency(arch):
    """decode_step(prefill(tokens[:-1]), tokens[-1]) logits ≈ the
    full-sequence forward's next-token logits — cache path correctness."""
    import dataclasses

    # fp32 so the cache path can be compared tightly (bf16 near-ties
    # flip the top token with random-init params)
    cfg = dataclasses.replace(reduced_config(get_config(arch)), dtype="float32")
    if cfg.moe is not None:
        # make routing capacity lossless for the equivalence check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    params = lm.init_params(jax.random.PRNGKey(4), cfg)
    batch = _batch(cfg, seed=5)
    tokens = batch["tokens"]
    modality = batch.get("modality")

    # full forward over S tokens → logits at position S-1 predicts token S
    logits_full, _ = lm.forward_train(params, cfg, tokens, modality, q_chunk=8)
    want = logits_full[:, -1]

    # prefill on S-1 tokens, grow the ring capacity, then decode token S-1
    logits_pre, cache = lm.forward_prefill(
        params, cfg, tokens[:, :-1], modality, q_chunk=8
    )
    S_pre = tokens.shape[1] - 1
    cache = lm.grow_cache(cfg, cache, S_pre + 1, S_pre)
    got, _ = lm.decode_step(
        params, cfg, tokens[:, -1], jnp.int32(tokens.shape[1] - 1), cache
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-3, atol=2e-3,
    )
    # and the ranking of the top token agrees
    assert int(jnp.argmax(got[0])) == int(jnp.argmax(want[0]))


def test_param_count_orders_of_magnitude():
    """Full configs land near their nameplate sizes."""
    expect = {
        "yi-9b": (7e9, 11e9),
        "qwen2-7b": (6e9, 9e9),
        "gemma2-27b": (21e9, 30e9),
        # our FFN is uniformly SwiGLU (3 mats); starcoder2's nameplate
        # assumes a 2-mat GELU MLP, so our instantiation lands ~10B
        "starcoder2-7b": (6e9, 11e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "olmoe-1b-7b": (5e9, 9e9),
        # our mLSTM block carries 4 full-width projections at 2× expansion
        # (simplified vs the paper's factored q/k heads) → ~2.1B
        "xlstm-1.3b": (0.9e9, 2.3e9),
        "internvl2-2b": (1.5e9, 3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


def test_moe_active_params_below_total():
    for arch in ("olmoe-1b-7b", "granite-moe-1b-a400m", "jamba-v0.1-52b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < cfg.param_count()


def test_gemma2_alternates_local_global():
    cfg = get_config("gemma2-27b")
    kinds = {cfg.layer_kind(i) for i in range(4)}
    assert kinds == {"attn", "attn_local"}
    assert cfg.local_window > 0


def test_jamba_attention_ratio():
    """jamba: 1 attention : 7 mamba per supercell of 8."""
    cfg = get_config("jamba-v0.1-52b")
    cell = cfg.block_pattern
    assert len(cell) == 8
    assert sum(1 for k in cell if k == "attn") == 1
    assert sum(1 for k in cell if k == "mamba") == 7


def test_xlstm_mixes_block_kinds():
    cfg = get_config("xlstm-1.3b")
    assert "slstm" in cfg.block_pattern and "mlstm" in cfg.block_pattern
    assert cfg.d_ff == 0  # pre-up-projection blocks, no transformer FFN
